"""The public op surface: one call, many formats, one policy object.

Every op resolves the active ExecutionPolicy (innermost `repro.api.policy`
context, overridden by any per-call keywords), maps it to a registry
implementation key, and dispatches. Resolution happens eagerly in Python —
the chosen implementation sees a concrete, hashable policy it can treat as a
static jit argument, so at THIS layer backend/format changes always retrace
instead of reusing a stale compiled path. The policy is reduced to the
fields each op actually consumes before it becomes a jit key, so unrelated
overrides (e.g. attention's `chunk`) never recompile matmuls.

Caveat (inherited from any Python-level config, including the old
`use_pallas` flag): a CALLER-level `jax.jit` around code that calls these
ops bakes the ambient policy in at its own trace time — the caller's cache
key cannot see the thread-local. Pin one policy per traced program (as
ServingEngine does via its `policy=` argument) or pass `policy=` explicitly
so it participates in your own static args.

    from repro import api

    y = api.ops.matmul(x, w)                          # default policy
    with api.policy(format="int8", backend="ref"):
        y = api.ops.matmul(x, w)                      # int8 reference path
        a = api.ops.attention(q, k, v)                # same policy object
    y = api.ops.matmul(x, w, format="int4")           # per-call override
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .policy import ExecutionPolicy, current_policy
from .registry import registry

__all__ = ["matmul", "matmul_codes", "attention", "attention_route",
           "depthwise_conv", "grouped_matmul", "quantize",
           "morphable_multi_gemm", "backend_from_prefer_pallas"]


def backend_from_prefer_pallas(prefer_pallas: Optional[bool]) -> Optional[str]:
    """Map the legacy tri-state kwarg onto a backend override (None = defer)."""
    if prefer_pallas is None:
        return None
    return "pallas" if prefer_pallas else "ref"


def _resolve(policy: Optional[ExecutionPolicy], **overrides) -> ExecutionPolicy:
    base = policy if policy is not None else current_policy()
    return base.override(**overrides)


# Fields each op's implementations actually consume. Dispatch reduces the
# resolved policy to these before calling the impl, so two policies that
# differ only in fields an op never reads share one jit cache entry.
_OP_FIELDS = {
    "matmul": ("format", "bm", "bn", "bk", "out_dtype", "interpret"),
    # the format plane comes from the QuantWeight itself, not the policy
    "matmul_codes": ("bm", "bn", "bk", "out_dtype", "interpret"),
    "quantize": ("format", "bm", "bn", "interpret"),
    "depthwise_conv": ("bh", "bc", "interpret"),
    "grouped_matmul": ("bm", "bn", "bk", "out_dtype", "interpret"),
    "attention": ("chunk", "bkv", "bq", "interpret"),
}


def _canonical(pol: ExecutionPolicy, op_name: str) -> ExecutionPolicy:
    fields = _OP_FIELDS.get(op_name)
    if fields is None:
        return pol
    return ExecutionPolicy(**{f: getattr(pol, f) for f in fields})


def _interpret_ctx(pol: ExecutionPolicy):
    if pol.interpret is None:
        return contextlib.nullcontext()
    from ..kernels import common            # deferred: kernels import the api
    return common.interpret_override(pol.interpret)


def _dispatch(op_name: str, impl: str, pol: ExecutionPolicy, *args, **kwargs):
    fn = registry.lookup(op_name, impl)
    with _interpret_ctx(pol):
        return fn(*args, policy=_canonical(pol, op_name), **kwargs)


# =============================================================================
# Ops
# =============================================================================

def matmul(x: jax.Array, w: jax.Array, *, format: Optional[str] = None,
           backend: Optional[str] = None, out_dtype: Any = None,
           bm: Optional[int] = None, bn: Optional[int] = None,
           bk: Optional[int] = None, interpret: Optional[bool] = None,
           policy: Optional[ExecutionPolicy] = None) -> jax.Array:
    """Quantize (M,K) x (K,N) operands to the policy format and multiply."""
    pol = _resolve(policy, format=format, backend=backend, out_dtype=out_dtype,
                   bm=bm, bn=bn, bk=bk, interpret=interpret)
    return _dispatch("matmul", pol.impl(), pol, x, w)


def matmul_codes(x: jax.Array, wq, *, backend: Optional[str] = None,
                 out_dtype: Any = None, bm: Optional[int] = None,
                 bn: Optional[int] = None, bk: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 policy: Optional[ExecutionPolicy] = None) -> jax.Array:
    """Matmul against a RESIDENT quantized weight (`formats.QuantWeight`).

    x: (..., K) activations; wq: pre-packed weight codes + per-output-channel
    pow2 scales, quantized ONCE by `transformer.quantize_params`. The
    quantize-operands stage of `matmul` is skipped for the weight side — the
    pallas impl unpacks int4 / decodes fp8 tiles in VMEM and folds the scales
    into the tile write; the ref impl dequantizes at dispatch (byte-identical
    to the per-channel fake-quant dense path). The weight's format rides in
    `wq.fmt`, so the policy's `format` field is ignored here.
    """
    if x.shape[-1] != wq.k:
        raise ValueError(f"activation K {x.shape[-1]} != resident weight K "
                         f"{wq.k} (format {wq.fmt!r})")
    pol = _resolve(policy, backend=backend, out_dtype=out_dtype,
                   bm=bm, bn=bn, bk=bk, interpret=interpret)
    return _dispatch("matmul_codes", pol.impl(), pol, x, wq)


# Longest query the flash-decode kernel takes on the legacy scalar-offset
# cache-shaped route; vector-offset multi-token chunks go to the varlen
# prefill kernel instead (see attention_route).
DECODE_MAX_LQ = 8


def attention_route(*, lq: int, lk: Optional[int] = None, causal: bool = True,
                    offset_ndim: int = 0, quantized: bool = False,
                    backend: Optional[str] = None,
                    policy: Optional[ExecutionPolicy] = None) -> str:
    """Which attention impl a call with this shape dispatches to.

    This IS the dispatch rule `attention` uses (not a parallel re-statement):
    under a pallas backend, causal attention OVER A CACHE routes to the
    serving kernels — multi-token (Lq > 1) per-row-offset chunks (the
    engine's chunked admission prefill, dense or int8 KV) to
    "pallas-prefill", and single-token decode steps (plus legacy
    scalar-offset short queries) to "pallas-decode"; 128-aligned
    scalar-offset full-sequence prefill routes to the "pallas" flash kernel;
    everything else (and every shape under backend="ref"/"auto"-off) falls
    back to "ref". Cache-shaped means lk > lq or a per-row offset vector
    (which only caches produce): the serving kernels are forward-only (no
    VJP), and plain short self-attention (lk == lq, scalar offset — e.g. a
    tiny training forward) must stay on the differentiable ref path. Exposed
    so serving benchmarks/engines can report the path their decode steps and
    prefill chunks take.
    """
    pol = _resolve(policy, backend=backend)
    if pol.use_pallas():
        cache_shaped = offset_ndim == 1 or (lk is not None and lk > lq)
        if causal and cache_shaped:
            if offset_ndim == 1 and lq > 1:
                return "pallas-prefill"
            if lq <= DECODE_MAX_LQ:
                return "pallas-decode"
        if not quantized and lq % 128 == 0 and offset_ndim == 0:
            return "pallas"
    return "ref"


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: Optional[int] = None, softcap: Optional[float] = None,
              scale: Optional[float] = None, offset=0,
              lengths: Optional[jax.Array] = None,
              k_scale: Optional[jax.Array] = None,
              v_scale: Optional[jax.Array] = None,
              chunk: Optional[int] = None, bkv: Optional[int] = None,
              bq: Optional[int] = None, backend: Optional[str] = None,
              interpret: Optional[bool] = None,
              block_tables: Optional[jax.Array] = None,
              policy: Optional[ExecutionPolicy] = None) -> jax.Array:
    """GQA attention. q: (B,Hq,Lq,D); k,v: (B,Hkv,Lk,D).

    offset: scalar or per-row (B,) cache position (continuous batching:
    every row sits at its own position). lengths: per-row (B,) VALID query
    count of a right-padded multi-token chunk (None = all valid) — the
    varlen prefill kernel prunes q-blocks and KV-blocks with it so work
    scales with real prompt tokens; the other impls ignore it (outputs at
    invalid positions are never consumed). k_scale/v_scale: when given, k/v
    are int8 codes with per-position pow2 scales (QuantKVCache layout) —
    dequantized inside the decode/prefill kernels' VMEM on the pallas
    routes, or up front on the others. block_tables: when given, k/v (and
    scales) are (P, Hkv, bs, .) BLOCK POOLS and block_tables is the
    (B, nblk) int32 per-row map — the serving kernels indirect through it
    via scalar prefetch, the ref path gathers `pool[table]`. See
    `attention_route` for which shapes hit "pallas" (full-sequence flash),
    "pallas-prefill" (varlen chunk prefill), "pallas-decode"
    (flash-decode), or "ref".
    """
    pol = _resolve(policy, backend=backend, chunk=chunk, bkv=bkv, bq=bq,
                   interpret=interpret)
    lk = k.shape[2] if block_tables is None \
        else block_tables.shape[1] * k.shape[2]
    impl = attention_route(lq=q.shape[2], lk=lk, causal=causal,
                           offset_ndim=jnp.ndim(offset),
                           quantized=k_scale is not None, policy=pol)
    if block_tables is not None and impl == "pallas":
        impl = "ref"    # no paged route on the full-sequence kernel
    return _dispatch("attention", impl, pol, q, k, v, causal=causal,
                     window=window, softcap=softcap, scale=scale,
                     offset=offset, lengths=lengths, k_scale=k_scale,
                     v_scale=v_scale, block_tables=block_tables)


def depthwise_conv(x: jax.Array, filt: jax.Array, *, bh: Optional[int] = None,
                   bc: Optional[int] = None, backend: Optional[str] = None,
                   interpret: Optional[bool] = None,
                   policy: Optional[ExecutionPolicy] = None) -> jax.Array:
    """x: (N, H, W, C); filt: (kh, kw, C); stride-1 SAME depthwise conv."""
    pol = _resolve(policy, bh=bh, bc=bc, backend=backend, interpret=interpret)
    return _dispatch("depthwise_conv", pol.impl(), pol, x, filt)


def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes: Sequence[int], *,
                   bm: Optional[int] = None, bn: Optional[int] = None,
                   bk: Optional[int] = None, out_dtype: Any = None,
                   backend: Optional[str] = None,
                   interpret: Optional[bool] = None,
                   policy: Optional[ExecutionPolicy] = None) -> jax.Array:
    """x (T,K) rows sorted by group; w (G,K,N); group_sizes sums to T."""
    pol = _resolve(policy, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                   backend=backend, interpret=interpret)
    return _dispatch("grouped_matmul", pol.impl(), pol, x, w,
                     tuple(group_sizes))


def quantize(x: jax.Array, *, format: Optional[str] = None,
             bm: Optional[int] = None, bn: Optional[int] = None,
             backend: Optional[str] = None, interpret: Optional[bool] = None,
             policy: Optional[ExecutionPolicy] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """x (M, N) -> (codes int8, per-row pow2 scale (M, 1))."""
    pol = _resolve(policy, format=format, bm=bm, bn=bn, backend=backend,
                   interpret=interpret)
    return _dispatch("quantize", pol.impl(), pol, x)


def morphable_multi_gemm(tenants, *, bm: Optional[int] = None,
                         bn: Optional[int] = None, bk: Optional[int] = None,
                         out_dtype: Any = None, backend: Optional[str] = None,
                         interpret: Optional[bool] = None,
                         policy: Optional[ExecutionPolicy] = None):
    """Run N unrelated tenant GEMMs in one grouped launch; returns
    (results, mac_utilization) — the software Fig 8/Fig 14 scenario."""
    pol = _resolve(policy, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                   backend=backend, interpret=interpret)
    from ..kernels.grouped_matmul.ops import multi_gemm_with_policy
    return multi_gemm_with_policy(tenants, pol)
