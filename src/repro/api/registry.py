"""KernelRegistry: one dispatch table for every op implementation.

Implementations register under ``(op_name, impl)`` with ``impl`` one of
{"pallas", "pallas-prefill", "pallas-decode", "ref"}; `repro.api.ops`
resolves the active
ExecutionPolicy to an impl key per call and dispatches here. Kernel packages self-register at
import time — `_ensure_kernels()` imports them lazily on first lookup so the
api package never needs kernels loaded just to construct a policy.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List, Tuple

__all__ = ["KernelRegistry", "registry", "register"]

IMPLS = ("pallas", "pallas-prefill", "pallas-decode", "ref")

# Packages whose import populates the registry (order is cosmetic).
_KERNEL_PACKAGES = (
    "repro.kernels.aio_matmul",
    "repro.kernels.aio_quant",
    "repro.kernels.depthwise",
    "repro.kernels.flash_attention",
    "repro.kernels.grouped_matmul",
)


class KernelRegistry:
    def __init__(self):
        self._impls: Dict[Tuple[str, str], Callable] = {}
        self._loaded = False

    # ------------------------------------------------------------- register
    def register(self, op_name: str, impl: str) -> Callable:
        """Decorator: ``@register("matmul", "pallas")`` on an impl callable.

        Impl callables take the op's array arguments plus a keyword-only
        ``policy`` (a resolved ExecutionPolicy) and any op-specific kwargs.
        """
        if impl not in IMPLS:
            raise ValueError(f"impl {impl!r} not in {IMPLS}")

        def deco(fn: Callable) -> Callable:
            self._impls[(op_name, impl)] = fn
            return fn
        return deco

    # -------------------------------------------------------------- lookup
    def _ensure_kernels(self):
        if self._loaded:
            return
        for pkg in _KERNEL_PACKAGES:
            importlib.import_module(pkg)
        self._loaded = True          # only after every import succeeded

    def lookup(self, op_name: str, impl: str) -> Callable:
        self._ensure_kernels()
        try:
            return self._impls[(op_name, impl)]
        except KeyError:
            avail = ", ".join(f"{o}/{i}" for o, i in sorted(self._impls))
            raise KeyError(f"no implementation registered for "
                           f"({op_name!r}, {impl!r}); available: {avail}"
                           ) from None

    def dispatch(self, op_name: str, impl: str, *args, **kwargs):
        return self.lookup(op_name, impl)(*args, **kwargs)

    # ---------------------------------------------------------- introspection
    def ops(self) -> List[str]:
        self._ensure_kernels()
        return sorted({op for op, _ in self._impls})

    def implementations(self, op_name: str) -> List[str]:
        self._ensure_kernels()
        return sorted(i for o, i in self._impls if o == op_name)


registry = KernelRegistry()
register = registry.register
