"""KernelRegistry: one dispatch table for every op implementation.

Implementations register under ``(op_name, impl)`` with ``impl`` one of
{"pallas", "pallas-prefill", "pallas-decode", "ref"}; `repro.api.ops`
resolves the active
ExecutionPolicy to an impl key per call and dispatches here. Kernel packages self-register at
import time — `_ensure_kernels()` imports them lazily on first lookup so the
api package never needs kernels loaded just to construct a policy.

Pallas impls additionally declare a LAUNCH CONTRACT: a pure-Python
description of the grid, BlockSpec geometry and index maps a call would
launch with, built for a concrete (case, policy) WITHOUT tracing or running
the kernel. `repro.analysis` sweeps these contracts out-of-trace and lints
them for out-of-bounds block indices, non-dividing tails, scalar-prefetch
arity mismatches and VMEM overcommit — the invariants the hand-written
index maps must hold (the PR 5 pad-tail overrun class of bug, caught
statically instead of by a byte-identity test).
"""
from __future__ import annotations

import contextlib
import dataclasses
import importlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["KernelRegistry", "registry", "register", "register_contract",
           "BlockContract", "LaunchContract", "DEFAULT_VMEM_BUDGET",
           "set_dispatch_hook", "dispatch_intercepted"]

IMPLS = ("pallas", "pallas-prefill", "pallas-decode", "ref")

# Per-launch VMEM budget the contract checker enforces (conservative TPU
# per-core VMEM; a launch whose resident blocks + scratch exceed this cannot
# pipeline and will fail to lower on hardware).
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024

# Packages whose import populates the registry (order is cosmetic).
_KERNEL_PACKAGES = (
    "repro.kernels.aio_matmul",
    "repro.kernels.aio_quant",
    "repro.kernels.depthwise",
    "repro.kernels.flash_attention",
    "repro.kernels.grouped_matmul",
)


# ---------------------------------------------------------------------------
# Dispatch interception — the fault-injection seam.
# ---------------------------------------------------------------------------
# A single module-level hook consulted on every registry lookup (the op
# dispatch boundary every `api.ops.*` call crosses at trace time). Production
# pays one `is not None` check; the fault harness (`repro.serving.faults`)
# installs a hook that raises a simulated kernel-launch failure at precise
# coordinates, which is how tests prove the engine's pallas->ref demotion
# without a real lowering error. The hook runs BEFORE the impl executes and
# may raise; returning normally lets the dispatch proceed untouched.

_dispatch_hook: Optional[Callable[[str, str], None]] = None


def set_dispatch_hook(hook: Optional[Callable[[str, str], None]]):
    """Install (or clear, with None) the dispatch interception hook.

    ``hook(op_name, impl)`` is called on every registry lookup. Returns the
    previously installed hook so callers can restore it.
    """
    global _dispatch_hook
    prev = _dispatch_hook
    _dispatch_hook = hook
    return prev


@contextlib.contextmanager
def dispatch_intercepted(hook: Callable[[str, str], None]):
    """Scope a dispatch hook to a with-block, restoring the previous one."""
    prev = set_dispatch_hook(hook)
    try:
        yield hook
    finally:
        set_dispatch_hook(prev)


# ---------------------------------------------------------------------------
# Launch contracts — the static mirror of a pallas_call's geometry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockContract:
    """One operand/output of a pallas_call, as the checker sees it.

    index_map takes the grid indices followed by the scalar-prefetch
    operands (the same signature the real BlockSpec index map has) and
    returns the BLOCK indices — evaluated here with plain ints/arrays,
    outside any trace.

    masked_tail=True declares that the kernel body masks reads/writes past
    the array's true extent, so a block shape that does not divide the
    array dimension is legal for this operand.

    is_output marks the pallas_call outputs (blocks list inputs first, then
    outputs, in operand order). For an output, ``revisits`` names the grid
    dimensions along which two grid points may legally map to the SAME
    output block — the reduction/accumulation dims (the matmul K loop, the
    attention KV loop) whose kernel body carries a scratch accumulator and
    writes the block once. The `repro.analysis` race detector (KB410)
    errors on any same-block revisit along an UNdeclared dim: two grid
    points racing on one output tile.

    quant names the AIO format whose codes this operand carries (e.g.
    "int8", "int4", "fp8a") and scale_for names the codes block a scale
    operand dequantizes — the declarations the KB42x quantized-dataflow
    audit traces through the kernel-body jaxpr.
    """
    name: str
    array_shape: Tuple[int, ...]
    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    dtype_bytes: int = 4
    masked_tail: bool = False
    is_output: bool = False
    revisits: Tuple[int, ...] = ()
    quant: Optional[str] = None
    scale_for: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class LaunchContract:
    """The full launch geometry of one pallas_call for one concrete case.

    body, when declared, is a ZERO-ARG callable that assembles and calls
    the real kernel launch on dummy operands of this contract's array
    shapes (jnp.zeros — nothing is executed; `repro.analysis` traces it
    with jax.make_jaxpr, extracts the pallas_call's kernel jaxpr, and runs
    the KB4xx abstract interpretation over the body). A pallas impl whose
    contracts carry no body is a KB430 coverage warning.
    """
    grid: Tuple[int, ...]
    blocks: Tuple[BlockContract, ...]          # inputs then outputs
    num_scalar_prefetch: int = 0
    scalars: Tuple[Any, ...] = ()              # concrete prefetch operands
    scratch_bytes: int = 0
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    body: Optional[Callable[[], Any]] = None


class KernelRegistry:
    def __init__(self):
        self._impls: Dict[Tuple[str, str], Callable] = {}
        self._contracts: Dict[Tuple[str, str], Callable] = {}
        self._loaded = False

    # ------------------------------------------------------------- register
    def register(self, op_name: str, impl: str) -> Callable:
        """Decorator: ``@register("matmul", "pallas")`` on an impl callable.

        Impl callables take the op's array arguments plus a keyword-only
        ``policy`` (a resolved ExecutionPolicy) and any op-specific kwargs.
        """
        if impl not in IMPLS:
            raise ValueError(f"impl {impl!r} not in {IMPLS}")

        def deco(fn: Callable) -> Callable:
            self._impls[(op_name, impl)] = fn
            return fn
        return deco

    def register_contract(self, op_name: str, impl: str, *,
                          cases: Sequence[dict] = (),
                          sweep_fields: Sequence[str] = ()) -> Callable:
        """Decorator: declare the launch contract of a pallas impl.

        The decorated callable maps ``(case: dict, policy: ExecutionPolicy)``
        to a LaunchContract mirroring exactly the pallas_call the impl would
        assemble for that case. ``cases`` is the impl's representative shape
        sweep; ``sweep_fields`` names the ExecutionPolicy tile fields the
        impl consumes (the checker crosses cases with a sweep over them).
        """
        if impl not in IMPLS:
            raise ValueError(f"impl {impl!r} not in {IMPLS}")

        def deco(fn: Callable) -> Callable:
            fn.cases = tuple(cases)
            fn.sweep_fields = tuple(sweep_fields)
            self._contracts[(op_name, impl)] = fn
            return fn
        return deco

    # -------------------------------------------------------------- lookup
    def _ensure_kernels(self):
        if self._loaded:
            return
        for pkg in _KERNEL_PACKAGES:
            importlib.import_module(pkg)
        self._loaded = True          # only after every import succeeded

    def lookup(self, op_name: str, impl: str) -> Callable:
        self._ensure_kernels()
        if _dispatch_hook is not None:
            _dispatch_hook(op_name, impl)
        try:
            return self._impls[(op_name, impl)]
        except KeyError:
            impls = self.implementations(op_name)
            if not impls:
                raise KeyError(
                    f"unknown op {op_name!r}; registered ops: "
                    f"{', '.join(self.ops())}") from None
            raise KeyError(
                f"op {op_name!r} has no {impl!r} implementation; registered "
                f"implementations: {', '.join(impls)}") from None

    def dispatch(self, op_name: str, impl: str, *args, **kwargs):
        return self.lookup(op_name, impl)(*args, **kwargs)

    # ---------------------------------------------------------- introspection
    def ops(self) -> List[str]:
        self._ensure_kernels()
        return sorted({op for op, _ in self._impls})

    def implementations(self, op_name: str) -> List[str]:
        self._ensure_kernels()
        return sorted(i for o, i in self._impls if o == op_name)

    def contract(self, op_name: str, impl: str) -> Optional[Callable]:
        self._ensure_kernels()
        return self._contracts.get((op_name, impl))

    def contracts(self) -> Dict[Tuple[str, str], Callable]:
        """Every declared launch contract, keyed by (op, impl)."""
        self._ensure_kernels()
        return dict(self._contracts)

    def pallas_impls(self) -> List[Tuple[str, str]]:
        """Every registered non-ref implementation key."""
        self._ensure_kernels()
        return sorted(k for k in self._impls if k[1] != "ref")


registry = KernelRegistry()
register = registry.register
register_contract = registry.register_contract
