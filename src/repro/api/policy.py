"""ExecutionPolicy: the one object that says how every op runs.

The paper's premise is a single substrate serving many data formats and
operation shapes; the software mirror is a single policy object carrying the
format plane (AIO format name), the backend plane (pallas kernels vs the
pure-jnp reference path), and the tiling geometry — declared once and obeyed
by every op dispatched through `repro.api.ops`.

Policies are frozen (hashable) so a resolved policy can ride through
`jax.jit(..., static_argnames=("policy",))` and participate in trace caching
correctly — the footgun the old hidden thread-local flag had when read at
trace time.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Iterator, Optional

import jax.numpy as jnp

__all__ = ["ExecutionPolicy", "policy", "current_policy", "default_policy",
           "policy_sweep", "TILE_FIELDS"]

_BACKENDS = ("auto", "pallas", "ref")
# Formats the matmul plane's kernels implement (core.formats.REGISTRY names).
_FORMATS = ("bf16", "fp8a", "fp8b", "int8", "int4", "fp16", "uint8", "uint4")


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How ops dispatched through repro.api execute.

    format:    AIO number format for the quantized-matmul/quantize plane.
    backend:   "pallas" forces the Pallas kernels, "ref" the pure-jnp oracle,
               "auto" defers to the legacy `kernels.common.use_pallas` flag
               (False by default — the XLA path that lowers on any backend).
    bm/bn/bk:  MXU tile sizes for matmul-family kernels.
    bh/bc:     height/channel tiles for the depthwise kernel.
    bkv:       KV-block length of the flash decode/prefill attention kernels.
    bq:        q-block length of the varlen flash-prefill kernel.
    chunk:     query-chunk length for the long-prefill attention path.
    out_dtype: accumulator/output dtype of matmul-family ops.
    interpret: force pallas interpret mode on (True) / off (False); None
               keeps the automatic rule (interpret everywhere but real TPU).
    """
    format: str = "bf16"
    backend: str = "auto"
    bm: int = 128
    bn: int = 128
    bk: int = 128
    bh: int = 8
    bc: int = 128
    bkv: int = 128
    bq: int = 32
    chunk: int = 1024
    out_dtype: Any = jnp.float32
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {_BACKENDS}")
        if self.format not in _FORMATS:
            raise ValueError(f"format {self.format!r} not in {_FORMATS}")

    # ------------------------------------------------------------ resolution
    def use_pallas(self) -> bool:
        """Resolve the backend plane to a concrete pallas-or-not choice."""
        if self.backend == "pallas":
            return True
        if self.backend == "ref":
            return False
        from ..kernels import common       # deferred: kernels import the api
        return common.pallas_enabled()

    def impl(self) -> str:
        """Registry implementation key this policy selects."""
        return "pallas" if self.use_pallas() else "ref"

    def override(self, **overrides) -> "ExecutionPolicy":
        """A copy with the non-None overrides applied (per-call kwargs)."""
        effective = {k: v for k, v in overrides.items() if v is not None}
        return dataclasses.replace(self, **effective) if effective else self

    def demoted(self) -> "ExecutionPolicy":
        """The safe-route copy of this policy: backend re-pinned to "ref"
        (the pure-jnp oracle every pallas kernel is byte-identical to), all
        other planes untouched. The serving engine installs this when a
        kernel launch raises — the software analogue of reconfiguring the
        morphable array back to its safe dataflow — so every subsequent
        traced step dispatches down the reference route while formats,
        tiling and out_dtype stay exactly what the engine pinned."""
        return dataclasses.replace(self, backend="ref")


default_policy = ExecutionPolicy()

# The tiling-geometry plane of the policy: the fields kernels consume as
# BlockSpec block lengths. `repro.analysis` sweeps launch contracts over
# these; REPRESENTATIVE_TILES are the per-field values the sweep uses
# (the default plus the smaller tiles the serving/test configs exercise).
TILE_FIELDS = ("bm", "bn", "bk", "bh", "bc", "bkv", "bq")
REPRESENTATIVE_TILES = {
    "bm": (128, 64), "bn": (128, 64), "bk": (128, 64),
    "bh": (8, 4), "bc": (128, 64),
    "bkv": (128, 16), "bq": (32, 8),
}


def policy_sweep(fields, base: Optional[ExecutionPolicy] = None,
                 values: Optional[dict] = None):
    """Representative ExecutionPolicy grid over the named tile fields.

    Returns the cartesian product of per-field candidate values (from
    ``values`` or REPRESENTATIVE_TILES) applied on top of ``base`` (the
    default policy when omitted). The analyzer uses this to evaluate every
    kernel launch contract across the tiling geometries production code can
    install; tests pin the semantics.
    """
    import itertools
    base = base if base is not None else default_policy
    table = values if values is not None else REPRESENTATIVE_TILES
    fields = tuple(fields)
    for f in fields:
        if f not in TILE_FIELDS:
            raise ValueError(f"{f!r} is not a tile field {TILE_FIELDS}")
    grids = [table[f] for f in fields]
    return tuple(base.override(**dict(zip(fields, combo)))
                 for combo in itertools.product(*grids))


_state = threading.local()


def _stack():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


def current_policy() -> ExecutionPolicy:
    """The innermost installed policy (the default one outside any context)."""
    stack = _stack()
    return stack[-1] if stack else default_policy


@contextlib.contextmanager
def policy(base: Optional[ExecutionPolicy] = None,
           **overrides) -> Iterator[ExecutionPolicy]:
    """Install an ExecutionPolicy for every op inside the block.

        with repro.api.policy(format="int4", backend="ref"):
            y = repro.api.ops.matmul(x, w)        # int4, reference path

    Nests: unspecified fields inherit from the innermost enclosing policy.
    Pass an ExecutionPolicy positionally to install it verbatim (plus any
    keyword overrides on top of it).
    """
    installed = (base if base is not None else current_policy()).override(
        **overrides)
    stack = _stack()
    stack.append(installed)
    try:
        yield installed
    finally:
        stack.pop()
