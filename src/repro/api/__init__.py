"""repro.api — the unified op-dispatch surface.

One import serves model code, serving, launchers, benchmarks, and examples:

    from repro import api

    with api.policy(format="int8", backend="pallas"):
        y = api.ops.matmul(x, w)
        o = api.ops.attention(q, k, v)

`ExecutionPolicy` declares format / backend / tiling once; `api.ops.*`
resolves it per call and dispatches through the `(op, impl)` KernelRegistry
that the five kernel packages register into. The per-kernel `mode=` /
`prefer_pallas=` / `bm/bn/bk` kwargs survive only as deprecated shims inside
`repro.kernels.*`.
"""
from . import ops  # noqa: F401
from .policy import (ExecutionPolicy, current_policy,  # noqa: F401
                     default_policy, policy, policy_sweep)
from .registry import (BlockContract, KernelRegistry,  # noqa: F401
                       LaunchContract, dispatch_intercepted, register,
                       register_contract, registry, set_dispatch_hook)

__all__ = ["ops", "ExecutionPolicy", "policy", "current_policy",
           "default_policy", "policy_sweep", "KernelRegistry", "register",
           "register_contract", "BlockContract", "LaunchContract", "registry",
           "set_dispatch_hook", "dispatch_intercepted"]
