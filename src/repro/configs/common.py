"""Shared shape-cell definitions and the architecture registry.

Each arch module exports CONFIG (full, paper-exact), SMOKE (reduced, same
family/features, CPU-runnable), and SHAPE_SUPPORT (which of the four assigned
input-shape cells apply, with the skip reason — the dry-run driver asserts
against this, so the grid is self-describing).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

__all__ = ["ShapeCell", "SHAPES", "ARCH_IDS", "get_arch", "get_config",
           "get_smoke", "shape_support"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = [
    "whisper_tiny", "zamba2_2p7b", "internvl2_76b", "kimi_k2", "olmoe_1b_7b",
    "xlstm_1p3b", "internlm2_20b", "gemma2_27b", "qwen2_1p5b", "olmo_1b",
    # the paper's own LLM benchmarks
    "gpt2_small", "llama2_7b",
]


def get_arch(arch_id: str):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str):
    return get_arch(arch_id).CONFIG


def get_smoke(arch_id: str):
    return get_arch(arch_id).SMOKE


def shape_support(arch_id: str) -> Dict[str, Optional[str]]:
    """shape name -> None (supported) or skip-reason string."""
    return get_arch(arch_id).SHAPE_SUPPORT


FULL_ATTN_SKIP = ("long_500k needs sub-quadratic sequence mixing; this arch "
                  "is (partially) full-attention — skipped per the brief "
                  "(DESIGN.md §4)")
