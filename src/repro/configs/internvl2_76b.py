"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — language backbone only; the InternViT frontend is a STUB
(input_specs provides precomputed, projected patch embeddings
(B, 1024, d_model) prepended to the token stream). [arXiv:2404.16821]"""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256,
    frontend="vision", frontend_len=1024, rope_theta=500000.0)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, frontend="vision", frontend_len=8,
    remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
