"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) d_ff=1024/expert
vocab=50304, 64 experts top-8. [arXiv:2409.02060; hf]"""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1024, vocab=50304, n_experts=64, top_k=8)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=64, vocab=512, n_experts=8, top_k=2, remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
