"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000. Local/global alternating attention (window 4096), logit
softcaps (attn 50, final 30), sandwich norms, GeGLU, head_dim=128.
[arXiv:2408.00118; hf]"""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense", n_layers=46, d_model=4608, n_heads=32,
    n_kv_heads=16, head_dim=128, d_ff=36864, vocab=256000,
    local_global=True, sliding_window=4096, softcap_attn=50.0,
    softcap_final=30.0, post_norm=True, mlp_kind="geglu",
    tie_embeddings=True, rope_theta=10000.0)

SMOKE = ModelConfig(
    name="gemma2-27b-smoke", family="dense", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
    local_global=True, sliding_window=16, softcap_attn=50.0,
    softcap_final=30.0, post_norm=True, mlp_kind="geglu",
    tie_embeddings=True, remat=False)

# half the layers are global full attention -> long_500k skipped
SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
