"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + ONE shared attention+MLP block
invoked every 6th layer (shared weights, per-invocation KV cache).
Sub-quadratic backbone -> runs long_500k. [arXiv:2411.15242; hf]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, ssm_state=64,
    ssm_expand=2, ssm_headdim=64, attn_every=6, subquadratic=True)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, ssm_state=16, ssm_expand=2,
    ssm_headdim=16, attn_every=3, subquadratic=True, remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": None}
