"""xlstm-1.3b [ssm]: 48L d_model=2048 4H vocab=50304 — sLSTM + mLSTM blocks
(7:1 ratio, every 8th layer sLSTM), d_ff=0 (blocks carry their own up/down
projections). Attention-free -> runs long_500k. [arXiv:2405.04517]

Deviation: our mLSTM uses DENSE q/k/v projections over d_inner; the published
1.3B config uses block-diagonal per-head projections, so this config lands at
~3.6B params. Structure/feature coverage is what the grid exercises; the
roofline records carry the actual N."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, slstm_every=8, subquadratic=True)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=512, slstm_every=2, subquadratic=True,
    remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": None}
