"""whisper-tiny [audio]: 4+4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
encoder-decoder; the conv frontend is a STUB (input_specs provides
precomputed frame embeddings (B, 1500, d)). LayerNorm + GELU MLP + learned
decoder positions (extended to 32k for the assigned decode shapes — the real
model's 448-token context is a deployment limit, not a structural one).
[arXiv:2212.04356]"""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384, n_heads=6,
    n_kv_heads=6, d_ff=1536, vocab=51865, norm="layernorm", mlp_kind="gelu",
    encoder_layers=4, cross_attention=True, frontend="audio",
    frontend_len=1500, learned_pos=True, max_seq=32_776)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, norm="layernorm", mlp_kind="gelu",
    encoder_layers=2, cross_attention=True, frontend="audio",
    frontend_len=16, learned_pos=True, max_seq=128, remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
