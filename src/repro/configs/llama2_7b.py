"""Llama-2 7B — one of the paper's own LLM benchmarks (Fig 14/15):
32L d_model=4096 32H d_ff=11008 vocab=32000."""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="llama2-7b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=11008, vocab=32000)

SMOKE = ModelConfig(
    name="llama2-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
