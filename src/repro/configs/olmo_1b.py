"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm (the arch's signature). [arXiv:2402.00838; hf]"""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=8192, vocab=50304, norm="nonparam_ln",
    mlp_kind="swiglu", tie_embeddings=True, rope_theta=10000.0)

SMOKE = ModelConfig(
    name="olmo-1b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, norm="nonparam_ln",
    mlp_kind="swiglu", tie_embeddings=True, remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
