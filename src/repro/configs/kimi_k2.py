"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert
vocab=163840, MoE 384 experts top-8 + 1 shared expert, first layer dense —
trillion-parameter MoE (paper-table). [arXiv:2501.kimi2]"""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840, n_experts=384,
    top_k=8, n_shared_experts=1, n_dense_layers=1, capacity_factor=1.25,
    rope_theta=50000.0)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=512, n_experts=8, top_k=2,
    n_shared_experts=1, n_dense_layers=1, remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
