"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936. QKV bias (the arch's signature). [arXiv:2407.10671; hf]"""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536, n_heads=12,
    n_kv_heads=2, d_ff=8960, vocab=151936, qkv_bias=True,
    tie_embeddings=True, rope_theta=1_000_000.0)

SMOKE = ModelConfig(
    name="qwen2-1.5b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, qkv_bias=True,
    tie_embeddings=True, remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
