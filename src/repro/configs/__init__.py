"""Architecture configs: the 10 assigned archs + the paper's own LLMs."""
from .common import (ARCH_IDS, SHAPES, ShapeCell, get_arch, get_config,  # noqa: F401
                     get_smoke, shape_support)
