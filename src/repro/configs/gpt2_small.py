"""GPT-2 small — one of the paper's own LLM benchmarks (Fig 14/15):
12L d_model=768 12H d_ff=3072 vocab=50257, learned positions, LayerNorm."""
from ..models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="gpt2-small", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab=50257, norm="layernorm",
    mlp_kind="gelu", learned_pos=True, max_seq=32_768, tie_embeddings=True)

SMOKE = ModelConfig(
    name="gpt2-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, norm="layernorm", mlp_kind="gelu",
    learned_pos=True, max_seq=128, tie_embeddings=True, remat=False)

SHAPE_SUPPORT = {"train_4k": None, "prefill_32k": None, "decode_32k": None,
                 "long_500k": FULL_ATTN_SKIP}
