"""All-rounder on TPU: multi-format + morphable-execution JAX framework.

Public surface: `repro.api` (ExecutionPolicy + KernelRegistry + api.ops.*).
"""
__version__ = "1.1.0"
