"""All-rounder on TPU: multi-format + morphable-execution JAX framework."""
__version__ = "1.0.0"
