from .scheduler import MeshPartition, MorphableScheduler, Tenant, fission_mesh  # noqa: F401
