"""Morphable multi-tenant scheduler — Fig 8 at mesh scale.

The paper fissions a 128x128 MAC array into blocks so several AI models run
at once; at pod scale the same morphing applies to the device mesh: a
(data, model) mesh is split into contiguous sub-meshes ("array blocks"),
tenants are assigned by load, and blocks re-fuse when a single tenant needs
the whole pod. `plan_for_tenants` (core/morphable.py) supplies the fusion
geometry; this module maps it onto jax devices and runs per-tenant programs.

Within one sub-mesh, co-resident *small* tenants additionally share kernel
launches through `kernels.grouped_matmul.morphable_multi_gemm` — the two
levels compose exactly like local vs global bridge logics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core.morphable import FusionPlan, plan_for_tenants
from ..dist.sharding import set_mesh

__all__ = ["Tenant", "MeshPartition", "fission_mesh", "MorphableScheduler"]


@dataclasses.dataclass(frozen=True)
class Tenant:
    name: str
    # characteristic GEMM of the tenant (stationary dims) for planning
    weight_rows: int
    weight_cols: int
    fmt: str = "bf16"
    # relative request rate (plan_for_tenants load-balances on it)
    load: float = 1.0


@dataclasses.dataclass(frozen=True)
class MeshPartition:
    tenants: Tuple[str, ...]
    mesh: Any               # jax Mesh over a contiguous device block


def fission_mesh(devices: np.ndarray, plan: FusionPlan,
                 axis_names=("data", "model")) -> List[Any]:
    """Split a 2D device grid into per-partition meshes following the plan's
    block rectangles (blocks laid out 2x2 like the paper's array blocks)."""
    rows, cols = devices.shape
    assert rows % 2 == 0 and cols % 2 == 0, "need a 2x2-divisible grid"
    hr, hc = rows // 2, cols // 2
    block_slices = {
        0: (slice(0, hr), slice(0, hc)),
        1: (slice(0, hr), slice(hc, cols)),
        2: (slice(hr, rows), slice(0, hc)),
        3: (slice(hr, rows), slice(hc, cols)),
    }
    def _unique_sorted(slices):
        # dedupe via (start, stop) keys — slice objects are unhashable < 3.12
        return sorted({(s.start, s.stop): s for s in slices}.values(),
                      key=lambda s: s.start)

    meshes = []
    for arr in plan.arrays:
        rs = _unique_sorted(block_slices[b][0] for b in arr.blocks)
        cs = _unique_sorted(block_slices[b][1] for b in arr.blocks)
        rows_sel = np.concatenate([devices[r, :] for r in rs], axis=0) \
            if len(rs) > 1 else devices[rs[0], :]
        sel = np.concatenate([rows_sel[:, c] for c in cs], axis=1) \
            if len(cs) > 1 else rows_sel[:, cs[0]]
        meshes.append(jax.sharding.Mesh(sel, axis_names))
    return meshes


class MorphableScheduler:
    """Assign tenants to mesh partitions and run their programs.

    reconfigure() is the global-bridge moment: it re-plans when the tenant
    set changes (tenant arrival/departure = the paper's multi-tenant
    scenario transitions between Fig 8 (e)-(h)).
    """

    def __init__(self, devices: Optional[np.ndarray] = None):
        if devices is None:
            n = len(jax.devices())
            side = int(np.sqrt(n))
            while n % side:
                side -= 1
            devices = np.array(jax.devices()).reshape(side, n // side)
        if devices.shape[0] % 2 or devices.shape[1] % 2:
            devices = devices[: devices.shape[0] - devices.shape[0] % 2 or None,
                              : devices.shape[1] - devices.shape[1] % 2 or None]
        self.devices = devices
        self.partitions: List[MeshPartition] = []
        self.plan: Optional[FusionPlan] = None
        self.engines: Dict[str, Any] = {}

    def reconfigure(self, tenants: Sequence[Tenant]) -> List[MeshPartition]:
        shapes = [(t.weight_rows, t.weight_cols) for t in tenants]
        fmt = tenants[0].fmt if tenants else "bf16"
        plan, assign = plan_for_tenants(shapes, fmt)
        self.plan = plan
        if self.devices.shape[0] < 2 or self.devices.shape[1] < 2:
            # degenerate host (e.g. 1 CPU device): everyone time-shares one
            # fused partition — the Fig 8-(h) configuration
            from ..core.morphable import FusedArray, FusionPlan
            self.plan = FusionPlan((FusedArray((0, 1, 2, 3), 128, 128),))
            mesh = jax.sharding.Mesh(self.devices, ("data", "model"))
            self.partitions = [MeshPartition(
                tuple(t.name for t in tenants), mesh)]
            return self.partitions
        meshes = fission_mesh(self.devices, plan)
        part_tenants: Dict[int, List[str]] = {}
        for t_idx, p_idx in assign.items():
            part_tenants.setdefault(p_idx, []).append(tenants[t_idx].name)
        self.partitions = [
            MeshPartition(tuple(part_tenants.get(i, ())), meshes[i])
            for i in range(plan.n_partitions)]
        return self.partitions

    def partition_of(self, tenant_name: str) -> MeshPartition:
        for p in self.partitions:
            if tenant_name in p.tenants:
                return p
        raise KeyError(tenant_name)

    def run(self, tenant_name: str, fn: Callable, *args, **kwargs):
        """Run `fn` jit-ted onto the tenant's sub-mesh devices."""
        part = self.partition_of(tenant_name)
        with set_mesh(part.mesh):
            return fn(*args, **kwargs)

    # ------------------------------------------------------- slot occupancy
    def attach_engine(self, tenant_name: str, engine: Any):
        """Register a tenant's serving engine so the scheduler can read its
        per-slot occupancy (the continuous-batching utilization signal that
        drives re-planning: a tenant whose slots idle is a fission candidate)."""
        self.engines[tenant_name] = engine

    def occupancy(self) -> Dict[str, List[Optional[dict]]]:
        """tenant -> per-slot occupancy ({rid, generated, remaining} | None)."""
        return {name: eng.occupancy() for name, eng in self.engines.items()}

    def utilization(self) -> Dict[str, float]:
        """tenant -> fraction of engine slots currently busy."""
        return {name: eng.utilization() for name, eng in self.engines.items()}
