"""Format-matrix checker: one table of truth for the AIO format grid.

The paper's premise is one multiplier serving many number formats; the
software mirror scatters that claim across four places — the format
registry (`core.formats.REGISTRY`), the policy plane
(`api.policy` routable formats), the MAC-array kernel modes
(`kernels.aio_matmul.MODES` + `formats.RESIDENT_FORMATS`), and the
perf model's energy/power tables (`perfmodel.accelerators`). FORMAT_MATRIX
below states, per format, which planes are SUPPOSED to support it; the
checker cross-references every plane against the table:

  FM301  format registry and matrix disagree on the format set   (error)
  FM302  policy routability disagrees with the matrix            (error)
  FM303  MAC-array mode set disagrees with the matrix            (error)
  FM304  weight-residency set disagrees with the matrix          (error)
  FM305  perf-model coverage disagrees with the matrix           (error)
  FM306  paper-claimed format with no MAC-array mode             (info)
  FM307  MAC-array mode with no perf-model entry                 (warning)
  FM308  residency format without a MAC-array mode               (error)

FM306/FM307 record the DOCUMENTED gaps (uint4/uint8 codes exist but have
no integer-MAC mode yet; fp16 is a software container, not an AIO mode)
without failing --strict; adding a format to formats.py without updating
this table is an FM301 error by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .findings import Report

__all__ = ["FormatClaim", "FORMAT_MATRIX", "check_format_matrix", "CODES"]

CODES = {
    "FM301": ("error", "FORMAT_MATRIX and core.formats.REGISTRY disagree"),
    "FM302": ("error", "policy-routing plane disagrees with the matrix"),
    "FM303": ("error", "MAC-array mode plane disagrees with the matrix"),
    "FM304": ("error", "weight-residency plane disagrees with the matrix"),
    "FM305": ("error", "perf-model plane disagrees with the matrix"),
    "FM306": ("info", "paper-claimed format with no MAC-array mode yet"),
    "FM307": ("warning", "MAC-array mode with no perf-model entry"),
    "FM308": ("error", "residency format without a MAC-array mode"),
}

CHECKER = "format-matrix"


@dataclasses.dataclass(frozen=True)
class FormatClaim:
    """What each plane is supposed to say about one format."""
    name: str
    paper: bool          # claimed for the paper's AIO multiplier (Table II)
    matmul_mode: bool    # an aio_matmul MAC-array operating mode
    residency: bool      # legal resident-weight format
    perf_model: bool     # has energy/power entries in perfmodel
    routable: bool       # ExecutionPolicy(format=...) accepts it


FORMAT_MATRIX = (
    FormatClaim("bf16", paper=True, matmul_mode=True, residency=False,
                perf_model=True, routable=True),
    FormatClaim("fp16", paper=False, matmul_mode=False, residency=False,
                perf_model=False, routable=True),
    FormatClaim("fp8a", paper=True, matmul_mode=True, residency=True,
                perf_model=True, routable=True),
    FormatClaim("fp8b", paper=True, matmul_mode=True, residency=True,
                perf_model=True, routable=True),
    FormatClaim("int8", paper=True, matmul_mode=True, residency=True,
                perf_model=True, routable=True),
    FormatClaim("int4", paper=True, matmul_mode=True, residency=True,
                perf_model=True, routable=True),
    FormatClaim("uint8", paper=True, matmul_mode=False, residency=False,
                perf_model=False, routable=True),
    FormatClaim("uint4", paper=True, matmul_mode=False, residency=False,
                perf_model=False, routable=True),
)


def _cross(rep: Report, code: str, plane: str, claimed: set, actual: set):
    """Two-sided set comparison, one finding per direction."""
    for name in sorted(claimed - actual):
        rep.add(code, "error", CHECKER, f"format {name}",
                f"matrix claims {plane} support but the code does not "
                f"provide it")
    for name in sorted(actual - claimed):
        rep.add(code, "error", CHECKER, f"format {name}",
                f"code provides {plane} support the matrix does not claim — "
                f"update FORMAT_MATRIX")


def check_format_matrix(matrix: Sequence[FormatClaim] = FORMAT_MATRIX, *,
                        registry_names: Optional[set] = None,
                        routable_names: Optional[set] = None,
                        matmul_modes: Optional[set] = None,
                        resident_names: Optional[set] = None,
                        perf_names: Optional[set] = None,
                        report: Optional[Report] = None) -> Report:
    """Cross-check every plane against the matrix. The keyword overrides
    exist for tests; by default each plane is read from the live code."""
    rep = report if report is not None else Report()

    if registry_names is None:
        from ..core import formats
        registry_names = set(formats.REGISTRY)
    if routable_names is None:
        from ..api.policy import _FORMATS
        routable_names = set(_FORMATS)
    if matmul_modes is None:
        from ..kernels.aio_matmul import MODES
        matmul_modes = set(MODES)
    if resident_names is None:
        from ..core import formats
        resident_names = set(formats.RESIDENT_FORMATS)
    if perf_names is None:
        from ..perfmodel import accelerators as acc
        perf_names = set(acc.MULT_ENERGY_PJ)
        for a in acc.ACCELERATORS.values():
            perf_names &= set(a.power_w)

    names = {c.name for c in matrix}

    # FM301: the matrix must cover exactly the format registry
    for name in sorted(registry_names - names):
        rep.add("FM301", "error", CHECKER, f"format {name}",
                "registered in core.formats.REGISTRY but missing from "
                "FORMAT_MATRIX — state its support row")
    for name in sorted(names - registry_names):
        rep.add("FM301", "error", CHECKER, f"format {name}",
                "listed in FORMAT_MATRIX but not registered in "
                "core.formats.REGISTRY")

    # FM302..FM305: per-plane cross-references
    _cross(rep, "FM302", "policy-routing",
           {c.name for c in matrix if c.routable}, routable_names)
    _cross(rep, "FM303", "MAC-array mode",
           {c.name for c in matrix if c.matmul_mode}, matmul_modes)
    _cross(rep, "FM304", "weight-residency",
           {c.name for c in matrix if c.residency}, resident_names)
    _cross(rep, "FM305", "perf-model",
           {c.name for c in matrix if c.perf_model}, perf_names)

    # FM306..FM308: internal consistency of the claims themselves
    for c in matrix:
        if c.paper and not c.matmul_mode:
            rep.add("FM306", "info", CHECKER, f"format {c.name}",
                    "paper-claimed format with no MAC-array mode yet "
                    "(documented gap)")
        if c.matmul_mode and not c.perf_model:
            rep.add("FM307", "warning", CHECKER, f"format {c.name}",
                    "MAC-array mode with no perf-model energy/power entry — "
                    "Fig 14-style sweeps will not cover it")
        if c.residency and not c.matmul_mode:
            rep.add("FM308", "error", CHECKER, f"format {c.name}",
                    "weight-residency format without a MAC-array mode: "
                    "resident codes would be unroutable at dispatch")
    return rep
