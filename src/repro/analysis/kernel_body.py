"""Kernel-body checker: abstract interpretation of Pallas kernel jaxprs.

PR 6's kernel-contract checker proves the *launch geometry* (index maps,
grids, VMEM); this checker proves properties of the kernel *bodies*. Each
LaunchContract now carries a zero-arg ``body`` thunk that assembles the
real launch on dummy operands; ``jax.make_jaxpr`` traces it (nothing
executes), the single ``pallas_call`` equation is extracted, and an
interval + taint abstract interpretation runs over the kernel jaxpr:

  KB400  ref index not provably within the block shape      (error)
  KB401  guarded ref index: the pl.when predicate interval
         does not cover the out-of-range lanes              (error)
  KB410  two grid points write the same output block along
         a grid dim not declared in ``revisits=``           (error)
  KB411  declared revisit dim with grid > 1 never revisits  (warning)
  KB420  dequantized value reaches an output store without
         a scale multiply (unscaled dequant)                (error)
  KB421  quant/scale contract declaration inconsistent
         (unknown format, dangling scale_for, scale plane
         not broadcastable onto its codes block)            (error)
  KB430  contract declares no traceable kernel body         (warning)
  KB431  body trace failed or drifted from its contract
         (grid/block-shape/operand-count mismatch)          (error)

The interpreter maps every jaxpr value to an interval [lo, hi] plus a
quantization taint (clean / scale / codes / dequant). ``program_id(i)``
seeds [0, grid[i]-1]; scalar-prefetch loads seed the min/max of the
contract's concrete scalar vectors; ``pl.when`` predicates refine
intervals inside the guarded branch by walking the predicate's def chain.
Ref reads/writes (the ``get``/``swap``/``addupdate`` state primitives)
re-materialize their NDIndexer and every scalar/slice index must prove
0 <= idx < dim. The taint lattice catches the Jack-Unit dequant contract:
a load from a ``quant=``-marked ref is CODES, int->float conversion makes
it DEQUANT, a multiply against a ``scale_for=``-marked operand clears it,
and storing a still-DEQUANT (or raw CODES) value to an output is KB420.

The race detector (KB410/411) needs no jaxpr: it replays the contract's
output index maps over the (stratified-sampled) grid and compares every
grid point against the first point that produced each output block —
complete for pairwise dim-difference containment because difference sets
against a common point union.

Known limits: ``scan``/``while`` bodies are not entered (their outputs
become unbounded, which is sound — no registered kernel loops in-body),
and bitwise shifts are unbounded (int4 nibble unpacking stays sound
because unpacked values are never used as indices).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..api.policy import ExecutionPolicy, policy_sweep
from ..api.registry import BlockContract, KernelRegistry, LaunchContract
from ..api.registry import registry as default_registry
from .findings import Report
from .format_matrix import FORMAT_MATRIX

__all__ = ["check_body", "check_kernel_bodies", "CODES"]

CHECKER = "kernel-body"

CODES = {
    "KB400": ("error", "ref index not provably within the block shape"),
    "KB401": ("error", "guarded ref index: pl.when predicate does not "
                       "cover the out-of-range lanes"),
    "KB410": ("error", "two grid points write the same output block along "
                       "an undeclared (non-revisits) grid dim"),
    "KB411": ("warning", "declared revisits= dim with grid > 1 never "
                         "revisits an output block"),
    "KB420": ("error", "dequantized/code value stored to an output without "
                       "a scale multiply"),
    "KB421": ("error", "quant/scale declaration inconsistent (unknown "
                       "format, dangling scale_for, bad scale plane)"),
    "KB430": ("warning", "launch contract declares no traceable body"),
    "KB431": ("error", "kernel body trace failed or drifted from its "
                       "contract"),
}

INF = float("inf")

# Taint lattice, ordered by badness; join = max.
CLEAN, SCALE, CODES_T, DEQ = 0, 1, 2, 3
_TAINT_NAMES = {CLEAN: "clean", SCALE: "scale", CODES_T: "codes",
                DEQ: "dequant"}


@dataclasses.dataclass(frozen=True)
class AbsVal:
    """Interval + quantization taint for one jaxpr value."""
    lo: float = -INF
    hi: float = INF
    taint: int = CLEAN

    @property
    def is_top(self) -> bool:
        return self.lo == -INF and self.hi == INF


TOP = AbsVal()


def _join(*vals: AbsVal) -> AbsVal:
    if not vals:
        return TOP
    return AbsVal(min(v.lo for v in vals), max(v.hi for v in vals),
                  max(v.taint for v in vals))


def _taint_of(*vals: AbsVal) -> int:
    return max((v.taint for v in vals), default=CLEAN)


def _mul_taint(a: AbsVal, b: AbsVal) -> int:
    """A scale multiply CLEARS codes/dequant taint — the dequant contract."""
    pair = {a.taint, b.taint}
    if SCALE in pair and (CODES_T in pair or DEQ in pair):
        return CLEAN
    return _taint_of(a, b)


def _mul_iv(a: AbsVal, b: AbsVal, taint: int) -> AbsVal:
    def m(x, y):                       # 0 * inf -> 0, not nan
        if x == 0 or y == 0:
            return 0.0
        return x * y
    prods = [m(a.lo, b.lo), m(a.lo, b.hi), m(a.hi, b.lo), m(a.hi, b.hi)]
    return AbsVal(min(prods), max(prods), taint)


def _floordiv_iv(a: AbsVal, b: AbsVal, taint: int) -> AbsVal:
    """Exact interval floor-division (the `bh // hkv` prefetch-index case)."""
    if b.lo <= 0 <= b.hi or a.is_top or b.is_top:
        return AbsVal(taint=taint)
    cands = []
    for x in (a.lo, a.hi):
        for y in (b.lo, b.hi):
            if math.isfinite(x) and math.isfinite(y):
                cands.append(math.floor(x / y))
            else:
                cands.append(math.copysign(INF, x / y if y else 1.0))
    return AbsVal(min(cands), max(cands), taint)


def _mod_iv(a: AbsVal, b: AbsVal, taint: int) -> AbsVal:
    if b.lo > 0 and math.isfinite(b.hi):
        # jnp.remainder follows the divisor's sign: result in [0, b)
        return AbsVal(0.0, b.hi - 1 if b.hi == int(b.hi) else b.hi, taint)
    return AbsVal(taint=taint)


@dataclasses.dataclass
class RefInfo:
    """One kernel ref operand: identity, shape, and mutable content taint."""
    name: str
    shape: Tuple[int, ...]
    kind: str                               # prefetch | input | output | scratch
    block: Optional[BlockContract] = None
    scalars: Optional[np.ndarray] = None    # concrete prefetch operand
    taint: int = CLEAN


def _is_literal(v) -> bool:
    """jax Literals carry .val and may be unhashable — never dict keys."""
    return hasattr(v, "val")


def _literal_val(v) -> Optional[float]:
    """Concrete scalar of a Literal/const var, else None."""
    if not _is_literal(v):
        return None
    try:
        arr = np.asarray(v.val)
    except Exception:  # noqa: BLE001 — opaque literal payload
        return None
    if arr.size == 1 and np.issubdtype(arr.dtype, np.number):
        return float(arr.reshape(()))
    return None


class _Env:
    """Var -> AbsVal and Var -> RefInfo scopes (shared mutable ref table)."""

    def __init__(self):
        self.vals: Dict[Any, AbsVal] = {}
        self.refs: Dict[Any, RefInfo] = {}

    def is_ref(self, v) -> bool:
        return not _is_literal(v) and v in self.refs

    def read(self, v) -> AbsVal:
        if _is_literal(v):
            lit = _literal_val(v)
            if lit is not None:
                return AbsVal(lit, lit)
            try:                            # non-scalar literal/const array
                arr = np.asarray(v.val)
                if arr.size and np.issubdtype(arr.dtype, np.number):
                    return AbsVal(float(arr.min()), float(arr.max()))
            except Exception:  # noqa: BLE001
                pass
            return TOP
        return self.vals.get(v, TOP)

    def child(self) -> "_Env":
        env = _Env()
        env.vals = dict(self.vals)
        env.refs = self.refs                # refs are shared, taint is global
        return env


def _dtype_bounds(aval) -> AbsVal:
    dt = getattr(aval, "dtype", None)
    if dt is not None and np.issubdtype(dt, np.integer):
        info = np.iinfo(dt)
        return AbsVal(float(info.min), float(info.max))
    if dt is not None and np.issubdtype(dt, np.bool_):
        return AbsVal(0.0, 1.0)
    return TOP


class _BodyInterp:
    """One pass over a kernel jaxpr for one (contract, grid) instance."""

    def __init__(self, rep: Report, where: str, grid: Tuple[int, ...]):
        self.rep = rep
        self.where = where
        self.grid = grid
        self.reported: set = set()          # (code, ref name) dedup

    # ------------------------------------------------------------- findings
    def _oob(self, ref: RefInfo, dim: int, iv: AbsVal, lo_ok: float,
             hi_ok: float, guarded: bool):
        code = "KB401" if guarded else "KB400"
        if (code, ref.name, dim) in self.reported:
            return
        self.reported.add((code, ref.name, dim))
        guard = ("the enclosing pl.when predicate does not restrict it to"
                 if guarded else "no pl.when guard restricts it to")
        self.rep.add(code, "error", CHECKER, self.where,
                     f"ref {ref.name!r} dim {dim}: index interval "
                     f"[{iv.lo:g}, {iv.hi:g}] not provably within "
                     f"[{lo_ok:g}, {hi_ok:g}] — {guard} the block")

    # ------------------------------------------------------------- indexing
    def _check_indexers(self, ref: RefInfo, tree, dyn_invars, env: _Env,
                        guarded: bool):
        """Re-materialize the NDIndexer pytree; prove every index in-bounds."""
        import jax

        try:
            indexers = jax.tree_util.tree_unflatten(tree, tuple(dyn_invars))
        except Exception:  # noqa: BLE001 — unknown layout, stay silent
            return
        if not isinstance(indexers, (tuple, list)):
            indexers = (indexers,)
        for indexer in indexers:
            indices = getattr(indexer, "indices", None)
            if indices is None:
                continue
            for dim, (idx, n) in enumerate(zip(indices, ref.shape)):
                start = getattr(idx, "start", None)
                if start is not None:       # a Slice(start, size, stride)
                    size = getattr(idx, "size", 1)
                    stride = getattr(idx, "stride", 1) or 1
                    siv = self._as_iv(start, env)
                    if not isinstance(size, int):
                        continue            # dynamic size: geometry unknown
                    last = AbsVal(siv.lo + (size - 1) * stride,
                                  siv.hi + (size - 1) * stride)
                    if siv.lo < 0 or last.hi > n - 1:
                        self._oob(ref, dim, AbsVal(siv.lo, last.hi), 0,
                                  n - 1, guarded)
                else:                       # scalar or array index
                    iv = self._as_iv(idx, env)
                    if iv.lo < 0 or iv.hi > n - 1:
                        self._oob(ref, dim, iv, 0, n - 1, guarded)

    def _as_iv(self, idx, env: _Env) -> AbsVal:
        if isinstance(idx, (int, np.integer)):
            return AbsVal(float(idx), float(idx))
        if isinstance(idx, np.ndarray):
            return AbsVal(float(idx.min()), float(idx.max()))
        return env.read(idx)

    def _load_interval(self, ref: RefInfo, tree, dyn_invars,
                       env: _Env) -> AbsVal:
        """Value interval of a ref read (concrete for prefetch operands)."""
        if ref.scalars is not None and ref.scalars.size:
            arr = np.asarray(ref.scalars)
            import jax
            try:
                indexers = jax.tree_util.tree_unflatten(tree,
                                                        tuple(dyn_invars))
                if not isinstance(indexers, (tuple, list)):
                    indexers = (indexers,)
                indices = getattr(indexers[0], "indices", ())
                if len(indices) == arr.ndim == 1:
                    iv = self._as_iv(indices[0], env)
                    if math.isfinite(iv.lo) and math.isfinite(iv.hi):
                        lo = max(0, int(iv.lo))
                        hi = min(arr.shape[0] - 1, int(iv.hi))
                        if lo <= hi:
                            sub = arr[lo:hi + 1]
                            return AbsVal(float(sub.min()), float(sub.max()),
                                          ref.taint)
            except Exception:  # noqa: BLE001 — fall back to the full range
                pass
            return AbsVal(float(arr.min()), float(arr.max()), ref.taint)
        return dataclasses.replace(TOP, taint=ref.taint)

    # --------------------------------------------------------- cond support
    def _refine_from_pred(self, pred_var, jaxpr, env: _Env) -> Dict[Any, AbsVal]:
        """Interval tightenings that hold inside the TRUE branch of pred."""
        defs = {}
        for eqn in jaxpr.eqns:
            for ov in eqn.outvars:
                defs[ov] = eqn
        out: Dict[Any, AbsVal] = {}

        def cur(v) -> AbsVal:
            if _is_literal(v):
                return env.read(v)
            return out.get(v, env.read(v))

        def visit(v):
            if _is_literal(v):
                return
            eqn = defs.get(v)
            if eqn is None:
                return
            name = eqn.primitive.name
            if name == "convert_element_type":
                visit(eqn.invars[0])
                return
            if name == "and":
                visit(eqn.invars[0])
                visit(eqn.invars[1])
                return
            if name not in ("lt", "le", "gt", "ge", "eq"):
                return
            a, b = eqn.invars
            av = cur(a)
            bv = cur(b)
            # rewrite gt/ge as lt/le with swapped sides
            if name in ("gt", "ge"):
                a, b, av, bv = b, a, bv, av
                name = "lt" if name == "gt" else "le"
            if name == "eq":
                both = AbsVal(max(av.lo, bv.lo), min(av.hi, bv.hi),
                              av.taint)
                if both.lo <= both.hi:
                    for side, t in ((a, av.taint), (b, bv.taint)):
                        if not _is_literal(side):
                            out[side] = dataclasses.replace(both, taint=t)
                return
            gap = 1.0 if name == "lt" else 0.0       # a < b  <=>  a <= b-1
            if not _is_literal(a):
                out[a] = AbsVal(av.lo, min(av.hi, bv.hi - gap), av.taint)
            if not _is_literal(b):
                out[b] = AbsVal(max(bv.lo, av.lo + gap), bv.hi, bv.taint)

        visit(pred_var)
        return {v: iv for v, iv in out.items() if iv.lo <= iv.hi}

    # ------------------------------------------------------------ the walk
    def run(self, jaxpr, env: _Env, guarded: bool):
        # _enclosing tracks the jaxpr whose def chains a cond predicate
        # refinement must walk (predicates are defined as siblings of the
        # cond equation, not inside the branch)
        saved = getattr(self, "_enclosing", None)
        self._enclosing = jaxpr
        try:
            for eqn in jaxpr.eqns:
                self.eqn(eqn, env, guarded)
        finally:
            self._enclosing = saved

    def _bind(self, eqn, env: _Env, *vals: AbsVal):
        for ov, v in zip(eqn.outvars, vals):
            env.vals[ov] = v

    def eqn(self, eqn, env: _Env, guarded: bool):  # noqa: C901 — dispatch
        name = eqn.primitive.name
        iv = [env.read(v) for v in eqn.invars
              if not env.is_ref(v)]          # value operands only

        if name == "program_id":
            ax = eqn.params["axis"]
            self._bind(eqn, env, AbsVal(0.0, float(self.grid[ax] - 1)))
        elif name == "num_programs":
            ax = eqn.params["axis"]
            g = float(self.grid[ax])
            self._bind(eqn, env, AbsVal(g, g))

        elif name in ("get", "swap", "addupdate"):
            ref = env.refs.get(eqn.invars[0])
            ndyn = {"get": 1, "swap": 2, "addupdate": 2}[name]
            dyn = eqn.invars[ndyn:]
            if ref is not None:
                self._check_indexers(ref, eqn.params.get("tree"), dyn, env,
                                     guarded)
            if name == "get":
                out = (self._load_interval(ref, eqn.params.get("tree"), dyn,
                                           env) if ref is not None else TOP)
                if out.is_top and eqn.outvars:
                    out = dataclasses.replace(
                        _dtype_bounds(eqn.outvars[0].aval), taint=out.taint)
                self._bind(eqn, env, out)
            else:
                stored = env.read(eqn.invars[1])
                if ref is not None:
                    self._store(ref, stored)
                if name == "swap" and eqn.outvars:
                    self._bind(eqn, env, dataclasses.replace(
                        _dtype_bounds(eqn.outvars[0].aval), taint=ref.taint
                        if ref is not None else CLEAN))

        elif name == "cond":
            self._cond(eqn, env, guarded)
        elif name == "pjit":
            self._pjit(eqn, env, guarded)
        elif name in ("custom_jvp_call", "custom_vjp_call",
                      "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr"):
            closed = (eqn.params.get("call_jaxpr")
                      or eqn.params.get("fun_jaxpr"))
            if closed is not None:
                self._inline(closed, eqn, env, guarded)
            else:
                self._bind(eqn, env, *[dataclasses.replace(
                    TOP, taint=_taint_of(*iv))] * len(eqn.outvars))

        elif name == "add":
            self._bind(eqn, env, AbsVal(iv[0].lo + iv[1].lo,
                                        iv[0].hi + iv[1].hi,
                                        _taint_of(*iv)))
        elif name == "sub":
            self._bind(eqn, env, AbsVal(iv[0].lo - iv[1].hi,
                                        iv[0].hi - iv[1].lo,
                                        _taint_of(*iv)))
        elif name == "mul":
            self._bind(eqn, env, _mul_iv(iv[0], iv[1],
                                         _mul_taint(iv[0], iv[1])))
        elif name == "div":
            # conservative: only the scale-clearing taint rule, interval TOP
            self._bind(eqn, env, AbsVal(taint=_mul_taint(iv[0], iv[1])))
        elif name == "rem":
            self._bind(eqn, env, _mod_iv(iv[0], iv[1], _taint_of(*iv)))
        elif name == "max":
            self._bind(eqn, env, AbsVal(max(iv[0].lo, iv[1].lo),
                                        max(iv[0].hi, iv[1].hi),
                                        _taint_of(*iv)))
        elif name == "min":
            self._bind(eqn, env, AbsVal(min(iv[0].lo, iv[1].lo),
                                        min(iv[0].hi, iv[1].hi),
                                        _taint_of(*iv)))
        elif name == "neg":
            self._bind(eqn, env, AbsVal(-iv[0].hi, -iv[0].lo, iv[0].taint))
        elif name == "sign":
            self._bind(eqn, env, AbsVal(-1.0, 1.0, iv[0].taint))
        elif name == "abs":
            lo = 0.0 if iv[0].lo <= 0 <= iv[0].hi else min(abs(iv[0].lo),
                                                           abs(iv[0].hi))
            self._bind(eqn, env, AbsVal(lo, max(abs(iv[0].lo), abs(iv[0].hi)),
                                        iv[0].taint))
        elif name == "clamp":
            # clamp(a, x, b) = max(a, min(x, b)) — monotone in all three
            lo = max(iv[0].lo, min(iv[1].lo, iv[2].lo))
            hi = max(iv[0].hi, min(iv[1].hi, iv[2].hi))
            self._bind(eqn, env, AbsVal(lo, max(lo, hi), _taint_of(*iv)))
        elif name in ("floor", "round", "ceil"):
            f = {"floor": math.floor, "ceil": math.ceil,
                 "round": round}[name]
            lo = f(iv[0].lo) if math.isfinite(iv[0].lo) else iv[0].lo
            hi = f(iv[0].hi) if math.isfinite(iv[0].hi) else iv[0].hi
            self._bind(eqn, env, AbsVal(float(lo), float(hi), iv[0].taint))

        elif name == "convert_element_type":
            new = eqn.params.get("new_dtype")
            taint = iv[0].taint
            if (taint == CODES_T and new is not None
                    and np.issubdtype(new, np.floating)):
                taint = DEQ                 # codes became float: needs a scale
            lo, hi = iv[0].lo, iv[0].hi
            if new is not None and np.issubdtype(new, np.integer):
                lo = math.floor(lo) if math.isfinite(lo) else lo
                hi = math.ceil(hi) if math.isfinite(hi) else hi
            self._bind(eqn, env, AbsVal(lo, hi, taint))

        elif name in ("lt", "le", "gt", "ge", "eq", "ne"):
            a, b = iv[0], iv[1]
            res = AbsVal(0.0, 1.0, _taint_of(a, b))
            if name == "lt" and a.hi < b.lo:
                res = AbsVal(1.0, 1.0)
            elif name == "lt" and a.lo >= b.hi:
                res = AbsVal(0.0, 0.0)
            elif name == "le" and a.hi <= b.lo:
                res = AbsVal(1.0, 1.0)
            elif name == "le" and a.lo > b.hi:
                res = AbsVal(0.0, 0.0)
            elif name == "ge" and a.lo >= b.hi:
                res = AbsVal(1.0, 1.0)
            elif name == "ge" and a.hi < b.lo:
                res = AbsVal(0.0, 0.0)
            elif name == "gt" and a.lo > b.hi:
                res = AbsVal(1.0, 1.0)
            elif name == "gt" and a.hi <= b.lo:
                res = AbsVal(0.0, 0.0)
            self._bind(eqn, env, res)
        elif name in ("and", "or", "not", "xor"):
            self._bind(eqn, env, AbsVal(0.0, 1.0, _taint_of(*iv)))

        elif name == "select_n":
            which, cases = iv[0], iv[1:]
            if which.lo == which.hi and 0 <= int(which.lo) < len(cases):
                self._bind(eqn, env, cases[int(which.lo)])
            else:
                self._bind(eqn, env, _join(*cases))
        elif name == "iota":
            dim = eqn.params.get("dimension", 0)
            shape = eqn.params.get("shape", (1,))
            self._bind(eqn, env, AbsVal(0.0, float(shape[dim] - 1)))
        elif name in ("broadcast_in_dim", "reshape", "squeeze", "transpose",
                      "slice", "rev", "expand_dims", "copy",
                      "stop_gradient", "reduce_precision"):
            self._bind(eqn, env, iv[0])
        elif name in ("concatenate", "pad"):
            self._bind(eqn, env, _join(*iv))
        elif name in ("reduce_max", "reduce_min", "reduce_or", "reduce_and"):
            self._bind(eqn, env, iv[0])
        elif name == "reduce_sum":
            axes = eqn.params.get("axes", ())
            shape = getattr(eqn.invars[0].aval, "shape", ())
            n = 1
            for a in axes:
                n *= shape[a] if a < len(shape) else 1
            self._bind(eqn, env, AbsVal(min(iv[0].lo * n, iv[0].lo),
                                        max(iv[0].hi * n, iv[0].hi),
                                        iv[0].taint))
        elif name == "dot_general":
            self._bind(eqn, env, AbsVal(taint=_mul_taint(iv[0], iv[1])))
        else:
            # unknown primitive: unbounded, taint joins through (sound for
            # KB400 — an unbounded index simply cannot be proven in-bounds)
            t = _taint_of(*iv)
            self._bind(eqn, env, *[AbsVal(taint=t)] * len(eqn.outvars))

    def _store(self, ref: RefInfo, stored: AbsVal):
        ref.taint = max(ref.taint, stored.taint)
        if ref.kind == "output" and stored.taint in (CODES_T, DEQ) \
                and not (ref.block is not None and ref.block.quant):
            key = ("KB420", ref.name)
            if key not in self.reported:
                self.reported.add(key)
                what = ("raw quantized codes" if stored.taint == CODES_T
                        else "a dequantized (int->float) value")
                self.rep.add("KB420", "error", CHECKER, self.where,
                             f"output {ref.name!r} stores {what} that was "
                             f"never multiplied by a scale_for= operand — "
                             f"unscaled dequant")

    # ------------------------------------------------- structured equations
    def _bind_sub(self, closed, operands, env: _Env) -> _Env:
        sub = env.child()
        jaxpr = getattr(closed, "jaxpr", closed)
        consts = getattr(closed, "consts", ())
        for cv, c in zip(jaxpr.constvars, consts):
            try:
                arr = np.asarray(c)
            except Exception:  # noqa: BLE001 — opaque const
                continue
            if arr.size and np.issubdtype(arr.dtype, np.number):
                sub.vals[cv] = AbsVal(float(arr.min()), float(arr.max()))
        for inv, op in zip(jaxpr.invars, operands):
            if env.is_ref(op):
                sub.refs[inv] = env.refs[op]
            else:
                sub.vals[inv] = env.read(op)
        return sub

    def _inline(self, closed, eqn, env: _Env, guarded: bool,
                refine: Optional[Dict[Any, AbsVal]] = None,
                operands: Optional[Sequence] = None):
        operands = eqn.invars if operands is None else operands
        if refine:
            env = env.child()
            env.vals.update(refine)
        sub = self._bind_sub(closed, operands, env)
        jaxpr = getattr(closed, "jaxpr", closed)
        self.run(jaxpr, sub, guarded)
        return [sub.read(ov) for ov in jaxpr.outvars]

    def _cond(self, eqn, env: _Env, guarded: bool):
        branches = eqn.params["branches"]
        idx_iv = env.read(eqn.invars[0])
        operands = eqn.invars[1:]
        constant = idx_iv.lo == idx_iv.hi and math.isfinite(idx_iv.lo)
        results: List[List[AbsVal]] = []
        for bi, closed in enumerate(branches):
            if constant and int(idx_iv.lo) != bi:
                continue
            refine = None
            inner_guarded = guarded
            if not constant:
                inner_guarded = True
                if bi == len(branches) - 1:       # the pl.when TRUE branch
                    refine = self._refine_from_pred(
                        eqn.invars[0], self._enclosing, env)
            results.append(self._inline(closed, eqn, env, inner_guarded,
                                        refine=refine, operands=operands))
        outs = []
        for i in range(len(eqn.outvars)):
            outs.append(_join(*[r[i] for r in results if i < len(r)]))
        self._bind(eqn, env, *outs)

    def _pjit(self, eqn, env: _Env, guarded: bool):
        pname = eqn.params.get("name", "")
        closed = eqn.params.get("jaxpr")
        iv = [env.read(v) for v in eqn.invars if not env.is_ref(v)]
        if pname == "floor_divide" and len(iv) == 2:
            self._bind(eqn, env,
                       _floordiv_iv(iv[0], iv[1], _taint_of(*iv)))
        elif pname in ("remainder", "mod") and len(iv) == 2:
            self._bind(eqn, env, _mod_iv(iv[0], iv[1], _taint_of(*iv)))
        elif closed is not None:
            self._bind(eqn, env, *self._inline(closed, eqn, env, guarded))
        else:
            t = _taint_of(*iv)
            self._bind(eqn, env, *[AbsVal(taint=t)] * len(eqn.outvars))

    def interpret(self, jaxpr, env: _Env):
        self.run(jaxpr, env, False)


# ---------------------------------------------------------------------------
# Grid sampling (shared with kernel_contracts' KC105 replacement)
# ---------------------------------------------------------------------------

def stratified_grid_points(grid: Sequence[int], max_points: int):
    """All grid points, or a stratified sample that ALWAYS includes the
    first and last block along every grid dim (where the clamp bugs live).

    Returns (iterator of points, truncated: bool).
    """
    import itertools
    total = 1
    for g in grid:
        total *= g
    if total <= max_points:
        return itertools.product(*(range(g) for g in grid)), False
    counts = [max(1, g) for g in grid]
    while True:
        prod = 1
        for c in counts:
            prod *= c
        if prod <= max_points:
            break
        d = counts.index(max(counts))
        if counts[d] <= 2:
            break
        counts[d] = max(2, counts[d] // 2)
    axes = []
    for g, c in zip(grid, counts):
        if g <= c:
            axes.append(range(g))
        else:
            vals = np.unique(np.linspace(0, g - 1, c).round().astype(int))
            axes.append([int(v) for v in vals])
    return itertools.product(*axes), True


# ---------------------------------------------------------------------------
# KB410/411 — the grid write-race detector (contract-level, no jaxpr)
# ---------------------------------------------------------------------------

MAX_RACE_POINTS = 65536


def _check_races(lc: LaunchContract, where: str, rep: Report):
    outputs = [b for b in lc.blocks if b.is_output]
    points, truncated = stratified_grid_points(lc.grid, MAX_RACE_POINTS)
    first_hit: Dict[Tuple[str, Tuple[int, ...]], Tuple[int, ...]] = {}
    observed: Dict[str, set] = {b.name: set() for b in outputs}
    raced: set = set()
    for point in points:
        for b in outputs:
            if b.name in raced:
                continue
            try:
                idx = tuple(int(v) for v in b.index_map(*point, *lc.scalars))
            except Exception:  # noqa: BLE001 — KC101/KC105 territory
                raced.add(b.name)
                continue
            key = (b.name, idx)
            prev = first_hit.setdefault(key, point)
            if prev is point or prev == point:
                continue
            diff = [d for d in range(len(lc.grid)) if prev[d] != point[d]]
            bad = [d for d in diff if d not in b.revisits]
            if bad:
                raced.add(b.name)
                rep.add("KB410", "error", CHECKER, where,
                        f"output {b.name!r}: grid points {prev} and {point} "
                        f"both write block {idx}, differing along grid "
                        f"dim(s) {bad} which are not declared in revisits="
                        f"{tuple(b.revisits)} — a write race (declare the "
                        f"reduction dim, or fix the index map)")
            else:
                observed[b.name].update(diff)
    if truncated:
        return
    for b in outputs:
        if b.name in raced:
            continue
        stale = [d for d in b.revisits
                 if d < len(lc.grid) and lc.grid[d] > 1
                 and d not in observed[b.name]]
        if stale:
            rep.add("KB411", "warning", CHECKER, where,
                    f"output {b.name!r} declares revisits={tuple(b.revisits)} "
                    f"but no two grid points revisit a block along dim(s) "
                    f"{stale} (grid {tuple(lc.grid)}) — stale declaration")


# ---------------------------------------------------------------------------
# KB421 — static quant/scale declaration audit vs FORMAT_MATRIX
# ---------------------------------------------------------------------------

def _check_quant_decls(lc: LaunchContract, where: str, rep: Report):
    known = {c.name for c in FORMAT_MATRIX}
    by_name = {b.name: b for b in lc.blocks}
    scaled = {b.scale_for for b in lc.blocks if b.scale_for}
    for b in lc.blocks:
        if b.quant is not None and b.quant not in known:
            rep.add("KB421", "error", CHECKER, where,
                    f"block {b.name!r} declares quant={b.quant!r} which is "
                    f"not a FORMAT_MATRIX format "
                    f"({', '.join(sorted(known))})")
        if b.quant is not None and b.name not in scaled:
            rep.add("KB421", "error", CHECKER, where,
                    f"quantized block {b.name!r} has no scale operand: no "
                    f"block declares scale_for={b.name!r}")
        if b.scale_for is not None:
            codes = by_name.get(b.scale_for)
            if codes is None:
                rep.add("KB421", "error", CHECKER, where,
                        f"block {b.name!r} declares scale_for="
                        f"{b.scale_for!r} but no such block exists")
            elif codes.quant is None:
                rep.add("KB421", "error", CHECKER, where,
                        f"block {b.name!r} scales {b.scale_for!r} which "
                        f"declares no quant= format")
            elif len(b.block_shape) == len(codes.block_shape):
                for d, (s, c) in enumerate(zip(b.block_shape,
                                               codes.block_shape)):
                    if s != c and s != 1:
                        rep.add("KB421", "error", CHECKER, where,
                                f"scale {b.name!r} dim {d}: plane length "
                                f"{s} is neither 1 nor the codes block "
                                f"length {c} — scale axis mismatch vs "
                                f"{b.scale_for!r}")
                        break


# ---------------------------------------------------------------------------
# KB43x + the body walk — one LaunchContract end to end
# ---------------------------------------------------------------------------

def _pallas_eqns(jaxpr, out):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                _pallas_eqns(sub, out)
            elif isinstance(v, (tuple, list)):
                for item in v:
                    sub = getattr(item, "jaxpr", None)
                    if sub is not None and hasattr(sub, "eqns"):
                        _pallas_eqns(sub, out)
    return out


def check_body(lc: LaunchContract, where: str,
               report: Optional[Report] = None) -> Report:
    """All KB4xx checks for one concrete LaunchContract."""
    rep = report if report is not None else Report()

    _check_quant_decls(lc, where, rep)
    outputs = [b for b in lc.blocks if b.is_output]
    if outputs and any(b.is_output for b in
                       lc.blocks[:len(lc.blocks) - len(outputs)]):
        rep.add("KB431", "error", CHECKER, where,
                "is_output blocks must be a contiguous suffix of blocks "
                "(pallas_call orders inputs before outputs)")
        return rep
    _check_races(lc, where, rep)

    if lc.body is None:
        return rep

    import jax
    try:
        closed = jax.make_jaxpr(lc.body)()
    except Exception as e:  # noqa: BLE001 — surfaced as a finding
        rep.add("KB431", "error", CHECKER, where,
                f"body trace raised {type(e).__name__}: {e}")
        return rep
    calls = _pallas_eqns(closed.jaxpr, [])
    if len(calls) != 1:
        rep.add("KB431", "error", CHECKER, where,
                f"body traced to {len(calls)} pallas_call equations "
                f"(contracts describe exactly one launch)")
        return rep
    eqn = calls[0]
    kernel_jaxpr = eqn.params["jaxpr"]
    gm = eqn.params.get("grid_mapping")
    grid = tuple(int(g) for g in getattr(gm, "grid", lc.grid))
    if grid != tuple(lc.grid):
        rep.add("KB431", "error", CHECKER, where,
                f"traced grid {grid} != contract grid {tuple(lc.grid)} — "
                f"the contract drifted from the kernel")
        return rep

    nsp = lc.num_scalar_prefetch
    invars = kernel_jaxpr.invars
    if len(invars) < nsp + len(lc.blocks):
        rep.add("KB431", "error", CHECKER, where,
                f"kernel body has {len(invars)} ref operand(s) but the "
                f"contract declares {nsp} prefetch + {len(lc.blocks)} "
                f"blocks")
        return rep

    env = _Env()
    n_in = len(lc.blocks) - len(outputs)
    for i in range(nsp):
        arr = np.asarray(lc.scalars[i])
        ref = RefInfo(f"prefetch[{i}]", tuple(arr.shape), "prefetch",
                      scalars=arr)
        shape = tuple(getattr(invars[i].aval, "shape", arr.shape))
        if shape != tuple(arr.shape):
            rep.add("KB431", "error", CHECKER, where,
                    f"prefetch operand {i}: traced shape {shape} != "
                    f"contract scalar shape {tuple(arr.shape)}")
            return rep
        env.refs[invars[i]] = ref
    for j, b in enumerate(lc.blocks):
        var = invars[nsp + j]
        shape = tuple(getattr(var.aval, "shape", b.block_shape))
        if shape != tuple(b.block_shape):
            rep.add("KB431", "error", CHECKER, where,
                    f"block {b.name!r}: traced kernel ref shape {shape} != "
                    f"contract block shape {tuple(b.block_shape)} — the "
                    f"contract drifted from the kernel")
            return rep
        taint = CODES_T if b.quant else (SCALE if b.scale_for else CLEAN)
        env.refs[var] = RefInfo(b.name, shape, "output" if b.is_output
                                else "input", block=b, taint=taint)
    for s, var in enumerate(invars[nsp + len(lc.blocks):]):
        env.refs[var] = RefInfo(f"scratch[{s}]",
                                tuple(getattr(var.aval, "shape", ())),
                                "scratch")

    interp = _BodyInterp(rep, where, grid)
    try:
        interp.interpret(kernel_jaxpr, env)
    except Exception as e:  # noqa: BLE001 — interpreter bug, not a pass
        rep.add("KB431", "error", CHECKER, where,
                f"body interpretation raised {type(e).__name__}: {e}")
    return rep


def check_kernel_bodies(reg: Optional[KernelRegistry] = None,
                        sweep_values: Optional[dict] = None,
                        report: Optional[Report] = None) -> Report:
    """Sweep every registered contract's body over case x policy tiles.

    KB430 warns once per (op, impl) whose contracts never declare a body —
    the coverage analogue of KC100, required to be zero on main.
    """
    reg = reg if reg is not None else default_registry
    rep = report if report is not None else Report()
    for op, impl in reg.pallas_impls():
        fn = reg.contract(op, impl)
        where = f"{op}/{impl}"
        if fn is None:
            continue                        # KC100 already covers this
        policies: Sequence[ExecutionPolicy] = policy_sweep(
            fn.sweep_fields, values=sweep_values)
        saw_body = False
        for ci, case in enumerate(fn.cases):
            for policy in policies:
                tiles = {f: getattr(policy, f) for f in fn.sweep_fields}
                at = f"{where} case[{ci}] {tiles}" if tiles \
                    else f"{where} case[{ci}]"
                try:
                    lc = fn(case, policy)
                except Exception:  # noqa: BLE001 — KC105 already reports it
                    continue
                saw_body = saw_body or lc.body is not None
                check_body(lc, at, rep)
        if fn.cases and not saw_body:
            rep.add("KB430", "warning", CHECKER, where,
                    "no contract case declares a body= thunk — the kernel "
                    "body is invisible to the KB4xx interpreter (declare "
                    "one on the LaunchContract)")
    return rep
