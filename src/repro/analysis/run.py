"""`python -m repro.analysis` — run every static checker, render a report.

Exit status: 0 always, unless --strict is given, in which case any
error-severity finding exits 1 (the CI gate). --json writes the full
findings report (the CI artifact) regardless of outcome.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from .findings import Report
from .format_matrix import check_format_matrix
from .hotloop import check_hot_loop
from .kernel_contracts import check_kernel_contracts

__all__ = ["run_all", "main"]

CHECKERS = {
    "kernel-contracts": check_kernel_contracts,
    "hot-loop": check_hot_loop,
    "format-matrix": check_format_matrix,
}


def run_all(names: Optional[Sequence[str]] = None) -> Report:
    """Run the named checkers (all by default) into one Report."""
    rep = Report()
    for name in (names or CHECKERS):
        CHECKERS[name](report=rep)
    return rep


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis: Pallas launch contracts, serving "
                    "hot-loop jaxprs, and the AIO data-format matrix.")
    p.add_argument("--check", action="append", choices=sorted(CHECKERS),
                   help="run only this checker (repeatable; default: all)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 if any error-severity finding is raised")
    p.add_argument("--json", metavar="PATH",
                   help="also write the findings report as JSON")
    args = p.parse_args(argv)

    rep = run_all(args.check)
    print(rep.render())
    if args.json:
        with open(args.json, "w") as f:
            f.write(rep.to_json() + "\n")
        print(f"wrote {args.json}")
    if args.strict and not rep.ok():
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
