"""`python -m repro.analysis` — run every static checker, render a report.

Exit status: 0 always, unless --strict is given (any error-severity finding
exits 1 — the CI gate) or --baseline is given (any per-code findings-count
drift from the committed baseline exits 1 — the warnings ratchet). --json
writes the full findings report (the CI artifact) regardless of outcome.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from typing import Optional, Sequence

from . import format_matrix, hotloop, kernel_body, kernel_contracts
from .findings import Report
from .format_matrix import check_format_matrix
from .hotloop import check_hot_loop
from .kernel_body import check_kernel_bodies
from .kernel_contracts import check_kernel_contracts

__all__ = ["run_all", "main", "counts_by_code", "compare_baseline"]

CHECKERS = {
    "kernel-contracts": check_kernel_contracts,
    "kernel-body": check_kernel_bodies,
    "hot-loop": check_hot_loop,
    "format-matrix": check_format_matrix,
}

# checker-module CODES tables, in family order, for --list-codes
CODE_TABLES = (
    ("kernel-contracts", kernel_contracts.CODES),
    ("kernel-body", kernel_body.CODES),
    ("hot-loop", hotloop.CODES),
    ("format-matrix", format_matrix.CODES),
)


def run_all(names: Optional[Sequence[str]] = None) -> Report:
    """Run the named checkers (all by default) into one Report."""
    rep = Report()
    for name in (names or CHECKERS):
        CHECKERS[name](report=rep)
    return rep


def list_codes() -> str:
    lines = []
    for checker, table in CODE_TABLES:
        for code, (severity, desc) in table.items():
            lines.append(f"{code}  {severity:7s} {checker:17s} {desc}")
    return "\n".join(lines)


def counts_by_code(rep: Report) -> dict:
    return dict(sorted(Counter(f.code for f in rep.findings).items()))


def compare_baseline(rep: Report, baseline: dict) -> list:
    """Findings-count ratchet: ANY per-code drift from the committed
    baseline is a failure — new findings obviously, but also fixed ones
    (fixing a warning requires regenerating the baseline, so the committed
    expectation never goes stale)."""
    expected = dict(baseline.get("counts_by_code", {}))
    actual = counts_by_code(rep)
    problems = []
    for code in sorted(set(expected) | set(actual)):
        want, got = expected.get(code, 0), actual.get(code, 0)
        if got > want:
            problems.append(
                f"{code}: {got} finding(s), baseline allows {want} — fix "
                f"the new finding(s) or regenerate with --write-baseline")
        elif got < want:
            problems.append(
                f"{code}: {got} finding(s), baseline expects {want} — a "
                f"finding was fixed; ratchet down by regenerating with "
                f"--write-baseline")
    return problems


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis: Pallas launch contracts + kernel-body "
                    "abstract interpretation, serving hot-loop jaxprs, and "
                    "the AIO data-format matrix.")
    p.add_argument("--check", action="append", choices=sorted(CHECKERS),
                   help="run only this checker (repeatable; default: all)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 if any error-severity finding is raised")
    p.add_argument("--json", metavar="PATH",
                   help="also write the findings report as JSON")
    p.add_argument("--list-codes", action="store_true",
                   help="print every finding code with its severity and "
                        "exit")
    p.add_argument("--baseline", metavar="PATH",
                   help="findings-count ratchet: fail on any per-code "
                        "count drift from this committed baseline JSON")
    p.add_argument("--write-baseline", metavar="PATH",
                   help="write the current per-code findings counts as a "
                        "new baseline JSON and exit 0")
    args = p.parse_args(argv)

    if args.list_codes:
        print(list_codes())
        return 0

    rep = run_all(args.check)
    print(rep.render())
    if args.json:
        with open(args.json, "w") as f:
            f.write(rep.to_json() + "\n")
        print(f"wrote {args.json}")

    rc = 0
    if args.write_baseline:
        payload = {
            "comment": "python -m repro.analysis --write-baseline — "
                       "per-code findings-count ratchet for CI",
            "counts_by_code": counts_by_code(rep),
        }
        with open(args.write_baseline, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.write_baseline}")
    elif args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        problems = compare_baseline(rep, baseline)
        for msg in problems:
            print(f"baseline ratchet: {msg}")
        if problems:
            rc = 1
    if args.strict and not rep.ok():
        rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
