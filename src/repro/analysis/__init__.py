"""Static analysis for the repro stack: four checkers, one report.

  * kernel-contracts — every Pallas impl's declared launch geometry,
    index maps evaluated out-of-trace over a (shape x policy-tile) sweep;
  * kernel-body — each contract's kernel body traced to a jaxpr and run
    through an interval/taint abstract interpreter: in-bounds proofs for
    every ref access (incl. pl.when guard coverage), a grid write-race
    detector over the declared ``revisits=`` reduction dims, and a
    quantized-dataflow audit (unscaled dequant, scale-plane mismatches);
  * hot-loop — the serving engine's step jaxpr audited for host
    callbacks, broken donation aliasing, materialized dequants, and the
    trace-count invariant;
  * format-matrix — the AIO format grid cross-checked against the format
    registry, the policy plane, the MAC-array modes, weight residency,
    and the perf model.

CLI: ``python -m repro.analysis [--strict] [--json PATH] [--check NAME]
[--list-codes] [--baseline PATH] [--write-baseline PATH]``.
"""
from .findings import Finding, Report, SEVERITIES  # noqa: F401
from .format_matrix import (FORMAT_MATRIX, FormatClaim,  # noqa: F401
                            check_format_matrix)
from .hotloop import (audit_donation, audit_step_jaxpr,  # noqa: F401
                      audit_trace_count, check_engine, check_hot_loop)
from .kernel_body import (check_body, check_kernel_bodies,  # noqa: F401
                          stratified_grid_points)
from .kernel_contracts import (check_kernel_contracts,  # noqa: F401
                               check_launch)
from .run import run_all  # noqa: F401
