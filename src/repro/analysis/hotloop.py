"""Hot-loop jaxpr auditor: the serving engine's step program, inspected.

The engine's whole life is one jitted step function; a host callback, a
broken donation, or a materialized dequant inside it taxes EVERY decoded
token. This checker traces the step abstractly (`engine.step_trace` — no
compile, no execution) at each lifetime width and walks the closed jaxpr:

  HL201  host transfer / callback primitive in the step       (error)
  HL202  donated buffer cannot alias any step output          (error)
  HL203  large quantized->f32 upcast (materialized dequant)   (warning)
  HL204  jit trace count != the engine's width invariant      (error)
  HL205  numeric-health guard missing / not a fused reduction (error)
  HL206  KV pool bytes leave the jitted step (swap in hot loop) (error)

HL202 is structural: donation is legal only when some output matches the
donated buffer's (shape, dtype), so a step that drops or reshapes a cache
on its way out silently turns in-place KV updates into full copies.
HL203 is a warning — block-wise dequant inside a pallas kernel converts
tile-sized operands (fine); only cache-scale converts trip the threshold.
HL205 pins the fault-tolerance contract: the engine's per-slot numeric
health (`all(isfinite(logits))`) must live INSIDE the traced step as an
`is_finite` + `reduce_and` fused reduction feeding a (slots,) bool output
— not as a host-side isfinite over fetched logits (an extra transfer every
token) and not via a callback (HL201 would also fire).
HL206 pins the graceful-degradation contract: host-swap of preempted rows'
KV blocks happens at the engine's already-synchronizing scheduler boundary
(`serving.swap`), NEVER inside the step program. Structurally: every step
output is either a donated cache buffer (stays device-resident via
aliasing) or a small host-consumed result (logits, health — rank <= 3).
A slab-ranked output that aliases no donated cache is pool bytes being
gathered out of the hot loop — a device->host copy of whole KV blocks on
every token.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable, Optional

from .findings import Report

__all__ = ["check_hot_loop", "check_engine", "audit_step_jaxpr",
           "audit_donation", "audit_trace_count", "audit_health_guard",
           "audit_swap_hygiene", "iter_eqns", "HOST_PRIMITIVES", "CODES"]

CHECKER = "hot-loop"

CODES = {
    "HL201": ("error", "host transfer / callback primitive in the step"),
    "HL202": ("error", "donated buffer cannot alias any step output"),
    "HL203": ("warning", "large quantized->f32 upcast (materialized "
                         "dequant)"),
    "HL204": ("error", "jit trace count != the engine's width invariant"),
    "HL205": ("error", "numeric-health guard missing or not a fused in-step "
                       "reduction"),
    "HL206": ("error", "KV pool bytes leave the jitted step — swap/transfer "
                       "of cache blocks belongs at the scheduler boundary"),
}

HOST_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "device_put",
})

# convert_element_type to f32 from a quantized dtype is expected at BLOCK
# granularity (in-kernel dequant); anything this big is a materialized
# cache/weight dequant in HBM.
UPCAST_ELEMENT_THRESHOLD = 1 << 16

_QUANT_DTYPES = ("int8", "int4", "uint8", "uint4")


def iter_eqns(jaxpr) -> Iterable:
    """Every eqn in a (closed) jaxpr, recursing into sub-jaxprs (scan/cond
    bodies, pallas_call kernels, custom_jvp wrappers...)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for item in (v if isinstance(v, (tuple, list)) else (v,)):
                inner = getattr(item, "jaxpr", item)
                if hasattr(inner, "eqns"):
                    yield from iter_eqns(inner)


def audit_step_jaxpr(closed, where: str, report: Optional[Report] = None, *,
                     quantized: bool = True) -> Report:
    """HL201 + HL203 over one step trace."""
    rep = report if report is not None else Report()
    seen_hosts = set()
    for eqn in iter_eqns(closed):
        name = eqn.primitive.name
        if name in HOST_PRIMITIVES:
            if name not in seen_hosts:
                seen_hosts.add(name)
                rep.add("HL201", "error", CHECKER, where,
                        f"host transfer/callback primitive {name!r} inside "
                        f"the jitted step — a device->host sync every token")
        elif quantized and name == "convert_element_type":
            aval = eqn.invars[0].aval
            out = eqn.params.get("new_dtype")
            if (str(aval.dtype) in _QUANT_DTYPES
                    and str(out) in ("float32", "float64")
                    and aval.size >= UPCAST_ELEMENT_THRESHOLD):
                rep.add("HL203", "warning", CHECKER, where,
                        f"{aval.dtype}->{out} upcast of a "
                        f"{tuple(aval.shape)} array ({aval.size} elements): "
                        f"looks like a materialized dequant in the "
                        f"quantized path")
    return rep


def audit_donation(donated_avals, out_avals, where: str,
                   report: Optional[Report] = None) -> Report:
    """HL202: every donated (shape, dtype) must be coverable by an output."""
    rep = report if report is not None else Report()
    need = Counter((tuple(s), str(d)) for s, d in donated_avals)
    have = Counter((tuple(a.shape), str(a.dtype)) for a in out_avals)
    missing = need - have
    for (shape, dtype), n in sorted(missing.items()):
        rep.add("HL202", "error", CHECKER, where,
                f"{n} donated buffer(s) of shape {shape} dtype {dtype} have "
                f"no matching step output to alias — donation silently "
                f"degrades to a copy")
    return rep


def audit_trace_count(actual: int, expected: int, where: str,
                      report: Optional[Report] = None) -> Report:
    """HL204: the jit cache must hold exactly the lifetime widths."""
    rep = report if report is not None else Report()
    if actual != expected:
        rep.add("HL204", "error", CHECKER, where,
                f"step jit cache holds {actual} trace(s), expected "
                f"{expected} (one per lifetime width) — a shape leak is "
                f"retracing the hot loop")
    return rep


def audit_health_guard(closed, where: str,
                       report: Optional[Report] = None) -> Report:
    """HL205: the step must carry a fused per-slot numeric-health output.

    Two structural facts are required of the step trace: (a) some output is
    a rank-1 bool vector (the per-slot health the host consumes at its
    already-syncing points), and (b) the trace contains the `is_finite` +
    `reduce_and` primitive pair — the guard computed as a fused reduction
    over the logits still on device, not a second pass or a host check."""
    rep = report if report is not None else Report()
    jaxpr = getattr(closed, "jaxpr", closed)
    bool_outs = [v for v in jaxpr.outvars
                 if str(v.aval.dtype) == "bool" and len(v.aval.shape) == 1]
    if not bool_outs:
        rep.add("HL205", "error", CHECKER, where,
                "step program has no (slots,) bool output — the numeric-"
                "health guard is not part of the traced step, so poisoned "
                "logits can only be caught by an extra host-side pass")
        return rep
    prims = {eqn.primitive.name for eqn in iter_eqns(closed)}
    if "is_finite" not in prims or "reduce_and" not in prims:
        rep.add("HL205", "error", CHECKER, where,
                f"health output present but the is_finite + reduce_and "
                f"fused-reduction pair is missing from the step jaxpr "
                f"(have: is_finite={'is_finite' in prims}, "
                f"reduce_and={'reduce_and' in prims}) — the guard is not "
                f"computed in-step over on-device logits")
    return rep


def audit_swap_hygiene(closed, donated_avals, where: str,
                       report: Optional[Report] = None) -> Report:
    """HL206: no KV pool bytes may leave the step program.

    Host-swap of preempted rows gathers whole physical blocks device->host;
    doing that INSIDE the jitted step (returning gathered slabs for the
    host to fetch) would ship block-sized buffers across the boundary on
    every token. The structural pin: every step output either aliases a
    donated cache buffer (same shape+dtype — it stays device-resident) or
    is a small host-consumed result (logits/health, rank <= 3). An output
    of slab rank (>= 4) with no donated counterpart is pool bytes escaping
    the hot loop."""
    rep = report if report is not None else Report()
    jaxpr = getattr(closed, "jaxpr", closed)
    have = Counter((tuple(s), str(d)) for s, d in donated_avals)
    for v in jaxpr.outvars:
        key = (tuple(v.aval.shape), str(v.aval.dtype))
        if have.get(key, 0) > 0:
            have[key] -= 1
            continue
        if len(v.aval.shape) <= 3:
            continue
        rep.add("HL206", "error", CHECKER, where,
                f"step output of shape {tuple(v.aval.shape)} dtype "
                f"{v.aval.dtype} aliases no donated cache buffer — KV pool "
                f"bytes are being gathered out of the jitted step; swap "
                f"transfers must run at the scheduler boundary "
                f"(serving.swap), not in the hot loop")
    return rep


def check_engine(engine, report: Optional[Report] = None, *,
                 warmup: bool = True, label: str = "") -> Report:
    """Run every hot-loop audit against one live ServingEngine."""
    rep = report if report is not None else Report()
    name = label or f"engine[{engine.cfg.name}]"
    quantized = bool(engine.cfg.kv_quant) or \
        engine.weight_route().startswith("resident")
    for w in engine.step_widths():
        where = f"{name} step(width={w})"
        closed = engine.step_trace(w)
        audit_step_jaxpr(closed, where, rep, quantized=quantized)
        audit_donation(engine.donated_avals(),
                       [v.aval for v in closed.jaxpr.outvars], where, rep)
        audit_health_guard(closed, where, rep)
        audit_swap_hygiene(closed, engine.donated_avals(), where, rep)
    if warmup:
        engine.warmup()
        audit_trace_count(engine.step_trace_count(),
                          len(engine.step_widths()), name, rep)
    return rep


def _default_engines():
    """The representative serving configs the default audit covers: the
    pallas-routed smoke engine with a quantized KV cache and int8-resident
    weights (the quantized hot path), the plain bf16 engine, and the paged
    block-pool engine with host-swap armed (the HL206 subject)."""
    import dataclasses

    import jax

    from ..api import ExecutionPolicy
    from ..configs import get_smoke
    from ..models import init_params, quantize_params
    from ..serving import ServingEngine

    pol = ExecutionPolicy(backend="pallas", format="int8")
    cfg = get_smoke("qwen2_1p5b")
    params = init_params(jax.random.key(0), cfg)

    qcfg = dataclasses.replace(cfg, kv_quant=True)
    qparams = quantize_params(init_params(jax.random.key(0), qcfg), "int8")
    yield ("quantized-pallas",
           ServingEngine(qcfg, qparams, slots=2, max_len=64, policy=pol,
                         prefill_chunk=8))
    yield ("dense-pallas",
           ServingEngine(cfg, params, slots=2, max_len=64, policy=pol,
                         prefill_chunk=8))
    # the paged pool with swap armed: the engine whose scheduler can now
    # spill live KV blocks to the host — HL206 pins that no such transfer
    # (and no block gather feeding one) sits inside the step program
    yield ("paged-swap",
           ServingEngine(cfg, params, slots=2, max_len=64, prefill_chunk=8,
                         paged=True, block_size=16, pool_blocks=12,
                         swap_watermark=0.75))


def check_hot_loop(report: Optional[Report] = None, *,
                   warmup: bool = True) -> Report:
    """Audit the default engine set (builds tiny smoke engines on CPU)."""
    rep = report if report is not None else Report()
    for label, engine in _default_engines():
        check_engine(engine, rep, warmup=warmup, label=label)
    return rep
