"""Finding / Report primitives shared by the `repro.analysis` checkers.

A Finding is one detected violation: a stable code (KCxxx kernel-contract,
HLxxx hot-loop, FMxxx format-matrix), a severity, the checker that raised
it, a `where` locator, and a human message. A Report is an ordered list of
findings with severity rollups, a JSON serialization (the CI artifact), and
a terminal rendering. `--strict` gates on errors only: warnings and infos
record known, documented gaps without failing the build.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, List

__all__ = ["Finding", "Report", "SEVERITIES"]

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    severity: str
    checker: str
    where: str
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {SEVERITIES}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"[{self.code}] {self.severity.upper():7s} "
                f"{self.checker} :: {self.where}\n    {self.message}")


class Report:
    """An ordered collection of findings from one or more checkers."""

    def __init__(self, findings: Iterable[Finding] = ()):
        self.findings: List[Finding] = list(findings)

    # ------------------------------------------------------------ building
    def add(self, code: str, severity: str, checker: str, where: str,
            message: str) -> Finding:
        f = Finding(code, severity, checker, where, message)
        self.findings.append(f)
        return f

    def extend(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        return self

    # ------------------------------------------------------------- queries
    def by_severity(self, severity: str) -> List[Finding]:
        return [f for f in self.findings if f.severity == severity]

    @property
    def errors(self) -> List[Finding]:
        return self.by_severity("error")

    @property
    def warnings(self) -> List[Finding]:
        return self.by_severity("warning")

    @property
    def infos(self) -> List[Finding]:
        return self.by_severity("info")

    def by_code(self, code: str) -> List[Finding]:
        return [f for f in self.findings if f.code == code]

    def ok(self) -> bool:
        """True when nothing error-severity was found (the --strict gate)."""
        return not self.errors

    # ----------------------------------------------------------- rendering
    def counts(self) -> dict:
        return {s: len(self.by_severity(s)) for s in SEVERITIES}

    def to_json(self) -> str:
        return json.dumps({
            "counts": self.counts(),
            "findings": [f.to_dict() for f in self.findings],
        }, indent=2)

    def render(self) -> str:
        lines = [f.render() for f in self.findings]
        c = self.counts()
        lines.append(f"{len(self.findings)} finding(s): "
                     f"{c['error']} error, {c['warning']} warning, "
                     f"{c['info']} info")
        return "\n".join(lines)
