"""Kernel-contract checker: static lint of every Pallas launch geometry.

Every pallas impl in the KernelRegistry declares a LaunchContract (see
`repro.api.registry`): grid, BlockSpec geometry, the REAL index-map
functions, scalar-prefetch operands and VMEM footprint, built in pure
Python without tracing a kernel. This checker sweeps each contract over
its representative cases crossed with an ExecutionPolicy tile sweep
(`policy_sweep`) and evaluates the index maps at EVERY grid point:

  KC100  pallas impl with no declared contract          (warning)
  KC101  index-map arity / rank mismatch                (error)
  KC102  block index out of bounds at some grid point   (error)
  KC103  non-dividing block shape without masked_tail   (error)
  KC104  resident blocks + scratch exceed VMEM budget   (error)
  KC105  contract builder raised                        (error)

KC102 is the load-bearing one: the decode/prefill clamp maps
(`_block_bounds`, `_kv_bounds`) are hand-written index arithmetic whose
off-by-ones are out-of-bounds DMAs on hardware; evaluating them out-of-
trace over concrete (pos, lengths) vectors proves the clamp for the whole
grid before any kernel runs.
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..api.policy import ExecutionPolicy, policy_sweep
from ..api.registry import KernelRegistry, LaunchContract
from ..api.registry import registry as default_registry
from .findings import Report

__all__ = ["check_kernel_contracts", "check_launch", "CODES"]

CHECKER = "kernel-contracts"

CODES = {
    "KC100": ("warning", "pallas impl with no declared launch contract"),
    "KC101": ("error", "index-map arity / rank mismatch"),
    "KC102": ("error", "block index out of bounds at some grid point"),
    "KC103": ("error", "non-dividing block shape without masked_tail"),
    "KC104": ("error", "resident blocks + scratch exceed the VMEM budget"),
    "KC105": ("error", "contract builder raised (warning when the grid "
                       "sweep is stratified-sampled)"),
}

# Grid sweeps beyond this are stratified-sampled (a contract case should be
# small — the geometry bugs this hunts are index arithmetic, not
# scale-dependent); the sample always keeps the first/last block along
# every grid dim, where the clamp off-by-ones live.
MAX_GRID_POINTS = 65536


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def check_launch(lc: LaunchContract, where: str,
                 report: Optional[Report] = None) -> Report:
    """Lint one concrete LaunchContract (all KC1xx checks except KC100)."""
    rep = report if report is not None else Report()

    if len(lc.scalars) != lc.num_scalar_prefetch:
        rep.add("KC101", "error", CHECKER, where,
                f"{len(lc.scalars)} scalar-prefetch operand(s) provided but "
                f"num_scalar_prefetch={lc.num_scalar_prefetch}")
        return rep

    # ---- shape-level checks (KC101 rank, KC103 tails, KC104 VMEM)
    resident = lc.scratch_bytes
    for b in lc.blocks:
        if len(b.array_shape) != len(b.block_shape):
            rep.add("KC101", "error", CHECKER, where,
                    f"block {b.name!r}: array rank {len(b.array_shape)} != "
                    f"block rank {len(b.block_shape)}")
            return rep
        for d, (dim, blk) in enumerate(zip(b.array_shape, b.block_shape)):
            if blk < 1 or (dim % blk and not b.masked_tail):
                rep.add("KC103", "error", CHECKER, where,
                        f"block {b.name!r} dim {d}: block length {blk} does "
                        f"not divide array length {dim} and the kernel does "
                        f"not declare a masked tail")
        size = b.dtype_bytes
        for blk in b.block_shape:
            size *= blk
        resident += 2 * size           # double-buffered pipeline stage
    if resident > lc.vmem_budget:
        rep.add("KC104", "error", CHECKER, where,
                f"resident footprint {resident} B (double-buffered blocks + "
                f"scratch) exceeds the {lc.vmem_budget} B VMEM budget")

    # ---- index-map sweep over every grid point (KC101 arity, KC102 bounds)
    from .kernel_body import stratified_grid_points
    total = 1
    for g in lc.grid:
        total *= g
    points, truncated = stratified_grid_points(lc.grid, MAX_GRID_POINTS)
    if truncated:
        rep.add("KC105", "warning", CHECKER, where,
                f"grid has {total} points; sweep stratified-sampled to "
                f"<= {MAX_GRID_POINTS} (first/last block kept along every "
                f"dim) — shrink the contract case for a full sweep")

    # dedup keys are (block name, finding kind) — one finding per distinct
    # defect per block, without one kind suppressing another
    bad = set()
    for point in points:
        evaluated = {}                 # id(index_map) -> block indices
        for b in lc.blocks:
            key = id(b.index_map)
            if key not in evaluated:
                try:
                    evaluated[key] = tuple(
                        int(v) for v in b.index_map(*point, *lc.scalars))
                except TypeError as e:
                    evaluated[key] = None
                    if (b.name, "KC101-arity") not in bad:
                        bad.add((b.name, "KC101-arity"))
                        rep.add("KC101", "error", CHECKER, where,
                                f"block {b.name!r}: index map rejected "
                                f"{len(point)} grid + {len(lc.scalars)} "
                                f"prefetch argument(s): {e}")
            idx = evaluated[key]
            if idx is None:
                continue
            if len(idx) != len(b.block_shape):
                if (b.name, "KC101-rank") not in bad:
                    bad.add((b.name, "KC101-rank"))
                    rep.add("KC101", "error", CHECKER, where,
                            f"block {b.name!r}: index map returned "
                            f"{len(idx)} indices for a "
                            f"rank-{len(b.block_shape)} block")
                continue
            if (b.name, "KC102") in bad:
                continue
            for d, (i, dim, blk) in enumerate(
                    zip(idx, b.array_shape, b.block_shape)):
                nblocks = _ceil_div(dim, blk)
                if not 0 <= i < nblocks:
                    bad.add((b.name, "KC102"))
                    rep.add("KC102", "error", CHECKER, where,
                            f"block {b.name!r} dim {d}: index map returned "
                            f"block {i} at grid point {point} but only "
                            f"blocks [0, {nblocks}) exist "
                            f"(array {dim}, block {blk})")
                    break
    return rep


def check_kernel_contracts(reg: Optional[KernelRegistry] = None,
                           sweep_values: Optional[dict] = None,
                           report: Optional[Report] = None) -> Report:
    """Sweep every registered pallas impl's contract; KC100 the missing ones."""
    reg = reg if reg is not None else default_registry
    rep = report if report is not None else Report()
    for op, impl in reg.pallas_impls():
        fn = reg.contract(op, impl)
        where = f"{op}/{impl}"
        if fn is None:
            rep.add("KC100", "warning", CHECKER, where,
                    "pallas implementation declares no launch contract "
                    "(register one with api.registry.register_contract)")
            continue
        policies: Sequence[ExecutionPolicy] = policy_sweep(
            fn.sweep_fields, values=sweep_values)
        for ci, case in enumerate(fn.cases):
            for policy in policies:
                tiles = {f: getattr(policy, f) for f in fn.sweep_fields}
                at = f"{where} case[{ci}] {tiles}" if tiles \
                    else f"{where} case[{ci}]"
                try:
                    lc = fn(case, policy)
                except Exception as e:  # noqa: BLE001 — surfaced as finding
                    rep.add("KC105", "error", CHECKER, at,
                            f"contract builder raised {type(e).__name__}: {e}")
                    continue
                check_launch(lc, at, rep)
    return rep
