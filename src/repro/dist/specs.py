"""NamedSharding spec builders for params / optimizer state / batches / caches.

These are the layouts the trainer `device_put`s onto and the dry-run pins as
`in_shardings`/`out_shardings`. Placement rules (Megatron-style TP + plain DP):

  * params replicate over the DP axes; over "model" they shard column-parallel
    (q/k/v/gate/up/fc1: last axis), row-parallel (o/down/fc2: second-to-last),
    vocab-parallel (embedding table), and expert-parallel (stacked MoE expert
    weights shard their expert axis — matching `moe_apply`'s constraints).
  * batches shard their leading axis over the composed DP axes.
  * KV/SSM caches shard the batch axis (axis 1 behind the layer-stack axis).

Every rule is divisibility-gated: a leaf that doesn't divide evenly is
replicated, so any mesh (including the single-device test mesh) is valid.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, tree_map_with_path

from .sharding import DP_AXES

__all__ = ["param_specs", "opt_state_specs", "batch_specs", "cache_specs"]

# Leaf-name classes for the Megatron placement of 2D weights.
_COL_PARALLEL = {"q", "k", "v", "gate", "up", "fc1", "lm_head", "router"}
_ROW_PARALLEL = {"o", "down", "fc2"}
_EXPERT_STACKED = {"gate", "up", "down"}          # raw arrays under a "moe" dict


def _dp(mesh):
    axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
    size = math.prod(dict(mesh.shape)[a] for a in axes) if axes else 1
    return axes, size


def _path_names(path):
    names = []
    for key in path:
        if isinstance(key, DictKey):
            names.append(str(key.key))
        elif isinstance(key, GetAttrKey):
            names.append(key.name)
    return names


def param_specs(tree: Any, mesh) -> Any:
    """Param layout: DP-replicated, model-axis TP/EP where divisible."""
    msize = dict(mesh.shape).get("model", 1)

    def spec(path, leaf):
        names = _path_names(path)
        ndim = getattr(leaf, "ndim", 0)
        entries = [None] * ndim
        if msize > 1 and ndim >= 2 and (not names or names[-1] != "b"):
            shape = leaf.shape
            if ("moe" in names and names[-1] in _EXPERT_STACKED
                    and ndim >= 3 and shape[-3] % msize == 0):
                entries[-3] = "model"             # expert axis of (E, din, dout)
            elif any(n in _COL_PARALLEL for n in names) and shape[-1] % msize == 0:
                entries[-1] = "model"
            elif any(n in _ROW_PARALLEL for n in names) and shape[-2] % msize == 0:
                entries[-2] = "model"
            elif ("embed" in names or "table" in names) and shape[-2] % msize == 0:
                entries[-2] = "model"             # vocab-parallel embedding
        return NamedSharding(mesh, P(*entries))

    return tree_map_with_path(spec, tree)


def opt_state_specs(opt: Any, mesh) -> Any:
    """Optimizer-state layout: moments/master mirror the param layout."""
    replicated = NamedSharding(mesh, P())
    fields = getattr(opt, "_fields", ())
    if {"mu", "nu", "master", "step"} <= set(fields):
        return type(opt)(step=replicated,
                         mu=param_specs(opt.mu, mesh),
                         nu=param_specs(opt.nu, mesh),
                         master=param_specs(opt.master, mesh))
    return jax.tree.map(lambda _: replicated, opt)


def batch_specs(tree: Any, mesh) -> Any:
    """Batch layout: leading axis over the composed DP axes where divisible."""
    dp_axes, dp_size = _dp(mesh)

    def spec(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim >= 1 and dp_size > 1 and leaf.shape[0] % dp_size == 0:
            return NamedSharding(mesh, P(dp_axes, *([None] * (ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(spec, tree)


def cache_specs(tree: Any, mesh) -> Any:
    """Decode-cache layout: batch axis (axis 1, behind the layer stack) over
    the DP axes; per-layer scalars (pos) replicated."""
    dp_axes, dp_size = _dp(mesh)

    def spec(leaf):
        ndim = getattr(leaf, "ndim", 0)
        entries = [None] * ndim
        if ndim >= 3 and dp_size > 1 and leaf.shape[1] % dp_size == 0:
            entries[1] = dp_axes
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(spec, tree)
