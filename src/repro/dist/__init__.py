"""Distribution layer: mesh context, sharding constraints, layout specs.

`dist.sharding` owns the ambient-mesh helpers model code calls inline
(`constrain`, `ctx_dp_axes`); this package root re-exports the spec builders
the trainer / dry-run / server use to place whole pytrees.
"""
from .sharding import constrain, ctx_dp_axes, ctx_mesh, set_mesh  # noqa: F401
from .specs import (batch_specs, cache_specs, opt_state_specs,  # noqa: F401
                    param_specs)
