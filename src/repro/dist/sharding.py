"""Mesh context + sharding-constraint helpers.

Model code never imports jax.sharding directly: it calls `constrain(x, *spec)`
with logical axis names ("model", the DP tuple from `ctx_dp_axes()`) and this
module translates against whatever mesh is ambient — a no-op when none is.

The helpers are version-tolerant: newer jax exposes the ambient mesh through
`jax.sharding.get_abstract_mesh()` / `jax.set_mesh`, older releases through
the `with mesh:` resource env. `set_mesh` / `ctx_mesh` pick whichever exists
so launchers and the dry-run behave identically on both.
"""
from __future__ import annotations

import contextlib
from typing import Tuple

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["DP_AXES", "ctx_mesh", "ctx_dp_axes", "constrain", "set_mesh"]

# Axes that compose into the batch (data-parallel) dimension, in mesh order.
DP_AXES = ("pod", "data")


def ctx_mesh():
    """The ambient mesh (abstract or physical), or None outside any context."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            am = get_abstract()
            if am is not None and not am.empty:
                return am
        except Exception:  # noqa: BLE001
            pass
    try:
        from jax.interpreters import pxla
        pm = pxla.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:  # noqa: BLE001
        pass
    return None


def ctx_dp_axes() -> Tuple[str, ...]:
    """Data-parallel axes of the ambient mesh ( () without a mesh )."""
    m = ctx_mesh()
    if m is None:
        return ()
    return tuple(a for a in m.axis_names if a in DP_AXES)


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the ambient mesh; identity without one.

    Spec entries are axis names, tuples of axis names, or None; entries naming
    axes the ambient mesh lacks are dropped (so "model" hints are safe on a
    data-only mesh).
    """
    m = ctx_mesh()
    if m is None:
        return x
    names = set(m.axis_names)

    def _keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    entries = tuple(_keep(e) for e in spec)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))


@contextlib.contextmanager
def set_mesh(mesh):
    """Bind `mesh` as the ambient mesh (jax.set_mesh where available, the
    classic `with mesh:` resource env otherwise)."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
