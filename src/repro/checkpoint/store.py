"""Sharded, fault-tolerant checkpointing.

Layout (one directory per step):
    ckpt_dir/step_000120/
        manifest.json      step, flat-key index, mesh fingerprint, extra state
        host0000.npz       this host's shard of every leaf (addressable slices)

Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts the
latest-complete pointer. Restore re-shards onto whatever mesh the restarting
job brings — the elastic-restart path (runtime/elastic.py) relies on this:
leaves are saved *unsharded per host* (host-local addressable shards merged),
and `restore` device_puts them against the new shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer", "gc_old"]

_MANIFEST = "manifest.json"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir, step: int, tree, extra: Optional[Dict] = None,
         host_id: int = 0) -> Path:
    """Write one checkpoint step atomically. Returns the final path."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}"
    tmp.mkdir(parents=True, exist_ok=True)

    flat = _flatten(tree)
    arrays = {}
    true_dtypes = {}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        true_dtypes[key] = str(arr.dtype)
        if arr.dtype.kind not in "fiub":      # ml_dtypes (bf16/fp8): byte view
            arr = np.ascontiguousarray(arr).view(np.uint8)
        arrays[key] = arr
    np.savez(tmp / f"host{host_id:04d}.npz", **arrays)
    manifest = {
        "step": step,
        "keys": [k for k, _ in flat],
        "dtypes": true_dtypes,
        "shapes": {k: list(np.asarray(jax.device_get(v)).shape)
                   for k, v in flat},
        "extra": extra or {},
        "time": time.time(),
    }
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / _MANIFEST).exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir, tree_like, step: Optional[int] = None,
            shardings=None, host_id: int = 0):
    """Restore into the structure of `tree_like` (shape/dtype template).

    `shardings`: optional pytree of NamedShardings — leaves are device_put
    against them, which is how a checkpoint taken on one mesh restarts on
    another (elastic re-mesh).
    Returns (tree, extra_state, step).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    data = np.load(d / f"host{host_id:04d}.npz")

    flat_template = _flatten(tree_like)
    flat_shardings = _flatten(shardings)[0:] if shardings is not None else None
    shard_map = dict(_flatten(shardings)) if shardings is not None else {}

    leaves = []
    for key, leaf in flat_template:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        want_shape = tuple(leaf.shape)
        if arr.dtype == np.uint8 and manifest["dtypes"].get(key) not in (
                "uint8",):                    # byte-view of an ml_dtype
            import ml_dtypes
            true = np.dtype(getattr(ml_dtypes, manifest["dtypes"][key], None)
                            or manifest["dtypes"][key])
            arr = arr.view(true)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"restore template {want_shape}")
        arr = arr.astype(leaf.dtype)
        if key in shard_map:
            arr = jax.device_put(arr, shard_map[key])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), leaves)
    return tree, manifest.get("extra", {}), step


def gc_old(ckpt_dir, keep: int = 3):
    """Delete all but the newest `keep` complete checkpoints."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted(
        int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
        if d.name.startswith("step_") and (d / _MANIFEST).exists())
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; `wait()` joins in-flight
    writes (call before exit / before deleting the source arrays)."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._lock = threading.Lock()
        self._inflight: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        # snapshot to host memory synchronously (cheap vs the disk write)
        flat = _flatten(tree)
        snap = {k: np.asarray(jax.device_get(v)) for k, v in flat}
        tdef = jax.tree_util.tree_structure(tree)

        def work():
            try:
                tree_h = jax.tree_util.tree_unflatten(
                    tdef, [snap[k] for k, _ in flat])
                save(self.ckpt_dir, step, tree_h, extra)
                gc_old(self.ckpt_dir, self.keep)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self.wait()
        with self._lock:
            self._inflight = threading.Thread(target=work, daemon=True)
            self._inflight.start()

    def wait(self):
        with self._lock:
            t = self._inflight
        if t is not None:
            t.join()
        if self.last_error is not None:
            raise self.last_error
