"""Fault-tolerant training runtime.

Responsibilities:
  * the jit'd train loop (sharded params/opt/batch via dist.sharding),
  * periodic async checkpoints with pipeline state (checkpoint/restart),
  * straggler mitigation: a per-step deadline watchdog — steps that exceed
    `straggler_factor` x the trailing-median step time are logged and counted;
    after `max_straggler_strikes` the runtime requests an elastic restart
    (on real fleets this maps to the pod-replacement path; here it is
    surfaced as a StragglerAbort for the harness/test to act on),
  * elastic re-mesh: `elastic_restart` reshapes to a new mesh and restores
    the latest checkpoint onto it (the dry-run proves both mesh shapes
    compile; this provides the runtime motion between them).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..checkpoint.store import AsyncCheckpointer, latest_step, restore
from ..data.pipeline import PipelineState
from ..dist import opt_state_specs, param_specs
from ..launch.steps import make_train_step
from ..models import transformer as T
from ..optim import adamw_init

__all__ = ["TrainerConfig", "Trainer", "StragglerAbort"]


class StragglerAbort(RuntimeError):
    """Raised when repeated straggling steps demand a re-mesh/restart."""


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_ckpts: int = 3
    base_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    straggler_factor: float = 3.0
    max_straggler_strikes: int = 5
    min_timing_samples: int = 8


class Trainer:
    def __init__(self, cfg: T.ModelConfig, tcfg: TrainerConfig, mesh,
                 params=None, key=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        # the data iterator owns this object and advances it; the trainer
        # only snapshots it into checkpoints (attach via attach_pipeline)
        self.pipeline_state = PipelineState()
        self.step_times: list = []
        self.straggler_strikes = 0
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)

        if params is None:
            params = T.init_params(
                key if key is not None else jax.random.key(0), cfg)
        p_specs = param_specs(jax.eval_shape(lambda: params), mesh)
        self.params = jax.device_put(params, p_specs)
        opt = adamw_init(self.params)
        o_specs = opt_state_specs(jax.eval_shape(lambda: opt), mesh)
        self.opt_state = jax.device_put(opt, o_specs)

        step_fn = make_train_step(cfg, base_lr=tcfg.base_lr,
                                  warmup=tcfg.warmup, total=tcfg.total_steps)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.metrics_log: list = []

    def attach_pipeline(self, state: PipelineState):
        """Share the data iterator's state so checkpoints capture it."""
        self.pipeline_state = state

    # ------------------------------------------------------------- restore
    def maybe_restore(self) -> Optional[int]:
        """Resume from the newest checkpoint if one exists."""
        step = latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return None
        tree_like = {"params": jax.eval_shape(lambda: self.params),
                     "opt": jax.eval_shape(lambda: self.opt_state)}
        shardings = {"params": param_specs(tree_like["params"], self.mesh),
                     "opt": opt_state_specs(tree_like["opt"], self.mesh)}
        tree, extra, step = restore(self.tcfg.ckpt_dir, tree_like,
                                    shardings=shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.pipeline_state = PipelineState.from_dict(
            extra.get("pipeline", {"step": 0}))
        return step

    # ------------------------------------------------------------- loop
    def run(self, data_iter, n_steps: int,
            on_step: Optional[Callable[[int, Dict], None]] = None) -> Dict:
        start = int(self.opt_state.step)
        for i in range(n_steps):
            batch = next(data_iter)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            self.params, self.opt_state, metrics = self._step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            self._watchdog(dt)
            step = start + i + 1
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step_time_s"] = dt
            self.metrics_log.append(rec)
            if on_step:
                on_step(step, rec)
            if step % self.tcfg.ckpt_every == 0:
                self.checkpoint(step)
        self.ckpt.wait()
        return self.metrics_log[-1] if self.metrics_log else {}

    def checkpoint(self, step: int):
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                       extra={"pipeline": self.pipeline_state.to_dict(),
                              "mesh": list(self.mesh.shape.values())})

    # ------------------------------------------------------------- watchdog
    def _watchdog(self, dt: float):
        self.step_times.append(dt)
        n = self.tcfg.min_timing_samples
        if len(self.step_times) <= n:
            return
        med = statistics.median(self.step_times[-50:-1])
        if dt > self.tcfg.straggler_factor * med:
            self.straggler_strikes += 1
            if self.straggler_strikes >= self.tcfg.max_straggler_strikes:
                raise StragglerAbort(
                    f"{self.straggler_strikes} steps exceeded "
                    f"{self.tcfg.straggler_factor}x median ({med:.3f}s); "
                    f"requesting re-mesh")
        else:
            self.straggler_strikes = max(0, self.straggler_strikes - 1)


def elastic_restart(cfg: T.ModelConfig, tcfg: TrainerConfig, new_mesh,
                    key=None) -> Trainer:
    """Rebuild a Trainer on a different mesh and restore the newest
    checkpoint onto it (leaves are saved unsharded per host, so resharding
    is just a device_put against the new specs)."""
    tr = Trainer(cfg, tcfg, new_mesh, key=key)
    tr.maybe_restore()
    return tr
