from .trainer import StragglerAbort, Trainer, TrainerConfig, elastic_restart  # noqa: F401
