"""Composable model zoo covering the 10 assigned architectures."""
from .transformer import (ModelConfig, active_param_count, decode_step,  # noqa: F401
                          forward, init_caches, init_params, loss_fn,
                          param_count, quantize_params, reset_slots,
                          resident_format)
from .layers import QuantPolicy  # noqa: F401
