"""Manual tensor+sequence-parallel dense block (shard_map, explicit
collectives) — §Perf iteration 3.

GSPMD's Auto partitioner keeps f32 activation all-gathers around every
column-parallel linear even under sharding hints (measured: ~7 full-
activation collectives per layer on internlm2-20b prefill). This block takes
manual control: the residual stream stays SEQUENCE-SHARDED over "model"
(sequence parallelism) and each sub-block does exactly

    all-gather(seq, bf16) -> column-parallel qkv / gate-up
    -> local attention / pointwise -> row-parallel o / down
    -> psum-scatter(seq, bf16)

i.e. 2 all-gathers + 2 reduce-scatters of the bf16 activation per layer —
the Megatron-SP optimum. GQA maps cleanly when n_heads % R == 0 and
R % n_kv == 0 (each rank owns n_heads/R query heads and exactly one kv head,
whose projection it computes from a replicated slice).

Eligibility is checked by `manual_tp_ok`; ineligible configs (whisper's 6
heads, qwen2's 12) fall back to the GSPMD path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..api import ops as aio_ops
from ..dist.sharding import ctx_dp_axes
from .layers import apply_norm, rope

__all__ = ["manual_tp_ok", "manual_dense_block"]


def _mesh_info():
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return None
    if am is None or am.empty or "model" not in am.axis_names:
        return None
    return am


def manual_tp_ok(cfg, x, cache, policy, params=None) -> bool:
    am = _mesh_info()
    if am is None or cache is not None or policy.active:
        return False
    # resident-quantized params (formats.QuantWeight) cannot ride this path:
    # the shard_map body addresses raw `["w"]` arrays. Normally policy.active
    # already excludes them (the engine pins `resident` onto cfg.quant), but
    # a caller handing quantize_params output to forward() with an unpinned
    # cfg must fall back to the GSPMD path, not crash at trace time.
    if params is not None:
        from ..core.formats import QuantWeight
        if any(isinstance(leaf, QuantWeight) for leaf in jax.tree.leaves(
                params, is_leaf=lambda l: isinstance(l, QuantWeight))):
            return False
    # no nesting: inside an already-manual region (compressed-DP train step)
    # sdy forbids re-binding axes — fall back to the GSPMD path there
    if any(str(t) != "Auto" for t in am.axis_types):
        return False
    r = am.shape["model"]
    b, l, d = x.shape
    dp = ctx_dp_axes()
    dp_size = 1
    for a in dp:
        dp_size *= am.shape[a]
    ff = cfg.d_ff if cfg.d_ff else 4 * cfg.d_model
    return (r > 1 and cfg.n_heads % r == 0 and r % cfg.n_kv_heads == 0
            and l % r == 0 and ff % r == 0 and b % dp_size == 0
            and (cfg.n_heads // r) % 1 == 0)


def manual_dense_block(p, x, cfg, *, window: Optional[int],
                       softcap: Optional[float], post_norm: bool,
                       with_mlp: bool = True):
    """x: (B, L, D) logically; physically sequence-sharded over "model" and
    batch-sharded over the DP axes. Returns the block output, same layout.
    with_mlp=False runs only the attention sub-block (MoE blocks pair it
    with the expert-parallel MoE path)."""
    am = _mesh_info()
    r = am.shape["model"]
    dp = ctx_dp_axes()
    n_heads, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h_loc = n_heads // r
    rpk = r // n_kv                      # ranks per kv head
    theta = cfg.rope_theta
    mlp_kind = cfg.mlp_kind

    x_spec = P(dp if dp else None, "model", None)
    col = P(None, "model")
    row = P("model", None)
    rep1 = P(None)
    rep2 = P(None, None)

    p_specs = {
        "ln1": jax.tree.map(lambda _: rep1, p["ln1"]),
        "attn": {"q": {"w": col}, "k": {"w": rep2}, "v": {"w": rep2},
                 "o": {"w": row}},
    }
    if with_mlp:
        p_specs["ln2"] = jax.tree.map(lambda _: rep1, p["ln2"])
        if mlp_kind in ("swiglu", "geglu"):
            p_specs["mlp"] = {"gate": {"w": col}, "up": {"w": col},
                              "down": {"w": row}}
        else:
            p_specs["mlp"] = {"fc1": {"w": col, "b": P("model")},
                              "fc2": {"w": row, "b": rep1}}
    if post_norm:
        p_specs["pn1"] = jax.tree.map(lambda _: rep1, p["pn1"])
        if with_mlp:
            p_specs["pn2"] = jax.tree.map(lambda _: rep1, p["pn2"])

    def body(xb, pb):
        rank = jax.lax.axis_index("model")
        # ---- attention sub-block -----------------------------------------
        h = apply_norm(cfg.norm, pb["ln1"], xb)          # per-token: sharded ok
        hg = jax.lax.all_gather(h, "model", axis=1, tiled=True)  # (B, L, D)
        b, l, d = hg.shape
        q = jnp.einsum("bld,df->blf", hg, pb["attn"]["q"]["w"],
                       preferred_element_type=jnp.float32).astype(hg.dtype)
        q = q.reshape(b, l, h_loc, hd).transpose(0, 2, 1, 3)
        kv_head = rank // rpk
        wk = jax.lax.dynamic_slice_in_dim(pb["attn"]["k"]["w"], kv_head * hd,
                                          hd, axis=1)
        wv = jax.lax.dynamic_slice_in_dim(pb["attn"]["v"]["w"], kv_head * hd,
                                          hd, axis=1)
        k = jnp.einsum("bld,df->blf", hg, wk,
                       preferred_element_type=jnp.float32).astype(hg.dtype)
        v = jnp.einsum("bld,df->blf", hg, wv,
                       preferred_element_type=jnp.float32).astype(hg.dtype)
        k = k.reshape(b, l, 1, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, l, 1, hd).transpose(0, 2, 1, 3)
        pos = jnp.arange(l)
        q = rope(q, pos, theta)
        k = rope(k, pos, theta)
        # inside shard_map: always the ref impl (one-shot short, chunked
        # long — the api-level size switch), never the pallas kernel
        att = aio_ops.attention(q, k, v, causal=True, window=window,
                                softcap=softcap, backend="ref", chunk=2048)
        att = att.transpose(0, 2, 1, 3).reshape(b, l, h_loc * hd)
        partial = jnp.einsum("blf,fd->bld", att, pb["attn"]["o"]["w"],
                             preferred_element_type=jnp.float32
                             ).astype(hg.dtype)
        rs = jax.lax.psum_scatter(partial, "model", scatter_dimension=1,
                                  tiled=True)
        if post_norm:
            rs = apply_norm(cfg.norm, pb["pn1"], rs)
        x1 = xb + rs
        if not with_mlp:
            return x1
        # ---- mlp sub-block ------------------------------------------------
        h2 = apply_norm(cfg.norm, pb["ln2"], x1)
        hg2 = jax.lax.all_gather(h2, "model", axis=1, tiled=True)
        if mlp_kind in ("swiglu", "geglu"):
            act = jax.nn.silu if mlp_kind == "swiglu" else jax.nn.gelu
            g = jnp.einsum("bld,df->blf", hg2, pb["mlp"]["gate"]["w"],
                           preferred_element_type=jnp.float32).astype(hg2.dtype)
            u = jnp.einsum("bld,df->blf", hg2, pb["mlp"]["up"]["w"],
                           preferred_element_type=jnp.float32).astype(hg2.dtype)
            ff = act(g) * u
            part2 = jnp.einsum("blf,fd->bld", ff, pb["mlp"]["down"]["w"],
                               preferred_element_type=jnp.float32
                               ).astype(hg2.dtype)
        else:
            ff = jax.nn.gelu(
                jnp.einsum("bld,df->blf", hg2, pb["mlp"]["fc1"]["w"],
                           preferred_element_type=jnp.float32
                           ).astype(hg2.dtype) + pb["mlp"]["fc1"]["b"])
            part2 = jnp.einsum("blf,fd->bld", ff, pb["mlp"]["fc2"]["w"],
                               preferred_element_type=jnp.float32
                               ).astype(hg2.dtype)
            part2 = part2 + pb["mlp"]["fc2"]["b"] / r   # bias once, not xR
        rs2 = jax.lax.psum_scatter(part2, "model", scatter_dimension=1,
                                   tiled=True)
        if post_norm:
            rs2 = apply_norm(cfg.norm, pb["pn2"], rs2)
        return x1 + rs2

    p_in = {k: p[k] for k in p_specs}
    manual_axes = {"model"} | set(dp)
    return jax.shard_map(body, mesh=am, in_specs=(x_spec, p_specs),
                         out_specs=x_spec, axis_names=manual_axes,
                         check_vma=False)(x, p_in)
