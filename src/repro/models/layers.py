"""Functional NN layers (pure JAX, pytree params).

Every Linear can route through the AIO quantized-matmul plane (fake-quant in
training, code-domain in serving) — the paper's multi-format support as a
first-class model feature. With `QuantPolicy.resident` weights additionally
become a *residency* format: `quantize_params` (models/transformer.py)
converts each Linear's weight into a `formats.QuantWeight` (packed codes +
per-output-channel pow2 scales) once, and `linear` dispatches those through
`api.ops.matmul_codes` so no dense weight is materialized in HBM. Norm
variants cover the assigned archs: RMSNorm (llama-family), LayerNorm
(whisper), non-parametric LN (olmo-1b).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import formats as F
from ..dist.sharding import constrain, ctx_dp_axes

__all__ = ["QuantPolicy", "linear_init", "linear", "embedding_init", "embedding",
           "rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm",
           "nonparam_layernorm", "rope", "mlp_init", "mlp", "norm_init",
           "apply_norm"]


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which AIO format each tensor class runs in (paper Table II formats).

    resident: weights live as packed codes (`formats.QuantWeight`, built by
    `transformer.quantize_params`) instead of being fake-quantized from a
    dense f32 copy on every call — int4 residency is 8x less HBM weight
    traffic than f32. Linears whose params were not converted (e.g. a
    recurrent block outside the pass's coverage) still fall back to the
    fake-quant plane under `weights`, so greedy outputs stay byte-identical
    to the non-resident path.
    """
    activations: str = "none"      # none | bf16 | fp8a | fp8b | int8 | int4
    weights: str = "none"
    resident: bool = False

    @property
    def active(self) -> bool:
        return (self.activations != "none" or self.weights != "none"
                or self.resident)


def _maybe_quant(x: jax.Array, fmt_name: str) -> jax.Array:
    if fmt_name in ("none", "bf16"):
        return x
    # per-tensor pow2 scale: hardware folds it into the programmable bias
    fmt = F.REGISTRY[fmt_name]
    scale = F.pow2_scale(jax.lax.stop_gradient(x), fmt)
    return F.fake_quant(x / scale, fmt_name) * scale


def _maybe_quant_weight(w: jax.Array, fmt_name: str) -> jax.Array:
    """Weight fake-quant with PER-OUTPUT-CHANNEL pow2 scales (axis=-2 is the
    contraction axis of a (..., K, N) weight) — the same scale geometry the
    resident codes use, so `dequantize_weight(quantize_weight(w, f))` equals
    this bitwise and the two paths produce byte-identical logits."""
    if fmt_name in ("none", "bf16"):
        return w
    fmt = F.REGISTRY[fmt_name]
    scale = F.pow2_scale(jax.lax.stop_gradient(w), fmt, axis=-2)
    return F.fake_quant(w / scale, fmt_name) * scale


# ----------------------------------------------------------------- linear
def linear_init(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None):
    if scale is None:
        scale = d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jax.Array, policy: QuantPolicy = QuantPolicy()) -> jax.Array:
    w = p["w"]
    if isinstance(w, F.QuantWeight):
        # resident codes: the weight never exists dense — the matmul_codes
        # op decodes tiles in VMEM (pallas) or dequantizes at dispatch (ref)
        from ..api import ops as aio_ops        # deferred: api ships no models
        x = _maybe_quant(x, policy.activations)
        y = aio_ops.matmul_codes(x, w).astype(x.dtype)
    else:
        if policy.active:
            x = _maybe_quant(x, policy.activations)
            w = _maybe_quant_weight(w, policy.weights)
        y = jnp.einsum("...d,df->...f", x, w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ----------------------------------------------------------------- embedding
def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embedding(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


# ----------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * p["g"]).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"] + p["b"]).astype(x.dtype)


def nonparam_layernorm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm: no learnable gain/bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm_init(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return rmsnorm_init(d, dtype)
    if kind == "layernorm":
        return layernorm_init(d, dtype)
    if kind == "nonparam_ln":
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, p, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(p, x)
    if kind == "layernorm":
        return layernorm(p, x)
    if kind == "nonparam_ln":
        return nonparam_layernorm(x)
    raise ValueError(kind)


# ----------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., L, D) with D even; positions: (L,) or (B, L)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head dim: x (..., H, L, D) vs ang (..., L, half)
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- MLP
def mlp_init(key, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"gate": linear_init(ks[0], d_model, d_ff, dtype=dtype),
                "up": linear_init(ks[1], d_model, d_ff, dtype=dtype),
                "down": linear_init(ks[2], d_ff, d_model, dtype=dtype)}
    if kind == "gelu":
        return {"fc1": linear_init(ks[0], d_model, d_ff, bias=True, dtype=dtype),
                "fc2": linear_init(ks[1], d_ff, d_model, bias=True, dtype=dtype)}
    raise ValueError(kind)


def _tp(x, *spec):
    """Megatron-style TP constraint against the ambient mesh (no-op without
    one). Keeping the residual stream model-replicated and the ff/head dim
    model-sharded turns GSPMD's per-linear activation all-reduces into ONE
    all-reduce per block — §Perf iteration 1."""
    dp = ctx_dp_axes()
    if not dp:
        return x
    full = (dp,) + spec if len(spec) == x.ndim - 1 else spec
    return constrain(x, *full)


def mlp(p, x: jax.Array, kind: str = "swiglu",
        policy: QuantPolicy = QuantPolicy()) -> jax.Array:
    # column-parallel up/gate (ff sharded), row-parallel down whose output
    # REDUCE-SCATTERS onto the sequence-sharded residual (sequence
    # parallelism, Korthikanti et al.) — one shared all-gather on entry, one
    # reduce-scatter on exit, both bf16, instead of per-linear f32 gathers.
    if kind == "swiglu":
        h = jax.nn.silu(_tp(linear(p["gate"], x, policy), None, "model")) * \
            _tp(linear(p["up"], x, policy), None, "model")
        return _tp(linear(p["down"], h, policy), "model", None)
    if kind == "geglu":
        h = jax.nn.gelu(_tp(linear(p["gate"], x, policy), None, "model")) * \
            _tp(linear(p["up"], x, policy), None, "model")
        return _tp(linear(p["down"], h, policy), "model", None)
    if kind == "gelu":
        h = jax.nn.gelu(_tp(linear(p["fc1"], x, policy), None, "model"))
        return _tp(linear(p["fc2"], h, policy), "model", None)
    raise ValueError(kind)
