"""GQA attention block with KV cache, covering the assigned archs' variants:
QKV bias (qwen2), logit softcap + sliding window + sandwich norms (gemma2),
cross attention (whisper decoder), and the AIO quantization policy."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..api import ops as aio_ops
from ..core.formats import pow2_ceil
from .layers import QuantPolicy, linear, linear_init, rope

__all__ = ["KVCache", "PagedKVCache", "PagedQuantKVCache", "attn_init",
           "attn_apply", "cross_attn_apply", "init_kv_cache",
           "init_paged_kv_cache", "pool_block_values", "store_pool_blocks"]


class KVCache(NamedTuple):
    """Pre-allocated decode cache. k/v: (B, Hkv, L_max, D); pos: (B,) vector —
    every batch row ("slot" in the serving engine) sits at its own position,
    the substrate for continuous per-slot batching."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array


class QuantKVCache(NamedTuple):
    """INT8 KV cache — the paper's format plane applied to cache residency.

    Codes are int8 with a per-(position, head) power-of-two scale (the
    bias-foldable kind): halves the decode memory term vs bf16. The
    dequantization happens at attention time (fused on real TPU).
    pos: (B,) per-row vector, like KVCache."""
    k_codes: jax.Array      # (B, Hkv, L, D) int8
    k_scale: jax.Array      # (B, Hkv, L, 1) f32, power-of-two
    v_codes: jax.Array
    v_scale: jax.Array
    pos: jax.Array


class PagedKVCache(NamedTuple):
    """Block-pool decode cache. Instead of a private (L_max, D) stripe per
    row, all rows share one pool of fixed-size KV blocks and each row maps
    logical block j -> physical block table[b, j]. Rows only pay for the
    context they actually hold, and identical prompt prefixes can alias the
    same physical blocks (copy-on-write sharing, managed host-side by the
    serving engine's allocator).

    k/v:   (P, Hkv, bs, D) pool — P physical blocks of bs positions
    table: (B, nblk) int32 — per-row logical->physical block map
    pos:   (B,) — per-row write frontier, same semantics as KVCache.pos
    """
    k: jax.Array
    v: jax.Array
    table: jax.Array
    pos: jax.Array


class PagedQuantKVCache(NamedTuple):
    """INT8 block-pool cache: PagedKVCache layout with QuantKVCache formats.
    codes (P, Hkv, bs, D) int8, scales (P, Hkv, bs, 1) f32 pow2."""
    k_codes: jax.Array
    k_scale: jax.Array
    v_codes: jax.Array
    v_scale: jax.Array
    table: jax.Array
    pos: jax.Array


def init_kv_cache(batch: int, n_kv: int, max_len: int, head_dim: int,
                  dtype=jnp.bfloat16, quantized: bool = False):
    if quantized:
        return QuantKVCache(
            k_codes=jnp.zeros((batch, n_kv, max_len, head_dim), jnp.int8),
            k_scale=jnp.ones((batch, n_kv, max_len, 1), jnp.float32),
            v_codes=jnp.zeros((batch, n_kv, max_len, head_dim), jnp.int8),
            v_scale=jnp.ones((batch, n_kv, max_len, 1), jnp.float32),
            pos=jnp.zeros((batch,), jnp.int32),
        )
    return KVCache(
        k=jnp.zeros((batch, n_kv, max_len, head_dim), dtype),
        v=jnp.zeros((batch, n_kv, max_len, head_dim), dtype),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def init_paged_kv_cache(batch: int, n_kv: int, pool_blocks: int,
                        block_size: int, nblk: int, head_dim: int,
                        dtype=jnp.bfloat16, quantized: bool = False):
    """Block-pool cache init. The table starts as a striped identity map
    (row b's logical block j -> physical b*nblk + j, modulo the pool) so a
    freshly initialized paged cache behaves exactly like per-slot stripes
    until an allocator rewrites the tables."""
    ident = (jnp.arange(batch)[:, None] * nblk
             + jnp.arange(nblk)[None, :]) % pool_blocks
    table = ident.astype(jnp.int32)
    pos = jnp.zeros((batch,), jnp.int32)
    if quantized:
        return PagedQuantKVCache(
            k_codes=jnp.zeros((pool_blocks, n_kv, block_size, head_dim),
                              jnp.int8),
            k_scale=jnp.ones((pool_blocks, n_kv, block_size, 1), jnp.float32),
            v_codes=jnp.zeros((pool_blocks, n_kv, block_size, head_dim),
                              jnp.int8),
            v_scale=jnp.ones((pool_blocks, n_kv, block_size, 1), jnp.float32),
            table=table, pos=pos)
    return PagedKVCache(
        k=jnp.zeros((pool_blocks, n_kv, block_size, head_dim), dtype),
        v=jnp.zeros((pool_blocks, n_kv, block_size, head_dim), dtype),
        table=table, pos=pos)


def pool_block_values(cache, ids: jax.Array) -> dict:
    """Slice physical pool blocks `ids` ((C,) int32) out of one paged cache
    leaf: each pool array narrowed to C entries along its block axis. Works
    on the bare (P, H, bs, ...) layout and on the serving engine's stacked
    (n_layers, P, H, bs, ...) layout alike — the block axis is located from
    the trailing (H, bs, last) structure. `store_pool_blocks` is the exact
    inverse; together they are the device halves of KV block swap-out/in."""
    def take(a):
        return jnp.take(a, ids, axis=a.ndim - 4)

    if isinstance(cache, PagedKVCache):
        return {"k": take(cache.k), "v": take(cache.v)}
    if isinstance(cache, PagedQuantKVCache):
        return {"k_codes": take(cache.k_codes), "k_scale": take(cache.k_scale),
                "v_codes": take(cache.v_codes), "v_scale": take(cache.v_scale)}
    raise TypeError(f"not a paged cache leaf: {type(cache).__name__}")


def store_pool_blocks(cache, values: dict, dst: jax.Array):
    """Scatter `pool_block_values`-shaped block contents back into the pool
    at physical blocks `dst` ((C,) int32). Entries equal to the pool size
    are padding and are dropped, so a fixed-width dst traces once."""
    def put(a, vals):
        idx = (slice(None),) * (a.ndim - 4) + (dst,)
        return a.at[idx].set(jnp.asarray(vals, a.dtype), mode="drop")

    if isinstance(cache, PagedKVCache):
        return cache._replace(k=put(cache.k, values["k"]),
                              v=put(cache.v, values["v"]))
    if isinstance(cache, PagedQuantKVCache):
        return cache._replace(
            k_codes=put(cache.k_codes, values["k_codes"]),
            k_scale=put(cache.k_scale, values["k_scale"]),
            v_codes=put(cache.v_codes, values["v_codes"]),
            v_scale=put(cache.v_scale, values["v_scale"]))
    raise TypeError(f"not a paged cache leaf: {type(cache).__name__}")


def _q8(x: jax.Array):
    """Per-(b, h, position) row int8 quantization with a pow2 scale."""
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8)
    scale = pow2_ceil(amax.astype(jnp.float32) / 127.0)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                     -128, 127).astype(jnp.int8)
    return codes, scale


def _dq8(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qkv_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "q": linear_init(ks[0], d_model, n_heads * head_dim, qkv_bias, dtype),
        "k": linear_init(ks[1], d_model, n_kv * head_dim, qkv_bias, dtype),
        "v": linear_init(ks[2], d_model, n_kv * head_dim, qkv_bias, dtype),
        "o": linear_init(ks[3], n_heads * head_dim, d_model, False, dtype),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, l, _ = x.shape
    return x.reshape(b, l, n, -1).transpose(0, 2, 1, 3)     # (B, H, L, D)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, l, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * d)


def _row_update(buf: jax.Array, new: jax.Array, start: jax.Array) -> jax.Array:
    """Per-row cache write. buf: (B, H, L_max, ...); new: (B, H, l, ...);
    start: (B,) — row b's new tokens land at start[b]..start[b]+l-1."""
    l = new.shape[2]
    if l == 1:
        # decode hot path: start <= L_max - 1 always, the slice write fits
        return jax.vmap(
            lambda bb, nn, ss: jax.lax.dynamic_update_slice_in_dim(
                bb, nn, ss, axis=1))(buf, new.astype(buf.dtype), start)
    # A multi-token chunk's write window may overrun L_max on a row's FINAL
    # partial chunk (pad tail only — valid tokens always fit, the engine
    # guarantees start + lengths[b] <= L_max). dynamic_update_slice would
    # CLAMP the window start and shift valid tokens to wrong positions;
    # scatter with mode="drop" keeps them in place and drops the
    # out-of-range pad writes instead.
    idx = start[:, None] + jnp.arange(l)
    return jax.vmap(lambda bb, nn, ii: bb.at[:, ii].set(nn, mode="drop"))(
        buf, new.astype(buf.dtype), idx)


def _paged_update(pool: jax.Array, new: jax.Array, start: jax.Array,
                  table: jax.Array, lengths: Optional[jax.Array]) -> jax.Array:
    """Scatter a (B, H, l, ...) update into the (P, H, bs, ...) block pool.
    Token i of row b lands at physical block table[b, (start[b]+i)//bs],
    offset (start[b]+i)%bs. Positions past lengths[b] (right-pad) or past
    the table's reach scatter out of bounds and are dropped."""
    b, _, l = new.shape[:3]
    pool_blocks, _, bs = pool.shape[:3]
    nblk = table.shape[1]
    tok = start[:, None] + jnp.arange(l)                        # (B, l)
    lb = tok // bs
    phys = jnp.take_along_axis(table, jnp.clip(lb, 0, nblk - 1), axis=1)
    valid = lb < nblk
    if lengths is not None:
        valid &= jnp.arange(l)[None, :] < lengths[:, None]
    phys = jnp.where(valid, phys, pool_blocks)                  # OOB sentinel
    vals = new.astype(pool.dtype).transpose(0, 2, 1, 3)         # (B, l, H, .)
    return pool.at[phys, :, tok % bs].set(vals, mode="drop")


def attn_apply(p, x: jax.Array, *, n_heads: int, n_kv: int, causal: bool = True,
               window: Optional[int] = None, softcap: Optional[float] = None,
               rope_theta: float = 10000.0, positions: Optional[jax.Array] = None,
               cache: Optional[KVCache] = None,
               lengths: Optional[jax.Array] = None,
               policy: QuantPolicy = QuantPolicy()):
    """Self attention. Returns (out, new_cache). With a cache, x holds the new
    token(s) and attends to cache[:pos[b]] + x, per batch row.

    lengths: optional (B,) count of VALID new tokens per row (continuous
    batching: a right-padded batched prefill, or rows sitting a call out).
    Rows with lengths[b] == 0 keep their cache and position untouched; rows
    with 0 < lengths[b] < l advance by lengths[b], so the pad tail is never
    inside any row's causal frontier — pad keys are thereby masked out of all
    future attention, and each pad slot is overwritten before the frontier
    reaches it.
    """
    from .layers import _tp
    b, l, _ = x.shape
    q = _split_heads(_tp(linear(p["q"], x, policy), None, "model"), n_heads)
    k = _split_heads(_tp(linear(p["k"], x, policy), None, "model"), n_kv)
    v = _split_heads(_tp(linear(p["v"], x, policy), None, "model"), n_kv)

    if cache is not None:
        start = cache.pos
        uniform = start.ndim == 0               # legacy batch-global scalar
        if uniform:
            assert lengths is None, \
                "per-row lengths need a per-row (B,) cache position"
        if positions is None:
            positions = start + jnp.arange(l) if uniform \
                else start[:, None] + jnp.arange(l)          # (l,) | (B, l)
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
        new_pos = start + (l if lengths is None else lengths)
        keep_row = None if lengths is None else lengths > 0

        def upd(buf, new):
            if uniform:
                # all rows at one position: a single contiguous slice write
                # lowers cheaper than the per-row scatter
                return jax.lax.dynamic_update_slice_in_dim(
                    buf, new.astype(buf.dtype), start, axis=2)
            out = _row_update(buf, new, start)
            if keep_row is not None:
                out = jnp.where(keep_row[:, None, None, None], out, buf)
            return out

        if isinstance(cache, (PagedKVCache, PagedQuantKVCache)):
            assert not uniform, "paged caches carry per-row (B,) positions"

            def pupd(pool, new):
                return _paged_update(pool, new, start, cache.table, lengths)

            if isinstance(cache, PagedQuantKVCache):
                kc, ks = _q8(k)
                vc, vs = _q8(v)
                new_cache = PagedQuantKVCache(
                    pupd(cache.k_codes, kc), pupd(cache.k_scale, ks),
                    pupd(cache.v_codes, vc), pupd(cache.v_scale, vs),
                    cache.table, new_pos)
                out = _cached_attn(q, new_cache.k_codes, new_cache.v_codes,
                                   start, l, causal, window, softcap,
                                   lengths=lengths,
                                   k_scale=new_cache.k_scale,
                                   v_scale=new_cache.v_scale,
                                   block_tables=cache.table)
            else:
                ck = pupd(cache.k, k)
                cv = pupd(cache.v, v)
                new_cache = PagedKVCache(ck, cv, cache.table, new_pos)
                out = _cached_attn(q, ck, cv, start, l, causal, window,
                                   softcap, lengths=lengths,
                                   block_tables=cache.table)
        elif isinstance(cache, QuantKVCache):
            kc, ks = _q8(k)
            vc, vs = _q8(v)
            new_cache = QuantKVCache(upd(cache.k_codes, kc),
                                     upd(cache.k_scale, ks),
                                     upd(cache.v_codes, vc),
                                     upd(cache.v_scale, vs), new_pos)
            # codes + scales go to attention UNMATERIALIZED: the decode /
            # prefill kernels dequantize block-by-block in VMEM, the ref
            # path at dispatch — either way no full-cache f32 copy in HBM
            out = _cached_attn(q, new_cache.k_codes, new_cache.v_codes,
                               start, l, causal, window, softcap,
                               lengths=lengths,
                               k_scale=new_cache.k_scale,
                               v_scale=new_cache.v_scale)
        else:
            ck = upd(cache.k, k)
            cv = upd(cache.v, v)
            new_cache = KVCache(ck, cv, new_pos)
            # attend over the full (static-length) cache; the per-row causal
            # mask at offset=start[b] kills each row's not-yet-written tail
            out = _cached_attn(q, ck, cv, start, l, causal, window, softcap,
                               lengths=lengths)
        out = _tp(_merge_heads(out), None, "model")
        return _tp(linear(p["o"], out, policy), "model", None), new_cache

    if positions is None:
        positions = jnp.arange(l)
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)
    out = aio_ops.attention(q, k, v, causal=causal, window=window,
                            softcap=softcap)
    out = _tp(_merge_heads(out), None, "model")
    return _tp(linear(p["o"], out, policy), "model", None), None


def _cached_attn(q, ck, cv, start, l, causal, window, softcap,
                 lengths=None, k_scale=None, v_scale=None, block_tables=None):
    """Decode-path attention: row b's query positions start[b]..start[b]+l-1
    over a cache of static length; the per-row offset lines the causal mask up
    and also masks the not-yet-written tail (kpos <= qpos < start[b]+l).
    lengths (B,) marks the valid query count of a right-padded chunk — the
    varlen prefill kernel prunes with it. With k_scale/v_scale, ck/cv are
    int8 codes (dequant happens at dispatch or inside the kernels)."""
    if k_scale is None:
        ck, cv = ck.astype(q.dtype), cv.astype(q.dtype)
    return aio_ops.attention(q, ck, cv, causal=True, window=window,
                             softcap=softcap, offset=start, lengths=lengths,
                             k_scale=k_scale, v_scale=v_scale,
                             block_tables=block_tables)


def cross_attn_apply(p, x: jax.Array, memory: jax.Array, *, n_heads: int,
                     n_kv: int, policy: QuantPolicy = QuantPolicy()):
    """Encoder-decoder cross attention (whisper): q from x, k/v from memory."""
    q = _split_heads(linear(p["q"], x, policy), n_heads)
    k = _split_heads(linear(p["k"], memory, policy), n_kv)
    v = _split_heads(linear(p["v"], memory, policy), n_kv)
    out = aio_ops.attention(q, k, v, causal=False)
    return linear(p["o"], _merge_heads(out), policy)
