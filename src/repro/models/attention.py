"""GQA attention block with KV cache, covering the assigned archs' variants:
QKV bias (qwen2), logit softcap + sliding window + sandwich norms (gemma2),
cross attention (whisper decoder), and the AIO quantization policy."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..api import ops as aio_ops
from .layers import QuantPolicy, linear, linear_init, rope

__all__ = ["KVCache", "attn_init", "attn_apply", "cross_attn_apply",
           "init_kv_cache"]


class KVCache(NamedTuple):
    """Pre-allocated decode cache. k/v: (B, Hkv, L_max, D); pos: scalar."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array


class QuantKVCache(NamedTuple):
    """INT8 KV cache — the paper's format plane applied to cache residency.

    Codes are int8 with a per-(position, head) power-of-two scale (the
    bias-foldable kind): halves the decode memory term vs bf16. The
    dequantization happens at attention time (fused on real TPU)."""
    k_codes: jax.Array      # (B, Hkv, L, D) int8
    k_scale: jax.Array      # (B, Hkv, L, 1) f32, power-of-two
    v_codes: jax.Array
    v_scale: jax.Array
    pos: jax.Array


def init_kv_cache(batch: int, n_kv: int, max_len: int, head_dim: int,
                  dtype=jnp.bfloat16, quantized: bool = False):
    if quantized:
        return QuantKVCache(
            k_codes=jnp.zeros((batch, n_kv, max_len, head_dim), jnp.int8),
            k_scale=jnp.ones((batch, n_kv, max_len, 1), jnp.float32),
            v_codes=jnp.zeros((batch, n_kv, max_len, head_dim), jnp.int8),
            v_scale=jnp.ones((batch, n_kv, max_len, 1), jnp.float32),
            pos=jnp.zeros((), jnp.int32),
        )
    return KVCache(
        k=jnp.zeros((batch, n_kv, max_len, head_dim), dtype),
        v=jnp.zeros((batch, n_kv, max_len, head_dim), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def _q8(x: jax.Array):
    """Per-(b, h, position) row int8 quantization with a pow2 scale."""
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8)
    _, e2 = jnp.frexp(amax.astype(jnp.float32) / 127.0)
    scale = jnp.exp2(e2.astype(jnp.float32))
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                     -128, 127).astype(jnp.int8)
    return codes, scale


def _dq8(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qkv_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "q": linear_init(ks[0], d_model, n_heads * head_dim, qkv_bias, dtype),
        "k": linear_init(ks[1], d_model, n_kv * head_dim, qkv_bias, dtype),
        "v": linear_init(ks[2], d_model, n_kv * head_dim, qkv_bias, dtype),
        "o": linear_init(ks[3], n_heads * head_dim, d_model, False, dtype),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, l, _ = x.shape
    return x.reshape(b, l, n, -1).transpose(0, 2, 1, 3)     # (B, H, L, D)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, l, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * d)


def attn_apply(p, x: jax.Array, *, n_heads: int, n_kv: int, causal: bool = True,
               window: Optional[int] = None, softcap: Optional[float] = None,
               rope_theta: float = 10000.0, positions: Optional[jax.Array] = None,
               cache: Optional[KVCache] = None,
               policy: QuantPolicy = QuantPolicy()):
    """Self attention. Returns (out, new_cache). With a cache, x holds the new
    token(s) and attends to cache[:pos] + x."""
    from .layers import _tp
    b, l, _ = x.shape
    q = _split_heads(_tp(linear(p["q"], x, policy), None, "model"), n_heads)
    k = _split_heads(_tp(linear(p["k"], x, policy), None, "model"), n_kv)
    v = _split_heads(_tp(linear(p["v"], x, policy), None, "model"), n_kv)

    if cache is not None:
        start = cache.pos
        if positions is None:
            positions = start + jnp.arange(l)
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
        if isinstance(cache, QuantKVCache):
            kc, ks = _q8(k)
            vc, vs = _q8(v)
            upd = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(  # noqa: E731
                buf, new, start, axis=2)
            new_cache = QuantKVCache(upd(cache.k_codes, kc),
                                     upd(cache.k_scale, ks),
                                     upd(cache.v_codes, vc),
                                     upd(cache.v_scale, vs), start + l)
            ck = _dq8(new_cache.k_codes, new_cache.k_scale, q.dtype)
            cv = _dq8(new_cache.v_codes, new_cache.v_scale, q.dtype)
            out = _cached_attn(q, ck, cv, start, l, causal, window, softcap)
            out = _tp(_merge_heads(out), None, "model")
            return _tp(linear(p["o"], out, policy), "model", None), new_cache
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype),
                                                 start, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype),
                                                 start, axis=2)
        new_cache = KVCache(ck, cv, start + l)
        # attend over the full (static-length) cache; the causal mask at
        # offset=start also kills the not-yet-written tail slots
        out = _cached_attn(q, ck, cv, start, l, causal, window, softcap)
        out = _tp(_merge_heads(out), None, "model")
        return _tp(linear(p["o"], out, policy), "model", None), new_cache

    if positions is None:
        positions = jnp.arange(l)
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)
    out = aio_ops.attention(q, k, v, causal=causal, window=window,
                            softcap=softcap)
    out = _tp(_merge_heads(out), None, "model")
    return _tp(linear(p["o"], out, policy), "model", None), None


def _cached_attn(q, ck, cv, start, l, causal, window, softcap):
    """Decode-path attention: query positions start..start+l-1 over a cache of
    static length; offset makes the causal mask line up and also masks the
    not-yet-written tail (kpos <= qpos < start+l)."""
    return aio_ops.attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                             causal=True, window=window, softcap=softcap,
                             offset=start)


def cross_attn_apply(p, x: jax.Array, memory: jax.Array, *, n_heads: int,
                     n_kv: int, policy: QuantPolicy = QuantPolicy()):
    """Encoder-decoder cross attention (whisper): q from x, k/v from memory."""
    q = _split_heads(linear(p["q"], x, policy), n_heads)
    k = _split_heads(linear(p["k"], memory, policy), n_kv)
    v = _split_heads(linear(p["v"], memory, policy), n_kv)
    out = aio_ops.attention(q, k, v, causal=False)
    return linear(p["o"], _merge_heads(out), policy)
