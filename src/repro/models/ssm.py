"""State-space / recurrent blocks: Mamba2 (zamba2) and xLSTM (mLSTM + sLSTM).

One generic *chunked linear recurrence* powers both families:

    S_t = a_t * S_{t-1} + k_t (x) v_t          S: (p, s) per head
    y_t = q_t . S_t                            contract over p

Mamba2's SSD maps as  k:=B, v:=dt*x, q:=C  (state transposed), and the mLSTM
maps as k:=i*key, v:=value, q:=query with the normalizer n folded in as an
extra ones-column of v. The chunked evaluation (intra-chunk quadratic +
inter-chunk state scan) is the TPU-native translation of the paper's
*unaccumulable-op* plane: the recurrent contraction never touches the C_in
axis, so it routes to VPU-friendly chunk GEMMs rather than the systolic plane
(DESIGN.md §2). Decode is the O(1) single-step recurrence on a state cache —
this is what makes zamba2/xlstm the two archs that run the long_500k cell.

Deviations from the published models (documented): mLSTM uses the sigmoid
input gate of xLSTM-7B (no exponential-gate stabilizer); Mamba2 uses a single
B/C group shared across heads.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import linear, linear_init, rmsnorm, rmsnorm_init

__all__ = ["chunked_gla", "gla_step", "mamba_init", "mamba_apply",
           "mamba_step", "MambaCache", "mlstm_init", "mlstm_apply",
           "mlstm_step", "MLSTMCache", "slstm_init", "slstm_apply",
           "slstm_step", "SLSTMCache"]


# =============================================================================
# Generic chunked gated linear recurrence
# =============================================================================

def chunked_gla(a_log: jax.Array, k: jax.Array, v: jax.Array, q: jax.Array,
                chunk: int = 128, init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Evaluate y_t = q_t . S_t with S_t = exp(a_log_t) S_{t-1} + k_t (x) v_t.

    a_log: (B, L, H) log-decays (<= 0); k, q: (B, L, H, P); v: (B, L, H, S).
    Returns (y (B, L, H, S), final_state (B, H, P, S)).

    Intra-chunk work is an attention-like (chunk x chunk) GEMM; inter-chunk
    state flows through a lax.scan of L/chunk steps — O(L * chunk) memory.
    """
    b, l, h, p = k.shape
    s = v.shape[-1]
    pad = (-l) % chunk
    if pad:
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nc = lp // chunk

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:])

    a_c, k_c, v_c, q_c = map(to_chunks, (a_log, k, v, q))
    a_c = a_c.astype(jnp.float32)
    cum = jnp.cumsum(a_c, axis=2)                          # (b, nc, q, h)
    total = cum[:, :, -1]                                  # (b, nc, h)

    # ---- intra-chunk: masked decay attention --------------------------------
    scores = jnp.einsum("bnihp,bnjhp->bnhij", q_c, k_c,
                        preferred_element_type=jnp.float32)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (b,nc,i,j,h)
    dec = jnp.transpose(dec, (0, 1, 4, 2, 3))              # (b,nc,h,i,j)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(mask, jnp.exp(dec), 0.0)
    y_intra = jnp.einsum("bnhij,bnjhs->bnihs", scores * w, v_c,
                         preferred_element_type=jnp.float32)

    # ---- chunk summaries: S_n = sum_j exp(total - cum_j) k_j (x) v_j --------
    wk = jnp.exp(total[:, :, None] - cum)                  # (b, nc, q, h)
    s_chunk = jnp.einsum("bnjh,bnjhp,bnjhs->bnhps", wk, k_c, v_c,
                         preferred_element_type=jnp.float32)

    # ---- inter-chunk scan ----------------------------------------------------
    s0 = jnp.zeros((b, h, p, s), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(state, xs):
        tot, s_new = xs                                    # (b,h), (b,h,p,s)
        carry = state * jnp.exp(tot)[..., None, None] + s_new
        return carry, state                                # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (b, nc, h, p, s)

    # ---- inter-chunk contribution: y_i += exp(cum_i) q_i . S_prev ------------
    y_inter = jnp.einsum("bnih,bnihp,bnhps->bnihs", jnp.exp(cum), q_c,
                         prev_states, preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, lp, h, s)[:, :l]
    return y, final


def gla_step(state: jax.Array, a_log: jax.Array, k: jax.Array, v: jax.Array,
             q: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. state: (B,H,P,S); a_log: (B,H); k,q: (B,H,P);
    v: (B,H,S) -> (y (B,H,S), new_state)."""
    new = state * jnp.exp(a_log.astype(jnp.float32))[..., None, None] + \
        jnp.einsum("bhp,bhs->bhps", k, v, preferred_element_type=jnp.float32)
    y = jnp.einsum("bhp,bhps->bhs", q, new, preferred_element_type=jnp.float32)
    return y, new


# =============================================================================
# Causal short conv (the Mamba/mLSTM front conv)
# =============================================================================

def _causal_conv(x: jax.Array, w: jax.Array,
                 cache: Optional[jax.Array] = None):
    """x: (B, L, C); w: (W, C) depthwise causal conv. cache: (B, W-1, C)
    carries the last W-1 inputs for decode. Returns (y, new_cache)."""
    width = w.shape[0]
    if cache is None:
        hist = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        hist = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + hist[:, i:i + x.shape[1]] * w[i]
    new_cache = hist[:, -(width - 1):] if width > 1 else None
    return y, new_cache


# =============================================================================
# Mamba2 block (zamba2 backbone)
# =============================================================================

class MambaCache(NamedTuple):
    ssm: jax.Array        # (B, H, S, P)   state (transposed: k=B rides P slot)
    conv: jax.Array       # (B, W-1, d_conv)


def mamba_init(key, d_model: int, d_state: int = 64, expand: int = 2,
               headdim: int = 64, conv_width: int = 4, dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    d_conv = d_inner + 2 * d_state                 # conv over [x, B, C]
    ks = jax.random.split(key, 4)
    return {
        "in_proj": linear_init(ks[0], d_model,
                               2 * d_inner + 2 * d_state + n_heads, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (conv_width, d_conv), dtype) * 0.2,
        "a_log": jnp.zeros((n_heads,), jnp.float32),       # A = -exp(a_log)
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": linear_init(ks[3], d_inner, d_model, dtype=dtype),
    }


def _mamba_core_inputs(p, x, *, d_state, headdim, conv_cache=None):
    from .layers import _tp
    b, l, _ = x.shape
    zxbcdt = _tp(linear(p["in_proj"], x), None, "model")
    n_heads = p["a_log"].shape[0]
    d_inner = n_heads * headdim
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * d_state], -1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_cache)
    xbc = jax.nn.silu(xbc)
    xin, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + d_state], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (b, l, h)
    a_log_step = -jnp.exp(p["a_log"]) * dt                        # (b, l, h)
    xh = xin.reshape(b, l, n_heads, headdim)
    return z, xh, bmat, cmat, dt, a_log_step, new_conv


def mamba_apply(p, x: jax.Array, *, d_state: int = 64, headdim: int = 64,
                chunk: int = 128,
                init_state: Optional[jax.Array] = None):
    """x: (B, L, D) -> (out, final ssm state). Chunked SSD evaluation."""
    b, l, _ = x.shape
    n_heads = p["a_log"].shape[0]
    z, xh, bmat, cmat, dt, a_log, _ = _mamba_core_inputs(
        p, x, d_state=d_state, headdim=headdim)
    # recurrence (state transposed): k := B (b,l,h,s), v := dt*x (b,l,h,p), q := C
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, l, n_heads, d_state))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, l, n_heads, d_state))
    v = xh * dt[..., None]
    y, final = chunked_gla(a_log, k, v, q, chunk=chunk, init_state=init_state)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, l, -1).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    from .layers import _tp
    return _tp(linear(p["out_proj"], y), "model", None), final


def mamba_step(p, x: jax.Array, cache: MambaCache, *, d_state: int = 64,
               headdim: int = 64):
    """Single-token decode. x: (B, 1, D) -> (out (B,1,D), new cache)."""
    b = x.shape[0]
    n_heads = p["a_log"].shape[0]
    z, xh, bmat, cmat, dt, a_log, new_conv = _mamba_core_inputs(
        p, x, d_state=d_state, headdim=headdim, conv_cache=cache.conv)
    k = jnp.broadcast_to(bmat[:, 0, None, :], (b, n_heads, d_state))
    q = jnp.broadcast_to(cmat[:, 0, None, :], (b, n_heads, d_state))
    v = xh[:, 0] * dt[:, 0, :, None]
    y, new_state = gla_step(cache.ssm, a_log[:, 0], k, v, q)
    y = y + xh[:, 0].astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, -1).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y), MambaCache(new_state, new_conv)


def mamba_cache_init(batch: int, d_model: int, *, d_state: int = 64,
                     expand: int = 2, headdim: int = 64, conv_width: int = 4,
                     dtype=jnp.float32) -> MambaCache:
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    return MambaCache(
        ssm=jnp.zeros((batch, n_heads, d_state, headdim), jnp.float32),
        conv=jnp.zeros((batch, conv_width - 1, d_inner + 2 * d_state), dtype))


# =============================================================================
# mLSTM block (xlstm-1.3b majority layer)
# =============================================================================

class MLSTMCache(NamedTuple):
    state: jax.Array      # (B, H, Dk, Dv+1) — last column is the normalizer
    conv: jax.Array       # (B, W-1, d_inner)


def mlstm_init(key, d_model: int, n_heads: int = 4, pf: float = 2.0,
               conv_width: int = 4, dtype=jnp.float32):
    d_inner = int(d_model * pf)
    ks = jax.random.split(key, 8)
    return {
        "up": linear_init(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (conv_width, d_inner), dtype) * 0.2,
        "q": linear_init(ks[2], d_inner, d_inner, dtype=dtype),
        "k": linear_init(ks[3], d_inner, d_inner, dtype=dtype),
        "v": linear_init(ks[4], d_inner, d_inner, dtype=dtype),
        "igate": linear_init(ks[5], d_inner, n_heads, bias=True, dtype=dtype),
        "fgate": linear_init(ks[6], d_inner, n_heads, bias=True, dtype=dtype),
        "norm": rmsnorm_init(d_inner, dtype),
        "down": linear_init(ks[7], d_inner, d_model, dtype=dtype),
    }


def _mlstm_core_inputs(p, x, n_heads, conv_cache=None):
    from .layers import _tp
    b, l, _ = x.shape
    up = _tp(linear(p["up"], x), None, "model")
    xi, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = _causal_conv(xi, p["conv_w"], conv_cache)
    xc = jax.nn.silu(xc)
    dh = xc.shape[-1] // n_heads
    def heads(t):
        return t.reshape(b, l, n_heads, dh)
    q = heads(linear(p["q"], xc))
    k = heads(linear(p["k"], xc)) * dh ** -0.5
    v = heads(linear(p["v"], xi))
    ig = jax.nn.sigmoid(linear(p["igate"], xc).astype(jnp.float32))  # (b,l,h)
    fg = jax.nn.log_sigmoid(linear(p["fgate"], xc).astype(jnp.float32))
    return z, q, k * ig[..., None], v, fg, new_conv, dh


def mlstm_apply(p, x: jax.Array, *, n_heads: int = 4, chunk: int = 128,
                init_state: Optional[jax.Array] = None):
    b, l, _ = x.shape
    z, q, k, v, fg, _, dh = _mlstm_core_inputs(p, x, n_heads)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], -1)  # normalizer col
    y, final = chunked_gla(fg, k, v_aug, q, chunk=chunk, init_state=init_state)
    h, n = y[..., :-1], y[..., -1:]
    h = h / jnp.maximum(jnp.abs(n), 1.0)
    h = h.reshape(b, l, -1).astype(x.dtype)
    h = rmsnorm(p["norm"], h) * jax.nn.silu(z)
    from .layers import _tp
    return _tp(linear(p["down"], h), "model", None), final


def mlstm_step(p, x: jax.Array, cache: MLSTMCache, *, n_heads: int = 4):
    b = x.shape[0]
    z, q, k, v, fg, new_conv, dh = _mlstm_core_inputs(p, x, n_heads, cache.conv)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], -1)
    y, new_state = gla_step(cache.state, fg[:, 0], k[:, 0], v_aug[:, 0], q[:, 0])
    h, n = y[..., :-1], y[..., -1:]
    h = (h / jnp.maximum(jnp.abs(n), 1.0)).reshape(b, 1, -1).astype(x.dtype)
    h = rmsnorm(p["norm"], h) * jax.nn.silu(z)
    return linear(p["down"], h), MLSTMCache(new_state, new_conv)


def mlstm_cache_init(batch: int, d_model: int, *, n_heads: int = 4,
                     pf: float = 2.0, conv_width: int = 4,
                     dtype=jnp.float32) -> MLSTMCache:
    d_inner = int(d_model * pf)
    dh = d_inner // n_heads
    return MLSTMCache(
        state=jnp.zeros((batch, n_heads, dh, dh + 1), jnp.float32),
        conv=jnp.zeros((batch, conv_width - 1, d_inner), dtype))


# =============================================================================
# sLSTM block (xlstm-1.3b every-8th layer) — sequential exp-gated scalar LSTM
# =============================================================================

class SLSTMCache(NamedTuple):
    c: jax.Array          # (B, D)
    n: jax.Array          # (B, D)
    m: jax.Array          # (B, D) stabilizer
    h: jax.Array          # (B, D) recurrent input


def slstm_init(key, d_model: int, n_heads: int = 4, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    dh = d_model // n_heads
    return {
        # input projections for gates z, i, f, o
        "wx": linear_init(ks[0], d_model, 4 * d_model, bias=True, dtype=dtype),
        # block-diagonal (head-wise) recurrent weights
        "r": jax.random.normal(ks[1], (4, n_heads, dh, dh), dtype) * dh ** -0.5,
        "norm": rmsnorm_init(d_model, dtype),
        "up": linear_init(ks[2], d_model, int(d_model * 4 / 3), dtype=dtype),
        "gate": linear_init(ks[3], d_model, int(d_model * 4 / 3), dtype=dtype),
        "down": linear_init(ks[4], int(d_model * 4 / 3), d_model, dtype=dtype),
    }


def _slstm_cell(p, gx, state: SLSTMCache, n_heads: int):
    """One timestep. gx: (B, 4D) pre-computed input contribution."""
    b, d4 = gx.shape
    d = d4 // 4
    dh = d // n_heads
    hprev = state.h.reshape(b, n_heads, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hprev, p["r"]).reshape(4, b, d)
    zt, it, ft, ot = [gx[:, i * d:(i + 1) * d] + rec[i] for i in range(4)]
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    m_new = jnp.maximum(ft + state.m, it)                 # log-domain stabilizer
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(ft + state.m - m_new)
    c_new = f_s * state.c + i_s * zt
    n_new = f_s * state.n + i_s
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMCache(c_new, n_new, m_new, h_new)


def slstm_apply(p, x: jax.Array, *, n_heads: int = 4,
                init: Optional[SLSTMCache] = None):
    """x: (B, L, D) -> (out, final state). Sequential lax.scan over L."""
    b, l, d = x.shape
    gx = linear(p["wx"], x).astype(jnp.float32)            # (B, L, 4D)
    if init is None:
        init = slstm_cache_init(b, d)

    def step(state, g):
        new = _slstm_cell(p, g, state, n_heads)
        return new, new.h

    final, hs = jax.lax.scan(step, init, jnp.moveaxis(gx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)             # (B, L, D)
    h = rmsnorm(p["norm"], h)
    out = linear(p["down"],
                 jax.nn.silu(linear(p["gate"], h)) * linear(p["up"], h))
    return out, final


def slstm_step(p, x: jax.Array, cache: SLSTMCache, *, n_heads: int = 4):
    b, _, d = x.shape
    gx = linear(p["wx"], x[:, 0]).astype(jnp.float32)
    new = _slstm_cell(p, gx, cache, n_heads)
    h = rmsnorm(p["norm"], new.h.astype(x.dtype))[:, None]
    out = linear(p["down"],
                 jax.nn.silu(linear(p["gate"], h)) * linear(p["up"], h))
    return out, new


def slstm_cache_init(batch: int, d_model: int, dtype=jnp.float32) -> SLSTMCache:
    z = jnp.zeros((batch, d_model), jnp.float32)
    return SLSTMCache(c=z, n=z, m=jnp.full((batch, d_model), -1e30), h=z)
