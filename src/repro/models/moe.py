"""Mixture-of-Experts layer (olmoe, kimi-k2) with sort-based capacity dispatch.

Experts ARE the morphable array blocks of this plane: tokens are sorted by
expert, padded to tile quanta, and the expert GEMMs run as one grouped
computation — `kernels/grouped_matmul` on TPU, a batched einsum under jit for
the dry-run. Experts shard over the "model" mesh axis (expert parallelism);
the dispatch/combine scatter-gathers become all-to-alls under GSPMD.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain, ctx_dp_axes
from .layers import QuantPolicy, linear_init

__all__ = ["moe_init", "moe_apply", "router_topk"]


def moe_init(key, d_model: int, d_ff: int, n_experts: int,
             n_shared: int = 0, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    scale = d_model ** -0.5
    p = {
        "router": linear_init(ks[0], d_model, n_experts, dtype=dtype),
        # experts stacked on the leading axis -> shard over "model"
        "gate": jax.random.normal(ks[1], (n_experts, d_model, d_ff), dtype) * scale,
        "up": jax.random.normal(ks[2], (n_experts, d_model, d_ff), dtype) * scale,
        "down": jax.random.normal(ks[3], (n_experts, d_ff, d_model), dtype) *
                (d_ff ** -0.5),
    }
    if n_shared:
        from .layers import mlp_init
        p["shared"] = mlp_init(ks[4], d_model, d_ff * n_shared, "swiglu", dtype)
    return p


def router_topk(router_logits: jax.Array, top_k: int,
                norm_probs: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Returns (gates (T, k), expert_ids (T, k)). Softmax-then-topk routing."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    if norm_probs:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, ids


def moe_apply(p, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25,
              policy: QuantPolicy = QuantPolicy()) -> Tuple[jax.Array, jax.Array]:
    """x: (B, L, D) -> (out, aux_loss). Sort-based dispatch with per-expert
    capacity; overflow tokens are dropped (their gate mass is lost), the
    standard GShard/Switch discipline.

    Under a mesh context (set_mesh), routes through the expert-parallel
    shard_map path: experts shard over "model", tokens over the DP axes, and
    the only cross-device traffic is one psum of the (T_loc, D) outputs —
    GSPMD cannot partition the global scatter-add dispatch (it all-gathers
    the full token buffer), so EP must be explicit.
    """
    ep = _ep_context(x, n_experts)
    if ep is not None:
        return _moe_apply_ep(p, x, n_experts=n_experts, top_k=top_k,
                             capacity_factor=capacity_factor, mesh_info=ep,
                             policy=policy)
    b, l, d = x.shape
    xt = x.reshape(b * l, d)
    t = b * l
    logits = jnp.einsum("td,de->te", xt, p["router"]["w"])
    gates, ids = router_topk(logits, top_k)

    # load-balancing auxiliary loss (Switch-style)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = probs.mean(0)                                   # mean router prob
    ce = jnp.zeros((n_experts,)).at[ids.reshape(-1)].add(
        jnp.ones((t * top_k,))) / (t * top_k)            # fraction routed
    aux = n_experts * jnp.sum(me * ce)

    capacity = int(max(top_k * t / n_experts * capacity_factor, 4))

    # ---- sort-based dispatch (no (T, E, C) one-hots) ----
    flat_e = ids.reshape(-1)                             # (T*k,)
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.arange(t * top_k) // top_k
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
    seg_start = jnp.searchsorted(se, jnp.arange(n_experts), side="left")
    pos = jnp.arange(t * top_k) - seg_start[se]          # position within expert
    keep = pos < capacity
    posc = jnp.minimum(pos, capacity - 1)

    # Expert-parallel constraints: expert buffers shard expert-wise over
    # "model" (each shard owns its experts' rows; the scatter below becomes
    # the dispatch all-to-all under GSPMD) — without these hints the
    # partitioner all-gathers the full expert weights per layer.
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    buf = buf.at[se, posc].add(jnp.where(keep[:, None], xt[st], 0))
    buf = constrain(buf, "model", None, None)

    # ---- expert GEMMs: one grouped computation over the expert axis ----
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["up"])
    h = constrain(h, "model", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, p["down"])
    y = constrain(y, "model", None, None)

    # ---- combine (the return all-to-all) ----
    gathered = y[se, posc] * jnp.where(keep, sg, 0.0)[:, None]
    out = jnp.zeros((t, d), y.dtype).at[st].add(gathered)

    if "shared" in p:                                    # kimi-k2 shared expert
        from .layers import mlp
        out = out + mlp(p["shared"], xt, "swiglu", policy)
    return out.reshape(b, l, d).astype(x.dtype), aux


# =============================================================================
# Expert-parallel shard_map path
# =============================================================================

def _ep_context(x, n_experts):
    """(dp_axes, model_size, mesh) if the ambient mesh supports EP here."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return None
    if am is None or am.empty or "model" not in am.axis_names:
        return None
    if any(str(t) != "Auto" for t in am.axis_types):
        return None                         # already inside a manual region
    dp = ctx_dp_axes()
    dp_size = 1
    for a in dp:
        dp_size *= am.shape[a]
    ms = am.shape["model"]
    b, l, _ = x.shape
    if n_experts % ms or (b * l) % dp_size:
        return None
    return dp, dp_size, ms, am


def _moe_apply_ep(p, x, *, n_experts, top_k, capacity_factor, mesh_info,
                  policy=QuantPolicy()):
    from jax.sharding import PartitionSpec as P

    dp, dp_size, ms, am = mesh_info
    b, l, d = x.shape
    t_loc = (b * l) // dp_size
    e_loc = n_experts // ms
    capacity = int(max(top_k * t_loc / n_experts * capacity_factor, 4))

    # sequence-sharded variant: input/output ride the "model" axis on the
    # sequence dim (pairing with tp_block's sequence parallelism) — dispatch
    # costs ONE bf16 all-gather in and one psum-scatter out instead of a
    # full psum of the combined outputs.
    seq_shard = l % ms == 0 and l >= ms
    x_spec = P(dp if dp else None, "model" if seq_shard else None, None)
    e_spec = P("model", None, None)
    rep = P(None, None)

    has_shared = "shared" in p
    col2 = P(None, "model")
    row2 = P("model", None)

    def body(xb, router_w, gate_w, up_w, down_w, shared_p):
        if seq_shard:
            xb = jax.lax.all_gather(xb, "model", axis=1, tiled=True)
        bb, lb, _ = xb.shape
        xt = xb.reshape(bb * lb, d)
        t = bb * lb
        rank = jax.lax.axis_index("model")
        e_lo = rank * e_loc
        logits = jnp.einsum("td,de->te", xt, router_w)
        gates, ids = router_topk(logits, top_k)

        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        me = probs.mean(0)
        ce = jnp.zeros((n_experts,)).at[ids.reshape(-1)].add(
            jnp.ones((t * top_k,))) / (t * top_k)
        aux = n_experts * jnp.sum(me * ce)
        if dp:
            aux = jax.lax.pmean(aux, dp)

        flat_e = ids.reshape(-1)
        flat_g = gates.reshape(-1)
        flat_t = jnp.arange(t * top_k) // top_k
        local = (flat_e >= e_lo) & (flat_e < e_lo + e_loc)
        le = jnp.where(local, flat_e - e_lo, e_loc)          # sentinel bin
        order = jnp.argsort(le, stable=True)
        se, st, sg, kept = le[order], flat_t[order], flat_g[order], local[order]
        seg_start = jnp.searchsorted(se, jnp.arange(e_loc), side="left")
        sec = jnp.minimum(se, e_loc - 1)
        pos = jnp.arange(t * top_k) - seg_start[sec]
        keep = kept & (pos < capacity) & (se < e_loc)
        posc = jnp.clip(pos, 0, capacity - 1)

        buf = jnp.zeros((e_loc, capacity, d), xb.dtype)
        buf = buf.at[sec, posc].add(jnp.where(keep[:, None], xt[st], 0))

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate_w)) * \
            jnp.einsum("ecd,edf->ecf", buf, up_w)
        y = jnp.einsum("ecf,efd->ecd", h, down_w)

        gathered = y[sec, posc] * jnp.where(keep, sg, 0.0)[:, None]
        out = jnp.zeros((t, d), y.dtype).at[st].add(gathered)

        # shared expert (kimi-k2): column/row-parallel inside the SAME
        # shard_map — its partial sums ride the existing combine collective
        # for free (folding it here removed ~6 activation-sized ARs/layer).
        if has_shared:
            hs = jax.nn.silu(jnp.einsum("td,df->tf", xt, shared_p["gate"]["w"])
                             ) * jnp.einsum("td,df->tf", xt, shared_p["up"]["w"])
            out = out + jnp.einsum("tf,fd->td", hs, shared_p["down"]["w"]
                                   ).astype(out.dtype)

        out = out.reshape(bb, lb, d)
        if seq_shard:                                        # combine + scatter
            out = jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                       tiled=True)
        else:
            out = jax.lax.psum(out, "model")                 # combine ranks
        return out.astype(xb.dtype), aux

    shared_specs = {"gate": {"w": col2}, "up": {"w": col2},
                    "down": {"w": row2}} if has_shared else None
    out, aux = jax.shard_map(
        body, mesh=am,
        in_specs=(x_spec, rep, e_spec, e_spec, e_spec, shared_specs),
        out_specs=(x_spec, P()),
        axis_names={"model"} | set(dp), check_vma=False,
    )(x, p["router"]["w"], p["gate"], p["up"], p["down"],
      p.get("shared"))
    return out, aux
