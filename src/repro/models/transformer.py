"""Composable transformer/SSM/MoE model assembly.

A model is a list of SEGMENTS; each segment is a repeating UNIT of block
kinds scanned `count` times (lax.scan over stacked params keeps the HLO one
unit deep regardless of depth — essential for 80-layer dry-run compiles):

    dense archs   [("dense",) x N]
    gemma2        [("dense_local", "dense_global") x N/2]
    kimi-k2       [("dense",) x 1] + [("moe",) x N-1]
    zamba2        [("mamba",)*5 + ("shared_attn",) x N/6]   (shared params!)
    xlstm         [("mlstm",)*7 + ("slstm",) x N/8]
    whisper       encoder [("enc",) x Ne] + decoder [("encdec",) x Nd]

Block kinds own their cache type; decode threads stacked caches through the
same scan. Shared-attention params (zamba2) are closed over, not scanned —
one weight copy, per-invocation KV caches, exactly the published trick.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ssm
from ..core import formats as F
from .attention import (KVCache, PagedKVCache, PagedQuantKVCache,
                        QuantKVCache, attn_apply, attn_init,
                        cross_attn_apply, init_kv_cache, init_paged_kv_cache,
                        pool_block_values, store_pool_blocks)
from .layers import (QuantPolicy, apply_norm, embedding, embedding_init,
                     linear, linear_init, mlp, mlp_init, norm_init)
from .moe import moe_apply, moe_init

__all__ = ["ModelConfig", "init_params", "forward", "loss_fn", "decode_step",
           "init_caches", "reset_slots", "scrub_slots", "set_block_tables",
           "copy_pool_blocks", "gather_pool_blocks", "write_pool_blocks",
           "param_count", "active_param_count",
           "quantize_params", "resident_format"]

# KV-bearing cache types (positional caches with a per-row write frontier)
_KV_TYPES = (KVCache, QuantKVCache, PagedKVCache, PagedQuantKVCache)
_PAGED_TYPES = (PagedKVCache, PagedQuantKVCache)


# =============================================================================
# Config
# =============================================================================

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"
    mlp_kind: str = "swiglu"
    qkv_bias: bool = False
    post_norm: bool = False                  # gemma2 sandwich norms
    softcap_attn: Optional[float] = None
    softcap_final: Optional[float] = None
    sliding_window: Optional[int] = None
    local_global: bool = False               # alternate local/global attention
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    attn_every: int = 0                      # zamba2 shared-attn period
    slstm_every: int = 0                     # xlstm slstm period
    # --- enc-dec / frontends ---
    encoder_layers: int = 0
    cross_attention: bool = False
    frontend: Optional[str] = None           # 'audio' | 'vision' (stub inputs)
    frontend_len: int = 0                    # frames / patches per sample
    max_seq: int = 8192                      # learned-pos table size (whisper)
    learned_pos: bool = False
    # --- capability flags / policies ---
    subquadratic: bool = False               # may run long_500k
    quant: QuantPolicy = QuantPolicy()
    remat: bool = True
    kv_quant: bool = False                   # int8 KV caches (format plane)
    # scan unroll factor for the layer loop. The dry-run lowers with full
    # unroll because XLA cost_analysis counts a while-loop body ONCE — an
    # unrolled module yields exact FLOP/byte/collective totals.
    scan_unroll: int = 1

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def segments(self) -> List[Tuple[Tuple[str, ...], int]]:
        if self.family == "audio":
            return [(("encdec",), self.n_layers)]
        if self.attn_every:                                  # zamba2
            assert self.n_layers % self.attn_every == 0
            unit = ("mamba",) * (self.attn_every - 1) + ("shared_attn",)
            return [(unit, self.n_layers // self.attn_every)]
        if self.slstm_every:                                 # xlstm
            assert self.n_layers % self.slstm_every == 0
            unit = ("mlstm",) * (self.slstm_every - 1) + ("slstm",)
            return [(unit, self.n_layers // self.slstm_every)]
        if self.local_global:                                # gemma2
            assert self.n_layers % 2 == 0
            return [(("dense_local", "dense_global"), self.n_layers // 2)]
        if self.n_experts:                                   # moe
            segs = []
            if self.n_dense_layers:
                segs.append((("dense",), self.n_dense_layers))
            segs.append((("moe",), self.n_layers - self.n_dense_layers))
            return segs
        return [(("dense",), self.n_layers)]

    def block_kinds(self) -> List[str]:
        kinds = []
        for unit, n in self.segments():
            kinds.extend(list(unit) * n)
        return kinds


# =============================================================================
# Block init / apply
# =============================================================================

def _block_init(key, kind: str, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind in ("dense", "dense_local", "dense_global", "moe", "enc"):
        p = {"ln1": norm_init(cfg.norm, d),
             "attn": attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                               cfg.qkv_bias),
             "ln2": norm_init(cfg.norm, d)}
        if cfg.post_norm:
            p["pn1"] = norm_init(cfg.norm, d)
            p["pn2"] = norm_init(cfg.norm, d)
        if kind == "moe":
            p["moe"] = moe_init(ks[1], d, cfg.d_ff, cfg.n_experts,
                                cfg.n_shared_experts)
        else:
            ff = cfg.d_ff if cfg.d_ff else 4 * d
            p["mlp"] = mlp_init(ks[1], d, ff, cfg.mlp_kind)
        return p
    if kind == "shared_attn":
        return _block_init(key, "dense", cfg)
    if kind == "mamba":
        return {"ln": norm_init(cfg.norm, d),
                "mamba": ssm.mamba_init(ks[0], d, d_state=cfg.ssm_state,
                                        expand=cfg.ssm_expand,
                                        headdim=cfg.ssm_headdim)}
    if kind == "mlstm":
        return {"ln": norm_init(cfg.norm, d),
                "mlstm": ssm.mlstm_init(ks[0], d, n_heads=cfg.n_heads)}
    if kind == "slstm":
        return {"ln": norm_init(cfg.norm, d),
                "slstm": ssm.slstm_init(ks[0], d, n_heads=cfg.n_heads)}
    if kind == "encdec":
        return {"ln1": norm_init(cfg.norm, d),
                "attn": attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
                "lnx": norm_init(cfg.norm, d),
                "xattn": attn_init(ks[1], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
                "ln2": norm_init(cfg.norm, d),
                "mlp": mlp_init(ks[2], d, cfg.d_ff, cfg.mlp_kind)}
    raise ValueError(kind)


def _select_rows(new_cache, old_cache, active: jax.Array):
    """Keep new_cache rows where active (B,) is True, old rows elsewhere —
    per-slot masking for recurrent states during a padded batched prefill."""
    def sel(n, o):
        a = active.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)
    return jax.tree.map(sel, new_cache, old_cache)


def _block_apply(kind: str, p, x: jax.Array, cfg: ModelConfig, *,
                 cache=None, memory: Optional[jax.Array] = None,
                 positions: Optional[jax.Array] = None,
                 lengths: Optional[jax.Array] = None):
    """Returns (x, new_cache, aux_loss).

    lengths: (B,) valid-new-token counts for cached paths (see attn_apply);
    recurrent blocks freeze state rows where lengths == 0."""
    aux = jnp.zeros((), jnp.float32)
    pol = cfg.quant
    if kind in ("dense", "dense_local", "dense_global", "moe", "enc",
                "shared_attn"):
        window = None
        if kind == "dense_local" or (kind == "dense" and cfg.sliding_window
                                     and not cfg.local_global):
            window = cfg.sliding_window
        causal = kind != "enc"
        # manual TP+SP fast path (explicit collectives; see tp_block.py) for
        # eligible dense/moe blocks without caches/quant — §Perf iterations 3/4
        if kind in ("dense", "dense_local", "dense_global", "moe") and causal:
            from .tp_block import manual_dense_block, manual_tp_ok
            if manual_tp_ok(cfg, x, cache, pol, params=p) and (
                    kind != "moe" or cfg.n_experts):
                if kind == "moe":
                    x = manual_dense_block(
                        p, x, cfg, window=window, softcap=cfg.softcap_attn,
                        post_norm=cfg.post_norm, with_mlp=False)
                    h = apply_norm(cfg.norm, p["ln2"], x)
                    h, aux = moe_apply(
                        p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                        capacity_factor=cfg.capacity_factor, policy=pol)
                    if cfg.post_norm:
                        h = apply_norm(cfg.norm, p["pn2"], h)
                    return x + h, None, aux
                return manual_dense_block(
                    p, x, cfg, window=window, softcap=cfg.softcap_attn,
                    post_norm=cfg.post_norm), None, aux
        h = apply_norm(cfg.norm, p["ln1"], x)
        h, new_cache = attn_apply(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            causal=causal, window=window, softcap=cfg.softcap_attn,
            rope_theta=cfg.rope_theta, positions=positions, cache=cache,
            lengths=lengths, policy=pol)
        if cfg.post_norm:
            h = apply_norm(cfg.norm, p["pn1"], h)
        x = x + h
        h = apply_norm(cfg.norm, p["ln2"], x)
        if kind == "moe":
            h, aux = moe_apply(
                p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, policy=pol)
        else:
            ff_kind = cfg.mlp_kind
            h = mlp(p["mlp"], h, ff_kind, pol)
        if cfg.post_norm:
            h = apply_norm(cfg.norm, p["pn2"], h)
        return x + h, new_cache, aux
    if kind == "mamba":
        h = apply_norm(cfg.norm, p["ln"], x)
        if cache is None:
            h, _ = ssm.mamba_apply(p["mamba"], h, d_state=cfg.ssm_state,
                                   headdim=cfg.ssm_headdim)
            return x + h, None, aux
        h, new_cache = ssm.mamba_step(p["mamba"], h, cache,
                                      d_state=cfg.ssm_state,
                                      headdim=cfg.ssm_headdim)
        if lengths is not None:
            new_cache = _select_rows(new_cache, cache, lengths > 0)
        return x + h, new_cache, aux
    if kind == "mlstm":
        h = apply_norm(cfg.norm, p["ln"], x)
        if cache is None:
            h, _ = ssm.mlstm_apply(p["mlstm"], h, n_heads=cfg.n_heads)
            return x + h, None, aux
        h, new_cache = ssm.mlstm_step(p["mlstm"], h, cache, n_heads=cfg.n_heads)
        if lengths is not None:
            new_cache = _select_rows(new_cache, cache, lengths > 0)
        return x + h, new_cache, aux
    if kind == "slstm":
        h = apply_norm(cfg.norm, p["ln"], x)
        if cache is None:
            h, _ = ssm.slstm_apply(p["slstm"], h, n_heads=cfg.n_heads)
            return x + h, None, aux
        h, new_cache = ssm.slstm_step(p["slstm"], h, cache, n_heads=cfg.n_heads)
        if lengths is not None:
            new_cache = _select_rows(new_cache, cache, lengths > 0)
        return x + h, new_cache, aux
    if kind == "encdec":
        h = apply_norm(cfg.norm, p["ln1"], x)
        h, new_cache = attn_apply(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            causal=True, rope_theta=cfg.rope_theta, positions=positions,
            cache=cache, lengths=lengths, policy=pol)
        x = x + h
        h = apply_norm(cfg.norm, p["lnx"], x)
        h = cross_attn_apply(p["xattn"], h, memory, n_heads=cfg.n_heads,
                             n_kv=cfg.n_kv_heads, policy=pol)
        x = x + h
        h = apply_norm(cfg.norm, p["ln2"], x)
        return x + mlp(p["mlp"], h, cfg.mlp_kind, pol), new_cache, aux
    raise ValueError(kind)


# =============================================================================
# Caches
# =============================================================================

def _block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16, paged: Optional[Tuple[int, int]] = None):
    if kind in ("dense", "dense_global", "moe", "shared_attn", "encdec",
                "dense_local"):
        if paged is not None:
            pool_blocks, block_size = paged
            nblk = -(-max_len // block_size)
            return init_paged_kv_cache(batch, cfg.n_kv_heads, pool_blocks,
                                       block_size, nblk, cfg.hd, dtype,
                                       quantized=cfg.kv_quant)
        return init_kv_cache(batch, cfg.n_kv_heads, max_len, cfg.hd, dtype,
                             quantized=cfg.kv_quant)
    if kind == "mamba":
        return ssm.mamba_cache_init(batch, cfg.d_model, d_state=cfg.ssm_state,
                                    expand=cfg.ssm_expand,
                                    headdim=cfg.ssm_headdim, dtype=dtype)
    if kind == "mlstm":
        return ssm.mlstm_cache_init(batch, cfg.d_model, n_heads=cfg.n_heads,
                                    dtype=dtype)
    if kind == "slstm":
        return ssm.slstm_cache_init(batch, cfg.d_model)
    if kind == "enc":
        return None
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16, paged: Optional[Tuple[int, int]] = None):
    """Per-segment stacked caches mirroring the stacked-params layout.

    paged: optional (pool_blocks, block_size) — KV caches become block-pool
    PagedKVCache/PagedQuantKVCache trees (recurrent states are positionless
    and keep their per-row layout either way)."""
    caches = []
    for unit, n in cfg.segments():
        seg = {}
        for j, kind in enumerate(unit):
            c = _block_cache(kind, cfg, batch, max_len, dtype, paged=paged)
            seg[f"{j}_{kind}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c) \
                if c is not None else None
        caches.append(seg)
    return caches


def reset_slots(caches, slot_mask: jax.Array,
                new_pos: Optional[jax.Array] = None):
    """Reset cache rows (slots) where slot_mask (B,) is True to their initial
    state, leaving other rows untouched — the slot-refill primitive for
    continuous batching. KV caches only rewind pos: stale K/V rows sit beyond
    the new causal frontier, so they are invisible to attention and each slot
    is overwritten before the frontier reaches it. Recurrent states are
    re-zeroed (slstm stabilizer m to its -inf-like init).

    new_pos: optional (B,) frontier to rewind TO instead of 0 — the paged
    engine admits a request with a shared prompt prefix by pointing the
    row's block table at the shared blocks and starting it at pos ==
    shared-token count.

    Cache leaves are the stacked (n_layers, B, ...) trees from init_caches.
    """
    cache_types = _KV_TYPES + (ssm.MambaCache, ssm.MLSTMCache, ssm.SLSTMCache)
    pos_to = 0 if new_pos is None else new_pos[None, :]

    def rows(a, value):
        m = slot_mask.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, jnp.asarray(value, a.dtype), a)

    def reset(c):
        if isinstance(c, _KV_TYPES):
            return c._replace(pos=jnp.where(slot_mask[None, :], pos_to, c.pos))
        if isinstance(c, ssm.SLSTMCache):
            return ssm.SLSTMCache(c=rows(c.c, 0), n=rows(c.n, 0),
                                  m=rows(c.m, -1e30), h=rows(c.h, 0))
        return jax.tree.map(lambda a: rows(a, 0), c)

    return jax.tree.map(reset, caches,
                        is_leaf=lambda x: isinstance(x, cache_types))


def scrub_slots(caches, slot_mask: jax.Array):
    """`reset_slots` plus VALUE scrubbing: rows where slot_mask (B,) is True
    get their cache VALUES re-initialized (KV values and int8 codes to 0,
    quant scales to 1), not just their positions rewound.

    `reset_slots` leans on causal masking to make stale rows invisible,
    which is sound for FINITE stale values but not for non-finite ones: an
    additive attention mask turns `NaN + (-inf)` into NaN, so a poisoned
    K/V row could leak through the very mask that hides ordinary stale
    data. The serving engine's quarantine path scrubs the offending slot
    before it is ever reused; everything else keeps using the cheap
    `reset_slots`.
    """
    cache_types = _KV_TYPES + (ssm.MambaCache, ssm.MLSTMCache, ssm.SLSTMCache)

    def rows(a, value):
        m = slot_mask.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, jnp.asarray(value, a.dtype), a)

    def pos0(pos):
        return jnp.where(slot_mask[None, :], 0, pos)

    def paged_scrub(c):
        # Scrub every physical block REFERENCED by a scrubbed row — including
        # blocks shared with other rows (a poisoned NaN in a shared block
        # must not survive into another tenant's attention; the engine
        # quarantines + replays the co-sharing rows it finds host-side).
        n, _, nblk = c.table.shape
        pool = (c.k if isinstance(c, PagedKVCache) else c.k_codes).shape[1]
        lay = jnp.broadcast_to(jnp.arange(n)[:, None, None], c.table.shape)
        hit = jnp.broadcast_to(slot_mask[None, :, None], c.table.shape)
        bmask = jnp.zeros((n, pool), bool).at[
            lay.reshape(-1), c.table.reshape(-1)].max(hit.reshape(-1))

        def blocks(a, value):
            m = bmask.reshape(bmask.shape + (1,) * (a.ndim - 2))
            return jnp.where(m, jnp.asarray(value, a.dtype), a)

        if isinstance(c, PagedKVCache):
            return c._replace(k=blocks(c.k, 0), v=blocks(c.v, 0),
                              pos=pos0(c.pos))
        return c._replace(k_codes=blocks(c.k_codes, 0),
                          k_scale=blocks(c.k_scale, 1),
                          v_codes=blocks(c.v_codes, 0),
                          v_scale=blocks(c.v_scale, 1),
                          pos=pos0(c.pos))

    def scrub(c):
        if isinstance(c, _PAGED_TYPES):
            return paged_scrub(c)
        if isinstance(c, KVCache):
            return KVCache(k=rows(c.k, 0), v=rows(c.v, 0), pos=pos0(c.pos))
        if isinstance(c, QuantKVCache):
            return QuantKVCache(k_codes=rows(c.k_codes, 0),
                                k_scale=rows(c.k_scale, 1),
                                v_codes=rows(c.v_codes, 0),
                                v_scale=rows(c.v_scale, 1),
                                pos=pos0(c.pos))
        if isinstance(c, ssm.SLSTMCache):
            return ssm.SLSTMCache(c=rows(c.c, 0), n=rows(c.n, 0),
                                  m=rows(c.m, -1e30), h=rows(c.h, 0))
        return jax.tree.map(lambda a: rows(a, 0), c)

    return jax.tree.map(scrub, caches,
                        is_leaf=lambda x: isinstance(x, cache_types))


def set_block_tables(caches, table: jax.Array):
    """Install a host-computed (B, nblk) block table into every paged cache
    leaf (the allocator keeps one logical table; each layer's pool gets the
    same map, broadcast over the stacked leading axis)."""
    def st(c):
        if isinstance(c, _PAGED_TYPES):
            n = c.table.shape[0]
            t = jnp.broadcast_to(table.astype(jnp.int32)[None],
                                 (n,) + table.shape)
            return c._replace(table=t)
        return c

    return jax.tree.map(st, caches,
                        is_leaf=lambda x: isinstance(x, _PAGED_TYPES))


def copy_pool_blocks(caches, src: jax.Array, dst: jax.Array):
    """Copy physical pool blocks src[i] -> dst[i] in every paged cache leaf —
    the device half of copy-on-write (fork a shared block before a row
    writes into it). src/dst are fixed-width (C,) int32; entries equal to
    the pool size are padding (the read clamps, the write drops)."""
    def mv(a):                                   # (n, P, H, bs, ...)
        pool = a.shape[1]
        vals = jnp.take(a, jnp.clip(src, 0, pool - 1), axis=1)
        return a.at[:, dst].set(vals, mode="drop")

    def cp(c):
        if isinstance(c, PagedKVCache):
            return c._replace(k=mv(c.k), v=mv(c.v))
        if isinstance(c, PagedQuantKVCache):
            return c._replace(k_codes=mv(c.k_codes), k_scale=mv(c.k_scale),
                              v_codes=mv(c.v_codes), v_scale=mv(c.v_scale))
        return c

    return jax.tree.map(cp, caches,
                        is_leaf=lambda x: isinstance(x, _PAGED_TYPES))


def gather_pool_blocks(caches, ids: jax.Array):
    """Read physical pool blocks `ids` ((C,) int32) out of every paged cache
    leaf. Returns a tree shaped like `caches` with each paged leaf replaced
    by its dict of (n_layers, C, H, bs, ...) block values (non-paged leaves
    become None); `write_pool_blocks` is the exact inverse. This is the
    device half of KV swap-out: the serving engine runs it at the
    scheduler boundary — never inside the jitted step (HL206) — and moves
    the result to host memory."""
    def gather(c):
        if isinstance(c, _PAGED_TYPES):
            return pool_block_values(c, ids)
        return None

    return jax.tree.map(gather, caches,
                        is_leaf=lambda x: isinstance(x, _PAGED_TYPES))


def write_pool_blocks(caches, values, dst: jax.Array):
    """Scatter `gather_pool_blocks`-shaped block values back into the pool
    at physical blocks `dst` ((C,) int32; entries equal to the pool size are
    padding and are dropped, so a sentinel-padded fixed-width dst traces
    once — same convention as `copy_pool_blocks`). The device half of KV
    swap-in: restored bytes are exactly the gathered bytes, so a preempted
    row resumes byte-identically."""
    def put(c, vals):
        if isinstance(c, _PAGED_TYPES):
            return store_pool_blocks(c, vals, dst)
        return c

    return jax.tree.map(put, caches, values,
                        is_leaf=lambda x: isinstance(x, _PAGED_TYPES))


# =============================================================================
# Params
# =============================================================================

def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embedding_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(keys[1], cfg.d_model, cfg.vocab,
                                        dtype=dtype)
    if cfg.learned_pos:
        params["pos"] = jax.random.normal(
            keys[2], (cfg.max_seq, cfg.d_model), dtype) * 0.01

    # encoder stack (audio family)
    if cfg.encoder_layers:
        ek = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: _block_init(k, "enc", cfg))(ek)
        params["enc_norm"] = norm_init(cfg.norm, cfg.d_model)

    # main stack, segment by segment
    segs = []
    kidx = 4
    for unit, n in cfg.segments():
        seg = {}
        for j, kind in enumerate(unit):
            if kind == "shared_attn":
                # ONE weight copy reused across all n invocations (zamba2)
                seg[f"{j}_{kind}"] = _block_init(
                    jax.random.fold_in(keys[kidx % 8], j), kind, cfg)
            else:
                ks = jax.random.split(jax.random.fold_in(keys[kidx % 8], j), n)
                seg[f"{j}_{kind}"] = jax.vmap(
                    lambda k: _block_init(k, kind, cfg))(ks)
            kidx += 1
        segs.append(seg)
    params["segments"] = segs
    return params


# =============================================================================
# Weight residency — quantize the Linear weights ONCE, serve from codes.
# =============================================================================

# Param subtrees whose weights must stay dense. "router"/"mamba"/"mlstm"/
# "slstm"/"lm_head" linears never receive the model's QuantPolicy (raw-einsum
# consumers or policy-less call sites), so the fake-quant reference path
# leaves them dense — residency mirrors that coverage exactly. "moe" stays
# dense wholesale because the expert-parallel shard_map path addresses its
# weights by raw pytree structure; the shared expert's linears fall back to
# the fake-quant plane under `QuantPolicy.weights`, which is the SAME math —
# so resident and fake-quant serving stay byte-identical everywhere.
_RESIDENT_SKIP = ("router", "mamba", "mlstm", "slstm", "lm_head", "moe")


def quantize_params(params, fmt: str, *, skip=_RESIDENT_SKIP):
    """Convert each policy-covered Linear's `w` into a `formats.QuantWeight`
    (int4 packed two-per-byte along K, int8/fp8 codes; per-output-channel
    pow2 scales). The pass is jit-able and donation-friendly: untouched
    leaves (embeddings, norms, biases, recurrent/router weights) alias the
    input buffers, so `jax.jit(..., donate_argnums=(0,))` frees the dense
    f32 weights as the codes are built — HBM never holds both pytrees.

    Works on the stacked per-layer layout `init_params` produces: a stacked
    (n_layers, K, N) weight becomes stacked (n_layers, K', N) codes whose
    leading axis `lax.scan` slices exactly like the dense leaves;
    `forward`/`decode_step` accept the converted pytree unchanged.
    """
    if fmt not in F.RESIDENT_FORMATS:
        raise ValueError(f"resident weight format {fmt!r} not in "
                         f"{F.RESIDENT_FORMATS}")

    def walk(node, path):
        if isinstance(node, dict):
            if any(s in path for s in skip):
                return node
            if "w" in node and not isinstance(node["w"], F.QuantWeight) \
                    and getattr(node["w"], "ndim", 0) >= 2:
                out = dict(node)
                out["w"] = F.quantize_weight(node["w"], fmt)
                return out
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path) for v in node)
        return node

    return walk(params, ())


def resident_format(params) -> Optional[str]:
    """The residency format of a param pytree (None when weights are dense)."""
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, F.QuantWeight)):
        if isinstance(leaf, F.QuantWeight):
            return leaf.fmt
    return None


# =============================================================================
# Forward
# =============================================================================

def _run_segment(seg_params, unit: Tuple[str, ...], n: int, x: jax.Array,
                 cfg: ModelConfig, memory=None, positions=None,
                 seg_caches=None, lengths=None):
    """Scan the unit n times; returns (x, new_caches, aux)."""
    scanned = {k: v for k, v in seg_params.items()
               if not k.endswith("shared_attn")}
    shared = {k: v for k, v in seg_params.items()
              if k.endswith("shared_attn")}
    caches = seg_caches or {}

    def body(carry, xs):
        h, aux = carry
        layer_params, layer_caches = xs
        new_caches = {}
        for j, kind in enumerate(unit):
            key = f"{j}_{kind}"
            p = shared[key] if key in shared else layer_params[key]
            c = layer_caches.get(key) if layer_caches else None
            h, nc, a = _block_apply(kind, p, h, cfg, cache=c, memory=memory,
                                    positions=positions, lengths=lengths)
            aux = aux + a
            if nc is not None:
                new_caches[key] = nc
        return (h, aux), new_caches

    if cfg.remat and seg_caches is None:
        body = jax.checkpoint(body)

    xs_caches = {k: v for k, v in caches.items() if v is not None}
    unroll = min(cfg.scan_unroll, n) if cfg.scan_unroll > 1 else 1
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        ({k: v for k, v in scanned.items()}, xs_caches), unroll=unroll)
    return x, new_caches, aux


def _positions(cfg: ModelConfig, b: int, l: int, offset=0):
    return jnp.arange(l) + offset


def forward(params, tokens: jax.Array, cfg: ModelConfig, *,
            prefix_embeds: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None):
    """Full-sequence forward. tokens: (B, L) -> logits (B, L, V).

    prefix_embeds: VLM patch embeddings prepended to the token stream.
    frames: audio-family encoder inputs (B, T_enc, d_model) from the stub
    frontend. Returns (logits, aux_loss).
    """
    b, l = tokens.shape
    x = embedding(params["embed"], tokens)
    memory = None

    if cfg.family == "audio":
        assert frames is not None
        mem = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
        for i in range(cfg.encoder_layers):
            p_i = jax.tree.map(lambda a: a[i], params["encoder"])
            mem, _, _ = _block_apply("enc", p_i, mem, cfg)
        memory = apply_norm(cfg.norm, params["enc_norm"], mem)

    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)

    if cfg.learned_pos:
        x = x + params["pos"][:x.shape[1]]
    if cfg.name.startswith("gemma"):
        x = x * math.sqrt(cfg.d_model)       # gemma2 embedding scaling

    # sequence-parallel residual stream: sequence sharded over "model"
    # between blocks (no-op without a mesh / when L doesn't divide)
    from .layers import _tp
    x = _tp(x, "model", None)
    aux_total = jnp.zeros((), jnp.float32)
    for (unit, n), seg in zip(cfg.segments(), params["segments"]):
        x, _, aux = _run_segment(seg, unit, n, x, cfg, memory=memory)
        aux_total = aux_total + aux

    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = _unembed(params, x, cfg)
    return logits, aux_total


def _unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bld,vd->blv", x, params["embed"]["table"],
                            preferred_element_type=jnp.float32)
    else:
        logits = linear(params["lm_head"], x).astype(jnp.float32)
    if cfg.softcap_final:
        logits = cfg.softcap_final * jnp.tanh(logits / cfg.softcap_final)
    return logits


def _sinusoid(length: int, d: int, dtype):
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (dim / (d // 2)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            aux_weight: float = 0.01):
    """Causal-LM cross entropy (+ MoE aux). batch: tokens, labels[, frames,
    patch_embeds]. labels = -100 masks a position out."""
    logits, aux = forward(params, batch["tokens"], cfg,
                          prefix_embeds=batch.get("patch_embeds"),
                          frames=batch.get("frames"))
    labels = batch["labels"]
    mask = labels >= 0
    labels_safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], -1)[..., 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


# =============================================================================
# Decode
# =============================================================================

def decode_step(params, caches, token: jax.Array, cfg: ModelConfig, *,
                memory: Optional[jax.Array] = None,
                lengths: Optional[jax.Array] = None):
    """One decode step. token: (B, l) -> (logits (B, l, V), new caches).
    l is usually 1; a one-shot batched prefill passes the whole (right-padded)
    prompt block with `lengths` (B,) marking each row's valid-token count —
    rows with lengths[b] == 0 keep caches and positions untouched.

    Caches carry per-row positions (KVCache.pos (B,)) / recurrent states;
    lowering this with a seq_len-sized cache is what the decode_32k/long_500k
    dry-run cells measure.
    """
    b, l = token.shape
    x = embedding(params["embed"], token)
    if cfg.learned_pos:
        # per-row position from the first attn cache (slots sit at their own
        # positions under continuous batching); clip guards rows idling past
        # the table — their logits are never consumed
        pos = _first_pos(caches)
        if pos.ndim == 0:
            pos = jnp.broadcast_to(pos, (b,))
        idx = jnp.clip(pos[:, None] + jnp.arange(l),
                       0, params["pos"].shape[0] - 1)
        x = x + jnp.take(params["pos"], idx, axis=0)
    new_caches = []
    for (unit, n), seg, seg_c in zip(cfg.segments(), params["segments"], caches):
        x, nc, _ = _run_segment(seg, unit, n, x, cfg, memory=memory,
                                seg_caches=seg_c, lengths=lengths)
        new_caches.append(nc)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return _unembed(params, x, cfg), new_caches


def _first_pos(caches):
    """Position of the first KV cache: (B,) per-row vector from the stacked
    (n, B) leaf, or a scalar from a legacy (n,) batch-global stack."""
    for seg in caches:
        for v in seg.values():
            if isinstance(v, _KV_TYPES):
                return v.pos[0] if v.pos.ndim else v.pos
    return jnp.zeros((), jnp.int32)


# =============================================================================
# Accounting (roofline MODEL_FLOPS)
# =============================================================================

def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ModelConfig) -> int:
    """MoE: only top_k of n_experts participate per token."""
    total = param_count(params)
    if not cfg.n_experts:
        return total
    expert_leaves = 0
    for seg in params["segments"]:
        for key, blk in seg.items():
            if "moe" in key and isinstance(blk, dict) and "moe" in blk:
                for nm in ("gate", "up", "down"):
                    expert_leaves += blk["moe"][nm].size
    inactive = expert_leaves * (1 - cfg.top_k / cfg.n_experts)
    return int(total - inactive)
