from .adamw import (AdamWState, adamw_init, adamw_update, clip_by_global_norm,  # noqa: F401
                    cosine_schedule, global_norm)
from .grad_compress import (compressed_grad_allreduce, compressed_psum,  # noqa: F401
                            init_error_state)
