"""Compressed data-parallel gradient all-reduce over the AIO formats.

The paper's format plane applied to *communication*: gradients are quantized
to int8/fp8 with a power-of-two shared scale (the programmable-bias trick —
dequantization is an exponent shift) and summed in the narrow domain, cutting
DP all-reduce bytes 4x (int8) vs fp32. Error feedback accumulates the
quantization residual locally and re-injects it next step, which keeps SGD
convergence (Karimireddy et al.'s EF-SGD argument).

Used through shard_map so the collective is explicit in the lowered HLO —
the §Perf collective-bytes lever for DP-bound cells.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core import formats as F

__all__ = ["compressed_psum", "compressed_grad_allreduce", "init_error_state"]


def compressed_psum(x: jax.Array, axis_name, fmt: F.AIOFormat) -> jax.Array:
    """psum(x) over axis_name with int-domain summation at fmt precision.

    Scale is the pmax of |x| mapped to a power of two, shared across the
    axis so the int sum is exact in int32 (members <= 127 * world fits).
    """
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    amax = jnp.maximum(amax, 1e-30)
    scale = F.pow2_ceil(amax / fmt.max_finite)        # pow2 >= amax/max_finite
    if fmt.kind == "int":
        q = jnp.clip(jnp.round(x / scale), fmt.int_min, fmt.int_max)
        s = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return s.astype(jnp.float32) * scale
    q = F.quantize(x / scale, fmt)
    return jax.lax.psum(q, axis_name) * scale


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grad_allreduce(grads, err, mesh: Mesh, *, fmt_name: str = "int8",
                              dp_axis: str = "data"
                              ) -> Tuple[Any, Any]:
    """Mean-reduce per-device grads over the DP axis with error feedback.

    grads: pytree of *unreduced* per-device gradients laid out with their
    TP sharding; the DP axis is reduced here (explicitly, compressed) instead
    of by autodiff's implicit psum. err: residual pytree (same layout).
    Returns (reduced grads, new err).
    """
    fmt = F.REGISTRY[fmt_name]
    world = mesh.shape[dp_axis]

    def one(g, e):
        spec = P(*([None] * g.ndim))

        @functools.partial(shard_map, mesh=mesh, in_specs=(spec, spec),
                           out_specs=(spec, spec), check_rep=False)
        def body(gl, el):
            x = gl.astype(jnp.float32) + el
            summed = compressed_psum(x, dp_axis, fmt)
            mean = summed / world
            # residual of what this shard contributed vs what got through
            new_e = x - _roundtrip(x, fmt)
            return mean.astype(gl.dtype), new_e

        return body(g, e)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e


def _roundtrip(x: jax.Array, fmt: F.AIOFormat) -> jax.Array:
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    scale = F.pow2_ceil(amax / fmt.max_finite)
    if fmt.kind == "int":
        return jnp.clip(jnp.round(x / scale), fmt.int_min, fmt.int_max) * scale
    return F.quantize(x / scale, fmt) * scale
