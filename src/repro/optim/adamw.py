"""AdamW with bf16 params + fp32 master weights, built for sharded training.

State layout mirrors the param pytree, so `dist.sharding.opt_state_specs`
shards moments/master identically to (or, ZeRO-1, more finely than) params.
Mixed precision follows the paper's footnote 1: one precision per step —
bf16/FP8 forward/backward, fp32 master update.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    master: Any          # fp32 master copy of the (possibly bf16) params


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        # copy=True: with f32 params .astype would alias the param buffer and
        # break donation (same buffer donated twice in the train step)
        master=jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                            params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: x * factor.astype(x.dtype), tree), norm


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, wd: float = 0.1,
                 clip: Optional[float] = 1.0):
    """Returns (new_params, new_state, grad_norm). lr may be a scalar or a
    schedule value computed outside."""
    if clip is not None:
        grads, gnorm = clip_by_global_norm(grads, clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / c1
        vhat = nu / c2
        m_new = m - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * m)
        return mu, nu, m_new

    flat_g = jax.tree.leaves(grads)
    tdef = jax.tree.structure(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    flat_m = jax.tree.leaves(state.master)
    new_mu, new_nu, new_m = [], [], []
    for g, mu, nu, m in zip(flat_g, flat_mu, flat_nu, flat_m):
        a, b, c = upd(g, mu, nu, m)
        new_mu.append(a)
        new_nu.append(b)
        new_m.append(c)
    new_state = AdamWState(step,
                           jax.tree.unflatten(tdef, new_mu),
                           jax.tree.unflatten(tdef, new_nu),
                           jax.tree.unflatten(tdef, new_m))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype),
                              new_state.master, params)
    return new_params, new_state, gnorm


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
