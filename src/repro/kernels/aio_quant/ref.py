"""Pure-jnp oracle for the tile quantizer: core.formats.quantize_scaled."""
from __future__ import annotations

import jax

from ...core import formats as F

__all__ = ["aio_quant_ref"]


def aio_quant_ref(x: jax.Array, *, fmt_name: str):
    """Returns (codes int32, per-row pow2 scale f32 (M,1))."""
    fmt = F.REGISTRY[fmt_name]
    codes, scale = F.quantize_scaled(x, fmt, axis=1, pow2=True)
    return codes, scale
