from .ops import aio_quantize  # noqa: F401
from .ref import aio_quant_ref  # noqa: F401
from .kernel import aio_quant_pallas  # noqa: F401
from . import contract  # noqa: F401  (registers launch contracts)
