"""Pallas tile-wise quantizer — the vector-unit's quantization stage (§V-A).

Quantizes a 2D tensor to any AIOFormat, one (bm x bn) VMEM tile per grid step,
emitting int8 codes plus a per-row power-of-two scale (the bias-foldable kind).
Two grid passes in one kernel: column-block 0 computes the row scale from a
pre-reduced row-max input; every block then encodes with that scale.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import encode_fp_code, interpret_mode
from ...core.formats import REGISTRY, pow2_ceil

__all__ = ["aio_quant_pallas", "quant_index_maps"]


def quant_index_maps():
    """BlockSpec index maps of a quantize launch, grid = (i, j).

    Module-level so the launch assembly and the `repro.analysis` contract
    checker evaluate the SAME functions.
    """
    return {
        "x": lambda i, j: (i, j),
        "rowmax": lambda i, j: (i, 0),
        "codes": lambda i, j: (i, j),
        "scale": lambda i, j: (i, 0),
    }


def _q_kernel(x_ref, rowmax_ref, codes_ref, scale_ref, *, fmt_name: str):
    fmt = REGISTRY[fmt_name]
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.maximum(rowmax_ref[...], jnp.float32(1e-30))   # (bm, 1)
    # power-of-two scale: 2^ceil(log2(amax / max_finite)); pow2_ceil keeps
    # the scale bit-identical with the aio_quant_ref oracle (exact powers of
    # two map to themselves — the naive frexp exponent doubled them)
    scale = pow2_ceil(amax / fmt.max_finite)
    xs = x / scale
    if fmt.kind == "fp":
        codes = encode_fp_code(xs, fmt.ebits, fmt.mbits, fmt.bias)
    else:
        q = jnp.clip(jnp.round(xs), fmt.int_min, fmt.int_max).astype(jnp.int32)
        codes = q & ((1 << fmt.bits) - 1)
    codes_ref[...] = codes.astype(jnp.int8)
    @pl.when(pl.program_id(1) == 0)
    def _():
        scale_ref[...] = scale


def aio_quant_pallas(x: jax.Array, *, fmt_name: str, bm: int = 128,
                     bn: int = 128,
                     interpret: Optional[bool] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """x (M, N) f32 -> (codes int8 (M, N), row scale f32 (M, 1)).

    M, N must be tile multiples (ops.py pads).
    """
    if interpret is None:
        interpret = interpret_mode()
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0
    rowmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)       # vector-unit prepass
    grid = (m // bm, n // bn)
    maps = quant_index_maps()
    return pl.pallas_call(
        functools.partial(_q_kernel, fmt_name=fmt_name),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), maps["x"]),
                  pl.BlockSpec((bm, 1), maps["rowmax"])],
        out_specs=[pl.BlockSpec((bm, bn), maps["codes"]),
                   pl.BlockSpec((bm, 1), maps["scale"])],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.int8),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        interpret=interpret,
    )(x, rowmax)
