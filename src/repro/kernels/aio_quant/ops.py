"""Public quantize op with Pallas / pure-JAX dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import common
from .kernel import aio_quant_pallas
from .ref import aio_quant_ref

__all__ = ["aio_quantize"]


def aio_quantize(x: jax.Array, *, fmt_name: str, bm: int = 128, bn: int = 128,
                 prefer_pallas: bool | None = None):
    """x (M, N) -> (codes int8, per-row pow2 scale (M, 1))."""
    use_pallas = common.pallas_enabled() if prefer_pallas is None else prefer_pallas
    if not use_pallas:
        codes, scale = aio_quant_ref(x, fmt_name=fmt_name)
        return codes.astype(jnp.int8), scale.astype(jnp.float32)
    m, n = x.shape
    xp = common.pad_to(common.pad_to(x, bm, 0), bn, 1)
    codes, scale = aio_quant_pallas(xp, fmt_name=fmt_name, bm=bm, bn=bn)
    return codes[:m, :n], scale[:m]
