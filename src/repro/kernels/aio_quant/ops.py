"""Quantize op: registry implementations + legacy shim."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import common
from ...api.policy import ExecutionPolicy
from ...api.registry import register
from .kernel import aio_quant_pallas
from .ref import aio_quant_ref

__all__ = ["aio_quantize"]


@register("quantize", "ref")
def _quantize_ref(x: jax.Array, *, policy: ExecutionPolicy):
    codes, scale = aio_quant_ref(x, fmt_name=policy.format)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)


@register("quantize", "pallas")
def _quantize_pallas(x: jax.Array, *, policy: ExecutionPolicy):
    m, n = x.shape
    xp = common.pad_to(common.pad_to(x, policy.bm, 0), policy.bn, 1)
    codes, scale = aio_quant_pallas(xp, fmt_name=policy.format, bm=policy.bm,
                                    bn=policy.bn)
    return codes[:m, :n], scale[:m]


def aio_quantize(x: jax.Array, *, fmt_name: str, bm: int = 128, bn: int = 128,
                 prefer_pallas: bool | None = None):
    """Deprecated: call `repro.api.ops.quantize` (policy-driven) instead."""
    from ... import api
    return api.ops.quantize(
        x, format=fmt_name, bm=bm, bn=bn,
        backend=api.ops.backend_from_prefer_pallas(prefer_pallas))
