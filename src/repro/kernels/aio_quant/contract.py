"""Launch contract for the tile-wise quantizer pallas impl.

Mirrors `ops._quantize_pallas`: the input pads to (bm, bn) multiples, the
row-max prepass rides along as a (M, 1) operand, and the launch emits int8
codes plus a per-row scale column.
"""
from __future__ import annotations

from ...api.policy import ExecutionPolicy
from ...api.registry import BlockContract, LaunchContract, register_contract
from ..common import ceil_div
from .kernel import quant_index_maps

__all__ = ["quantize_contract"]

_CASES = ({"m": 96, "n": 320}, {"m": 256, "n": 96})
_SWEEP = ("bm", "bn")


@register_contract("quantize", "pallas", cases=_CASES, sweep_fields=_SWEEP)
def quantize_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    m, n = case["m"], case["n"]
    bm, bn = policy.bm, policy.bn
    mp = ceil_div(m, bm) * bm
    np_ = ceil_div(n, bn) * bn
    maps = quant_index_maps()
    return LaunchContract(
        grid=(mp // bm, np_ // bn),
        blocks=(
            BlockContract("x", (mp, np_), (bm, bn), maps["x"]),
            BlockContract("rowmax", (mp, 1), (bm, 1), maps["rowmax"]),
            BlockContract("codes", (mp, np_), (bm, bn), maps["codes"],
                          dtype_bytes=1),
            BlockContract("scale", (mp, 1), (bm, 1), maps["scale"]),
        ),
    )
