"""Launch contract for the tile-wise quantizer pallas impl.

Mirrors `ops._quantize_pallas`: the input pads to (bm, bn) multiples, the
row-max prepass rides along as a (M, 1) operand, and the launch emits int8
codes plus a per-row scale column. The scale output is written only by the
j == 0 column pass (`pl.when(program_id(1) == 0)`), so every other column
block legally revisits it — declared as ``revisits=(1,)`` and proved by
the KB410 race detector. Cases sweep int and fp encode paths (the kernel
body branches on the format kind).
"""
from __future__ import annotations

import jax.numpy as jnp

from ...api.policy import ExecutionPolicy
from ...api.registry import BlockContract, LaunchContract, register_contract
from ..common import ceil_div
from .kernel import aio_quant_pallas, quant_index_maps

__all__ = ["quantize_contract"]

_CASES = (
    {"m": 96, "n": 320, "fmt": "int8"},
    {"m": 256, "n": 96, "fmt": "int8"},
    {"m": 96, "n": 96, "fmt": "fp8a"},
    {"m": 96, "n": 96, "fmt": "int4"},
)
_SWEEP = ("bm", "bn")


@register_contract("quantize", "pallas", cases=_CASES, sweep_fields=_SWEEP)
def quantize_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    m, n, fmt = case["m"], case["n"], case["fmt"]
    bm, bn = policy.bm, policy.bn
    mp = ceil_div(m, bm) * bm
    np_ = ceil_div(n, bn) * bn
    maps = quant_index_maps()

    def body():
        return aio_quant_pallas(jnp.zeros((mp, np_), jnp.float32),
                                fmt_name=fmt, bm=bm, bn=bn)

    return LaunchContract(
        grid=(mp // bm, np_ // bn),
        blocks=(
            BlockContract("x", (mp, np_), (bm, bn), maps["x"]),
            BlockContract("rowmax", (mp, 1), (bm, 1), maps["rowmax"]),
            BlockContract("codes", (mp, np_), (bm, bn), maps["codes"],
                          dtype_bytes=1, is_output=True, quant=fmt),
            BlockContract("scale", (mp, 1), (bm, 1), maps["scale"],
                          is_output=True, revisits=(1,),
                          scale_for="codes"),
        ),
        body=body,
    )
