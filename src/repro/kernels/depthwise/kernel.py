"""Pallas depthwise-conv kernel — the unaccumulable-op mapping (paper Fig 9).

A rigid systolic array maps C_in to its rows, so depthwise conv (no C_in
accumulation) strands all but K*K rows. The All-rounder instead makes the
*filter taps* the contraction: 9-row subarray groups hold one filter's taps,
channels ride the 64-wide columns. The TPU-native translation: channels ride
the 128 lanes (VPU/MXU minor dim), taps become the kernel's reduction loop —
kh runs on the grid (tap-blocks of the input are streamed HBM->VMEM, the
double-buffered-SPM analogue), kw unrolls inside the kernel over the loaded
row, and a VMEM accumulator carries the partial sums.

Layout: NHWC. ops.py pre-shifts the padded input into a (kh, N, H_out, W_pad,
C) tap stack so every grid block is a clean BlockSpec rectangle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import interpret_mode

__all__ = ["depthwise_pallas", "depthwise_index_maps"]


def depthwise_index_maps():
    """BlockSpec index maps of a depthwise launch, grid = (n, h, c, dh).

    Module-level so the launch assembly and the `repro.analysis` contract
    checker evaluate the SAME functions.
    """
    return {
        "x_taps": lambda n_, h, ci, dh: (dh, n_, h, 0, ci),
        "filt": lambda n_, h, ci, dh: (dh, 0, ci),
        "out": lambda n_, h, ci, dh: (n_, h, 0, ci),
    }


def _dw_kernel(x_ref, f_ref, o_ref, acc_ref, *, kh: int, kw: int, w_out: int):
    """Grid = (n, h_tile, c_tile, dh). x block: (1, 1, bh, W_pad, bc);
    f block: (1, kw, bc); out block: (1, bh, w_out, bc)."""
    dh = pl.program_id(3)

    @pl.when(dh == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0, 0]                       # (bh, W_pad, bc)
    f = f_ref[0]                          # (kw, bc)
    acc = acc_ref[...]
    for dw in range(kw):                  # static unroll — taps as contraction
        acc = acc + x[:, dw:dw + w_out, :] * f[dw][None, None, :]
    acc_ref[...] = acc

    @pl.when(dh == kh - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def depthwise_pallas(x_taps: jax.Array, filt: jax.Array, *, w_out: int,
                     bh: int = 8, bc: int = 128,
                     interpret: Optional[bool] = None) -> jax.Array:
    """x_taps: (kh, N, H_out, W_pad, C) pre-shifted rows; filt: (kh, kw, C).

    Returns (N, H_out, w_out, C). H_out % bh == 0, C % bc == 0 (ops.py pads).
    """
    if interpret is None:
        interpret = interpret_mode()
    kh, n, h_out, w_pad, c = x_taps.shape
    _, kw, _ = filt.shape
    assert h_out % bh == 0 and c % bc == 0, (x_taps.shape, bh, bc)
    grid = (n, h_out // bh, c // bc, kh)
    maps = depthwise_index_maps()

    return pl.pallas_call(
        functools.partial(_dw_kernel, kh=kh, kw=kw, w_out=w_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bh, w_pad, bc), maps["x_taps"]),
            pl.BlockSpec((1, kw, bc), maps["filt"]),
        ],
        out_specs=pl.BlockSpec((1, bh, w_out, bc), maps["out"]),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, c), x_taps.dtype),
        scratch_shapes=[pltpu.VMEM((bh, w_out, bc), jnp.float32)],
        interpret=interpret,
    )(x_taps, filt)
