from .ops import depthwise_conv  # noqa: F401
from .ref import depthwise_ref  # noqa: F401
from .kernel import depthwise_pallas  # noqa: F401
from . import contract  # noqa: F401  (registers launch contracts)
