"""Launch contract for the depthwise-conv pallas impl.

Mirrors `ops._depthwise_pallas`: the SAME-padded input becomes a
(kh, N, H, W_pad, C) tap stack, H pads to bh and C to bc, and the kernel
runs a (n, h_tile, c_tile, dh) grid with the tap axis outermost-iterated
innermost so the VMEM accumulator carries across taps.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...api.policy import ExecutionPolicy
from ...api.registry import BlockContract, LaunchContract, register_contract
from ..common import ceil_div
from .kernel import depthwise_index_maps, depthwise_pallas

__all__ = ["depthwise_contract"]

_CASES = (
    {"n": 2, "h": 12, "w": 20, "c": 96, "kh": 3, "kw": 3},
    {"n": 1, "h": 7, "w": 7, "c": 320, "kh": 5, "kw": 5},
)
_SWEEP = ("bh", "bc")


@register_contract("depthwise_conv", "pallas", cases=_CASES,
                   sweep_fields=_SWEEP)
def depthwise_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    n, h, w, c = case["n"], case["h"], case["w"], case["c"]
    kh, kw = case["kh"], case["kw"]
    bh, bc = policy.bh, policy.bc
    hp = ceil_div(h, bh) * bh
    cp = ceil_div(c, bc) * bc
    w_pad = w + kw - 1                          # SAME padding, stride 1
    maps = depthwise_index_maps()

    def body():
        return depthwise_pallas(
            jnp.zeros((kh, n, hp, w_pad, cp), jnp.float32),
            jnp.zeros((kh, kw, cp), jnp.float32), w_out=w, bh=bh, bc=bc)

    return LaunchContract(
        grid=(n, hp // bh, cp // bc, kh),
        blocks=(
            BlockContract("x_taps", (kh, n, hp, w_pad, cp),
                          (1, 1, bh, w_pad, bc), maps["x_taps"]),
            BlockContract("filt", (kh, kw, cp), (1, kw, bc), maps["filt"]),
            # the tap axis (grid dim 3) accumulates into the VMEM scratch
            # and writes the output block once — a declared revisit
            BlockContract("out", (n, hp, w, cp), (1, bh, w, bc), maps["out"],
                          is_output=True, revisits=(3,)),
        ),
        scratch_bytes=bh * w * bc * 4,          # f32 accumulator
        body=body,
    )
