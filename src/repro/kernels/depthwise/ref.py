"""Pure-jnp oracle for depthwise conv (NHWC, VALID on pre-padded input)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["depthwise_ref"]


def depthwise_ref(x: jax.Array, filt: jax.Array, stride: int = 1,
                  padding: str = "SAME") -> jax.Array:
    """x: (N, H, W, C); filt: (kh, kw, C) -> (N, H_out, W_out, C)."""
    kh, kw, c = filt.shape
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        filt.astype(jnp.float32).reshape(kh, kw, 1, c),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return out.astype(x.dtype)
