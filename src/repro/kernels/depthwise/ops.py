"""Depthwise conv (stride 1, SAME): registry implementations + legacy shim."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import common
from ...api.policy import ExecutionPolicy
from ...api.registry import register
from .kernel import depthwise_pallas
from .ref import depthwise_ref

__all__ = ["depthwise_conv"]


@register("depthwise_conv", "ref")
def _depthwise_ref(x: jax.Array, filt: jax.Array, *,
                   policy: ExecutionPolicy) -> jax.Array:
    return depthwise_ref(x, filt, stride=1, padding="SAME")


@register("depthwise_conv", "pallas")
def _depthwise_pallas(x: jax.Array, filt: jax.Array, *,
                      policy: ExecutionPolicy) -> jax.Array:
    bh, bc = policy.bh, policy.bc
    n, h, w, c = x.shape
    kh, kw, _ = filt.shape
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    # tap stack: x_taps[dh] = rows dh..dh+H-1 of the padded input
    x_taps = jnp.stack([xp[:, dh:dh + h, :, :] for dh in range(kh)], axis=0)
    # pad H to bh and C to bc
    hp = common.ceil_div(h, bh) * bh
    cp = common.ceil_div(c, bc) * bc
    x_taps = jnp.pad(x_taps, ((0, 0), (0, 0), (0, hp - h), (0, 0), (0, cp - c)))
    f = jnp.pad(filt, ((0, 0), (0, 0), (0, cp - c)))
    out = depthwise_pallas(x_taps, f, w_out=w, bh=bh, bc=bc)
    return out[:, :h, :, :c]


def depthwise_conv(x: jax.Array, filt: jax.Array, *, bh: int = 8,
                   bc: int = 128, prefer_pallas: bool | None = None) -> jax.Array:
    """Deprecated: call `repro.api.ops.depthwise_conv` (policy-driven)."""
    from ... import api
    return api.ops.depthwise_conv(
        x, filt, bh=bh, bc=bc,
        backend=api.ops.backend_from_prefer_pallas(prefer_pallas))
