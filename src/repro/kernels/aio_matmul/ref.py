"""Pure-jnp oracle for the multi-format matmul kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...core import formats as F

__all__ = ["aio_matmul_ref", "quantize_operands_ref"]


def quantize_operands_ref(x: jax.Array, w: jax.Array, mode: str):
    """Quantize f32 operands exactly as ops.py does: per-row scales for x,
    per-col scales for w, pow2 scaling (bias-foldable). Returns
    (x_codes, w_codes, x_scale, w_scale) in the kernel's expected layouts
    (int4 stays unpacked here; ops.py packs)."""
    if mode == "bf16":
        return x.astype(jnp.bfloat16), w.astype(jnp.bfloat16), None, None
    fmt = F.REGISTRY[mode]
    x_codes, x_scale = F.quantize_scaled(x, fmt, axis=1, pow2=True)
    w_codes, w_scale = F.quantize_scaled(w, fmt, axis=0, pow2=True)
    return x_codes, w_codes, x_scale.astype(jnp.float32), w_scale.astype(jnp.float32)


def aio_matmul_ref(x_codes, w_codes, x_scale: Optional[jax.Array],
                   w_scale: Optional[jax.Array], *, mode: str,
                   out_dtype=jnp.float32) -> jax.Array:
    """Decode -> f32 matmul -> rescale. Codes are *unpacked* (int4 included)."""
    if mode == "bf16":
        out = jnp.dot(x_codes.astype(jnp.float32), w_codes.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        return out.astype(out_dtype)
    fmt = F.REGISTRY[mode]
    xv = F.decode(x_codes, fmt)
    wv = F.decode(w_codes, fmt)
    out = jnp.dot(xv, wv, preferred_element_type=jnp.float32)
    if x_scale is not None:
        out = out * x_scale * w_scale
    return out.astype(out_dtype)
