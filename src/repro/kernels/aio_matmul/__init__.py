from .ops import aio_matmul, aio_matmul_codes  # noqa: F401
from .ref import aio_matmul_ref, quantize_operands_ref  # noqa: F401
from .kernel import aio_matmul_pallas, MODES  # noqa: F401
from . import contract  # noqa: F401  (registers launch contracts)
