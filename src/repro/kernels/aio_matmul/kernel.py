"""Pallas multi-format matmul — the MAC-array plane of the all-in-one multiplier.

One kernel body, five operating modes (bf16 / fp8a / fp8b / int8 / int4),
mirroring Fig 7's mode gating:
  * bf16  — native MXU matmul (the 8b-significand path).
  * fp8a/fp8b — codes decoded to f32 in VMEM (VPU work), MXU matmul, f32 acc.
  * int8  — integer dot with int32 accumulation (CSM-only path, Fig 7-d).
  * int4  — codes packed 2-per-byte along K; unpacked in VMEM. Packing halves
    HBM traffic and doubles effective lanes — the software realization of the
    "4 results per multiplier" throughput morph (Table III 128x128 -> 256x256).

Scaling factors are applied on the final tile write as a per-row x per-col
outer product; power-of-two scales correspond to the paper's programmable
exponent bias (no extra multipliers on hardware).

BlockSpec tiling: (bm x bk) @ (bk x bn) with a VMEM accumulator, grid
(M/bm, N/bn, K/bk), K innermost so the accumulator lives across the K loop.
Tiles are MXU-aligned (multiples of 128 in lanes; sublane quantum per dtype).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import decode_fp_code, interpret_mode
from ...core.formats import REGISTRY

__all__ = ["aio_matmul_pallas", "matmul_index_maps", "MODES"]

MODES = ("bf16", "fp8a", "fp8b", "int8", "int4")


def matmul_index_maps():
    """BlockSpec index maps of an AIO matmul launch, grid = (i, j, k).

    Module-level so the launch assembly and the `repro.analysis` contract
    checker evaluate the SAME functions.
    """
    return {
        "x": lambda i, j, k: (i, k),
        "w": lambda i, j, k: (k, j),
        "xs": lambda i, j, k: (i, 0),
        "ws": lambda i, j, k: (0, j),
        "out": lambda i, j, k: (i, j),
    }


def _mm_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, mode: str,
               nsteps: int, out_dtype):
    """Grid = (i, j, k); acc_ref is VMEM scratch carried over the k loop."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if mode == "bf16":
        x = x_ref[...]
        w = w_ref[...]
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    elif mode in ("fp8a", "fp8b"):
        fmt = REGISTRY[mode]
        x = decode_fp_code(x_ref[...], fmt.ebits, fmt.mbits, fmt.bias)
        w = decode_fp_code(w_ref[...], fmt.ebits, fmt.mbits, fmt.bias)
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    elif mode == "int8":
        x = x_ref[...].astype(jnp.int32)
        w = w_ref[...].astype(jnp.int32)
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.int32)
    elif mode == "int4":
        # packed along K: byte b holds K=2b (low nibble) and K=2b+1 (high);
        # dot(lo,lo) covers even K, dot(hi,hi) odd K — together the full
        # contraction, with half the HBM traffic (the 4x-results morph).
        xlo, xhi = unpack_x(x_ref[...])
        wlo, whi = unpack_w(w_ref[...])
        acc_ref[...] += jnp.dot(xlo, wlo, preferred_element_type=jnp.int32)
        acc_ref[...] += jnp.dot(xhi, whi, preferred_element_type=jnp.int32)
    else:  # pragma: no cover
        raise ValueError(mode)

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _finish():
        acc = acc_ref[...].astype(jnp.float32)
        if xs_ref is not None:
            acc = acc * xs_ref[...] * ws_ref[...]
        o_ref[...] = acc.astype(out_dtype)


def unpack_x(packed):
    """x packed along its last (K) axis: (bm, bk//2) int8 -> two (bm, bk//2)
    int32 operands for even/odd K. Even/odd split keeps dot shapes aligned."""
    p32 = packed.astype(jnp.int32)
    lo = (p32 << 28) >> 28
    hi = p32 >> 4
    return lo, hi


def unpack_w(packed):
    """w packed along its first (K) axis: (bk//2, bn) int8 -> (lo, hi)."""
    p32 = packed.astype(jnp.int32)
    lo = (p32 << 28) >> 28
    hi = p32 >> 4
    return lo, hi


def aio_matmul_pallas(x, w, x_scale: Optional[jax.Array],
                      w_scale: Optional[jax.Array], *, mode: str,
                      out_dtype=jnp.float32, bm: int = 128, bn: int = 128,
                      bk: int = 128, interpret: Optional[bool] = None):
    """x:(M,K[,/2]) w:(K[,/2],N) in mode's code dtype; scales (M,1)/(1,N) f32.

    Shapes must be pre-padded to tile multiples by ops.py. int4 mode expects
    K pre-packed (two nibbles per byte) and bk counts *packed* bytes.
    """
    if mode not in MODES:
        raise ValueError(f"mode {mode} not in {MODES}")
    if interpret is None:
        interpret = interpret_mode()
    m, kx = x.shape
    kw, n = w.shape
    assert kx == kw, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and kx % bk == 0, \
        f"unpadded shapes {x.shape}x{w.shape} for tiles ({bm},{bn},{bk})"
    grid = (m // bm, n // bn, kx // bk)

    has_scale = x_scale is not None
    if has_scale:
        assert w_scale is not None
        assert x_scale.shape == (m, 1) and w_scale.shape == (1, n)

    acc_dtype = jnp.int32 if mode in ("int8", "int4") else jnp.float32
    kernel = functools.partial(_mm_kernel, mode=mode, nsteps=grid[2],
                               out_dtype=out_dtype)
    maps = matmul_index_maps()
    in_specs = [
        pl.BlockSpec((bm, bk), maps["x"]),
        pl.BlockSpec((bk, bn), maps["w"]),
    ]
    args = [x, w]
    if has_scale:
        in_specs += [pl.BlockSpec((bm, 1), maps["xs"]),
                     pl.BlockSpec((1, bn), maps["ws"])]
        args += [x_scale, w_scale]
        body = kernel
    else:
        body = lambda xr, wr, o, a: kernel(xr, wr, None, None, o, a)  # noqa: E731

    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), maps["out"]),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(*args)
