"""Quantized multi-format matmul: registry implementations + legacy shim.

The vector-unit part (quantization, per-channel scaling — §V-A assigns this
to the 128-ALU vector unit) runs as plain XLA; the MAC-array part runs in the
Pallas kernel ("pallas" impl; interpret mode in tests, real kernels on TPU)
or the jnp oracle ("ref" impl). Both register into `repro.api`'s
KernelRegistry — `repro.api.ops.matmul` is the public entry; `aio_matmul`
remains as a deprecated kwarg-compatible shim.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .. import common
from ...api.policy import ExecutionPolicy
from ...api.registry import register
from ...core import formats as F
from .kernel import aio_matmul_pallas
from .ref import aio_matmul_ref, quantize_operands_ref

__all__ = ["aio_matmul", "aio_matmul_codes", "aio_matmul_resident"]


def _pack_k_last(codes: jax.Array) -> jax.Array:
    """Pack int4 codes along the last axis (x layout)."""
    return F.pack_int4(codes)


def _pack_k_first(codes: jax.Array) -> jax.Array:
    """Pack int4 codes along the first axis (w layout)."""
    return F.pack_int4(codes.T).T


# =============================================================================
# Registry implementations (policy is static: retraces per format/backend)
# =============================================================================

@register("matmul", "ref")
@functools.partial(jax.jit, static_argnames=("policy",))
def _matmul_ref(x: jax.Array, w: jax.Array, *,
                policy: ExecutionPolicy) -> jax.Array:
    assert x.shape[1] == w.shape[0]
    xq, wq, xs, ws = quantize_operands_ref(x, w, policy.format)
    return aio_matmul_ref(xq, wq, xs, ws, mode=policy.format,
                          out_dtype=policy.out_dtype)


@register("matmul", "pallas")
@functools.partial(jax.jit, static_argnames=("policy",))
def _matmul_pallas(x: jax.Array, w: jax.Array, *,
                   policy: ExecutionPolicy) -> jax.Array:
    assert x.shape[1] == w.shape[0]
    xq, wq, xs, ws = quantize_operands_ref(x, w, policy.format)
    return aio_matmul_codes(xq, wq, xs, ws, mode=policy.format,
                            out_dtype=policy.out_dtype, bm=policy.bm,
                            bn=policy.bn, bk=policy.bk)


# =============================================================================
# Resident-weight implementations: w arrives as a formats.QuantWeight (codes
# packed once at load); only the activations are quantized per call.
# =============================================================================

@register("matmul_codes", "ref")
@functools.partial(jax.jit, static_argnames=("policy",))
def _matmul_codes_ref(x: jax.Array, wq: F.QuantWeight, *,
                      policy: ExecutionPolicy) -> jax.Array:
    """Dequantize-then-einsum oracle. Uses the exact contraction the dense
    fake-quant `linear` path uses, and `dequantize_weight` reproduces the
    per-output-channel fake-quant bitwise — so greedy serving with resident
    weights is byte-identical to the fake-quant reference path."""
    wv = F.dequantize_weight(wq)
    out = jnp.einsum("...d,df->...f", x, wv,
                     preferred_element_type=jnp.float32)
    return out.astype(policy.out_dtype)


@register("matmul_codes", "pallas")
@functools.partial(jax.jit, static_argnames=("policy",))
def _matmul_codes_pallas(x: jax.Array, wq: F.QuantWeight, *,
                         policy: ExecutionPolicy) -> jax.Array:
    lead = x.shape[:-1]
    fmt = F.REGISTRY[wq.fmt]
    x2 = x.reshape(-1, wq.k)
    # the vector-unit stage runs only on the activations now: per-row codes
    # + pow2 scales, same geometry as quantize_operands_ref's x operand
    xq, xs = F.quantize_scaled(x2, fmt, axis=1, pow2=True)
    out = aio_matmul_resident(xq, wq, xs.astype(jnp.float32),
                              out_dtype=policy.out_dtype, bm=policy.bm,
                              bn=policy.bn, bk=policy.bk)
    return out.reshape(*lead, out.shape[-1])


# =============================================================================
# Kernel entry on pre-quantized codes (also used directly by tests)
# =============================================================================

def aio_matmul_codes(xq, wq, xs, ws, *, mode: str, out_dtype=jnp.float32,
                     bm: int = 128, bn: int = 128, bk: int = 128):
    """Kernel entry on already-quantized codes (unpacked layouts).

    Pads to tile multiples, packs int4, strips padding from the result.
    """
    m, k = xq.shape
    _, n = wq.shape
    if mode == "int4":
        # pack along K *after* padding K to 2*bk so packed K is bk-aligned
        xq = common.pad_to(xq, 2 * bk, axis=1)
        wq = common.pad_to(wq, 2 * bk, axis=0)
        xq = _pack_k_last(xq)
        wq = _pack_k_first(wq)
    else:
        kmult = bk
        xq = common.pad_to(xq, kmult, axis=1)
        wq = common.pad_to(wq, kmult, axis=0)
        if mode in ("fp8a", "fp8b", "int8"):
            xq = xq.astype(jnp.int8)
            wq = wq.astype(jnp.int8)
    xq = common.pad_to(xq, bm, axis=0)
    wq = common.pad_to(wq, bn, axis=1)
    if xs is not None:
        xs = common.pad_to(xs.astype(jnp.float32), bm, axis=0)
        ws = common.pad_to(ws.astype(jnp.float32), bn, axis=1)
    out = aio_matmul_pallas(xq, wq, xs, ws, mode=mode, out_dtype=out_dtype,
                            bm=bm, bn=bn, bk=bk)
    return out[:m, :n]


def aio_matmul_resident(xq, wq: F.QuantWeight, xs, *, out_dtype=jnp.float32,
                        bm: int = 128, bn: int = 128, bk: int = 128):
    """Kernel entry where the weight is already resident codes.

    xq: (M, K) UNPACKED activation codes (int32 container) with per-row
    scales xs (M, 1); wq carries the pre-packed weight codes and per-column
    scales. Skips the weight half of the quantize-operands stage entirely —
    int4 weight bytes go to the kernel as stored (the pad bytes appended
    here are zero nibbles, matching the zero-padded activation codes).
    """
    if wq.codes.ndim != 2:
        raise ValueError("kernel entry takes an unstacked (K[/2], N) weight; "
                         f"got codes shape {wq.codes.shape}")
    mode = wq.fmt
    m, k = xq.shape
    assert k == wq.k, (xq.shape, wq.k)
    n = wq.codes.shape[-1]
    wcodes = wq.codes
    ws = wq.scale.reshape(1, n).astype(jnp.float32)
    if mode == "int4":
        # pad K to 2*bk BEFORE packing so packed K is bk-aligned; the stored
        # w codes are already packed — pad ceil(K/2) bytes up to the same
        # packed length (ceil(ceil(K/2)/bk) == ceil(K/(2*bk)))
        xq = _pack_k_last(common.pad_to(xq, 2 * bk, axis=1))
        wcodes = common.pad_to(wcodes, bk, axis=0)
    else:
        xq = common.pad_to(xq, bk, axis=1).astype(jnp.int8)
        wcodes = common.pad_to(wcodes, bk, axis=0)
    xq = common.pad_to(xq, bm, axis=0)
    wcodes = common.pad_to(wcodes, bn, axis=1)
    xs = common.pad_to(xs.astype(jnp.float32), bm, axis=0)
    ws = common.pad_to(ws, bn, axis=1)
    out = aio_matmul_pallas(xq, wcodes, xs, ws, mode=mode, out_dtype=out_dtype,
                            bm=bm, bn=bn, bk=bk)
    return out[:m, :n]


# =============================================================================
# Deprecated shim (old per-kernel kwargs -> policy overrides)
# =============================================================================

def aio_matmul(x: jax.Array, w: jax.Array, *, mode: str = "bf16",
               out_dtype=jnp.float32, bm: int = 128, bn: int = 128,
               bk: int = 128, prefer_pallas: Optional[bool] = None) -> jax.Array:
    """Deprecated: call `repro.api.ops.matmul` (policy-driven) instead."""
    from ... import api
    return api.ops.matmul(
        x, w, format=mode, out_dtype=out_dtype, bm=bm, bn=bn, bk=bk,
        backend=api.ops.backend_from_prefer_pallas(prefer_pallas))
