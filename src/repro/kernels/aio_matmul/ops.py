"""Public op: quantized multi-format matmul with Pallas/pure-JAX dispatch.

`aio_matmul(x, w, mode=...)` is what model code calls. The vector-unit part
(quantization, per-channel scaling — §V-A assigns this to the 128-ALU vector
unit) runs as plain XLA; the MAC-array part dispatches to the Pallas kernel
when enabled (TPU, or interpret mode in tests) and to the jnp oracle
otherwise, so the multi-pod dry-run lowers cleanly on any backend.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .. import common
from ...core import formats as F
from .kernel import aio_matmul_pallas
from .ref import aio_matmul_ref, quantize_operands_ref

__all__ = ["aio_matmul", "aio_matmul_codes"]


def _pack_k_last(codes: jax.Array) -> jax.Array:
    """Pack int4 codes along the last axis (x layout)."""
    return F.pack_int4(codes)


def _pack_k_first(codes: jax.Array) -> jax.Array:
    """Pack int4 codes along the first axis (w layout)."""
    return F.pack_int4(codes.T).T


@functools.partial(jax.jit, static_argnames=("mode", "out_dtype", "bm", "bn",
                                             "bk", "prefer_pallas"))
def aio_matmul(x: jax.Array, w: jax.Array, *, mode: str = "bf16",
               out_dtype=jnp.float32, bm: int = 128, bn: int = 128,
               bk: int = 128, prefer_pallas: Optional[bool] = None) -> jax.Array:
    """Quantize f32/bf16 operands to `mode` and multiply. Returns (M, N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    xq, wq, xs, ws = quantize_operands_ref(x, w, mode)

    use_pallas = common.pallas_enabled() if prefer_pallas is None else prefer_pallas
    if not use_pallas:
        return aio_matmul_ref(xq, wq, xs, ws, mode=mode, out_dtype=out_dtype)
    return aio_matmul_codes(xq, wq, xs, ws, mode=mode, out_dtype=out_dtype,
                            bm=bm, bn=bn, bk=bk)


def aio_matmul_codes(xq, wq, xs, ws, *, mode: str, out_dtype=jnp.float32,
                     bm: int = 128, bn: int = 128, bk: int = 128):
    """Kernel entry on already-quantized codes (unpacked layouts).

    Pads to tile multiples, packs int4, strips padding from the result.
    """
    m, k = xq.shape
    _, n = wq.shape
    if mode == "int4":
        # pack along K *after* padding K to 2*bk so packed K is bk-aligned
        xq = common.pad_to(xq, 2 * bk, axis=1)
        wq = common.pad_to(wq, 2 * bk, axis=0)
        xq = _pack_k_last(xq)
        wq = _pack_k_first(wq)
    else:
        kmult = bk
        xq = common.pad_to(xq, kmult, axis=1)
        wq = common.pad_to(wq, kmult, axis=0)
        if mode in ("fp8a", "fp8b", "int8"):
            xq = xq.astype(jnp.int8)
            wq = wq.astype(jnp.int8)
    xq = common.pad_to(xq, bm, axis=0)
    wq = common.pad_to(wq, bn, axis=1)
    mp, np_ = xq.shape[0], wq.shape[1]
    if xs is not None:
        xs = common.pad_to(xs.astype(jnp.float32), bm, axis=0)
        ws = common.pad_to(ws.astype(jnp.float32), bn, axis=1)
    out = aio_matmul_pallas(xq, wq, xs, ws, mode=mode, out_dtype=out_dtype,
                            bm=bm, bn=bn, bk=bk)
    return out[:m, :n]
