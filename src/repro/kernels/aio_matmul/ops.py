"""Quantized multi-format matmul: registry implementations + legacy shim.

The vector-unit part (quantization, per-channel scaling — §V-A assigns this
to the 128-ALU vector unit) runs as plain XLA; the MAC-array part runs in the
Pallas kernel ("pallas" impl; interpret mode in tests, real kernels on TPU)
or the jnp oracle ("ref" impl). Both register into `repro.api`'s
KernelRegistry — `repro.api.ops.matmul` is the public entry; `aio_matmul`
remains as a deprecated kwarg-compatible shim.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .. import common
from ...api.policy import ExecutionPolicy
from ...api.registry import register
from ...core import formats as F
from .kernel import aio_matmul_pallas
from .ref import aio_matmul_ref, quantize_operands_ref

__all__ = ["aio_matmul", "aio_matmul_codes"]


def _pack_k_last(codes: jax.Array) -> jax.Array:
    """Pack int4 codes along the last axis (x layout)."""
    return F.pack_int4(codes)


def _pack_k_first(codes: jax.Array) -> jax.Array:
    """Pack int4 codes along the first axis (w layout)."""
    return F.pack_int4(codes.T).T


# =============================================================================
# Registry implementations (policy is static: retraces per format/backend)
# =============================================================================

@register("matmul", "ref")
@functools.partial(jax.jit, static_argnames=("policy",))
def _matmul_ref(x: jax.Array, w: jax.Array, *,
                policy: ExecutionPolicy) -> jax.Array:
    assert x.shape[1] == w.shape[0]
    xq, wq, xs, ws = quantize_operands_ref(x, w, policy.format)
    return aio_matmul_ref(xq, wq, xs, ws, mode=policy.format,
                          out_dtype=policy.out_dtype)


@register("matmul", "pallas")
@functools.partial(jax.jit, static_argnames=("policy",))
def _matmul_pallas(x: jax.Array, w: jax.Array, *,
                   policy: ExecutionPolicy) -> jax.Array:
    assert x.shape[1] == w.shape[0]
    xq, wq, xs, ws = quantize_operands_ref(x, w, policy.format)
    return aio_matmul_codes(xq, wq, xs, ws, mode=policy.format,
                            out_dtype=policy.out_dtype, bm=policy.bm,
                            bn=policy.bn, bk=policy.bk)


# =============================================================================
# Kernel entry on pre-quantized codes (also used directly by tests)
# =============================================================================

def aio_matmul_codes(xq, wq, xs, ws, *, mode: str, out_dtype=jnp.float32,
                     bm: int = 128, bn: int = 128, bk: int = 128):
    """Kernel entry on already-quantized codes (unpacked layouts).

    Pads to tile multiples, packs int4, strips padding from the result.
    """
    m, k = xq.shape
    _, n = wq.shape
    if mode == "int4":
        # pack along K *after* padding K to 2*bk so packed K is bk-aligned
        xq = common.pad_to(xq, 2 * bk, axis=1)
        wq = common.pad_to(wq, 2 * bk, axis=0)
        xq = _pack_k_last(xq)
        wq = _pack_k_first(wq)
    else:
        kmult = bk
        xq = common.pad_to(xq, kmult, axis=1)
        wq = common.pad_to(wq, kmult, axis=0)
        if mode in ("fp8a", "fp8b", "int8"):
            xq = xq.astype(jnp.int8)
            wq = wq.astype(jnp.int8)
    xq = common.pad_to(xq, bm, axis=0)
    wq = common.pad_to(wq, bn, axis=1)
    if xs is not None:
        xs = common.pad_to(xs.astype(jnp.float32), bm, axis=0)
        ws = common.pad_to(ws.astype(jnp.float32), bn, axis=1)
    out = aio_matmul_pallas(xq, wq, xs, ws, mode=mode, out_dtype=out_dtype,
                            bm=bm, bn=bn, bk=bk)
    return out[:m, :n]


# =============================================================================
# Deprecated shim (old per-kernel kwargs -> policy overrides)
# =============================================================================

def aio_matmul(x: jax.Array, w: jax.Array, *, mode: str = "bf16",
               out_dtype=jnp.float32, bm: int = 128, bn: int = 128,
               bk: int = 128, prefer_pallas: Optional[bool] = None) -> jax.Array:
    """Deprecated: call `repro.api.ops.matmul` (policy-driven) instead."""
    from ... import api
    return api.ops.matmul(
        x, w, format=mode, out_dtype=out_dtype, bm=bm, bn=bn, bk=bk,
        backend=api.ops.backend_from_prefer_pallas(prefer_pallas))
