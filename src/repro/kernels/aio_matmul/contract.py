"""Launch contracts for the AIO matmul pallas impls.

Each contract rebuilds — in pure Python, without tracing — the exact
geometry `ops.aio_matmul_codes` / `ops.aio_matmul_resident` would hand to
`aio_matmul_pallas`: the same padding arithmetic (including the int4
pack-along-K rule: K pads to a 2*bk multiple BEFORE packing so the packed
byte length is bk-aligned) and the same module-level index maps.
`repro.analysis` sweeps these over (case x policy) and flags geometry bugs
before any kernel runs.
"""
from __future__ import annotations

from ...api.policy import ExecutionPolicy
from ...api.registry import BlockContract, LaunchContract, register_contract
from ..common import ceil_div
from .kernel import MODES, matmul_index_maps

__all__ = ["matmul_contract", "matmul_codes_contract"]

# One case per operating mode; shapes deliberately NOT tile multiples so the
# contract exercises the padding arithmetic, and small enough that the full
# grid sweep stays cheap.
_CASES = tuple({"m": 96, "k": 192, "n": 160, "mode": mode} for mode in MODES)
_SWEEP = ("bm", "bn", "bk")


def _matmul_launch(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    """The padded aio_matmul_pallas launch for quantized (or resident) codes."""
    m, k, n, mode = case["m"], case["k"], case["n"], case["mode"]
    bm, bn, bk = policy.bm, policy.bn, policy.bk
    mp = ceil_div(m, bm) * bm
    np_ = ceil_div(n, bn) * bn
    if mode == "int4":
        # K pads to 2*bk then packs two nibbles per byte -> bk-aligned bytes
        kp = ceil_div(k, 2 * bk) * bk
    else:
        kp = ceil_div(k, bk) * bk
    x_bytes = 2 if mode == "bf16" else 1          # bf16 operands vs int8 codes
    maps = matmul_index_maps()

    blocks = [
        BlockContract("x", (mp, kp), (bm, bk), maps["x"], dtype_bytes=x_bytes),
        BlockContract("w", (kp, np_), (bk, bn), maps["w"], dtype_bytes=x_bytes),
    ]
    if mode != "bf16":                            # scaled modes carry (xs, ws)
        blocks += [
            BlockContract("xs", (mp, 1), (bm, 1), maps["xs"]),
            BlockContract("ws", (1, np_), (1, bn), maps["ws"]),
        ]
    blocks.append(BlockContract("out", (mp, np_), (bm, bn), maps["out"]))
    return LaunchContract(
        grid=(mp // bm, np_ // bn, kp // bk),
        blocks=tuple(blocks),
        scratch_bytes=bm * bn * 4,                # VMEM accumulator
    )


@register_contract("matmul", "pallas", cases=_CASES, sweep_fields=_SWEEP)
def matmul_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    return _matmul_launch(case, policy)


@register_contract("matmul_codes", "pallas", cases=_CASES, sweep_fields=_SWEEP)
def matmul_codes_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    # resident weights pad the stored packed codes to the same bk-aligned
    # length the codes path produces (ceil(ceil(K/2)/bk) == ceil(K/(2*bk))),
    # so the launch geometry is identical
    return _matmul_launch(case, policy)
