"""Launch contracts for the AIO matmul pallas impls.

Each contract rebuilds — in pure Python, without tracing — the exact
geometry `ops.aio_matmul_codes` / `ops.aio_matmul_resident` would hand to
`aio_matmul_pallas`: the same padding arithmetic (including the int4
pack-along-K rule: K pads to a 2*bk multiple BEFORE packing so the packed
byte length is bk-aligned) and the same module-level index maps.
`repro.analysis` sweeps these over (case x policy) and flags geometry bugs
before any kernel runs.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...api.policy import ExecutionPolicy
from ...api.registry import BlockContract, LaunchContract, register_contract
from ..common import ceil_div
from .kernel import MODES, aio_matmul_pallas, matmul_index_maps

__all__ = ["matmul_contract", "matmul_codes_contract"]

# One case per operating mode; shapes deliberately NOT tile multiples so the
# contract exercises the padding arithmetic, and small enough that the full
# grid sweep stays cheap.
_CASES = tuple({"m": 96, "k": 192, "n": 160, "mode": mode} for mode in MODES)
_SWEEP = ("bm", "bn", "bk")


def _matmul_launch(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    """The padded aio_matmul_pallas launch for quantized (or resident) codes."""
    m, k, n, mode = case["m"], case["k"], case["n"], case["mode"]
    bm, bn, bk = policy.bm, policy.bn, policy.bk
    mp = ceil_div(m, bm) * bm
    np_ = ceil_div(n, bn) * bn
    if mode == "int4":
        # K pads to 2*bk then packs two nibbles per byte -> bk-aligned bytes
        kp = ceil_div(k, 2 * bk) * bk
    else:
        kp = ceil_div(k, bk) * bk
    x_bytes = 2 if mode == "bf16" else 1          # bf16 operands vs int8 codes
    quant = None if mode == "bf16" else mode
    maps = matmul_index_maps()

    blocks = [
        BlockContract("x", (mp, kp), (bm, bk), maps["x"], dtype_bytes=x_bytes,
                      quant=quant),
        BlockContract("w", (kp, np_), (bk, bn), maps["w"],
                      dtype_bytes=x_bytes, quant=quant),
    ]
    if mode != "bf16":                            # scaled modes carry (xs, ws)
        blocks += [
            BlockContract("xs", (mp, 1), (bm, 1), maps["xs"],
                          scale_for="x"),
            BlockContract("ws", (1, np_), (1, bn), maps["ws"],
                          scale_for="w"),
        ]
    # the K loop is grid dim 2: every K step revisits the same (i, j) output
    # block and accumulates into the VMEM scratch — declared, so the KB410
    # race detector proves it is the ONLY dim that revisits
    blocks.append(BlockContract("out", (mp, np_), (bm, bn), maps["out"],
                                is_output=True, revisits=(2,)))

    def body():
        code_dt = jnp.bfloat16 if mode == "bf16" else jnp.int8
        x = jnp.zeros((mp, kp), code_dt)
        w = jnp.zeros((kp, np_), code_dt)
        xs = ws = None
        if mode != "bf16":
            xs = jnp.zeros((mp, 1), jnp.float32)
            ws = jnp.zeros((1, np_), jnp.float32)
        return aio_matmul_pallas(x, w, xs, ws, mode=mode, bm=bm, bn=bn,
                                 bk=bk)

    return LaunchContract(
        grid=(mp // bm, np_ // bn, kp // bk),
        blocks=tuple(blocks),
        scratch_bytes=bm * bn * 4,                # VMEM accumulator
        body=body,
    )


@register_contract("matmul", "pallas", cases=_CASES, sweep_fields=_SWEEP)
def matmul_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    return _matmul_launch(case, policy)


@register_contract("matmul_codes", "pallas", cases=_CASES, sweep_fields=_SWEEP)
def matmul_codes_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    # resident weights pad the stored packed codes to the same bk-aligned
    # length the codes path produces (ceil(ceil(K/2)/bk) == ceil(K/(2*bk))),
    # so the launch geometry is identical
    return _matmul_launch(case, policy)
