"""Launch contract for the grouped-GEMM pallas impl.

Mirrors `ops._grouped_pallas`: rows are pre-sorted by group with each
group's row count a bm multiple, the row-tile group ids ride in via scalar
prefetch, and the weight index map routes each row-tile to its tenant's
(K, N) plane — `gid[i]` is the global-bridge configuration the checker must
prove stays inside the stacked weight array.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...api.policy import ExecutionPolicy
from ...api.registry import BlockContract, LaunchContract, register_contract
from ..common import ceil_div
from .kernel import grouped_index_maps, grouped_matmul_pallas

__all__ = ["grouped_matmul_contract"]

# group sizes are multiples of every swept bm (the make_group_ids contract)
_CASES = (
    {"group_sizes": (128, 384, 128), "k": 192, "n": 160},
    {"group_sizes": (256, 128), "k": 96, "n": 96},
)
_SWEEP = ("bm", "bn", "bk")


@register_contract("grouped_matmul", "pallas", cases=_CASES,
                   sweep_fields=_SWEEP)
def grouped_matmul_contract(case: dict,
                            policy: ExecutionPolicy) -> LaunchContract:
    sizes, k, n = case["group_sizes"], case["k"], case["n"]
    bm, bn, bk = policy.bm, policy.bn, policy.bk
    t = sum(sizes)
    g = len(sizes)
    kp = ceil_div(k, bk) * bk
    np_ = ceil_div(n, bn) * bn
    gids = np.asarray(
        [gi for gi, size in enumerate(sizes) for _ in range(size // bm)],
        np.int32)
    maps = grouped_index_maps()

    def body():
        return grouped_matmul_pallas(
            jnp.asarray(gids), jnp.zeros((t, kp), jnp.float32),
            jnp.zeros((g, kp, np_), jnp.float32), bm=bm, bn=bn, bk=bk)

    return LaunchContract(
        grid=(t // bm, np_ // bn, kp // bk),
        blocks=(
            BlockContract("x", (t, kp), (bm, bk), maps["x"]),
            BlockContract("w", (g, kp, np_), (1, bk, bn), maps["w"]),
            # the K loop (grid dim 2) accumulates in VMEM scratch and
            # revisits the (row-tile, col-tile) output block each step
            BlockContract("out", (t, np_), (bm, bn), maps["out"],
                          is_output=True, revisits=(2,)),
        ),
        num_scalar_prefetch=1,
        scalars=(gids,),
        scratch_bytes=bm * bn * 4,
        body=body,
    )
