"""Pure-jnp oracle for the grouped GEMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["grouped_matmul_ref"]


def grouped_matmul_ref(group_ids: jax.Array, x: jax.Array, w: jax.Array, *,
                       bm: int = 128, out_dtype=jnp.float32) -> jax.Array:
    """Gather each row-tile's weight and batch-matmul."""
    t, k = x.shape
    tiles = t // bm
    xt = x.reshape(tiles, bm, k)
    wt = w[group_ids]                       # (tiles, K, N)
    out = jnp.einsum("tbk,tkn->tbn", xt, wt,
                     preferred_element_type=jnp.float32)
    return out.reshape(t, w.shape[-1]).astype(out_dtype)
