"""Pallas grouped GEMM — the kernel-level morphable MAC array (paper §IV-C).

One grid serves many independent GEMMs ("tenants" / MoE experts): row-tiles of
the token matrix are tagged with a group id (scalar-prefetched, so the weight
tile for the right group is fetched HBM->VMEM ahead of compute), exactly like
the paper's array blocks being fissioned among tenants — the grid is the
128x128 array, a contiguous run of row-tiles is a fused sub-array, and the
group id stream is the global-bridge configuration.

Contract: rows are sorted by group and each group's row count is padded to a
multiple of bm (ops.py does this), so a row-tile never straddles two groups —
the same alignment the hardware needs (a 64-row block can't split mid-tenant).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import interpret_mode

__all__ = ["grouped_matmul_pallas", "grouped_index_maps"]


def grouped_index_maps():
    """BlockSpec index maps of a grouped-GEMM launch, grid = (i, j, s) with
    the row-tile group ids as the scalar-prefetch operand.

    Module-level so the launch assembly and the `repro.analysis` contract
    checker evaluate the SAME functions (the tenant-routing `gid[i]` weight
    lookup lives here).
    """
    return {
        "x": lambda i, j, s, gid: (i, s),
        "w": lambda i, j, s, gid: (gid[i], s, j),
        "out": lambda i, j, s, gid: (i, j),
    }


def _gmm_kernel(gids, x_ref, w_ref, o_ref, acc_ref, *, nk: int, out_dtype):
    del gids  # consumed by the index maps
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def grouped_matmul_pallas(group_ids: jax.Array, x: jax.Array, w: jax.Array, *,
                          bm: int = 128, bn: int = 128, bk: int = 128,
                          out_dtype=jnp.float32,
                          interpret: Optional[bool] = None) -> jax.Array:
    """out[t] = x[t] @ w[group_of_row_tile(t)].

    group_ids: (T//bm,) int32 — group per row-tile (scalar-prefetched).
    x: (T, K); w: (G, K, N). T, K, N must be tile multiples.
    """
    if interpret is None:
        interpret = interpret_mode()
    t, k = x.shape
    g, kw, n = w.shape
    assert k == kw and t % bm == 0 and k % bk == 0 and n % bn == 0
    assert group_ids.shape == (t // bm,)
    grid = (t // bm, n // bn, k // bk)
    maps = grouped_index_maps()

    return pl.pallas_call(
        functools.partial(_gmm_kernel, nk=grid[2], out_dtype=out_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), maps["x"]),
                pl.BlockSpec((1, bk, bn), maps["w"]),
            ],
            out_specs=pl.BlockSpec((bm, bn), maps["out"]),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t, n), out_dtype),
        interpret=interpret,
    )(group_ids, x, w)
