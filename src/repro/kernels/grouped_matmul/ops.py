"""Grouped GEMM: registry implementations + the morphable multi-tenant entry.

Two public entries survive as shims over `repro.api`:
  * ``grouped_matmul(x, w, group_sizes)``        — MoE path (experts = groups)
  * ``morphable_multi_gemm([(x_i, w_i), ...])``  — multi-tenant path: several
    unrelated GEMMs packed into ONE kernel launch, the software analogue of
    Fig 8's fissioned array blocks running several AI models at once.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .. import common
from ...api.policy import ExecutionPolicy
from ...api.registry import register
from .kernel import grouped_matmul_pallas
from .ref import grouped_matmul_ref

__all__ = ["grouped_matmul", "make_group_ids", "morphable_multi_gemm",
           "pack_tenants"]


def make_group_ids(group_sizes: Sequence[int], bm: int) -> jnp.ndarray:
    """Row-tile group ids from per-group row counts (must be bm multiples)."""
    ids = []
    for g, size in enumerate(group_sizes):
        if size % bm:
            raise ValueError(f"group {g} size {size} not a multiple of bm={bm}")
        ids.extend([g] * (size // bm))
    return jnp.asarray(ids, jnp.int32)


def _prepare(x, w, group_sizes, policy: ExecutionPolicy):
    gids = make_group_ids(group_sizes, policy.bm)
    xk = common.pad_to(x, policy.bk, axis=1)
    wk = common.pad_to(common.pad_to(w, policy.bk, axis=1), policy.bn, axis=2)
    return gids, xk, wk, w.shape[-1]


@register("grouped_matmul", "pallas")
def _grouped_pallas(x: jax.Array, w: jax.Array, group_sizes: Sequence[int], *,
                    policy: ExecutionPolicy) -> jax.Array:
    gids, xk, wk, n = _prepare(x, w, group_sizes, policy)
    out = grouped_matmul_pallas(gids, xk, wk, bm=policy.bm, bn=policy.bn,
                                bk=policy.bk, out_dtype=policy.out_dtype)
    return out[:, :n]


@register("grouped_matmul", "ref")
def _grouped_ref(x: jax.Array, w: jax.Array, group_sizes: Sequence[int], *,
                 policy: ExecutionPolicy) -> jax.Array:
    gids, xk, wk, n = _prepare(x, w, group_sizes, policy)
    out = grouped_matmul_ref(gids, xk, wk, bm=policy.bm,
                             out_dtype=policy.out_dtype)
    return out[:, :n]


def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes: Sequence[int], *,
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   out_dtype=jnp.float32,
                   prefer_pallas: bool | None = None) -> jax.Array:
    """Deprecated: call `repro.api.ops.grouped_matmul` (policy-driven)."""
    from ... import api
    return api.ops.grouped_matmul(
        x, w, group_sizes, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        backend=api.ops.backend_from_prefer_pallas(prefer_pallas))


# =============================================================================
# Morphable multi-tenant GEMM
# =============================================================================

def pack_tenants(tenants: Sequence[Tuple[jax.Array, jax.Array]], bm: int,
                 bk: int, bn: int):
    """Pad heterogeneous tenant GEMMs onto a common (K, N) grid and stack.

    Returns (x_packed (T,Kmax), w_packed (G,Kmax,Nmax), group_sizes, metas)
    where metas[i] = (row_slice, n_i) to slice each tenant's result back out.
    The padding waste IS the utilization loss a rigid accelerator would turn
    into idle cycles; `morphable_multi_gemm` reports it.
    """
    kmax = max(x.shape[1] for x, _ in tenants)
    nmax = max(w.shape[1] for _, w in tenants)
    kmax = common.ceil_div(kmax, bk) * bk
    nmax = common.ceil_div(nmax, bn) * bn
    xs, ws, sizes, metas = [], [], [], []
    row = 0
    for x, w in tenants:
        m, k = x.shape
        _, n = w.shape
        mpad = common.ceil_div(m, bm) * bm
        xp = jnp.zeros((mpad, kmax), x.dtype).at[:m, :k].set(x)
        wp = jnp.zeros((kmax, nmax), w.dtype).at[:k, :n].set(w)
        xs.append(xp)
        ws.append(wp)
        sizes.append(mpad)
        metas.append((slice(row, row + m), n))
        row += mpad
    return jnp.concatenate(xs, 0), jnp.stack(ws, 0), sizes, metas


def multi_gemm_with_policy(tenants: Sequence[Tuple[jax.Array, jax.Array]],
                           policy: ExecutionPolicy):
    """Resolved-policy body behind `repro.api.ops.morphable_multi_gemm`.

    Returns (results list, mac_utilization) — utilization is useful MACs over
    launched MACs, directly comparable to the paper's Fig 14 metric.
    """
    x, w, sizes, metas = pack_tenants(tenants, policy.bm, policy.bk, policy.bn)
    from ... import api
    out = api.ops.grouped_matmul(x, w, sizes, policy=policy)
    results = [out[sl, :n] for sl, n in metas]
    useful = sum(xi.shape[0] * xi.shape[1] * wi.shape[1] for xi, wi in tenants)
    launched = x.shape[0] * x.shape[1] * w.shape[-1]
    return results, useful / launched


def morphable_multi_gemm(tenants: Sequence[Tuple[jax.Array, jax.Array]], *,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         out_dtype=jnp.float32,
                         prefer_pallas: bool | None = None):
    """Deprecated: call `repro.api.ops.morphable_multi_gemm` (policy-driven)."""
    from ... import api
    return api.ops.morphable_multi_gemm(
        tenants, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        backend=api.ops.backend_from_prefer_pallas(prefer_pallas))
