from .ops import grouped_matmul, make_group_ids, morphable_multi_gemm, pack_tenants  # noqa: F401
from .ref import grouped_matmul_ref  # noqa: F401
from .kernel import grouped_matmul_pallas  # noqa: F401
from . import contract  # noqa: F401  (registers launch contracts)
