"""Shared helpers for the Pallas kernel layer.

The kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling, MXU-aligned
tiles); on this CPU container they are validated with interpret=True against
the pure-jnp oracles in each kernel's ref.py. Backend choice lives in
`repro.api.ExecutionPolicy`; the thread-local `use_pallas()` flag remains as
the legacy default that policy backend="auto" defers to, so the multi-pod
dry-run (CPU backend) lowers the pure-JAX paths while real-TPU deployments
flip the flag or install `api.policy(backend="pallas")`.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

__all__ = ["ceil_div", "pad_to", "use_pallas", "pallas_enabled",
           "interpret_mode", "interpret_override", "decode_fp_code",
           "encode_fp_code", "MXU_LANE", "dtype_sublane"]

MXU_LANE = 128          # lane (minor-most) tile quantum on TPU


def dtype_sublane(dtype) -> int:
    """Sublane quantum for a dtype on TPU (8 for f32, 16 bf16, 32 int8/fp8)."""
    itemsize = jnp.dtype(dtype).itemsize
    return {4: 8, 2: 16, 1: 32}.get(itemsize, 8)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    """Zero-pad `axis` up to the next multiple."""
    size = x.shape[axis]
    target = ceil_div(size, multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Pallas dispatch flag (thread-local so tests can flip it safely)
# ---------------------------------------------------------------------------
_state = threading.local()


def pallas_enabled() -> bool:
    return getattr(_state, "enabled", False)


def interpret_mode() -> bool:
    """interpret=True everywhere except a real TPU backend (unless an
    ExecutionPolicy.interpret override is installed)."""
    override = getattr(_state, "interpret", None)
    if override is not None:
        return override
    return jax.default_backend() != "tpu"


@contextlib.contextmanager
def interpret_override(value: bool):
    """Force interpret mode on/off while tracing (repro.api wires
    ExecutionPolicy.interpret through here; the policy rides the jit cache
    key, so the override stays consistent with retracing)."""
    prev = getattr(_state, "interpret", None)
    _state.interpret = value
    try:
        yield
    finally:
        _state.interpret = prev


@contextlib.contextmanager
def use_pallas(enabled: bool = True):
    prev = pallas_enabled()
    _state.enabled = enabled
    try:
        yield
    finally:
        _state.enabled = prev


# ---------------------------------------------------------------------------
# In-kernel fp code decode/encode (pure jnp -> usable inside Pallas bodies).
# These mirror core.formats decode/encode but avoid ldexp (exp2 vectorizes
# better on the VPU) — exact for the narrow formats involved.
# ---------------------------------------------------------------------------

def decode_fp_code(code: jax.Array, ebits: int, mbits: int, bias: int) -> jax.Array:
    code = code.astype(jnp.int32)
    m_mask = (1 << mbits) - 1
    m = code & m_mask
    e = (code >> mbits) & ((1 << ebits) - 1)
    s = (code >> (ebits + mbits)) & 1
    normal = e > 0
    sig = jnp.where(normal, (1 << mbits) + m, m).astype(jnp.float32)
    exp = (jnp.where(normal, e, 1) - bias - mbits).astype(jnp.float32)
    val = sig * jnp.exp2(exp)
    return jnp.where(s == 1, -val, val)


def encode_fp_code(x: jax.Array, ebits: int, mbits: int, bias: int) -> jax.Array:
    """RNE-encode f32 -> fp code (saturating). Mirrors formats.encode."""
    x = x.astype(jnp.float32)
    a = jnp.abs(x)
    sgn = jnp.signbit(x).astype(jnp.int32)
    emin = 1 - bias
    emax = (1 << ebits) - 1 - bias
    max_finite = (2.0 - 2.0 ** (-mbits)) * 2.0 ** emax
    _, e2 = jnp.frexp(jnp.maximum(a, 2.0 ** (emin - mbits)))
    ebit = e2 - 1
    eff = jnp.maximum(ebit, emin)
    step = (eff - mbits).astype(jnp.float32)
    q = jnp.round(a * jnp.exp2(-step)) * jnp.exp2(step)
    q = jnp.minimum(q, max_finite)
    # re-derive exponent after rounding (may cross a binade)
    _, e2q = jnp.frexp(jnp.maximum(q, 2.0 ** (emin - mbits)))
    ebq = jnp.maximum(e2q - 1, emin)
    is_normal = q >= 2.0 ** emin
    e_code = jnp.where(is_normal, ebq + bias, 0).astype(jnp.int32)
    m_norm = jnp.round(q * jnp.exp2(-(ebq - mbits).astype(jnp.float32))) - (1 << mbits)
    m_sub = jnp.round(q * jnp.exp2(jnp.float32(-(emin - mbits))))
    m_code = jnp.where(is_normal, m_norm, m_sub).astype(jnp.int32)
    code = (sgn << (ebits + mbits)) | (e_code << mbits) | m_code
    return jnp.where(a == 0, sgn << (ebits + mbits), code)
