"""Pallas TPU kernels (validated via interpret=True on CPU) + jnp oracles.

Each kernel package: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (public jit'd wrapper with backend dispatch), ref.py (pure-jnp oracle).
"""
from . import common  # noqa: F401
from .aio_matmul import aio_matmul  # noqa: F401
from .aio_quant import aio_quantize  # noqa: F401
from .depthwise import depthwise_conv  # noqa: F401
from .flash_attention import attention, chunked_attention, mha_ref  # noqa: F401
from .grouped_matmul import grouped_matmul, morphable_multi_gemm  # noqa: F401
from .common import use_pallas, pallas_enabled  # noqa: F401
