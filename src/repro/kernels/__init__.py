"""Pallas TPU kernels (validated via interpret=True on CPU) + jnp oracles.

Each kernel package: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (implementations registered into `repro.api`'s KernelRegistry under
(op_name, "pallas"|"ref") plus a deprecated kwarg-compatible shim), ref.py
(pure-jnp oracle). New code should dispatch through `repro.api.ops` with an
ExecutionPolicy instead of these per-kernel entry points.
"""
from . import common  # noqa: F401
from .aio_matmul import aio_matmul  # noqa: F401
from .aio_quant import aio_quantize  # noqa: F401
from .depthwise import depthwise_conv  # noqa: F401
from .flash_attention import attention, chunked_attention, mha_ref  # noqa: F401
from .grouped_matmul import grouped_matmul, morphable_multi_gemm  # noqa: F401
from .common import use_pallas, pallas_enabled  # noqa: F401
