from .ops import attention  # noqa: F401
from .ref import chunked_attention, mha_ref  # noqa: F401
from .kernel import flash_attention_pallas  # noqa: F401
