from .ops import attention  # noqa: F401
from .ref import chunked_attention, mha_ref  # noqa: F401
from .kernel import flash_attention_pallas  # noqa: F401
from .decode import (decode_block_visits, flash_decode_pallas,  # noqa: F401
                     flash_decode_paged_pallas,
                     flash_decode_paged_quant_pallas,
                     flash_decode_quant_pallas)
from .prefill import (flash_prefill_paged_pallas,  # noqa: F401
                      flash_prefill_paged_quant_pallas,
                      flash_prefill_pallas,
                      flash_prefill_quant_pallas, prefill_block_visits)
from . import contract  # noqa: F401  (registers launch contracts)
