"""Pallas flash attention (forward) with GQA / causal / window / softcap.

Grid: (B*Hq, Lq/bq, Lk/bk), KV innermost; running (m, l, acc) in VMEM scratch,
normalized on the last KV block. The GQA head mapping happens in the K/V
BlockSpec index maps (q-head h reads kv-head h // group), so K/V tiles are
fetched once per kv-head — no materialized head broadcast in HBM.

On the target TPU: bq x bk = 128 x 512 keeps q, k, v, p tiles + (m,l,acc)
under ~2.5 MB VMEM at D=128 in bf16, and all matmul dims are 128-multiples
for the MXU. Validated here in interpret mode against ref.mha_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import interpret_mode

__all__ = ["flash_attention_pallas", "flash_index_maps"]

_NEG_INF = -1e30


def flash_index_maps(*, hq: int, hkv: int):
    """The q and K/V BlockSpec index maps of a full-sequence flash launch.

    Module-level so the launch assembly and the `repro.analysis` contract
    checker evaluate the SAME functions (the GQA head mapping lives here).
    """
    group = hq // hkv

    def q_index(h, i, j):
        return (h, i, 0)

    def kv_index(h, i, j):
        # q-head h = batch*hq + hh reads kv row batch*hkv + hh // group
        return ((h // hq) * hkv + (h % hq) // group, j, 0)

    return q_index, kv_index


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: Optional[int],
               softcap: Optional[float], bq: int, bk: int, nk: int,
               lk_real: int, offset: int):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, D)
    k = k_ref[0].astype(jnp.float32)                 # (bk, D)
    v = v_ref[0].astype(jnp.float32)                 # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    keep = kpos < lk_real
    if causal:
        keep &= kpos <= qpos
    if window is not None:
        keep &= kpos > qpos - window
    s = jnp.where(keep, s, _NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.maximum(m_prev[:, 0], s.max(-1))
    alpha = jnp.exp(m_prev[:, 0] - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_cur = l_prev[:, 0] * alpha + p.sum(-1)
    acc_cur = acc_prev * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur[:, None]
    l_ref[...] = l_cur[:, None]
    acc_ref[...] = acc_cur

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None, offset: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D) -> (B, Hq, Lq, D).

    Lq % bq == 0 required; Lk is padded here (mask handles the tail).
    """
    if interpret is None:
        interpret = interpret_mode()
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    if scale is None:
        scale = d ** -0.5
    assert lq % bq == 0, (lq, bq)
    lk_real = lk
    if lk % bk:
        pad = bk - lk % bk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        lk = k.shape[2]

    qr = q.reshape(b * hq, lq, d)
    kr = k.reshape(b * hkv, lk, d)
    vr = v.reshape(b * hkv, lk, d)
    nk = lk // bk
    grid = (b * hq, lq // bq, nk)

    q_index, kv_index = flash_index_maps(hq=hq, hkv=hkv)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          nk=nk, lk_real=lk_real, offset=offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_index),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, lq, d)
