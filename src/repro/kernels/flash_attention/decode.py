"""Pallas flash-decode: short-query attention over a long per-row KV cache.

The serving engine's hottest loop is Lq=1 attention over a (B, Hkv, max_len,
D) cache where every batch row ("slot") sits at its own position — exactly
the shape the prefill flash kernel cannot take (it requires Lq % 128 == 0
and a scalar offset). This kernel is specialized for it:

  * grid (B*Hkv, nk) over KV blocks with the per-row cache position vector
    delivered via SCALAR PREFETCH, so the K/V BlockSpec index maps can see it
    before any DMA is issued;
  * per-row BLOCK PRUNING: blocks entirely beyond row b's causal frontier
    (`pos[b] + Lq - 1`) are skipped with `pl.when`, and their index maps
    clamp to the last needed block so the pipeline never fetches them from
    HBM — work scales with each row's RESIDENT context, not max_len;
  * the GQA head group is packed into the q tile: (group·Lq, D) instead of a
    degenerate (1, D) row, so the score matmul feeds the MXU a real operand
    and K/V tiles are read once per kv-head;
  * a fused INT8-KV variant takes `(codes, pow2 scale)` and dequantizes in
    VMEM — the full-cache dequant materialization in HBM disappears. The
    in-kernel dequant rounds through `cast_dtype` (the q dtype) so it is
    bit-identical to dequantize-then-dense-kernel.

Validated in interpret mode against ref.mha_ref (tests/test_decode_kernel.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import interpret_mode, pad_to
from .shared import NEG_INF as _NEG_INF
from .shared import as_row_vector, vmem_dequant

__all__ = ["flash_decode_pallas", "flash_decode_quant_pallas",
           "flash_decode_paged_pallas", "flash_decode_paged_quant_pallas",
           "decode_block_visits", "decode_index_maps",
           "paged_decode_index_maps"]


def _block_bounds(start, lq: int, window: Optional[int], bkv: int):
    """KV-block range a row with cache position `start` actually needs:
    up to the causal frontier (start + lq - 1), and — with a sliding
    window — no earlier than the oldest in-window key of the first query
    (start - window + 1), so windowed decode work scales with the WINDOW,
    not the resident context. first <= last always (window >= 1)."""
    last = (start + lq - 1) // bkv
    if window is None:
        return 0, last
    return jnp.maximum(start - (window - 1), 0) // bkv, last


def _online_block(pos_ref, q_ref, load_k, load_v, o_ref, visits_ref, m_ref,
                  l_ref, acc_ref, *, scale: float, window: Optional[int],
                  softcap: Optional[float], lq: int, hkv: int, bkv: int,
                  nk: int, lk_real: int):
    """One (bh, ik) grid step of the online-softmax accumulation."""
    bh, ik = pl.program_id(0), pl.program_id(1)
    start = pos_ref[bh // hkv]
    first_blk, last_blk = _block_bounds(start, lq, window, bkv)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if visits_ref is not None:
            visits_ref[...] = jnp.zeros_like(visits_ref)

    @pl.when((ik >= first_blk) & (ik <= last_blk))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # (group*lq, D)
        k = load_k()                                       # (bkv, D) f32
        v = load_v()
        gl = q.shape[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        # packed row r = g*lq + i sits at query position start + i
        qpos = start + jax.lax.broadcasted_iota(jnp.int32, (gl, bkv), 0) % lq
        kpos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (gl, bkv), 1)
        keep = (kpos < lk_real) & (kpos <= qpos)
        if window is not None:
            keep &= kpos > qpos - window
        s = jnp.where(keep, s, _NEG_INF)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_cur = jnp.maximum(m_prev[:, 0], s.max(-1))
        alpha = jnp.exp(m_prev[:, 0] - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        m_ref[...] = m_cur[:, None]
        l_ref[...] = (l_prev[:, 0] * alpha + p.sum(-1))[:, None]
        acc_ref[...] = acc_prev * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        if visits_ref is not None:
            visits_ref[0, ik] = 1

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _dense_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *rest, debug_visits,
                  **kw):
    visits_ref, (m_ref, l_ref, acc_ref) = \
        (rest[0], rest[1:]) if debug_visits else (None, rest)
    _online_block(pos_ref, q_ref,
                  lambda: k_ref[0].astype(jnp.float32),
                  lambda: v_ref[0].astype(jnp.float32),
                  o_ref, visits_ref, m_ref, l_ref, acc_ref, **kw)


def _quant_kernel(pos_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref, o_ref,
                  *rest, debug_visits, cast_dtype, **kw):
    visits_ref, (m_ref, l_ref, acc_ref) = \
        (rest[0], rest[1:]) if debug_visits else (None, rest)
    _online_block(pos_ref, q_ref,
                  lambda: vmem_dequant(kc_ref, ks_ref, cast_dtype),
                  lambda: vmem_dequant(vc_ref, vs_ref, cast_dtype),
                  o_ref, visits_ref, m_ref, l_ref, acc_ref, **kw)


def decode_index_maps(*, lq: int, hkv: int, bkv: int,
                      window: Optional[int]):
    """The q and K/V BlockSpec index maps of a decode launch.

    Module-level (not a `_launch` closure) so the launch assembly and the
    `repro.analysis` kernel-contract checker evaluate the SAME functions —
    the checker sweeps them out-of-trace over (shape x policy) cases and
    flags out-of-bounds block indices before any kernel runs.
    """
    def q_index(bh, ik, pos_ref):
        return (bh, 0, 0)

    def kv_index(bh, ik, pos_ref):
        # clamp pruned steps into [first, last]: the pipeline sees an index
        # it already fetched and skips the HBM fetch entirely
        first, last = _block_bounds(pos_ref[bh // hkv], lq, window, bkv)
        return (bh, jnp.clip(ik, first, last), 0)

    return q_index, kv_index


def paged_decode_index_maps(*, lq: int, hkv: int, bs: int,
                            window: Optional[int]):
    """Index maps of a PAGED decode launch: same per-row block pruning as
    `decode_index_maps`, then one extra indirection — logical KV block `lb`
    of row b lives at physical pool block `table[b, lb]`. The pool is laid
    out (P*Hkv, bs, D), so head h of physical block p is row p*hkv + h.
    The clamp runs BEFORE the table lookup, so only table entries a row
    actually owns (logical blocks up to its frontier) are ever read."""
    def q_index(bh, ik, pos_ref, tbl_ref):
        return (bh, 0, 0)

    def kv_index(bh, ik, pos_ref, tbl_ref):
        b = bh // hkv
        first, last = _block_bounds(pos_ref[b], lq, window, bs)
        lb = jnp.clip(ik, first, last)
        return (tbl_ref[b, lb] * hkv + bh % hkv, 0, 0)

    return q_index, kv_index


def _paged_launch(kernel, q, pool_arrays, pos, table, *, interpret, window,
                  softcap, scale):
    """pallas_call assembly for the paged variants. pool_arrays are
    (P, Hkv, bs, last) block pools; `table` (B, nblk) int32 is scalar-
    prefetched alongside `pos` so the K/V index maps can indirect."""
    b, hq, lq, d = q.shape
    hkv, bs = pool_arrays[0].shape[1:3]
    group = hq // hkv
    gl = group * lq
    nblk = table.shape[1]

    qr = q.reshape(b, hkv, gl, d).reshape(b * hkv, gl, d)
    kvr = [a.reshape(a.shape[0] * hkv, bs, a.shape[-1]) for a in pool_arrays]

    q_index, kv_index = paged_decode_index_maps(lq=lq, hkv=hkv, bs=bs,
                                                window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hkv, nblk),
        in_specs=[pl.BlockSpec((1, gl, d), q_index)] +
                 [pl.BlockSpec((1, bs, a.shape[-1]), kv_index)
                  for a in kvr],
        out_specs=[pl.BlockSpec((1, gl, d), q_index)],
        scratch_shapes=[
            pltpu.VMEM((gl, 1), jnp.float32),
            pltpu.VMEM((gl, 1), jnp.float32),
            pltpu.VMEM((gl, d), jnp.float32),
        ],
    )
    outs = pl.pallas_call(
        # every logical position a row can reach maps through its table, so
        # the only tail to mask is the causal frontier itself
        functools.partial(kernel, debug_visits=False, scale=scale,
                          window=window, softcap=softcap, lq=lq, hkv=hkv,
                          bkv=bs, nk=nblk, lk_real=nblk * bs),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b * hkv, gl, d), q.dtype)],
        interpret=interpret,
    )(pos, table, qr, *kvr)
    return outs[0].reshape(b, hkv, group, lq, d).reshape(b, hq, lq, d)


def _paged_dense_kernel(pos_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                        **kw):
    # the table steers the index maps only; the body's logical-position math
    # (kpos = ik*bs + iota) is exactly the dense kernel's
    _dense_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *rest, **kw)


def _paged_quant_kernel(pos_ref, tbl_ref, q_ref, kc_ref, ks_ref, vc_ref,
                        vs_ref, o_ref, *rest, **kw):
    _quant_kernel(pos_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref, o_ref,
                  *rest, **kw)


def flash_decode_paged_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                              table: jax.Array, pos,
                              window: Optional[int] = None,
                              softcap: Optional[float] = None,
                              scale: Optional[float] = None,
                              interpret: Optional[bool] = None):
    """Paged flash-decode. q: (B, Hq, Lq, D); k/v: (P, Hkv, bs, D) BLOCK
    POOLS shared by all rows; table: (B, nblk) int32 maps row b's logical
    block j to a physical pool block. Block size bs doubles as the launch's
    KV tile, so a paged launch at bs == bkv visits the same logical blocks
    with the same masks as the dense kernel — bit-identical outputs."""
    if interpret is None:
        interpret = interpret_mode()
    b = q.shape[0]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return _paged_launch(_paged_dense_kernel, q, [k, v],
                         as_row_vector(pos, b), table.astype(jnp.int32),
                         interpret=interpret, window=window, softcap=softcap,
                         scale=scale)


def flash_decode_paged_quant_pallas(q: jax.Array, k_codes: jax.Array,
                                    k_scale: jax.Array, v_codes: jax.Array,
                                    v_scale: jax.Array, *, table: jax.Array,
                                    pos, window: Optional[int] = None,
                                    softcap: Optional[float] = None,
                                    scale: Optional[float] = None,
                                    interpret: Optional[bool] = None):
    """Paged int8-KV decode: codes (P, Hkv, bs, D) int8 + pow2 scales
    (P, Hkv, bs, 1) f32 pools, dequantized block-by-block in VMEM."""
    if interpret is None:
        interpret = interpret_mode()
    b = q.shape[0]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    kernel = functools.partial(_paged_quant_kernel, cast_dtype=q.dtype)
    return _paged_launch(kernel, q, [k_codes, k_scale, v_codes, v_scale],
                         as_row_vector(pos, b), table.astype(jnp.int32),
                         interpret=interpret, window=window, softcap=softcap,
                         scale=scale)


def _launch(kernel, q, kv_arrays, pos, *, bkv, interpret, debug_visits,
            window, softcap, scale, lk_real):
    """Shared pallas_call assembly for the dense and quantized variants.

    kv_arrays: (B, Hkv, Lk_padded, last) arrays sharing the KV index map
    (codes last=D, scales last=1)."""
    b, hq, lq, d = q.shape
    hkv = kv_arrays[0].shape[1]
    group = hq // hkv
    gl = group * lq
    lk = kv_arrays[0].shape[2]
    nk = lk // bkv

    # pack the GQA group into the q tile: head h = kv*group + g, so a plain
    # reshape groups each kv-head's queries contiguously
    qr = q.reshape(b, hkv, gl, d).reshape(b * hkv, gl, d)
    kvr = [a.reshape(b * hkv, lk, a.shape[-1]) for a in kv_arrays]

    q_index, kv_index = decode_index_maps(lq=lq, hkv=hkv, bkv=bkv,
                                          window=window)

    out_shape = [jax.ShapeDtypeStruct((b * hkv, gl, d), q.dtype)]
    out_specs = [pl.BlockSpec((1, gl, d), q_index)]
    if debug_visits:
        out_shape.append(jax.ShapeDtypeStruct((b * hkv, nk), jnp.int32))
        out_specs.append(pl.BlockSpec((1, nk), lambda bh, ik, pos_ref:
                                      (bh, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, nk),
        in_specs=[pl.BlockSpec((1, gl, d), q_index)] +
                 [pl.BlockSpec((1, bkv, a.shape[-1]), kv_index)
                  for a in kvr],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((gl, 1), jnp.float32),
            pltpu.VMEM((gl, 1), jnp.float32),
            pltpu.VMEM((gl, d), jnp.float32),
        ],
    )
    outs = pl.pallas_call(
        functools.partial(kernel, debug_visits=debug_visits, scale=scale,
                          window=window, softcap=softcap, lq=lq, hkv=hkv,
                          bkv=bkv, nk=nk, lk_real=lk_real),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(pos, qr, *kvr)
    out = outs[0].reshape(b, hkv, group, lq, d).reshape(b, hq, lq, d)
    return (out, outs[1]) if debug_visits else out


def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        pos, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None, bkv: int = 128,
                        interpret: Optional[bool] = None,
                        debug_visits: bool = False):
    """q: (B, Hq, Lq, D) short query; k, v: (B, Hkv, Lk, D) cache.

    pos: per-row (B,) cache position (or a scalar, broadcast): row b's
    queries sit at absolute positions pos[b]..pos[b]+Lq-1 and attend causally
    — keys beyond the frontier (the not-yet-written cache tail) are never
    visited, not merely masked.

    debug_visits=True additionally returns an (B*Hkv, nk) int32 map of KV
    blocks whose compute actually ran — the block-pruning evidence used by
    tests and benchmarks (interpret/debug use).
    """
    if interpret is None:
        interpret = interpret_mode()
    b = q.shape[0]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    lk_real = k.shape[2]
    k, v = pad_to(k, bkv, 2), pad_to(v, bkv, 2)
    return _launch(_dense_kernel, q, [k, v], as_row_vector(pos, b),
                   bkv=bkv, interpret=interpret, debug_visits=debug_visits,
                   window=window, softcap=softcap, scale=scale,
                   lk_real=lk_real)


def flash_decode_quant_pallas(q: jax.Array, k_codes: jax.Array,
                              k_scale: jax.Array, v_codes: jax.Array,
                              v_scale: jax.Array, *, pos,
                              window: Optional[int] = None,
                              softcap: Optional[float] = None,
                              scale: Optional[float] = None, bkv: int = 128,
                              interpret: Optional[bool] = None,
                              debug_visits: bool = False):
    """Fused int8-KV decode: codes (B, Hkv, Lk, D) int8 + per-position pow2
    scales (B, Hkv, Lk, 1) f32, dequantized block-by-block in VMEM."""
    if interpret is None:
        interpret = interpret_mode()
    b = q.shape[0]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    lk_real = k_codes.shape[2]
    arrays = [pad_to(a, bkv, 2)
              for a in (k_codes, k_scale, v_codes, v_scale)]
    kernel = functools.partial(_quant_kernel, cast_dtype=q.dtype)
    return _launch(kernel, q, arrays, as_row_vector(pos, b), bkv=bkv,
                   interpret=interpret, debug_visits=debug_visits,
                   window=window, softcap=softcap, scale=scale,
                   lk_real=lk_real)


def decode_block_visits(pos, lq: int, lk: int, bkv: int = 128,
                        window: Optional[int] = None):
    """Expected (visited, total) KV-block counts per kv-head row for a decode
    launch — what `debug_visits` measures, available without running it."""
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    nk = -(-max(lk, 1) // bkv)
    first, last = _block_bounds(pos, lq, window, bkv)
    visited = jnp.minimum(last, nk - 1) - first + 1
    return int(visited.sum()), int(pos.shape[0] * nk)
