"""Launch contracts for the three flash-attention pallas impls.

These reuse the REAL index-map factories (`flash_index_maps`,
`decode_index_maps`, `prefill_index_maps`) — the GQA head mapping and the
per-row block-pruning clamps are exactly the functions a production launch
installs, evaluated here out-of-trace over concrete (pos, lengths) vectors.
The decode/prefill clamps are load-bearing: an off-by-one in `_block_bounds`
or `_kv_bounds` is an out-of-bounds DMA on hardware, which is what the
KC102 sweep exists to catch.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...api.policy import ExecutionPolicy
from ...api.registry import BlockContract, LaunchContract, register_contract
from ..common import ceil_div
from .decode import (decode_index_maps, flash_decode_paged_pallas,
                     flash_decode_paged_quant_pallas, flash_decode_pallas,
                     flash_decode_quant_pallas, paged_decode_index_maps)
from .kernel import flash_attention_pallas, flash_index_maps
from .prefill import (flash_prefill_paged_pallas,
                      flash_prefill_paged_quant_pallas, flash_prefill_pallas,
                      flash_prefill_quant_pallas, paged_prefill_index_maps,
                      prefill_index_maps)

__all__ = ["attention_contract", "decode_contract", "prefill_contract"]

_BF16 = 2


def _kv_blocks(b, hkv, lk_pad, bkv, d, kv_index, *, quant):
    """K/V operand blocks: dense (k, v) or quantized (codes + scale) x2."""
    if not quant:
        return [
            BlockContract("k", (b * hkv, lk_pad, d), (1, bkv, d), kv_index,
                          dtype_bytes=_BF16),
            BlockContract("v", (b * hkv, lk_pad, d), (1, bkv, d), kv_index,
                          dtype_bytes=_BF16),
        ]
    blocks = []
    for name in ("k", "v"):
        blocks.append(BlockContract(f"{name}_codes", (b * hkv, lk_pad, d),
                                    (1, bkv, d), kv_index, dtype_bytes=1,
                                    quant="int8"))
        blocks.append(BlockContract(f"{name}_scale", (b * hkv, lk_pad, 1),
                                    (1, bkv, 1), kv_index,
                                    scale_for=f"{name}_codes"))
    return blocks


# --------------------------------------------------------------------------
# attention / pallas — the full-sequence flash kernel (fixed 128x128 tiles)
# --------------------------------------------------------------------------

_FLASH_CASES = (
    {"b": 1, "hq": 4, "hkv": 2, "lq": 256, "lk": 300, "d": 64},
    {"b": 2, "hq": 2, "hkv": 2, "lq": 128, "lk": 128, "d": 128},
)


@register_contract("attention", "pallas", cases=_FLASH_CASES)
def attention_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    b, hq, hkv = case["b"], case["hq"], case["hkv"]
    lq, lk, d = case["lq"], case["lk"], case["d"]
    bq = bk = 128                     # the impl pins both (no policy fields)
    lk_pad = ceil_div(lk, bk) * bk
    q_index, kv_index = flash_index_maps(hq=hq, hkv=hkv)

    def body():
        return flash_attention_pallas(
            jnp.zeros((b, hq, lq, d), jnp.bfloat16),
            jnp.zeros((b, hkv, lk, d), jnp.bfloat16),
            jnp.zeros((b, hkv, lk, d), jnp.bfloat16))

    return LaunchContract(
        grid=(b * hq, lq // bq, lk_pad // bk),
        blocks=(
            BlockContract("q", (b * hq, lq, d), (1, bq, d), q_index,
                          dtype_bytes=_BF16),
            BlockContract("k", (b * hkv, lk_pad, d), (1, bk, d), kv_index,
                          dtype_bytes=_BF16),
            BlockContract("v", (b * hkv, lk_pad, d), (1, bk, d), kv_index,
                          dtype_bytes=_BF16),
            # the KV loop (grid dim 2) is the flash accumulation dim: every
            # KV block revisits the same (head, q-block) output tile
            BlockContract("out", (b * hq, lq, d), (1, bq, d), q_index,
                          dtype_bytes=_BF16, is_output=True, revisits=(2,)),
        ),
        scratch_bytes=(bq + bq + bq * d) * 4,    # m, l, acc
        body=body,
    )


# --------------------------------------------------------------------------
# attention / pallas-decode — per-row positions via scalar prefetch
# --------------------------------------------------------------------------

def _paged_table(b: int, nblk: int, pool: int) -> np.ndarray:
    """A deterministic scattered-but-valid block table: rows interleave the
    pool so the checker proves in-bounds for NON-identity maps too."""
    return np.asarray([[(i * nblk + j) * 7 % pool for j in range(nblk)]
                       for i in range(b)], np.int32)


_DECODE_CASES = (
    {"b": 3, "hq": 4, "hkv": 2, "lq": 1, "lk": 640, "d": 64,
     "pos": (0, 37, 639), "window": None, "quant": False},
    {"b": 3, "hq": 4, "hkv": 2, "lq": 1, "lk": 640, "d": 64,
     "pos": (0, 37, 639), "window": 64, "quant": False},
    {"b": 2, "hq": 8, "hkv": 2, "lq": 4, "lk": 512, "d": 64,
     "pos": (12, 500), "window": None, "quant": True},
    # paged: the pool is (P, Hkv, bs, D), the KV tile IS the block size, and
    # the index map indirects through the scalar-prefetched block table
    {"b": 3, "hq": 4, "hkv": 2, "lq": 1, "d": 64, "paged": True,
     "bs": 16, "nblk": 8, "pool": 26, "pos": (0, 37, 127), "window": None,
     "quant": False},
    {"b": 2, "hq": 8, "hkv": 2, "lq": 4, "d": 64, "paged": True,
     "bs": 16, "nblk": 8, "pool": 18, "pos": (12, 124), "window": None,
     "quant": True},
)


@register_contract("attention", "pallas-decode", cases=_DECODE_CASES,
                   sweep_fields=("bkv",))
def decode_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    if case.get("paged"):
        return _paged_decode_contract(case)
    b, hq, hkv = case["b"], case["hq"], case["hkv"]
    lq, lk, d = case["lq"], case["lk"], case["d"]
    bkv = policy.bkv
    gl = (hq // hkv) * lq                       # GQA group packed into q
    lk_pad = ceil_div(lk, bkv) * bkv
    pos = np.asarray(case["pos"], np.int32)
    q_index, kv_index = decode_index_maps(lq=lq, hkv=hkv, bkv=bkv,
                                          window=case["window"])
    blocks = [BlockContract("q", (b * hkv, gl, d), (1, gl, d), q_index,
                            dtype_bytes=_BF16)]
    blocks += _kv_blocks(b, hkv, lk_pad, bkv, d, kv_index,
                         quant=case["quant"])
    # the KV loop (grid dim 1) accumulates online-softmax state in scratch
    # and revisits the row's single output tile every block
    blocks.append(BlockContract("out", (b * hkv, gl, d), (1, gl, d), q_index,
                                dtype_bytes=_BF16, is_output=True,
                                revisits=(1,)))

    def body():
        q = jnp.zeros((b, hq, lq, d), jnp.bfloat16)
        if case["quant"]:
            codes = jnp.zeros((b, hkv, lk, d), jnp.int8)
            scl = jnp.zeros((b, hkv, lk, 1), jnp.float32)
            return flash_decode_quant_pallas(
                q, codes, scl, codes, scl, pos=jnp.asarray(pos),
                window=case["window"], bkv=bkv)
        kv = jnp.zeros((b, hkv, lk, d), jnp.bfloat16)
        return flash_decode_pallas(q, kv, kv, pos=jnp.asarray(pos),
                                   window=case["window"], bkv=bkv)

    return LaunchContract(
        grid=(b * hkv, lk_pad // bkv),
        blocks=tuple(blocks),
        num_scalar_prefetch=1,
        scalars=(pos,),
        scratch_bytes=(gl + gl + gl * d) * 4,
        body=body,
    )


def _paged_decode_contract(case: dict) -> LaunchContract:
    """The paged decode launch: grid walks (row-head, logical block); the
    K/V operands are the (P*Hkv, bs, D)-reshaped pools and their index map
    indirects through the prefetched (B, nblk) table — the in-bounds proof
    must hold THROUGH the indirection (every table entry < P). The KV tile
    is pinned to the pool block size, not policy.bkv."""
    b, hq, hkv = case["b"], case["hq"], case["hkv"]
    lq, d = case["lq"], case["d"]
    bs, nblk, pool = case["bs"], case["nblk"], case["pool"]
    gl = (hq // hkv) * lq
    pos = np.asarray(case["pos"], np.int32)
    table = _paged_table(b, nblk, pool)
    q_index, kv_index = paged_decode_index_maps(lq=lq, hkv=hkv, bs=bs,
                                                window=case["window"])
    blocks = [BlockContract("q", (b * hkv, gl, d), (1, gl, d), q_index,
                            dtype_bytes=_BF16)]
    blocks += _kv_blocks(pool, hkv, bs, bs, d, kv_index, quant=case["quant"])
    blocks.append(BlockContract("out", (b * hkv, gl, d), (1, gl, d), q_index,
                                dtype_bytes=_BF16, is_output=True,
                                revisits=(1,)))

    def body():
        q = jnp.zeros((b, hq, lq, d), jnp.bfloat16)
        jt, jp = jnp.asarray(table), jnp.asarray(pos)
        if case["quant"]:
            codes = jnp.zeros((pool, hkv, bs, d), jnp.int8)
            scl = jnp.zeros((pool, hkv, bs, 1), jnp.float32)
            return flash_decode_paged_quant_pallas(
                q, codes, scl, codes, scl, table=jt, pos=jp,
                window=case["window"])
        kv = jnp.zeros((pool, hkv, bs, d), jnp.bfloat16)
        return flash_decode_paged_pallas(q, kv, kv, table=jt, pos=jp,
                                         window=case["window"])

    return LaunchContract(
        grid=(b * hkv, nblk),
        blocks=tuple(blocks),
        num_scalar_prefetch=2,
        scalars=(pos, table),
        scratch_bytes=(gl + gl + gl * d) * 4,
        body=body,
    )


# --------------------------------------------------------------------------
# attention / pallas-prefill — per-row positions AND lengths prefetched
# --------------------------------------------------------------------------

_PREFILL_CASES = (
    {"b": 3, "hq": 4, "hkv": 2, "lq": 64, "lk": 384, "d": 64,
     "pos": (0, 37, 256), "lens": (3, 64, 17), "window": None,
     "quant": False},
    {"b": 3, "hq": 4, "hkv": 2, "lq": 64, "lk": 384, "d": 64,
     "pos": (0, 37, 256), "lens": (3, 64, 17), "window": 64, "quant": False},
    {"b": 2, "hq": 8, "hkv": 2, "lq": 48, "lk": 256, "d": 64,
     "pos": (128, 0), "lens": (48, 1), "window": None, "quant": True},
    # paged: pool-shaped K/V, table-indirected index maps, KV tile == bs
    {"b": 3, "hq": 4, "hkv": 2, "lq": 32, "d": 64, "paged": True,
     "bs": 16, "nblk": 8, "pool": 26, "pos": (0, 37, 70),
     "lens": (3, 32, 17), "window": None, "quant": False},
    {"b": 2, "hq": 8, "hkv": 2, "lq": 48, "d": 64, "paged": True,
     "bs": 16, "nblk": 8, "pool": 18, "pos": (80, 0), "lens": (48, 1),
     "window": None, "quant": True},
)


@register_contract("attention", "pallas-prefill", cases=_PREFILL_CASES,
                   sweep_fields=("bq", "bkv"))
def prefill_contract(case: dict, policy: ExecutionPolicy) -> LaunchContract:
    if case.get("paged"):
        return _paged_prefill_contract(case, policy)
    b, hq, hkv = case["b"], case["hq"], case["hkv"]
    lq, lk, d = case["lq"], case["lk"], case["d"]
    bq = max(1, min(policy.bq, lq))             # _prep's resolution rule
    bkv = policy.bkv
    group = hq // hkv
    lq_pad = ceil_div(lq, bq) * bq
    lk_pad = ceil_div(lk, bkv) * bkv
    nk = lk_pad // bkv
    pos = np.asarray(case["pos"], np.int32)
    lens = np.asarray(case["lens"], np.int32)
    q_index, kv_index = prefill_index_maps(bq=bq, bkv=bkv, nk=nk, hkv=hkv,
                                           window=case["window"])
    blocks = [BlockContract("q", (b * hkv, group, lq_pad, d),
                            (1, group, bq, d), q_index, dtype_bytes=_BF16)]
    blocks += _kv_blocks(b, hkv, lk_pad, bkv, d, kv_index,
                         quant=case["quant"])
    # the KV loop (grid dim 2) revisits each (row, q-block) output tile —
    # the online-softmax accumulation dim
    blocks.append(BlockContract(
        "out", (b * hkv, group, lq_pad, d), (1, group, bq, d),
        lambda bh, iq, ik, pos_ref, len_ref: (bh, 0, iq, 0),
        dtype_bytes=_BF16, is_output=True, revisits=(2,)))

    def body():
        q = jnp.zeros((b, hq, lq, d), jnp.bfloat16)
        jpos, jlens = jnp.asarray(pos), jnp.asarray(lens)
        if case["quant"]:
            codes = jnp.zeros((b, hkv, lk, d), jnp.int8)
            scl = jnp.zeros((b, hkv, lk, 1), jnp.float32)
            return flash_prefill_quant_pallas(
                q, codes, scl, codes, scl, pos=jpos, lengths=jlens,
                window=case["window"], bq=policy.bq, bkv=bkv)
        kv = jnp.zeros((b, hkv, lk, d), jnp.bfloat16)
        return flash_prefill_pallas(q, kv, kv, pos=jpos, lengths=jlens,
                                    window=case["window"], bq=policy.bq,
                                    bkv=bkv)

    return LaunchContract(
        grid=(b * hkv, lq_pad // bq, nk),
        blocks=tuple(blocks),
        num_scalar_prefetch=2,
        scalars=(pos, lens),
        scratch_bytes=(group * bq * 2 + group * bq * d) * 4,
        body=body,
    )


def _paged_prefill_contract(case: dict,
                            policy: ExecutionPolicy) -> LaunchContract:
    """The paged varlen-prefill launch: same (row-head, q-block, KV-block)
    grid walk as the dense contract, K/V operands swapped for the
    (P*Hkv, bs, D) pools with table-indirected index maps. bq still comes
    from the policy; the KV tile is the pool block size."""
    b, hq, hkv = case["b"], case["hq"], case["hkv"]
    lq, d = case["lq"], case["d"]
    bs, nblk, pool = case["bs"], case["nblk"], case["pool"]
    bq = max(1, min(policy.bq, lq))             # _prep's resolution rule
    group = hq // hkv
    lq_pad = ceil_div(lq, bq) * bq
    pos = np.asarray(case["pos"], np.int32)
    lens = np.asarray(case["lens"], np.int32)
    table = _paged_table(b, nblk, pool)
    q_index, kv_index = paged_prefill_index_maps(bq=bq, bs=bs, nblk=nblk,
                                                 hkv=hkv,
                                                 window=case["window"])
    blocks = [BlockContract("q", (b * hkv, group, lq_pad, d),
                            (1, group, bq, d), q_index, dtype_bytes=_BF16)]
    blocks += _kv_blocks(pool, hkv, bs, bs, d, kv_index, quant=case["quant"])
    blocks.append(BlockContract(
        "out", (b * hkv, group, lq_pad, d), (1, group, bq, d),
        lambda bh, iq, ik, pos_ref, len_ref, tbl_ref: (bh, 0, iq, 0),
        dtype_bytes=_BF16, is_output=True, revisits=(2,)))

    def body():
        q = jnp.zeros((b, hq, lq, d), jnp.bfloat16)
        jp, jl = jnp.asarray(pos), jnp.asarray(lens)
        jt = jnp.asarray(table)
        if case["quant"]:
            codes = jnp.zeros((pool, hkv, bs, d), jnp.int8)
            scl = jnp.zeros((pool, hkv, bs, 1), jnp.float32)
            return flash_prefill_paged_quant_pallas(
                q, codes, scl, codes, scl, table=jt, pos=jp, lengths=jl,
                window=case["window"], bq=policy.bq)
        kv = jnp.zeros((pool, hkv, bs, d), jnp.bfloat16)
        return flash_prefill_paged_pallas(q, kv, kv, table=jt, pos=jp,
                                          lengths=jl, window=case["window"],
                                          bq=policy.bq)

    return LaunchContract(
        grid=(b * hkv, lq_pad // bq, nblk),
        blocks=tuple(blocks),
        num_scalar_prefetch=3,
        scalars=(pos, lens, table),
        scratch_bytes=(group * bq * 2 + group * bq * d) * 4,
        body=body,
    )
