"""Helpers shared by the serving attention kernels (decode + varlen
prefill): the masked-score sentinel, per-row scalar-vector normalization,
and the in-VMEM QuantKVCache dequant rounding rule.

The dequant lives here so there is exactly ONE copy of the rounding
contract (codes * scale cast through the q dtype, matching
models.attention._dq8): both kernels' fused int8-KV paths assert
bit-identity against dequantize-then-dense, and a drift between two copies
would silently break one of them.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["NEG_INF", "as_row_vector", "vmem_dequant"]

NEG_INF = -1e30


def as_row_vector(x, b: int, fill: int = 0) -> jnp.ndarray:
    """Normalize a per-row scalar argument: None -> `fill`, a scalar
    broadcasts, a (B,) vector passes through."""
    if x is None:
        x = fill
    x = jnp.asarray(x, jnp.int32)
    return jnp.broadcast_to(x.reshape(-1) if x.ndim else x, (b,))


def vmem_dequant(codes_ref, scale_ref, cast_dtype) -> jnp.ndarray:
    """Dequantize a QuantKVCache block inside the kernel, rounding through
    `cast_dtype` (the q dtype) so the fused path is bit-identical to
    dequantize-in-HBM-then-dense-kernel (models.attention._dq8's rule)."""
    return (codes_ref[0].astype(jnp.float32) * scale_ref[0]) \
        .astype(cast_dtype).astype(jnp.float32)
