"""Pallas varlen flash-prefill: batched variable-length prompt attention
over a cache-shaped K/V.

Admission prefill is the other half of every request's latency: the engine
feeds each admitted slot a fixed-width chunk of prompt tokens (right-padded)
whose queries sit at that row's own cache position. Until this kernel, those
launches fell back to the ref path (vector per-row offsets had no
Pallas-eligible route) and did O(width x max_len) f32 score work per row
regardless of how many tokens were real. This kernel is specialized for the
chunk shape:

  * grid (B*Hkv, nq, nk) over q-blocks x KV-blocks with the per-row cache
    position AND valid-length vectors delivered via SCALAR PREFETCH, so
    every BlockSpec index map can see them before any DMA is issued;
  * Q-BLOCK PRUNING: q-blocks entirely past a row's valid token count
    (`lengths[b]`) are skipped with `pl.when` and their index maps clamp to
    the last needed block — a row with 3 real tokens in a 64-wide chunk does
    one q-block of work, not ceil(64/bq);
  * KV-BLOCK PRUNING per (row, q-block): blocks beyond the q-block's causal
    frontier (`pos[b] + min((iq+1)*bq, lengths[b]) - 1`) are skipped, and a
    sliding window adds a LOWER bound, so work scales with each row's REAL
    prompt tokens and resident context, not the chunk width x max_len;
  * the GQA head group is packed into the q tile — (group, bq, D) reshaped
    to a (group*bq, D) MXU operand — so K/V tiles are read once per kv-head;
  * a fused INT8-KV variant takes `(codes, pow2 scale)` and dequantizes in
    VMEM, rounding through `cast_dtype` (the q dtype) so it is bit-identical
    to dequantize-then-dense-kernel.

Rows' invalid (right-pad) query positions return ZEROS — deterministic and
never consumed (the engine gathers each row's last VALID position).

Validated in interpret mode against ref.mha_ref (tests/test_prefill_kernel).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import interpret_mode, pad_to
from .shared import NEG_INF as _NEG_INF
from .shared import as_row_vector, vmem_dequant

__all__ = ["flash_prefill_pallas", "flash_prefill_quant_pallas",
           "flash_prefill_paged_pallas", "flash_prefill_paged_quant_pallas",
           "prefill_block_visits", "prefill_index_maps",
           "paged_prefill_index_maps"]


def _q_last_block(ln, bq: int):
    """Last q-block index a row with `ln` valid tokens needs (>= 0)."""
    return jnp.maximum((ln + bq - 1) // bq - 1, 0)


def _kv_bounds(start, ln, iq, *, bq: int, bkv: int, nk: int,
               window: Optional[int]):
    """KV-block range q-block `iq` of a row at cache position `start` with
    `ln` valid tokens actually needs. The upper bound is the q-block's causal
    frontier (its last VALID query position); a sliding window adds a lower
    bound from its first query. Clamped so first <= last always — pruned
    steps clip into this range and re-see an already-fetched block."""
    qlo = iq * bq
    qhi = jnp.maximum(jnp.minimum(qlo + bq, ln) - 1, 0)
    last = jnp.minimum((start + qhi) // bkv, nk - 1)
    if window is None:
        return jnp.zeros_like(last), last
    first = jnp.maximum(start + qlo - (window - 1), 0) // bkv
    return jnp.minimum(first, last), last


def _online_block(pos_ref, len_ref, q_ref, load_k, load_v, o_ref, visits_ref,
                  m_ref, l_ref, acc_ref, *, scale: float,
                  window: Optional[int], softcap: Optional[float], bq: int,
                  group: int, hkv: int, bkv: int, nk: int, lk_real: int):
    """One (bh, iq, ik) grid step of the online-softmax accumulation."""
    bh, iq, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    start = pos_ref[bh // hkv]
    ln = len_ref[bh // hkv]
    qlo = iq * bq
    first_blk, last_blk = _kv_bounds(start, ln, iq, bq=bq, bkv=bkv, nk=nk,
                                     window=window)
    gl = group * bq

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if visits_ref is not None:
            visits_ref[...] = jnp.zeros_like(visits_ref)

    @pl.when((qlo < ln) & (ik >= first_blk) & (ik <= last_blk))
    def _compute():
        q = q_ref[0].reshape(gl, q_ref.shape[-1]).astype(jnp.float32)
        k = load_k()                                       # (bkv, D) f32
        v = load_v()
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        # packed row r = g*bq + i is query i of the block: position
        # start + qlo + i, valid while qlo + i < ln
        qrel = qlo + jax.lax.broadcasted_iota(jnp.int32, (gl, bkv), 0) % bq
        kpos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (gl, bkv), 1)
        keep = (kpos < lk_real) & (qrel < ln) & (kpos <= start + qrel)
        if window is not None:
            keep &= kpos > start + qrel - window
        s = jnp.where(keep, s, _NEG_INF)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_cur = jnp.maximum(m_prev[:, 0], s.max(-1))
        alpha = jnp.exp(m_prev[:, 0] - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        m_ref[...] = m_cur[:, None]
        l_ref[...] = (l_prev[:, 0] * alpha + p.sum(-1))[:, None]
        acc_ref[...] = acc_prev * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        if visits_ref is not None:
            visits_ref[0, 0, ik] = 1

    @pl.when(ik == nk - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        # invalid (pad) query rows return deterministic zeros; fully-pruned
        # q-blocks are already zero (acc never accumulated)
        qrel = qlo + jax.lax.broadcasted_iota(jnp.int32, (gl, 1), 0) % bq
        out = jnp.where(qrel < ln, out, 0.0)
        o_ref[0] = out.reshape(group, bq, out.shape[-1]).astype(o_ref.dtype)


def _dense_kernel(pos_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                  debug_visits, **kw):
    visits_ref, (m_ref, l_ref, acc_ref) = \
        (rest[0], rest[1:]) if debug_visits else (None, rest)
    _online_block(pos_ref, len_ref, q_ref,
                  lambda: k_ref[0].astype(jnp.float32),
                  lambda: v_ref[0].astype(jnp.float32),
                  o_ref, visits_ref, m_ref, l_ref, acc_ref, **kw)


def _quant_kernel(pos_ref, len_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                  o_ref, *rest, debug_visits, cast_dtype, **kw):
    visits_ref, (m_ref, l_ref, acc_ref) = \
        (rest[0], rest[1:]) if debug_visits else (None, rest)
    _online_block(pos_ref, len_ref, q_ref,
                  lambda: vmem_dequant(kc_ref, ks_ref, cast_dtype),
                  lambda: vmem_dequant(vc_ref, vs_ref, cast_dtype),
                  o_ref, visits_ref, m_ref, l_ref, acc_ref, **kw)


def prefill_index_maps(*, bq: int, bkv: int, nk: int, hkv: int,
                       window: Optional[int]):
    """The q and K/V BlockSpec index maps of a varlen prefill launch.

    Module-level (not a `_launch` closure) so the launch assembly and the
    `repro.analysis` kernel-contract checker evaluate the SAME functions —
    the checker sweeps them out-of-trace over (shape x policy) cases and
    flags out-of-bounds block indices before any kernel runs.
    """
    def q_index(bh, iq, ik, pos_ref, len_ref):
        # pruned q-blocks clamp to the last block the row needs: the
        # pipeline re-sees a fetched index and skips the HBM fetch
        return (bh, 0, jnp.minimum(iq, _q_last_block(len_ref[bh // hkv], bq)),
                0)

    def kv_index(bh, iq, ik, pos_ref, len_ref):
        i = bh // hkv
        first, last = _kv_bounds(pos_ref[i], len_ref[i], iq, bq=bq, bkv=bkv,
                                 nk=nk, window=window)
        return (bh, jnp.clip(ik, first, last), 0)

    return q_index, kv_index


def paged_prefill_index_maps(*, bq: int, bs: int, nblk: int, hkv: int,
                             window: Optional[int]):
    """Index maps of a PAGED varlen-prefill launch: the same per-(row,
    q-block) pruning as `prefill_index_maps`, then logical KV block `lb`
    indirects to physical pool block `table[b, lb]` (pool laid out
    (P*Hkv, bs, D); head h of block p is row p*hkv + h). The clamp runs
    before the lookup, so only owned table entries are read."""
    def q_index(bh, iq, ik, pos_ref, len_ref, tbl_ref):
        return (bh, 0, jnp.minimum(iq, _q_last_block(len_ref[bh // hkv], bq)),
                0)

    def kv_index(bh, iq, ik, pos_ref, len_ref, tbl_ref):
        i = bh // hkv
        first, last = _kv_bounds(pos_ref[i], len_ref[i], iq, bq=bq, bkv=bs,
                                 nk=nblk, window=window)
        return (tbl_ref[i, jnp.clip(ik, first, last)] * hkv + bh % hkv, 0, 0)

    return q_index, kv_index


def _paged_launch(kernel, q, pool_arrays, pos, lens, table, *, bq, interpret,
                  window, softcap, scale, lq_real):
    """pallas_call assembly for the paged variants. pool_arrays are
    (P, Hkv, bs, last) block pools; `table` (B, nblk) int32 rides scalar
    prefetch with pos/lengths so the K/V index maps can indirect."""
    b, hq, lq, d = q.shape
    hkv, bs = pool_arrays[0].shape[1:3]
    group = hq // hkv
    nblk = table.shape[1]
    nq = lq // bq

    qr = q.reshape(b, hkv, group, lq, d).reshape(b * hkv, group, lq, d)
    kvr = [a.reshape(a.shape[0] * hkv, bs, a.shape[-1]) for a in pool_arrays]

    q_index, kv_index = paged_prefill_index_maps(bq=bq, bs=bs, nblk=nblk,
                                                 hkv=hkv, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * hkv, nq, nblk),
        in_specs=[pl.BlockSpec((1, group, bq, d), q_index)] +
                 [pl.BlockSpec((1, bs, a.shape[-1]), kv_index)
                  for a in kvr],
        out_specs=[pl.BlockSpec((1, group, bq, d),
                                lambda bh, iq, ik, pos_ref, len_ref, tbl_ref:
                                (bh, 0, iq, 0))],
        scratch_shapes=[
            pltpu.VMEM((group * bq, 1), jnp.float32),
            pltpu.VMEM((group * bq, 1), jnp.float32),
            pltpu.VMEM((group * bq, d), jnp.float32),
        ],
    )
    outs = pl.pallas_call(
        functools.partial(kernel, debug_visits=False, scale=scale,
                          window=window, softcap=softcap, bq=bq, group=group,
                          hkv=hkv, bkv=bs, nk=nblk, lk_real=nblk * bs),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b * hkv, group, lq, d), q.dtype)],
        interpret=interpret,
    )(pos, lens, table, qr, *kvr)
    out = outs[0].reshape(b, hkv, group, lq, d).reshape(b, hq, lq, d)
    return out[:, :, :lq_real]                    # drop the bq-pad tail


def _paged_dense_kernel(pos_ref, len_ref, tbl_ref, q_ref, k_ref, v_ref,
                        o_ref, *rest, **kw):
    # the table steers the index maps only; the body's logical-position math
    # (kpos = ik*bs + iota) is exactly the dense kernel's
    _dense_kernel(pos_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *rest, **kw)


def _paged_quant_kernel(pos_ref, len_ref, tbl_ref, q_ref, kc_ref, ks_ref,
                        vc_ref, vs_ref, o_ref, *rest, **kw):
    _quant_kernel(pos_ref, len_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                  o_ref, *rest, **kw)


def flash_prefill_paged_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                               table: jax.Array, pos, lengths=None,
                               window: Optional[int] = None,
                               softcap: Optional[float] = None,
                               scale: Optional[float] = None, bq: int = 32,
                               interpret: Optional[bool] = None):
    """Paged varlen prefill. q: (B, Hq, Lq, D) right-padded chunk; k/v:
    (P, Hkv, bs, D) BLOCK POOLS; table: (B, nblk) int32 block map. Block
    size bs doubles as the KV tile, so a paged launch at bs == bkv visits
    the same logical blocks with the same masks as the dense kernel."""
    lq_real = q.shape[2]
    q, pos, lens, bq, interpret = _prep(q, pos, lengths, bq, interpret)
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return _paged_launch(_paged_dense_kernel, q, [k, v], pos, lens,
                         table.astype(jnp.int32), bq=bq, interpret=interpret,
                         window=window, softcap=softcap, scale=scale,
                         lq_real=lq_real)


def flash_prefill_paged_quant_pallas(q: jax.Array, k_codes: jax.Array,
                                     k_scale: jax.Array, v_codes: jax.Array,
                                     v_scale: jax.Array, *, table: jax.Array,
                                     pos, lengths=None,
                                     window: Optional[int] = None,
                                     softcap: Optional[float] = None,
                                     scale: Optional[float] = None,
                                     bq: int = 32,
                                     interpret: Optional[bool] = None):
    """Paged int8-KV prefill: codes (P, Hkv, bs, D) int8 + pow2 scales
    (P, Hkv, bs, 1) f32 pools, dequantized block-by-block in VMEM."""
    lq_real = q.shape[2]
    q, pos, lens, bq, interpret = _prep(q, pos, lengths, bq, interpret)
    if scale is None:
        scale = q.shape[-1] ** -0.5
    kernel = functools.partial(_paged_quant_kernel, cast_dtype=q.dtype)
    return _paged_launch(kernel, q, [k_codes, k_scale, v_codes, v_scale],
                         pos, lens, table.astype(jnp.int32), bq=bq,
                         interpret=interpret, window=window, softcap=softcap,
                         scale=scale, lq_real=lq_real)


def _launch(kernel, q, kv_arrays, pos, lens, *, bq, bkv, interpret,
            debug_visits, window, softcap, scale, lk_real, lq_real):
    """Shared pallas_call assembly for the dense and quantized variants.

    kv_arrays: (B, Hkv, Lk_padded, last) arrays sharing the KV index map
    (codes last=D, scales last=1)."""
    b, hq, lq, d = q.shape
    hkv = kv_arrays[0].shape[1]
    group = hq // hkv
    lk = kv_arrays[0].shape[2]
    nq, nk = lq // bq, lk // bkv

    # pack the GQA group into the q tile: head h = kv*group + g, so the
    # reshape groups each kv-head's query heads contiguously and a
    # (1, group, bq, d) block packs to a (group*bq, d) MXU operand
    qr = q.reshape(b, hkv, group, lq, d).reshape(b * hkv, group, lq, d)
    kvr = [a.reshape(b * hkv, lk, a.shape[-1]) for a in kv_arrays]

    q_index, kv_index = prefill_index_maps(bq=bq, bkv=bkv, nk=nk, hkv=hkv,
                                           window=window)

    out_shape = [jax.ShapeDtypeStruct((b * hkv, group, lq, d), q.dtype)]
    out_specs = [pl.BlockSpec((1, group, bq, d),
                              lambda bh, iq, ik, pos_ref, len_ref:
                              (bh, 0, iq, 0))]
    if debug_visits:
        out_shape.append(jax.ShapeDtypeStruct((b * hkv, nq, nk), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1, nk),
                                      lambda bh, iq, ik, pos_ref, len_ref:
                                      (bh, iq, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hkv, nq, nk),
        in_specs=[pl.BlockSpec((1, group, bq, d), q_index)] +
                 [pl.BlockSpec((1, bkv, a.shape[-1]), kv_index)
                  for a in kvr],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((group * bq, 1), jnp.float32),
            pltpu.VMEM((group * bq, 1), jnp.float32),
            pltpu.VMEM((group * bq, d), jnp.float32),
        ],
    )
    outs = pl.pallas_call(
        functools.partial(kernel, debug_visits=debug_visits, scale=scale,
                          window=window, softcap=softcap, bq=bq, group=group,
                          hkv=hkv, bkv=bkv, nk=nk, lk_real=lk_real),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(pos, lens, qr, *kvr)
    out = outs[0].reshape(b, hkv, group, lq, d).reshape(b, hq, lq, d)
    out = out[:, :, :lq_real]                     # drop the bq-pad tail
    return (out, outs[1]) if debug_visits else out


def _prep(q, pos, lengths, bq: int, interpret):
    """Resolve interpret/bq, pad Lq to a bq multiple, build (B,) vectors."""
    if interpret is None:
        interpret = interpret_mode()
    b, _, lq, _ = q.shape
    bq = max(1, min(bq, lq))
    return (pad_to(q, bq, 2), as_row_vector(pos, b),
            as_row_vector(lengths, b, fill=lq), bq, interpret)


def flash_prefill_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         pos, lengths=None, window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None, bq: int = 32,
                         bkv: int = 128, interpret: Optional[bool] = None,
                         debug_visits: bool = False):
    """q: (B, Hq, Lq, D) right-padded prompt chunk; k, v: (B, Hkv, Lk, D)
    cache (the chunk's keys already written at pos[b]..pos[b]+lengths[b]-1).

    pos: per-row (B,) cache position (or scalar, broadcast): row b's query i
    sits at absolute position pos[b] + i. lengths: per-row (B,) VALID query
    count (None = all Lq valid): rows attend causally only within their own
    prompt; queries at i >= lengths[b] return zeros and their q-blocks /
    KV-blocks are pruned, never fetched.

    debug_visits=True additionally returns a (B*Hkv, nq, nk) int32 map of
    (q-block, KV-block) pairs whose compute actually ran — the pruning
    evidence used by tests and benchmarks (interpret/debug use).
    """
    lq_real = q.shape[2]
    q, pos, lens, bq, interpret = _prep(q, pos, lengths, bq, interpret)
    if scale is None:
        scale = q.shape[-1] ** -0.5
    lk_real = k.shape[2]
    k, v = pad_to(k, bkv, 2), pad_to(v, bkv, 2)
    return _launch(_dense_kernel, q, [k, v], pos, lens, bq=bq, bkv=bkv,
                   interpret=interpret, debug_visits=debug_visits,
                   window=window, softcap=softcap, scale=scale,
                   lk_real=lk_real, lq_real=lq_real)


def flash_prefill_quant_pallas(q: jax.Array, k_codes: jax.Array,
                               k_scale: jax.Array, v_codes: jax.Array,
                               v_scale: jax.Array, *, pos, lengths=None,
                               window: Optional[int] = None,
                               softcap: Optional[float] = None,
                               scale: Optional[float] = None, bq: int = 32,
                               bkv: int = 128,
                               interpret: Optional[bool] = None,
                               debug_visits: bool = False):
    """Fused int8-KV prefill: codes (B, Hkv, Lk, D) int8 + per-position pow2
    scales (B, Hkv, Lk, 1) f32, dequantized block-by-block in VMEM."""
    lq_real = q.shape[2]
    q, pos, lens, bq, interpret = _prep(q, pos, lengths, bq, interpret)
    if scale is None:
        scale = q.shape[-1] ** -0.5
    lk_real = k_codes.shape[2]
    arrays = [pad_to(a, bkv, 2)
              for a in (k_codes, k_scale, v_codes, v_scale)]
    kernel = functools.partial(_quant_kernel, cast_dtype=q.dtype)
    return _launch(kernel, q, arrays, pos, lens, bq=bq, bkv=bkv,
                   interpret=interpret, debug_visits=debug_visits,
                   window=window, softcap=softcap, scale=scale,
                   lk_real=lk_real, lq_real=lq_real)


def prefill_block_visits(pos, lengths, lq: int, lk: int, *, bq: int = 32,
                         bkv: int = 128, window: Optional[int] = None):
    """Expected (visited, total) (q-block, KV-block) pair counts per kv-head
    row for a varlen prefill launch — what `debug_visits` measures, available
    without running it. `total` counts the unpruned grid (every row doing
    every q-block against every KV-block of the padded chunk/cache)."""
    import numpy as np
    pos = np.asarray(pos, np.int64).reshape(-1)
    lens = np.asarray(lengths, np.int64).reshape(-1)
    bq = max(1, min(bq, lq))
    nq = -(-max(lq, 1) // bq)
    nk = -(-max(lk, 1) // bkv)
    visited = 0
    for start, ln in zip(pos, lens):
        for iq in range(nq):
            qlo = iq * bq
            if qlo >= ln:
                continue
            qhi = min(qlo + bq, ln) - 1
            last = min((start + qhi) // bkv, nk - 1)
            first = 0 if window is None \
                else max(start + qlo - (window - 1), 0) // bkv
            visited += int(last - min(first, last) + 1)
    return visited, int(pos.shape[0] * nq * nk)
