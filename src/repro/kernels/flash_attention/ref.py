"""Attention references: naive oracle + memory-bounded chunked implementation.

``mha_ref`` materializes the full score matrix — the test oracle.
``chunked_attention`` is the production pure-JAX path (lax.scan over KV blocks
with online softmax): O(L) memory, used by the model zoo for 32k prefill so
the dry-run HLO reflects a production memory footprint. Supports GQA, causal,
sliding window (gemma2 local layers) and logit softcapping (gemma2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["mha_ref", "chunked_attention"]

_NEG_INF = -1e30


def _mask(lq: int, lk: int, causal: bool, window: Optional[int], offset: int):
    """(lq, lk) boolean keep-mask. offset = kv length already cached, so query
    i sits at absolute position offset + i."""
    qpos = jnp.arange(lq)[:, None] + offset
    kpos = jnp.arange(lk)[None, :]
    keep = jnp.ones((lq, lk), bool)
    if causal:
        keep &= kpos <= qpos
    if window is not None:
        keep &= kpos > qpos - window
    return keep


def _softcap(s: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
            window: Optional[int] = None, softcap: Optional[float] = None,
            scale: Optional[float] = None, offset: int = 0) -> jax.Array:
    """q: (B, Hq, Lq, D); k,v: (B, Hkv, Lk, D) -> (B, Hq, Lq, D)."""
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    keep = _mask(lq, lk, causal, window, offset)
    s = jnp.where(keep[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None, offset: int = 0,
                      chunk: int = 1024) -> jax.Array:
    """Online-softmax attention scanning KV in `chunk`-sized blocks.

    Equivalent to mha_ref to fp32 accuracy but with O(Lq * chunk) live memory
    per head — the same blocking the Pallas kernel performs in VMEM, expressed
    at the XLA level so it lowers on any backend.
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    if lk % chunk:
        pad = chunk - lk % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nchunks = k.shape[2] // chunk
    kc = k.reshape(b, hkv, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)

    qf = q.astype(jnp.float32)
    qpos = jnp.arange(lq) + offset

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, cidx = xs
        kq = jnp.repeat(kblk, group, axis=1).astype(jnp.float32)
        vq = jnp.repeat(vblk, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kq) * scale
        s = _softcap(s, softcap)
        kpos = cidx * chunk + jnp.arange(chunk)
        keep = kpos[None, :] < lk
        if causal:
            keep &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            keep &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(keep[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vq)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, lq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, lq), jnp.float32)
    a0 = jnp.zeros((b, hq, lq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
