"""Attention references: naive oracle + memory-bounded chunked implementation.

``mha_ref`` materializes the full score matrix — the test oracle.
``chunked_attention`` is the production pure-JAX path (lax.scan over KV blocks
with online softmax): O(L) memory, used by the model zoo for 32k prefill so
the dry-run HLO reflects a production memory footprint. Supports GQA, causal,
sliding window (gemma2 local layers) and logit softcapping (gemma2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["mha_ref", "chunked_attention"]

_NEG_INF = -1e30


def _mask(lq: int, lk: int, causal: bool, window: Optional[int], offset):
    """Boolean keep-mask. offset = kv length already cached, so query i sits
    at absolute position offset + i. offset may be a scalar -> (lq, lk) mask,
    or a per-batch-row vector (B,) -> (B, lq, lk) mask (continuous batching:
    each row's cache is at its own position, and the per-row causal frontier
    is what masks a row's not-yet-valid / pad key slots)."""
    qpos = jnp.asarray(offset)[..., None, None] + jnp.arange(lq)[:, None]
    kpos = jnp.arange(lk)[None, :]
    keep = jnp.broadcast_to(jnp.asarray(True),
                            jnp.broadcast_shapes(qpos.shape, kpos.shape))
    if causal:
        keep = keep & (kpos <= qpos)
    if window is not None:
        keep = keep & (kpos > qpos - window)
    return keep


def _apply_mask(s: jax.Array, keep: jax.Array) -> jax.Array:
    """s: (B, H, lq, lk); keep: (lq, lk) or (B, lq, lk)."""
    keep = keep[None, None] if keep.ndim == 2 else keep[:, None]
    return jnp.where(keep, s, _NEG_INF)


def _softcap(s: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
            window: Optional[int] = None, softcap: Optional[float] = None,
            scale: Optional[float] = None, offset=0) -> jax.Array:
    """q: (B, Hq, Lq, D); k,v: (B, Hkv, Lk, D) -> (B, Hq, Lq, D).

    offset: scalar or per-row (B,) query-position offset (see _mask)."""
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    s = _apply_mask(s, _mask(lq, lk, causal, window, offset))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None, offset=0,
                      chunk: int = 1024) -> jax.Array:
    """Online-softmax attention scanning KV in `chunk`-sized blocks.

    Equivalent to mha_ref to fp32 accuracy but with O(Lq * chunk) live memory
    per head — the same blocking the Pallas kernel performs in VMEM, expressed
    at the XLA level so it lowers on any backend.
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    if lk % chunk:
        pad = chunk - lk % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nchunks = k.shape[2] // chunk
    kc = k.reshape(b, hkv, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)

    qf = q.astype(jnp.float32)
    # (lq, 1) for a scalar offset, (B, lq, 1) for per-row offsets
    qpos = jnp.asarray(offset)[..., None, None] + jnp.arange(lq)[:, None]

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, cidx = xs
        kq = jnp.repeat(kblk, group, axis=1).astype(jnp.float32)
        vq = jnp.repeat(vblk, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kq) * scale
        s = _softcap(s, softcap)
        kpos = cidx * chunk + jnp.arange(chunk)[None, :]
        keep = jnp.broadcast_to(kpos < lk,
                                jnp.broadcast_shapes(qpos.shape, kpos.shape))
        if causal:
            keep = keep & (kpos <= qpos)
        if window is not None:
            keep = keep & (kpos > qpos - window)
        s = _apply_mask(s, keep)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vq)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, lq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, lq), jnp.float32)
    a0 = jnp.zeros((b, hq, lq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
