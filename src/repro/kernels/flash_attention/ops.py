"""Public attention op with Pallas / chunked-JAX dispatch."""
from __future__ import annotations

from typing import Optional

import jax

from .. import common
from .kernel import flash_attention_pallas
from .ref import chunked_attention, mha_ref

__all__ = ["attention"]


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              offset: int = 0, chunk: int = 1024,
              prefer_pallas: bool | None = None) -> jax.Array:
    """GQA attention. q: (B,Hq,Lq,D); k,v: (B,Hkv,Lk,D).

    Pallas path on TPU/tests; chunked online-softmax XLA path elsewhere
    (memory-bounded, so 32k-prefill dry-runs reflect production footprints).
    """
    use_pallas = common.pallas_enabled() if prefer_pallas is None else prefer_pallas
    lq, lk = q.shape[2], k.shape[2]
    if use_pallas and lq % 128 == 0:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      softcap=softcap, scale=scale,
                                      offset=offset)
    # One-shot scores up to 4k x 8k: under layer-level remat the score matrix
    # is transient, and autodiff through it is cheap. The chunked scan is for
    # LONG no-grad prefill only — under grad it would checkpoint every
    # chunk's probabilities (O(L^2) saved residuals, the exact blow-up flash
    # attention exists to avoid).
    if lq == 1 or lq * lk <= 4096 * 8192:
        return mha_ref(q, k, v, causal=causal, window=window, softcap=softcap,
                       scale=scale, offset=offset)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, offset=offset,
                             chunk=chunk)
