"""Attention: registry implementations + legacy shim.

"pallas" is the 128-aligned scalar-offset flash kernel (full-sequence
prefill); "pallas-prefill" is the VARLEN flash-prefill kernel (multi-token
right-padded chunks over a cache at per-row positions — scalar-prefetched
pos+lengths, q-block and KV-block pruning, fused int8-KV dequant);
"pallas-decode" is the flash-decode kernel (short Lq over a long per-row
cache, scalar-prefetched positions, block pruning, fused int8-KV dequant);
"ref" is the XLA path — one-shot scores for short contexts, chunked
online-softmax for long no-grad prefill (memory-bounded, so 32k-prefill
dry-runs reflect production footprints). `repro.api.ops.attention` owns the
dispatch, including shape eligibility (see `repro.api.ops.attention_route`).

Every impl accepts optional `k_scale`/`v_scale`: when given, k/v are int8
codes with per-position pow2 scales (the QuantKVCache layout) and the impl
dequantizes — in VMEM for the decode/prefill kernels, up front for the
others. Every impl also accepts `lengths` (per-row valid query counts for a
right-padded chunk): the varlen prefill kernel PRUNES with it; the others
ignore it (their outputs at invalid positions are garbage the engine never
consumes, and masking them would change nothing downstream).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...api.policy import ExecutionPolicy
from ...api.registry import register
from .decode import (flash_decode_paged_pallas,
                     flash_decode_paged_quant_pallas, flash_decode_pallas,
                     flash_decode_quant_pallas)
from .kernel import flash_attention_pallas
from .prefill import (flash_prefill_paged_pallas,
                      flash_prefill_paged_quant_pallas, flash_prefill_pallas,
                      flash_prefill_quant_pallas)
from .ref import chunked_attention, mha_ref

__all__ = ["attention"]


def _dequant(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """QuantKVCache dequant, matching models.attention._q8's inverse: round
    through the compute dtype so ref results are unchanged by the move from
    materialize-in-HBM to dequant-at-dispatch."""
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def _maybe_dequant(q, k, v, k_scale, v_scale):
    if k_scale is None:
        return k, v
    return _dequant(k, k_scale, q.dtype), _dequant(v, v_scale, q.dtype)


def _gather_pages(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Materialize a (P, Hkv, bs, last) block pool into per-row cache-shaped
    (B, Hkv, nblk*bs, last) via the (B, nblk) block table — the ref path's
    view of a paged cache. Positions past a row's frontier read whatever the
    mapped blocks hold; the causal/frontier mask removes them exactly, the
    same contract the per-slot cache tail relies on."""
    g = pool[table]                              # (B, nblk, Hkv, bs, last)
    b, nblk, h, bs, last = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, h, nblk * bs, last)


@register("attention", "pallas")
def _attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None, offset=0,
                      lengths: Optional[jax.Array] = None,
                      k_scale: Optional[jax.Array] = None,
                      v_scale: Optional[jax.Array] = None,
                      block_tables: Optional[jax.Array] = None,
                      policy: ExecutionPolicy) -> jax.Array:
    assert block_tables is None, \
        "the full-sequence kernel has no paged route (dispatch sends paged " \
        "cache-shaped calls to pallas-prefill/pallas-decode/ref)"
    k, v = _maybe_dequant(q, k, v, k_scale, v_scale)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale, offset=offset)


@register("attention", "pallas-prefill")
def _attention_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       scale: Optional[float] = None, offset=0,
                       lengths: Optional[jax.Array] = None,
                       k_scale: Optional[jax.Array] = None,
                       v_scale: Optional[jax.Array] = None,
                       block_tables: Optional[jax.Array] = None,
                       policy: ExecutionPolicy) -> jax.Array:
    assert causal, "the varlen prefill kernel is causal by construction"
    if block_tables is not None:
        if k_scale is not None:
            return flash_prefill_paged_quant_pallas(
                q, k, k_scale, v, v_scale, table=block_tables, pos=offset,
                lengths=lengths, window=window, softcap=softcap, scale=scale,
                bq=policy.bq)
        return flash_prefill_paged_pallas(
            q, k, v, table=block_tables, pos=offset, lengths=lengths,
            window=window, softcap=softcap, scale=scale, bq=policy.bq)
    if k_scale is not None:
        return flash_prefill_quant_pallas(
            q, k, k_scale, v, v_scale, pos=offset, lengths=lengths,
            window=window, softcap=softcap, scale=scale, bq=policy.bq,
            bkv=policy.bkv)
    return flash_prefill_pallas(q, k, v, pos=offset, lengths=lengths,
                                window=window, softcap=softcap, scale=scale,
                                bq=policy.bq, bkv=policy.bkv)


@register("attention", "pallas-decode")
def _attention_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None, offset=0,
                      lengths: Optional[jax.Array] = None,
                      k_scale: Optional[jax.Array] = None,
                      v_scale: Optional[jax.Array] = None,
                      block_tables: Optional[jax.Array] = None,
                      policy: ExecutionPolicy) -> jax.Array:
    assert causal, "the decode kernel is causal by construction"
    if block_tables is not None:
        if k_scale is not None:
            return flash_decode_paged_quant_pallas(
                q, k, k_scale, v, v_scale, table=block_tables, pos=offset,
                window=window, softcap=softcap, scale=scale)
        return flash_decode_paged_pallas(
            q, k, v, table=block_tables, pos=offset, window=window,
            softcap=softcap, scale=scale)
    if k_scale is not None:
        return flash_decode_quant_pallas(
            q, k, k_scale, v, v_scale, pos=offset, window=window,
            softcap=softcap, scale=scale, bkv=policy.bkv)
    return flash_decode_pallas(q, k, v, pos=offset, window=window,
                               softcap=softcap, scale=scale, bkv=policy.bkv)


@register("attention", "ref")
def _attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: Optional[int] = None,
                   softcap: Optional[float] = None,
                   scale: Optional[float] = None, offset=0,
                   lengths: Optional[jax.Array] = None,
                   k_scale: Optional[jax.Array] = None,
                   v_scale: Optional[jax.Array] = None,
                   block_tables: Optional[jax.Array] = None,
                   policy: ExecutionPolicy) -> jax.Array:
    if block_tables is not None:
        # gather codes AND scales through the table, then dequantize — the
        # same value order as dequantize-then-gather, without a f32 pool copy
        k = _gather_pages(k, block_tables)
        v = _gather_pages(v, block_tables)
        if k_scale is not None:
            k_scale = _gather_pages(k_scale, block_tables)
            v_scale = _gather_pages(v_scale, block_tables)
    k, v = _maybe_dequant(q, k, v, k_scale, v_scale)
    lq, lk = q.shape[2], k.shape[2]
    # One-shot scores up to 4k x 8k: under layer-level remat the score matrix
    # is transient, and autodiff through it is cheap. The chunked scan is for
    # LONG no-grad prefill only — under grad it would checkpoint every
    # chunk's probabilities (O(L^2) saved residuals, the exact blow-up flash
    # attention exists to avoid).
    if lq == 1 or lq * lk <= 4096 * 8192:
        return mha_ref(q, k, v, causal=causal, window=window, softcap=softcap,
                       scale=scale, offset=offset)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, offset=offset,
                             chunk=policy.chunk)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              offset: int = 0, chunk: int = 1024,
              prefer_pallas: bool | None = None) -> jax.Array:
    """Deprecated: call `repro.api.ops.attention` (policy-driven) instead."""
    from ... import api
    return api.ops.attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        offset=offset, chunk=chunk,
        backend=api.ops.backend_from_prefer_pallas(prefer_pallas))
