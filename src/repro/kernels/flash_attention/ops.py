"""Attention: registry implementations + legacy shim.

"pallas" is the flash kernel (TPU, or interpret mode in tests); "ref" is the
XLA path — one-shot scores for short contexts, chunked online-softmax for
long no-grad prefill (memory-bounded, so 32k-prefill dry-runs reflect
production footprints). `repro.api.ops.attention` owns the dispatch,
including the Lq % 128 pallas-eligibility fallback.
"""
from __future__ import annotations

from typing import Optional

import jax

from ...api.policy import ExecutionPolicy
from ...api.registry import register
from .kernel import flash_attention_pallas
from .ref import chunked_attention, mha_ref

__all__ = ["attention"]


@register("attention", "pallas")
def _attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None, offset=0,
                      policy: ExecutionPolicy) -> jax.Array:
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale, offset=offset)


@register("attention", "ref")
def _attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: Optional[int] = None,
                   softcap: Optional[float] = None,
                   scale: Optional[float] = None, offset=0,
                   policy: ExecutionPolicy) -> jax.Array:
    lq, lk = q.shape[2], k.shape[2]
    # One-shot scores up to 4k x 8k: under layer-level remat the score matrix
    # is transient, and autodiff through it is cheap. The chunked scan is for
    # LONG no-grad prefill only — under grad it would checkpoint every
    # chunk's probabilities (O(L^2) saved residuals, the exact blow-up flash
    # attention exists to avoid).
    if lq == 1 or lq * lk <= 4096 * 8192:
        return mha_ref(q, k, v, causal=causal, window=window, softcap=softcap,
                       scale=scale, offset=offset)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, offset=offset,
                             chunk=policy.chunk)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              offset: int = 0, chunk: int = 1024,
              prefer_pallas: bool | None = None) -> jax.Array:
    """Deprecated: call `repro.api.ops.attention` (policy-driven) instead."""
    from ... import api
    return api.ops.attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        offset=offset, chunk=chunk,
        backend=api.ops.backend_from_prefer_pallas(prefer_pallas))
