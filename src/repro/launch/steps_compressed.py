"""Train step with AIO-compressed data-parallel gradient all-reduce.

The paper's format plane applied to communication (§Perf iteration 6): the
DP gradient sync — the dominant collective for giant-MoE training after the
EP/TP fixes — runs in int8 with a shared power-of-two scale (bias-foldable
on the paper's hardware) and local error feedback.

Mechanics: shard_map over the DP axes with the "model" axis left AUTO, so
TP/EP inside the model still partition normally while autodiff's implicit
DP psum disappears (each DP shard sees only its batch slice). The explicit
compressed all-reduce then syncs grads at 1/4 the wire bytes of f32 (1/2 of
bf16). Error feedback keeps SGD convergence (EF-SGD).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import formats as F
from ..models import transformer as T
from ..optim import adamw_update, cosine_schedule
from ..optim.grad_compress import compressed_psum

__all__ = ["make_compressed_train_step"]


def make_compressed_train_step(cfg: T.ModelConfig, mesh, *, fmt_name="int8",
                               base_lr: float = 3e-4, warmup: int = 100,
                               total: int = 10_000):
    """Returns train_step(params, opt_state, err, batch) -> (p, o, err, m).

    err: error-feedback pytree (same structure as params, f32).
    """
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    auto = frozenset(a for a in mesh.axis_names if a not in dp)
    fmt = F.REGISTRY[fmt_name]
    world = 1
    for a in dp:
        world *= mesh.shape[a]

    def local_grads(params, batch):
        """Per-DP-shard loss/grads; model axis stays auto-partitioned."""
        def body(p, b):
            (loss, metrics), grads = jax.value_and_grad(
                T.loss_fn, has_aux=True)(p, b, cfg)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp), metrics)
            return grads, metrics

        batch_specs = jax.tree.map(lambda _: P(dp), batch)
        rep = jax.tree.map(lambda _: P(), params)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(rep, batch_specs),
            out_specs=(rep, P()),
            axis_names=set(dp), check_vma=False,
        )(params, batch)

    def sync(grads, err):
        """Compressed mean-all-reduce over DP with error feedback."""
        def one(g, e):
            spec = P(*([None] * g.ndim))

            @partial(jax.shard_map, mesh=mesh, in_specs=(spec, spec),
                     out_specs=(spec, spec), axis_names=set(dp),
                     check_vma=False)
            def body(gl, el):
                xl = gl.astype(jnp.float32) + el
                total_ = compressed_psum(xl, dp, fmt)
                new_e = xl - _rt(xl)
                return (total_ / world).astype(gl.dtype), new_e
            return body(g, e)

        def _rt(x):
            amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
            scale = F.pow2_ceil(amax / fmt.max_finite)
            if fmt.kind == "int":
                return jnp.clip(jnp.round(x / scale), fmt.int_min,
                                fmt.int_max) * scale
            return F.quantize(x / scale, fmt) * scale

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
                jax.tree.unflatten(tdef, [o[1] for o in outs]))

    def train_step(params, opt_state, err, batch):
        grads, metrics = local_grads(params, batch)
        grads, err = sync(grads, err)
        lr = cosine_schedule(opt_state.step, base_lr=base_lr, warmup=warmup,
                             total=total)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                lr=lr)
        return params, opt_state, err, dict(metrics, grad_norm=gnorm, lr=lr)

    return train_step
