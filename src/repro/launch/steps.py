"""Step functions (train / prefill / decode) + ShapeDtypeStruct input specs.

These are the units the multi-pod dry-run lowers and the trainer/server jit.
``input_specs(cfg, cell)`` follows the brief: weak-type-correct stand-ins for
every model input, shardable, no device allocation. Modality frontends are
stubs — input_specs provides the precomputed frame/patch embeddings.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.common import ShapeCell
from ..models import transformer as T
from ..optim import adamw_init, adamw_update, cosine_schedule

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step",
           "input_specs", "params_shapes", "opt_shapes", "cache_shapes"]


# =============================================================================
# Steps
# =============================================================================

def make_train_step(cfg: T.ModelConfig, *, base_lr: float = 3e-4,
                    warmup: int = 100, total: int = 10_000):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, batch, cfg)
        lr = cosine_schedule(opt_state.step, base_lr=base_lr, warmup=warmup,
                             total=total)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                lr=lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: T.ModelConfig):
    """Forward over the request batch; returns next-token ids (greedy)."""
    def prefill_step(params, batch):
        logits, _ = T.forward(params, batch["tokens"], cfg,
                              prefix_embeds=batch.get("patch_embeds"),
                              frames=batch.get("frames"))
        return jnp.argmax(logits[:, -1], axis=-1)
    return prefill_step


def make_serve_step(cfg: T.ModelConfig):
    """One decode step for the whole batch against seq_len-sized caches."""
    def serve_step(params, caches, token, memory=None):
        logits, caches = T.decode_step(params, caches, token, cfg,
                                       memory=memory)
        return jnp.argmax(logits[:, -1], axis=-1)[:, None], caches
    return serve_step


# =============================================================================
# Shape stand-ins (no allocation)
# =============================================================================

def params_shapes(cfg: T.ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda k: T.init_params(k, cfg, dtype=dtype),
                          jax.random.key(0))


def opt_shapes(cfg: T.ModelConfig, dtype=jnp.bfloat16):
    p = params_shapes(cfg, dtype)
    return jax.eval_shape(adamw_init, p)


def cache_shapes(cfg: T.ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    return jax.eval_shape(
        partial(T.init_caches, cfg, batch=batch, max_len=max_len,
                dtype=dtype))


def input_specs(cfg: T.ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Model inputs for one shape cell as ShapeDtypeStructs.

    train/prefill: {tokens, labels?, frames?/patch_embeds?}
    decode: {token, (memory for enc-dec)} — caches come from cache_shapes.
    Frontend stubs: text length shrinks by frontend_len for VLM so the total
    stream is the assigned seq_len; audio frames ride alongside in full.
    """
    b, l = cell.batch, cell.seq
    i32 = jnp.int32
    f32 = jnp.float32
    if cell.kind in ("train", "prefill"):
        l_text = l
        specs: Dict[str, Any] = {}
        if cfg.family == "vlm":
            l_text = l - cfg.frontend_len
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), f32)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), f32)
        specs["tokens"] = jax.ShapeDtypeStruct((b, l_text), i32)
        if cell.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, l_text), i32)
        return specs
    # decode
    specs = {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "audio":
        specs["memory"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), f32)
    return specs
