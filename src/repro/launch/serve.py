"""Serving launcher — single- or multi-tenant.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1p5b --smoke \\
        --requests 6 --max-new 8
    PYTHONPATH=src python -m repro.launch.serve --multi-tenant --smoke

--multi-tenant runs the paper's §VI-C scenario shape: two engines (a
captioning-style tenant and a classification-style tenant stand-in) on
mesh partitions chosen by the morphable scheduler.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .. import api
from ..configs import get_config, get_smoke
from ..models import init_params
from ..serving import Request, ServingEngine
from ..tenancy import MorphableScheduler, Tenant


def _occupancy_line(eng: ServingEngine) -> str:
    cells = ["--" if o is None else f"r{o['rid']}+{o['generated']}"
             for o in eng.occupancy()]
    return f"slots [{' '.join(cells)}] util {eng.utilization():.2f}"


def _run_engine(arch: str, smoke: bool, n_requests: int, max_new: int,
                seed: int = 0, policy: api.ExecutionPolicy = None,
                sched=None, tenant: str = None, weight_format: str = None,
                prefill_chunk: int = 32, max_queue: int = None,
                deadline_steps: int = None, ttl_s: float = None,
                paged: bool = False, block_size: int = 16,
                pool_blocks: int = None, swap_watermark: float = 1.0,
                priorities: list = None):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if policy is not None and policy.format != "bf16":
        # the policy's format plane reaches the model through its
        # QuantPolicy: every linear fake-quants acts+weights to the format
        import dataclasses
        from ..models.layers import QuantPolicy
        cfg = dataclasses.replace(cfg, quant=QuantPolicy(
            activations=policy.format, weights=policy.format))
    params = init_params(jax.random.key(seed), cfg)
    if weight_format not in (None, "none"):
        # quantize at load and DONATE the dense pytree into the pass: the
        # f32 weights are freed as the codes are built (untouched leaves
        # alias through), so HBM never holds weights twice. The engine then
        # serves from the code pytree — no dense weight in its hot loop.
        from ..models import quantize_params
        params = jax.jit(lambda p: quantize_params(p, weight_format),
                         donate_argnums=(0,))(params)
    eng = ServingEngine(cfg, params, slots=4, max_len=128, policy=policy,
                        prefill_chunk=prefill_chunk, max_queue=max_queue,
                        deadline_steps=deadline_steps, ttl_s=ttl_s,
                        paged=paged, block_size=block_size,
                        pool_blocks=pool_blocks,
                        swap_watermark=swap_watermark)
    # compile the decode- and chunk-shaped step programs up front: the first
    # request pays zero compile stall, and the fixed chunk shape means these
    # two traces are ALL the engine ever compiles
    t_warm = time.time()
    eng.warmup()
    print(f"[serve:{arch}] warmup traced decode + chunk({prefill_chunk}) "
          f"prefill in {time.time() - t_warm:.2f}s "
          f"(prefill route {eng.prefill_route()}, "
          f"decode route {eng.decode_route()})")
    if weight_format not in (None, "none"):
        print(f"[serve:{arch}] weight residency: {eng.weight_route()}")
    if sched is not None and tenant is not None:
        sched.attach_engine(tenant, eng)
    rng = np.random.RandomState(seed)
    t0 = time.time()
    for rid in range(n_requests):
        prompt = rng.randint(1, cfg.vocab, rng.randint(3, 10)).astype(np.int32)
        prio = priorities[rid % len(priorities)] if priorities else 0
        if not eng.submit(Request(rid, prompt, max_new_tokens=max_new,
                                  priority=prio)):
            print(f"[serve:{arch}] request {rid} REJECTED "
                  f"(queue full at {max_queue})")
    # drive step-by-step so per-slot occupancy is observable mid-flight
    while eng.pending():
        eng.step()
        if eng.stats.decode_steps in (1, max(2, max_new // 2)):
            print(f"[serve:{arch}] step {eng.stats.decode_steps}: "
                  f"{_occupancy_line(eng)}")
    done = eng.finished
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    st = eng.stats
    print(f"[serve:{arch}] {len(done)} requests, {toks} tokens, "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s; {st.decode_steps} decode steps, "
          f"{st.prefill_chunk_calls} chunked prefills)")
    # the fault surface: zero everywhere on a healthy run, and the first
    # place to look when outputs or latency drift
    print(f"[serve:{arch}] fault counters: quarantines={st.quarantines} "
          f"demotions={st.demotions} timeouts={st.timeouts} "
          f"rejected={st.rejected_submits} failed={st.failed_requests}")
    if paged:
        ps = eng.pool_stats()
        print(f"[serve:{arch}] pool: {ps['pool_blocks']} blocks "
              f"(block_size={ps['block_size']}, watermark="
              f"{ps['swap_watermark']:.2f} -> soft cap "
              f"{ps['watermark_blocks']} blocks) "
              f"evictions={ps['evictions']} skips={ps['eviction_skips']} "
              f"deferred={ps['deferred_admissions']}")
        print(f"[serve:{arch}] swap: preemptions={ps['preemptions']} "
              f"out={ps['swap_outs']} in={ps['swap_ins']} "
              f"bytes_out={ps['swap_bytes_out']} "
              f"bytes_in={ps['swap_bytes_in']} "
              f"host_resident={ps['host_blocks']} blk "
              f"({ps['host_bytes']} B)")
    for ev in eng.degraded_routes():
        print(f"[serve:{arch}] DEGRADED at step {ev['step']}: "
              f"{ev['from']} -> {ev['to']} ({ev['error']})")
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1p5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--multi-tenant", action="store_true")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "ref"),
                    help="ExecutionPolicy backend plane; 'pallas' routes "
                         "decode-step attention to the flash-decode kernel, "
                         "chunked admission prefill to the varlen "
                         "flash-prefill kernel, and 128-aligned prefill to "
                         "the flash kernel (see api.ops.attention_route)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="tokens a new prompt advances per admission launch "
                         "(interleaved with decode steps): small chunks keep "
                         "resident slots generating smoothly while a long "
                         "prompt admits, large chunks admit in fewer "
                         "launches; greedy outputs identical either way")
    ap.add_argument("--format", default="bf16",
                    choices=("bf16", "fp8a", "fp8b", "int8", "int4"),
                    help="AIO format: applied to every linear via the model's "
                         "QuantPolicy (bf16 = no fake-quant)")
    ap.add_argument("--weight-format", default="none",
                    choices=("none", "int4", "int8", "fp8a", "fp8b"),
                    help="make Linear weights RESIDENT in this AIO format: "
                         "quantized once at load (dense pytree donated away) "
                         "and served as packed codes through "
                         "api.ops.matmul_codes — int4 is 8x less HBM weight "
                         "traffic than f32, greedy outputs byte-identical to "
                         "the fake-quant path")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged block-pool KV cache (prefix "
                         "sharing + CoW + host-swap under pressure)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per physical KV block (--paged)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="physical blocks in the pool (--paged; default "
                         "sized so every slot can reach max_len)")
    ap.add_argument("--swap-watermark", type=float, default=1.0,
                    help="high-watermark fraction of the pool above which "
                         "admission evicts cold prefixes and then PREEMPTS "
                         "lower-priority rows (live KV swapped to host, "
                         "byte-identical resume); 1.0 = swap only when a "
                         "reservation cannot be met at all")
    ap.add_argument("--priority", default=None,
                    help="comma-separated priority cycle assigned to "
                         "submitted requests, e.g. '0,1' alternates low/"
                         "high; higher preempts lower under pool pressure")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the admission queue: submits beyond this "
                         "depth are REJECTED (backpressure) instead of "
                         "queueing without limit")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="per-request deadline in engine steps; expired "
                         "requests finish with status TIMEOUT")
    ap.add_argument("--ttl-s", type=float, default=None,
                    help="per-request wall-clock TTL in seconds")
    args = ap.parse_args()

    policy = api.ExecutionPolicy(format=args.format, backend=args.backend)
    priorities = ([int(x) for x in args.priority.split(",")]
                  if args.priority else None)
    if not args.multi_tenant:
        _run_engine(args.arch, args.smoke, args.requests, args.max_new,
                    policy=policy, weight_format=args.weight_format,
                    prefill_chunk=args.prefill_chunk,
                    max_queue=args.max_queue,
                    deadline_steps=args.deadline_steps, ttl_s=args.ttl_s,
                    paged=args.paged, block_size=args.block_size,
                    pool_blocks=args.pool_blocks,
                    swap_watermark=args.swap_watermark,
                    priorities=priorities)
        return

    # §VI-C-shaped scenario: two tenants, morphable mesh partitions
    sched = MorphableScheduler()
    parts = sched.reconfigure([
        Tenant("captioning", weight_rows=64, weight_cols=512, fmt="int8"),
        Tenant("classification", weight_rows=64, weight_cols=768, fmt="int8"),
    ])
    print(f"[serve] fusion plan: {sched.plan.describe()}; partitions: "
          f"{[p.tenants for p in parts]}")
    for tenant, arch in (("captioning", "olmoe_1b_7b"),
                         ("classification", "qwen2_1p5b")):
        sched.run(tenant, _run_engine, arch, True, args.requests,
                  args.max_new, policy=policy, sched=sched, tenant=tenant,
                  weight_format=args.weight_format,
                  prefill_chunk=args.prefill_chunk)
    for name, occ in sched.occupancy().items():
        print(f"[serve] tenant {name}: final {len(occ)} slots, "
              f"{sum(o is not None for o in occ)} busy")


if __name__ == "__main__":
    main()
