"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_1p5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Per cell this records compile success, per-device memory analysis, HLO
FLOPs/bytes (cost_analysis), per-device collective operand bytes (parsed from
the compiled SPMD module), and MODEL_FLOPS — everything §Roofline consumes.
Layers lower fully unrolled (exact loop-body accounting; see ModelConfig.
scan_unroll). Placeholder devices are CPU threads: lowering uses
ShapeDtypeStructs, nothing is allocated.
"""
# The VERY FIRST lines, before any other import — jax locks the device count
# on first init:
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import math          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, get_config, shape_support  # noqa: E402
from ..dist import batch_specs, cache_specs, opt_state_specs, param_specs  # noqa: E402
from ..dist.sharding import set_mesh  # noqa: E402
from ..models import transformer as T  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from . import steps as S  # noqa: E402

# Assigned archs only (the paper's own gpt2/llama ride through benchmarks/)
DRYRUN_ARCHS = [a for a in ARCH_IDS if a not in ("gpt2_small", "llama2_7b")]

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                       r"\[([\d,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dtype, 4)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo: str):
    """Per-device *wire* bytes of every collective in the compiled module.

    The SPMD module is the per-device program: result shapes are shard-local.
    Compiled HLO prints operands as bare names, so sizes come from the RESULT
    shape + the replica group size G, converted with the ring model:
        all-reduce        2*(G-1)/G * result   (reduce-scatter + all-gather)
        all-gather        (G-1)/G * result     (receives all but own shard)
        reduce-scatter    (G-1)/G * result*G   (operand is G x result)
        all-to-all        (G-1)/G * result
        collective-permute result               (one hop)
    `-start` variants cover async collectives; `-done` is skipped.
    """
    per_op = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            m = re.search(rf"= (.*?) ({kind}|{kind}-start)\(", stripped)
            if not m:
                continue
            result = m.group(1)            # e.g. "f32[64,1024]{1,0}" or tuple
            rbytes = sum(_shape_bytes(sm.group(1), sm.group(2))
                         for sm in _SHAPE_RE.finditer(result))
            gm = _GROUPS_RE.search(stripped)
            g = int(gm.group(2)) if gm else 1
            if g <= 1:
                wire = 0.0
            elif kind == "all-reduce":
                wire = 2.0 * (g - 1) / g * rbytes
            elif kind == "reduce-scatter":
                wire = (g - 1) / g * rbytes * g
            elif kind == "collective-permute":
                wire = float(rbytes)
            else:                           # all-gather / all-to-all
                wire = (g - 1) / g * rbytes
            per_op[kind] += wire
            counts[kind] += 1
            break
    per_op["total"] = sum(per_op[k] for k in _COLLECTIVES)
    per_op["counts"] = counts
    return per_op


def model_flops(cfg, n_params: int, n_active: int, cell) -> float:
    """6*N*D for training, 2*N*D forward-only (N_active for MoE)."""
    n = n_active if cfg.n_experts else n_params
    if cell.kind == "train":
        return 6.0 * n * cell.batch * cell.seq
    if cell.kind == "prefill":
        return 2.0 * n * cell.batch * cell.seq
    return 2.0 * n * cell.batch          # decode: one token per sequence


def probe_pair(cfg):
    """Two shallow configs (all segment types present; the repeating unit
    appears once vs twice) + the extrapolation multiplier.

    total(metric) = F(base) + mult * (F(base+1unit) - F(base)).
    Exact by linearity of per-unit HLO cost — the dry-run compiles these two
    UNROLLED (cost_analysis counts a while body once, so the full scanned
    module can't be used for FLOP totals)."""
    r = dataclasses.replace
    if cfg.family == "audio":           # encoder fixed, decoder unit scales
        return r(cfg, n_layers=1), r(cfg, n_layers=2), cfg.n_layers - 1
    if cfg.attn_every:
        u = cfg.attn_every
        return (r(cfg, n_layers=u), r(cfg, n_layers=2 * u),
                cfg.n_layers // u - 1)
    if cfg.slstm_every:
        u = cfg.slstm_every
        return (r(cfg, n_layers=u), r(cfg, n_layers=2 * u),
                cfg.n_layers // u - 1)
    if cfg.local_global:
        return r(cfg, n_layers=2), r(cfg, n_layers=4), cfg.n_layers // 2 - 1
    if cfg.n_experts and cfg.n_dense_layers:
        nd = cfg.n_dense_layers
        return (r(cfg, n_layers=nd + 1), r(cfg, n_layers=nd + 2),
                cfg.n_layers - nd - 1)
    return r(cfg, n_layers=1), r(cfg, n_layers=2), cfg.n_layers - 1


def _lower_one(cfg, cell, mesh):
    """Lower + compile one step function; returns the compiled artifact.
    Runs under set_mesh so in-model sharding constraints (EP in moe_apply)
    bind to the production mesh."""
    with set_mesh(mesh):
        return _lower_one_inner(cfg, cell, mesh)


def _lower_one_inner(cfg, cell, mesh):
    p_shapes = S.params_shapes(cfg)
    p_specs = param_specs(p_shapes, mesh)
    if cell.kind == "train":
        o_shapes = S.opt_shapes(cfg)
        o_specs = opt_state_specs(o_shapes, mesh)
        b_shapes = S.input_specs(cfg, cell)
        b_specs = batch_specs(b_shapes, mesh)
        step = S.make_train_step(cfg)
        # out_shardings pinned to the input specs: params/opt must come back
        # in the same layout every step (otherwise XLA picks a different
        # output sharding and the train loop reshards on every iteration)
        lowered = jax.jit(step, in_shardings=(p_specs, o_specs, b_specs),
                          out_shardings=(p_specs, o_specs, None)
                          ).lower(p_shapes, o_shapes, b_shapes)
    elif cell.kind == "prefill":
        b_shapes = S.input_specs(cfg, cell)
        b_specs = batch_specs(b_shapes, mesh)
        step = S.make_prefill_step(cfg)
        lowered = jax.jit(step, in_shardings=(p_specs, b_specs)
                          ).lower(p_shapes, b_shapes)
    else:  # decode
        c_shapes = S.cache_shapes(cfg, cell.batch, cell.seq)
        c_specs = cache_specs(c_shapes, mesh)
        b_shapes = S.input_specs(cfg, cell)
        b_specs = batch_specs(b_shapes, mesh)
        step = S.make_serve_step(cfg)
        if cfg.family == "audio":
            fn = lambda p, c, t, m: step(p, c, t, memory=m)  # noqa: E731
            lowered = jax.jit(fn, in_shardings=(
                p_specs, c_specs, b_specs["token"], b_specs["memory"])
            ).lower(p_shapes, c_shapes, b_shapes["token"], b_shapes["memory"])
        else:
            fn = lambda p, c, t: step(p, c, t)  # noqa: E731
            lowered = jax.jit(fn, in_shardings=(
                p_specs, c_specs, b_specs["token"])
            ).lower(p_shapes, c_shapes, b_shapes["token"])
    return lowered.compile()


def _costs(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):        # older jax: one dict per program
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_override=None, tag: str = "", probes: bool = True):
    """Lower+compile one cell; returns the result record (raises on failure).

    Full config compiles SCANNED (memory analysis + compile-success gate);
    roofline terms come from two unrolled probe configs extrapolated
    linearly over the repeating layer unit."""
    cell = SHAPES[shape_name]
    support = shape_support(arch)
    if support[shape_name] is not None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped": support[shape_name]}

    cfg = cfg_override or get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    p_shapes = S.params_shapes(cfg)
    n_params = sum(math.prod(x.shape) for x in jax.tree.leaves(p_shapes))
    n_active = _active_params(p_shapes, cfg)

    t0 = time.time()
    compiled = _lower_one(cfg, cell, mesh)
    t_full = time.time() - t0
    ma = compiled.memory_analysis()

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag,
        "chips": int(n_chips),
        "n_params": int(n_params),
        "n_params_active": int(n_active),
        "model_flops": model_flops(cfg, n_params, n_active, cell),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "compile_s": round(t_full, 2),
    }

    if probes:
        base_cfg, big_cfg, mult = probe_pair(cfg)
        unroll = dict(scan_unroll=10 ** 6)
        f_base = _costs(_lower_one(dataclasses.replace(base_cfg, **unroll),
                                   cell, mesh))
        f_big = _costs(_lower_one(dataclasses.replace(big_cfg, **unroll),
                                  cell, mesh))
        def extrap(key):
            # clamp: tiny decode cells can have F(big) < F(base) on noise-
            # level terms (XLA folds differently); totals stay >= base
            return max(f_base[key] + mult * (f_big[key] - f_base[key]),
                       f_base[key] * 0.5)
        coll = {}
        for k in list(f_base["coll"].keys()):
            if k == "counts":
                continue
            coll[k] = max(f_base["coll"][k] + mult * (f_big["coll"][k] -
                                                      f_base["coll"][k]), 0.0)
        rec["hlo_flops"] = extrap("flops")
        rec["hlo_bytes"] = extrap("bytes")
        rec["collective_bytes"] = coll
        rec["probe"] = {"base_layers": base_cfg.n_layers,
                        "big_layers": big_cfg.n_layers, "mult": mult}

    print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: OK "
          f"(compile {t_full:.1f}s, "
          f"flops/dev {rec.get('hlo_flops', 0):.3e}, "
          f"args {ma.argument_size_in_bytes/2**30:.2f} GiB/dev, "
          f"temp {ma.temp_size_in_bytes/2**30:.2f} GiB/dev, "
          f"coll {rec.get('collective_bytes', {}).get('total', 0)/2**20:.1f}"
          f" MiB/dev)")
    print(f"  memory_analysis: {ma}")
    return rec


def _active_params(p_shapes, cfg) -> int:
    total = sum(math.prod(x.shape) for x in jax.tree.leaves(p_shapes))
    if not cfg.n_experts:
        return total
    expert = 0
    for seg in p_shapes["segments"]:
        for key, blk in seg.items():
            if "moe" in key and isinstance(blk, dict) and "moe" in blk:
                for nm in ("gate", "up", "down"):
                    expert += math.prod(blk["moe"][nm].shape)
    return int(total - expert * (1 - cfg.top_k / cfg.n_experts))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=DRYRUN_ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = DRYRUN_ARCHS if args.all else [args.arch]
    shapes = list(SHAPES) if args.all else ([args.shape] if args.shape
                                            else list(SHAPES))
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'multi' if mp else 'single'}.json"
                path = out / name
                if path.exists() and not args.force:
                    print(f"[dryrun] skip existing {name}")
                    continue
                try:
                    # probes (roofline terms) only on the single-pod mesh;
                    # the multi-pod pass proves the "pod" axis shards
                    rec = lower_cell(arch, shape, mp, probes=not mp)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}"}
                    failures.append(name)
                path.write_text(json.dumps(rec, indent=2))
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all requested cells OK")


if __name__ == "__main__":
    main()
