"""Launch layer: production meshes, step functions, dry-run, train/serve CLIs."""
from .mesh import make_local_mesh, make_production_mesh  # noqa: F401
