"""Production meshes: 16x16 single pod (256 chips) and 2x16x16 (512 chips).

Defined as FUNCTIONS so importing this module never touches jax device
state. Axes: "pod" x "data" compose into the batch dimension (the gradient
all-reduce crosses pods exactly once per step); "model" carries TP/EP.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh_compat", "make_production_mesh", "make_local_mesh"]


def make_mesh_compat(shape, axes):
    """jax.make_mesh with Auto axis types where the jax version has them
    (older releases have no AxisType and every axis is implicitly Auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host has, as (data, model) — for tests/examples."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh_compat((n // model, model), ("data", "model"))
