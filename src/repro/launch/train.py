"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo_1b --smoke \\
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Full-config runs use the same path on real hardware; on this CPU container
use --smoke (reduced config). Handles restart-from-checkpoint automatically;
--simulate-preemption N kills the loop at step N and restarts, exercising the
fault-tolerance path end-to-end.
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_config, get_smoke
from ..data import DataConfig, Prefetcher, SyntheticLM
from ..dist.sharding import set_mesh
from ..runtime import Trainer, TrainerConfig
from .mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--simulate-preemption", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(model=args.model_parallel)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         base_lr=args.lr, total_steps=args.steps,
                         warmup=max(args.steps // 20, 1))

    def make_data(state=None):
        src = SyntheticLM(DataConfig(
            vocab=cfg.vocab, batch=args.batch, seq=args.seq,
            frontend=cfg.frontend, frontend_len=cfg.frontend_len,
            d_model=cfg.d_model), state)
        # NOTE: prefetch depth advances the source state ahead of
        # consumption; on restart up to `depth` batches are skipped — a
        # documented at-most-once data guarantee.
        return src, Prefetcher(src)

    def on_step(step, m):
        if step % 10 == 0 or step == 1:
            print(f"step {step:5d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
                  f"{m['step_time_s']*1e3:.0f} ms")

    with set_mesh(mesh):
        trainer = Trainer(cfg, tcfg, mesh, key=jax.random.key(0))
        resumed = trainer.maybe_restore()
        if resumed:
            print(f"[train] resumed from checkpoint step {resumed}")
        start = int(trainer.opt_state.step)
        todo = args.steps - start
        if args.simulate_preemption and start < args.simulate_preemption:
            todo = args.simulate_preemption - start
        src, data = make_data(trainer.pipeline_state)
        trainer.attach_pipeline(src.state)
        trainer.run(data, todo, on_step=on_step)
        trainer.checkpoint(int(trainer.opt_state.step))
        trainer.ckpt.wait()
        if args.simulate_preemption and \
                int(trainer.opt_state.step) < args.steps:
            print("[train] simulated preemption — restarting from checkpoint")
            trainer2 = Trainer(cfg, tcfg, mesh, key=jax.random.key(0))
            trainer2.maybe_restore()
            src2, data2 = make_data(trainer2.pipeline_state)
            trainer2.attach_pipeline(src2.state)
            trainer2.run(data2,
                         args.steps - int(trainer2.opt_state.step),
                         on_step=on_step)
            trainer2.checkpoint(int(trainer2.opt_state.step))
            trainer2.ckpt.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
