from .engine import EngineStats, Request, ServingEngine  # noqa: F401
