from .engine import (EngineStalledError, EngineStats,  # noqa: F401
                     Request, ServingEngine, TERMINAL_STATES)
from .faults import (Fault, FaultPlan, KernelLaunchError,  # noqa: F401
                     drive_with_plan, malformed_request)
from .swap import HostBlockStore  # noqa: F401
