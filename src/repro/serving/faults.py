"""Deterministic fault injection for the serving stack.

The paper pitches a hyperscale accelerator serving multi-DNN traffic; at
that scale the hard problem is staying up under tail events, not peak
throughput. This module is the proving harness for the engine's fault
surface: a seeded `FaultPlan` injects faults at precise (step, slot)
coordinates so every recovery path is exercised deterministically and the
recovered output can be compared byte-for-byte against an un-faulted run.

Fault classes (`Fault.kind`):

  "launch"     a kernel-launch failure. boundary="launch" raises
               `KernelLaunchError` at the engine's step-launch site (the
               stand-in for an XLA/pallas runtime failure on hardware);
               boundary="dispatch" installs the `api.registry` dispatch hook
               so the exception fires at the op-dispatch boundary the next
               time the step TRACES (a lowering-time failure — arm it on an
               un-warmed engine).
  "poison"     NaN/Inf corruption. target="logits" corrupts one slot's step
               logits; target="kv" corrupts one slot's KV cache rows (bf16
               values, or the f32 scales of an int8 QuantKVCache — int codes
               have no NaN, the scales are the poisonable plane);
               target="weight" corrupts the shared weights (a QuantWeight
               scale when the engine serves resident codes, else the final
               norm) — every slot's logits go non-finite, the
               slot-quarantine recovery cannot help, and the engine fails
               requests over to snapshot/restore recovery.
  "latency"    a host-side stall of `delay_s` seconds before the step's
               launches — visible in inter-token latency and TTL deadlines,
               invisible in outputs.
  "malformed"  a hostile submission. `malformed_request` builds the request;
               `drive_with_plan` submits it at the fault's step and records
               the engine's rejection.
  "pool_pressure"
               a capacity fault for paged engines: at `step`, squeeze the
               block pool's effective free list down to `blocks` blocks
               (the rest are held aside, released after `duration` steps —
               None holds them forever). Deterministically forces the
               eviction -> preemption -> host-swap path; a no-op (not
               tripped) on non-paged engines or an already-full pool.

Faults are ONE-SHOT: `FaultPlan.take` marks them fired. Production code
pays zero cost when no plan is armed — the engine guards every consult
behind an `is None` check and the registry hook is a single `is not None`
test per op dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = ["Fault", "FaultPlan", "KernelLaunchError", "KINDS",
           "POISON_TARGETS", "MALFORMED_KINDS", "malformed_request",
           "poison_logits", "poison_caches", "poison_weights",
           "drive_with_plan"]

KINDS = ("launch", "poison", "latency", "malformed", "pool_pressure")
POISON_TARGETS = ("logits", "kv", "weight")
LAUNCH_BOUNDARIES = ("launch", "dispatch")
MALFORMED_KINDS = ("empty-prompt", "float-prompt", "2d-prompt",
                   "negative-max-new", "float-max-new", "absurd-max-new")

NAN = float("nan")
INF = float("inf")


class KernelLaunchError(RuntimeError):
    """Simulated kernel-launch failure — the fault-injection stand-in for a
    pallas lowering/launch error on real hardware."""


@dataclasses.dataclass
class Fault:
    """One injected fault at a precise (step, slot) coordinate.

    step is the engine step index (`ServingEngine.step_no`) at which the
    fault fires; slot targets one cache row (None = global, e.g. weight
    poison). fired/tripped record the harness consuming the fault vs the
    failure actually manifesting (a dispatch-boundary launch fault on an
    already-compiled step never trips — nothing re-traces)."""
    kind: str
    step: int = 0
    slot: Optional[int] = None
    target: str = "logits"            # poison target / malformed defect
    value: float = NAN                # poison value (nan or +/-inf)
    boundary: str = "launch"          # launch faults: launch | dispatch
    op: Optional[str] = None          # dispatch faults: restrict to one op
    delay_s: float = 0.0              # latency faults
    blocks: int = 0                   # pool_pressure: free blocks LEFT
    duration: Optional[int] = None    # pool_pressure: steps until release
    fired: bool = False
    tripped: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {KINDS}")
        if self.kind == "poison" and self.target not in POISON_TARGETS:
            raise ValueError(f"poison target {self.target!r} not in "
                             f"{POISON_TARGETS}")
        if self.kind == "launch" and self.boundary not in LAUNCH_BOUNDARIES:
            raise ValueError(f"launch boundary {self.boundary!r} not in "
                             f"{LAUNCH_BOUNDARIES}")
        if self.kind == "malformed" and self.target not in MALFORMED_KINDS:
            raise ValueError(f"malformed defect {self.target!r} not in "
                             f"{MALFORMED_KINDS}")
        if self.kind == "pool_pressure":
            if self.blocks < 0:
                raise ValueError(
                    f"pool_pressure blocks ({self.blocks}) must be >= 0")
            if self.duration is not None and self.duration < 1:
                raise ValueError(
                    f"pool_pressure duration ({self.duration}) must be "
                    f">= 1 step (or None to hold forever)")

    def describe(self) -> str:
        extra = {
            "launch": f"boundary={self.boundary}" +
                      (f" op={self.op}" if self.op else ""),
            "poison": f"target={self.target} slot={self.slot} "
                      f"value={self.value}",
            "latency": f"delay={self.delay_s}s",
            "malformed": f"defect={self.target}",
            "pool_pressure": f"free->{self.blocks} "
                             f"duration={self.duration}",
        }[self.kind]
        return f"{self.kind}@step{self.step} {extra}"


class FaultPlan:
    """An ordered, seeded registry of one-shot faults.

    Arm it on an engine (`ServingEngine.arm_fault_plan`) and the engine
    consults it at its step/launch boundaries; drive with `drive_with_plan`
    to also submit the plan's malformed requests at their coordinates."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: List[Fault] = list(faults)

    # ------------------------------------------------------------ building
    @classmethod
    def single(cls, kind: str, **kw) -> "FaultPlan":
        return cls([Fault(kind=kind, **kw)])

    @classmethod
    def seeded(cls, seed: int, *, steps: int, slots: int,
               kinds: Sequence[str] = KINDS,
               n_faults: int = 4) -> "FaultPlan":
        """A deterministic plan: `n_faults` faults drawn from `kinds` at
        seeded (step, slot) coordinates inside [1, steps) x [0, slots).
        Same seed -> same plan, run after run — the reproducibility the
        byte-identity recovery gate needs."""
        rng = np.random.RandomState(seed)
        faults = []
        for i in range(n_faults):
            kind = kinds[int(rng.randint(len(kinds)))]
            step = int(rng.randint(1, max(steps, 2)))
            slot = int(rng.randint(slots))
            if kind == "poison":
                # weight poison is global and unrecoverable in place — the
                # seeded sweep sticks to the slot-recoverable targets
                target = ("logits", "kv")[int(rng.randint(2))]
                value = (NAN, INF, -INF)[int(rng.randint(3))]
                faults.append(Fault("poison", step=step, slot=slot,
                                    target=target, value=value))
            elif kind == "launch":
                faults.append(Fault("launch", step=step))
            elif kind == "latency":
                faults.append(Fault("latency", step=step,
                                    delay_s=0.001 * (1 + int(rng.randint(5)))))
            elif kind == "pool_pressure":
                # bounded squeeze: always releases, so a seeded sweep can't
                # deadlock an engine whose preempted rows never fit again
                faults.append(Fault("pool_pressure", step=step,
                                    blocks=int(rng.randint(3)),
                                    duration=2 + int(rng.randint(6))))
            else:
                defect = MALFORMED_KINDS[int(rng.randint(
                    len(MALFORMED_KINDS)))]
                faults.append(Fault("malformed", step=step, target=defect))
        return cls(faults)

    # ------------------------------------------------------------- querying
    def take(self, kind: str, step: int,
             target: Optional[str] = None) -> List[Fault]:
        """Unfired faults of `kind` due at `step` (optionally filtered by
        target), marked fired — the one-shot consume the engine calls."""
        hits = [f for f in self.faults
                if not f.fired and f.kind == kind and f.step == step
                and (target is None or f.target == target)]
        for f in hits:
            f.fired = True
        return hits

    def take_due(self, kind: str, step: int, target: Optional[str] = None,
                 pred=None) -> List[Fault]:
        """Like `take`, but matches faults due AT OR BEFORE `step` and lets
        `pred(fault)` veto the consume. Logits poison uses this: the fault
        fires at the first launch from its step onward whose logits the
        target slot actually CONSUMES (a mid-prompt chunk's logits are never
        read, so corrupting them would be a silent no-op — the deferral
        keeps every injected fault observable)."""
        hits = [f for f in self.faults
                if not f.fired and f.kind == kind and f.step <= step
                and (target is None or f.target == target)
                and (pred is None or pred(f))]
        for f in hits:
            f.fired = True
        return hits

    def pending(self, kind: Optional[str] = None) -> List[Fault]:
        return [f for f in self.faults
                if not f.fired and (kind is None or f.kind == kind)]

    def exhausted(self) -> bool:
        return not self.pending()

    def counts(self) -> dict:
        out: dict = {}
        for f in self.faults:
            out[f.kind] = out.get(f.kind, 0) + 1
        return out

    def describe(self) -> str:
        return "; ".join(f.describe() for f in self.faults) or "(empty plan)"


# ---------------------------------------------------------------------------
# Poison application — corrupt device state at precise coordinates.
# ---------------------------------------------------------------------------

def poison_logits(logits, slot: int, value: float = NAN):
    """Corrupt one slot's logits row with a non-finite value."""
    return logits.at[slot].set(jnp.asarray(value, logits.dtype))


def _cache_types():
    from ..models import ssm
    from ..models.attention import (KVCache, PagedKVCache, PagedQuantKVCache,
                                    QuantKVCache)
    return (KVCache, QuantKVCache, PagedKVCache, PagedQuantKVCache,
            (ssm.MambaCache, ssm.MLSTMCache, ssm.SLSTMCache))


def poison_caches(caches, slot: int, value: float = NAN):
    """Corrupt one slot's cache rows: bf16 K values at position 0 of every
    layer for a dense KVCache (attended as soon as the row holds >= 1
    token), the f32 K scales for an int8 QuantKVCache (int codes have no
    NaN — the scales are the poisonable float plane), or the recurrent
    state rows. Paged caches are poisoned THROUGH the block table: the
    slot's first mapped block takes the hit, so a prefix-shared block
    poisons every row mapping it — the leak the engine's transitive
    quarantine exists to contain. The corruption propagates to the slot's
    logits at its next consuming launch, where the engine's fused
    numeric-health guard trips."""
    import jax

    KVCache, QuantKVCache, PagedKV, PagedQuantKV, recurrent = _cache_types()

    def pool_hit(c, a):
        # (n, P, Hkv, bs, last) pool, (n, B, nblk) table: position 0 of the
        # slot's first block in every layer
        ids = c.table[:, slot, 0]
        n = a.shape[0]
        return a.at[jnp.arange(n), ids, :, 0, :].set(
            jnp.asarray(value, a.dtype))

    def poison(c):
        if isinstance(c, KVCache):
            return c._replace(k=c.k.at[:, slot, :, 0, :].set(
                jnp.asarray(value, c.k.dtype)))
        if isinstance(c, QuantKVCache):
            return c._replace(k_scale=c.k_scale.at[:, slot, :, 0, :].set(
                jnp.asarray(value, c.k_scale.dtype)))
        if isinstance(c, PagedKV):
            return c._replace(k=pool_hit(c, c.k))
        if isinstance(c, PagedQuantKV):
            return c._replace(k_scale=pool_hit(c, c.k_scale))
        if isinstance(c, recurrent):
            return jax.tree.map(
                lambda a: a.at[:, slot].set(jnp.asarray(value, a.dtype))
                if jnp.issubdtype(a.dtype, jnp.floating) and a.ndim >= 2
                else a, c)
        return c

    leaf_types = (KVCache, QuantKVCache, PagedKV, PagedQuantKV) + recurrent
    return jax.tree.map(poison, caches,
                        is_leaf=lambda x: isinstance(x, leaf_types))


def poison_weights(params, value: float = NAN):
    """Corrupt the SHARED weight plane: one scale element of the first
    resident `QuantWeight` (the "weight code block" of a quantized-resident
    engine), or one final-norm element of a dense engine. Either way every
    slot's logits go non-finite on the next launch — the all-slot signature
    that distinguishes weight corruption from per-slot cache poison."""
    import jax

    from ..core import formats as F

    box = {"done": False}

    def walk(node):
        if isinstance(node, F.QuantWeight) and not box["done"]:
            box["done"] = True
            flat_ix = (0,) * node.scale.ndim
            return F.QuantWeight(
                codes=node.codes,
                scale=node.scale.at[flat_ix].set(
                    jnp.asarray(value, node.scale.dtype)),
                fmt=node.fmt, k=node.k)
        return node

    out = jax.tree.map(
        walk, params, is_leaf=lambda x: isinstance(x, F.QuantWeight))
    if box["done"]:
        return out
    # dense engine: the final norm touches every row and position, so one
    # poisoned element reaches every slot's logits deterministically
    out = dict(params)
    fn = {k: v for k, v in out["final_norm"].items()}
    key = next(iter(fn))
    fn[key] = fn[key].at[(0,) * fn[key].ndim].set(
        jnp.asarray(value, fn[key].dtype))
    out["final_norm"] = fn
    return out


# ---------------------------------------------------------------------------
# Malformed requests — the hostile-input plane.
# ---------------------------------------------------------------------------

def malformed_request(defect: str, rid: int = 9000, vocab: int = 32):
    """Build a Request exhibiting one input defect `submit()` must reject
    with a clear ValueError/TypeError instead of a trace-time failure."""
    from .engine import Request
    if defect == "empty-prompt":
        return Request(rid, np.zeros(0, np.int32))
    if defect == "float-prompt":
        return Request(rid, np.asarray([1.5, 2.5, 3.5], np.float32))
    if defect == "2d-prompt":
        return Request(rid, np.ones((2, 3), np.int32))
    if defect == "negative-max-new":
        return Request(rid, np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=-4)
    if defect == "float-max-new":
        return Request(rid, np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=2.5)                 # type: ignore
    if defect == "absurd-max-new":
        return Request(rid, np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=1 << 40)
    raise ValueError(f"malformed defect {defect!r} not in {MALFORMED_KINDS}")


def drive_with_plan(engine, plan: FaultPlan, max_steps: int = 100000):
    """Drain `engine` with `plan` armed, submitting the plan's malformed
    requests at their step coordinates. Returns (finished, rejections):
    rejections lists one (step, defect, error_message) triple per malformed
    submission the engine turned away. The engine consults the plan itself
    for launch/poison/latency faults; this driver only owns the host-side
    submission faults an engine cannot inject into itself."""
    engine.arm_fault_plan(plan)
    rejections = []
    for _ in range(max_steps):
        for f in plan.take("malformed", engine.step_no):
            bad = malformed_request(f.target)
            try:
                engine.submit(bad)
            except (ValueError, TypeError) as e:
                f.tripped = True
                rejections.append((engine.step_no, f.target, str(e)))
        if not engine.pending() and not plan.pending("malformed"):
            break
        engine.step()
    else:
        raise RuntimeError(f"fault drive not drained after {max_steps} steps")
    return engine.finished, rejections
