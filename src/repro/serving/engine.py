"""Serving engine: wave-synchronous batched decode over the morphable substrate.

Requests are admitted in WAVES of up to `slots` requests: a wave's prompts
are right-aligned-padded to a common length, prefilled teacher-forced in one
batch (their KV lands in the wave's caches), then decoded one token per step
for the whole batch until every member finishes. Wave-synchronous batching
keeps a single cache position per wave (KVCache.pos is batch-global), which
matches the morphable-array execution model: a fused block runs one tenant's
batch lock-step; continuous per-slot batching corresponds to per-slot
positions and is listed as future work in DESIGN.md.

Multi-tenant serving stacks one engine per tenant on its mesh partition
(tenancy/scheduler.py — the §VI-C scenario).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from .. import api
from ..models import transformer as T
from ..models.layers import apply_norm
from ..models.transformer import _block_apply, _sinusoid

__all__ = ["Request", "ServingEngine"]

PAD = 0


def _encode_memory(params, frames, cfg):
    """Run the audio encoder stack once (prefill of the cross-attn memory)."""
    mem = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
    for i in range(cfg.encoder_layers):
        p_i = jax.tree.map(lambda a: a[i], params["encoder"])
        mem, _, _ = _block_apply("enc", p_i, mem, cfg)
    return apply_norm(cfg.norm, params["enc_norm"], mem)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                # (L,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: T.ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 frames: Optional[np.ndarray] = None,
                 policy: Optional[api.ExecutionPolicy] = None):
        """frames: (slots, frontend_len, d_model) audio features for enc-dec
        archs — encoded once, cross-attended by every decode step.

        policy: an ExecutionPolicy governing every op the engine traces
        (backend/format/tiling); one engine = one policy, so the jit caches
        stay coherent."""
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.policy = policy
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.memory = None
        if cfg.family == "audio":
            assert frames is not None, "enc-dec serving needs audio frames"
            with self._policy_ctx():
                self.memory = jax.jit(
                    lambda p, f: _encode_memory(p, f, cfg))(params,
                                                            jnp.asarray(frames))
        self._decode_fn = jax.jit(
            lambda p, c, t, m: T.decode_step(p, c, t, cfg, memory=m))

    def _policy_ctx(self):
        return api.policy(self.policy) if self.policy is not None \
            else contextlib.nullcontext()

    def _decode(self, params, caches, token, memory):
        with self._policy_ctx():
            return self._decode_fn(params, caches, token, memory)

    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    # ------------------------------------------------------------- waves
    def _next_wave(self) -> List[Request]:
        wave = []
        while self.queue and len(wave) < self.slots:
            wave.append(self.queue.popleft())
        return wave

    def _prefill(self, wave: List[Request], caches):
        """Teacher-forced batched prefill; prompts left-padded to align their
        last token (so the first generated token follows every prompt)."""
        lmax = max(len(r.prompt) for r in wave)
        toks = np.full((self.slots, lmax), PAD, np.int32)
        for s, r in enumerate(wave):
            toks[s, lmax - len(r.prompt):] = r.prompt
        logits = None
        for t in range(lmax):
            step_tok = jnp.asarray(toks[:, t:t + 1])
            logits, caches = self._decode(self.params, caches, step_tok,
                                          self.memory)
        return logits, caches

    def run_wave(self) -> List[Request]:
        """Admit one wave, prefill, decode to completion. Returns finished."""
        wave = self._next_wave()
        if not wave:
            return []
        caches = T.init_caches(self.cfg, batch=self.slots,
                               max_len=self.max_len)
        logits, caches = self._prefill(wave, caches)
        last = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        active = np.array([True] * len(wave) +
                          [False] * (self.slots - len(wave)))
        remaining = np.array([r.max_new_tokens for r in wave] +
                             [0] * (self.slots - len(wave)))
        for s, r in enumerate(wave):
            r.out_tokens.append(int(last[s, 0]))
            remaining[s] -= 1

        while active.any() and remaining.max() > 0:
            logits, caches = self._decode(self.params, caches, last,
                                          self.memory)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for s, r in enumerate(wave):
                if not active[s]:
                    continue
                tok = int(nxt[s])
                r.out_tokens.append(tok)
                remaining[s] -= 1
                if remaining[s] <= 0 or (self.eos_id is not None
                                         and tok == self.eos_id):
                    active[s] = False
            last = jnp.asarray(nxt)[:, None].astype(jnp.int32)

        for r in wave:
            r.done = True
            self.finished.append(r)
        return wave

    def run_until_drained(self, max_waves: int = 1000) -> List[Request]:
        for _ in range(max_waves):
            if not self.queue:
                break
            self.run_wave()
        return self.finished
