"""Serving engine: continuous per-slot batched decode over the morphable
substrate, with CHUNKED admission prefill.

The engine owns `slots` cache rows and runs one decode step per iteration for
the whole batch. Every slot progresses independently — `KVCache.pos` is a
per-row vector — so a finished slot is refilled from the queue IMMEDIATELY
while the other slots keep decoding, instead of the old wave-synchronous
scheme where a whole wave stalled until its slowest member finished. This is
the serving-side analogue of the paper's morphable MAC array: one substrate,
independently progressing lanes.

Admission is CHUNKED: a new prompt advances in fixed `prefill_chunk`-token
right-padded slices, one chunk launch per engine step, INTERLEAVED with the
decode launches — resident slots keep generating while a long prompt admits,
so admission no longer head-of-line-blocks every in-flight request for the
whole prompt. Rows mid-decode pass `lengths == 0` through a chunk launch and
keep their caches; admitted rows advance only by their true token count, so
pad keys sit beyond every row's causal frontier and are never attended. The
chunk shape is FIXED, so prefill traces ONCE instead of once per pow2 bucket
(the old `_bucket` ladder is gone), and under a pallas backend the chunk
dispatches to the varlen flash-prefill kernel, which prunes q-blocks and
KV-blocks to each row's real tokens (`prefill_route()` reports the path).
Greedy outputs are byte-identical to one-shot admission (tested).

Architectures with recurrent state (mamba / mlstm / slstm blocks) advance
strictly one token at a time; their prefill and decode MERGE into a single
l=1 launch per step — prefilling rows feed their next prompt token while
decoding rows feed their last sampled one.

Greedy outputs are byte-identical to serving each request alone (tested),
except MoE archs whose capacity-factor routing couples batch rows by design.

Multi-tenant serving stacks one engine per tenant on its mesh partition
(tenancy/scheduler.py — the §VI-C scenario); engines report per-slot
occupancy through `occupancy()` for the scheduler's utilization view.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import api
from ..models import transformer as T
from ..models.layers import apply_norm
from ..models.transformer import _block_apply, _sinusoid

__all__ = ["Request", "ServingEngine", "EngineStats"]

PAD = 0

_RECURRENT_KINDS = ("mamba", "mlstm", "slstm")


def _encode_memory(params, frames, cfg):
    """Run the audio encoder stack once (prefill of the cross-attn memory)."""
    mem = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
    for i in range(cfg.encoder_layers):
        p_i = jax.tree.map(lambda a: a[i], params["encoder"])
        mem, _, _ = _block_apply("enc", p_i, mem, cfg)
    return apply_norm(cfg.norm, params["enc_norm"], mem)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                # (L,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    """Model-invocation accounting (the serving_bench comparison currency)."""
    prefill_chunk_calls: int = 0      # chunk-shaped batched prefill launches
    prefill_token_steps: int = 0      # merged l=1 launches (recurrent archs)
    prefill_tokens: int = 0           # valid prompt tokens prefilled
    decode_steps: int = 0             # batch decode launches
    generated_tokens: int = 0

    @property
    def model_calls(self) -> int:
        return self.prefill_chunk_calls + self.prefill_token_steps + \
            self.decode_steps


class ServingEngine:
    """Continuous per-slot batching over `slots` preallocated cache rows."""

    def __init__(self, cfg: T.ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 frames: Optional[np.ndarray] = None,
                 policy: Optional[api.ExecutionPolicy] = None,
                 weight_format: Optional[str] = None,
                 prefill_chunk: int = 32):
        """frames: (slots, frontend_len, d_model) audio features for enc-dec
        archs — encoded once, cross-attended by every decode step.

        policy: an ExecutionPolicy governing every op the engine traces
        (backend/format/tiling); one engine = one policy, so the jit caches
        stay coherent.

        weight_format: make the Linear weights RESIDENT in this AIO format
        (int4/int8/fp8a/fp8b): `quantize_params` converts the pytree once at
        construction and every covered matmul dispatches through
        `api.ops.matmul_codes` — greedy outputs stay byte-identical to the
        fake-quant path (tested). Other format names (incl. "bf16") raise,
        they are not residency formats. The conversion here does NOT donate
        the caller's dense params (they may be shared across engines); the
        serve launcher quantizes with donation before handing the codes
        over.

        prefill_chunk: tokens a new prompt advances per admission launch.
        Small chunks keep resident decode slots generating smoothly (low
        inter-token stall) at the cost of more launches per admitted prompt;
        a chunk >= the longest prompt degenerates to one-shot admission.
        Greedy outputs are identical either way (tested)."""
        if weight_format not in (None, "none"):
            params = T.quantize_params(params, weight_format)
        rfmt = T.resident_format(params)
        if rfmt is not None and (cfg.quant.weights != rfmt
                                 or not cfg.quant.resident):
            # pin the model policy to the residency format so the linears the
            # pass leaves dense fall back to the SAME fake-quant plane
            cfg = dataclasses.replace(
                cfg, quant=dataclasses.replace(cfg.quant, weights=rfmt,
                                               resident=True))
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk ({prefill_chunk}) must be >= 1")
        # a chunk wider than the cache can never fill: clamp so small-cache
        # engines work under the default without the caller minding the knob
        prefill_chunk = min(prefill_chunk, max_len)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.policy = policy
        self.prefill_chunk = prefill_chunk
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.stats = EngineStats()
        self.memory = None
        if cfg.family == "audio":
            assert frames is not None, "enc-dec serving needs audio frames"
            with self._policy_ctx():
                self.memory = jax.jit(
                    lambda p, f: _encode_memory(p, f, cfg))(params,
                                                            jnp.asarray(frames))
        # chunked prefill only works where every cache is positional (KV);
        # recurrent states advance one token per launch (the merged path)
        self._recurrent = any(k in _RECURRENT_KINDS
                              for k in cfg.block_kinds())
        # ONE traced step program serves decode (l=1) and chunk prefill
        # (l=prefill_chunk): both are decode_step with a per-row `lengths`
        # validity vector, so the jit cache holds exactly the two chunk
        # shapes for the engine's whole lifetime. The cache pytree is
        # donated on every call: the engine is the sole owner and always
        # rebinds self.caches to the output, so XLA updates the
        # (B, Hkv, max_len, D)-per-layer buffers in place instead of copying
        # the whole KV residency each step. (On backends without donation
        # support this is a no-op.)
        self._step_fn = jax.jit(
            lambda p, c, t, lens, m: T.decode_step(p, c, t, cfg, memory=m,
                                                   lengths=lens),
            donate_argnums=(1,))
        self._reset_fn = jax.jit(T.reset_slots, donate_argnums=(0,))
        # per-slot runtime state
        self.caches = T.init_caches(cfg, batch=slots, max_len=max_len)
        self._slot_req: List[Optional[Request]] = [None] * slots
        self._last = np.zeros((slots, 1), np.int32)
        self._remaining = np.zeros(slots, np.int64)
        self._prefilling = np.zeros(slots, bool)
        self._prefill_off = np.zeros(slots, np.int64)

    def _policy_ctx(self):
        return api.policy(self.policy) if self.policy is not None \
            else contextlib.nullcontext()

    def _merged_mode(self) -> bool:
        """Recurrent archs (and chunk=1 engines) advance prefill one token
        per launch — prefill and decode share a single l=1 launch."""
        return self._recurrent or self.prefill_chunk == 1

    # ------------------------------------------------------------ admission
    def submit(self, req: Request):
        """Queue a request. Rejects requests that could not fit their prompt
        plus max_new_tokens inside the preallocated cache rows."""
        plen = int(len(req.prompt))
        if plen == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 0:
            raise ValueError(f"request {req.rid}: max_new_tokens < 0")
        if plen + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt_len ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds the engine's max_len "
                f"({self.max_len}); shorten the request or grow the cache")
        req.out_tokens = []
        req.done = False
        self.queue.append(req)

    def _finish(self, slot: int):
        req = self._slot_req[slot]
        req.done = True
        self.finished.append(req)
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        self._prefilling[slot] = False

    def _admit(self, newly_finished: List[Request]):
        """Assign queued requests to free slots and reset their cache rows.
        NO model call happens here — the prompts advance chunk by chunk in
        subsequent step()s, interleaved with everyone else's decode."""
        admitted = []
        for s in range(self.slots):
            while self._slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                if req.max_new_tokens == 0:
                    # emit nothing: respect the limit without spending a
                    # single prefill launch on it
                    req.done = True
                    self.finished.append(req)
                    newly_finished.append(req)
                    continue
                self._slot_req[s] = req
                self._prefilling[s] = True
                self._prefill_off[s] = 0
                self._remaining[s] = req.max_new_tokens
                admitted.append(s)
        if admitted:
            reset = np.zeros(self.slots, bool)
            reset[admitted] = True
            self.caches = self._reset_fn(self.caches, jnp.asarray(reset))

    def _emit_first(self, s: int, tok: int, newly: List[Request]):
        """Record a freshly-completed prefill's first sampled token."""
        req = self._slot_req[s]
        req.out_tokens.append(tok)
        self.stats.generated_tokens += 1
        self._remaining[s] -= 1
        self._last[s, 0] = tok
        if self._remaining[s] <= 0 or (self.eos_id is not None
                                       and tok == self.eos_id):
            self._finish(s)
            newly.append(req)

    def _prefill_chunk_step(self, newly: List[Request]):
        """ONE chunk-shaped prefill launch: every prefilling row advances by
        up to `prefill_chunk` prompt tokens (right-padded, `lengths` marking
        the real count); decoding/free rows ride along with lengths == 0 and
        keep their caches untouched."""
        c = self.prefill_chunk
        toks = np.full((self.slots, c), PAD, np.int32)
        lens = np.zeros(self.slots, np.int32)
        finishing = []
        for s, r in enumerate(self._slot_req):
            if r is None or not self._prefilling[s]:
                continue
            off = int(self._prefill_off[s])
            take = min(c, len(r.prompt) - off)
            toks[s, :take] = r.prompt[off:off + take]
            lens[s] = take
            if off + take >= len(r.prompt):
                finishing.append(s)
        with self._policy_ctx():
            logits, self.caches = self._step_fn(
                self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(lens), self.memory)
        self.stats.prefill_chunk_calls += 1
        self.stats.prefill_tokens += int(lens.sum())
        if finishing:
            # only launches that COMPLETE a prompt consume logits; mid-prompt
            # chunks skip the sync + transfer entirely. Gather + argmax run
            # ON DEVICE: only (slots,) int32 crosses to host, never a logits
            # block
            idx = jnp.asarray(np.clip(lens - 1, 0, c - 1))
            last = jnp.take_along_axis(logits, idx[:, None, None],
                                       axis=1)[:, 0]
            first_tok = np.asarray(jnp.argmax(last, axis=-1))
        for s, r in enumerate(self._slot_req):
            if r is None or not self._prefilling[s]:
                continue
            self._prefill_off[s] += lens[s]
            if s in finishing:
                self._prefilling[s] = False
                self._emit_first(s, int(first_tok[s]), newly)

    def _decode_launch(self, newly: List[Request]):
        """ONE batched decode launch for every mid-generation slot;
        prefilling/free rows pass lengths == 0 and sit the launch out."""
        active = np.asarray(
            [r is not None and not self._prefilling[s]
             for s, r in enumerate(self._slot_req)])
        if not active.any():
            return
        with self._policy_ctx():
            logits, self.caches = self._step_fn(
                self.params, self.caches, jnp.asarray(self._last),
                jnp.asarray(active.astype(np.int32)), self.memory)
        self.stats.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        for s in range(self.slots):
            req = self._slot_req[s]
            if req is None or not active[s]:
                continue
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self.stats.generated_tokens += 1
            self._remaining[s] -= 1
            if self._remaining[s] <= 0 or (self.eos_id is not None
                                           and tok == self.eos_id):
                self._finish(s)
                newly.append(req)
            else:
                self._last[s, 0] = tok

    def _merged_step(self, newly: List[Request]):
        """Recurrent archs / chunk=1: ONE l=1 launch advances everything —
        prefilling rows feed their next prompt token, decoding rows their
        last sampled one. Counted as a decode step when any row decoded,
        else as a prefill token step."""
        toks = np.full((self.slots, 1), PAD, np.int32)
        lens = np.zeros(self.slots, np.int32)
        n_prefill = n_decode = 0
        for s, r in enumerate(self._slot_req):
            if r is None:
                continue
            lens[s] = 1
            if self._prefilling[s]:
                toks[s, 0] = r.prompt[int(self._prefill_off[s])]
                n_prefill += 1
            else:
                toks[s, 0] = self._last[s, 0]
                n_decode += 1
        with self._policy_ctx():
            logits, self.caches = self._step_fn(
                self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(lens), self.memory)
        if n_decode:
            self.stats.decode_steps += 1
        else:
            self.stats.prefill_token_steps += 1
        self.stats.prefill_tokens += n_prefill
        # argmax ON DEVICE: only (slots,) int32 crosses to host — the first
        # token of a finishing prefill row IS its argmax, same as a decode
        # row's, so one vector serves both
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1)).astype(np.int32)
        for s in range(self.slots):
            req = self._slot_req[s]
            if req is None:
                continue
            if self._prefilling[s]:
                self._prefill_off[s] += 1
                if self._prefill_off[s] >= len(req.prompt):
                    self._prefilling[s] = False
                    self._emit_first(s, int(nxt[s]), newly)
                continue
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self.stats.generated_tokens += 1
            self._remaining[s] -= 1
            if self._remaining[s] <= 0 or (self.eos_id is not None
                                           and tok == self.eos_id):
                self._finish(s)
                newly.append(req)
            else:
                self._last[s, 0] = tok

    # --------------------------------------------------------------- driving
    def step(self) -> List[Request]:
        """Admit into free slots, then advance every in-flight request once:
        one chunk-prefill launch for admitting rows (when any) interleaved
        with one batched decode launch for generating rows (when any).
        Returns the requests that finished during this step."""
        newly: List[Request] = []
        self._admit(newly)
        if not any(r is not None for r in self._slot_req):
            return newly
        if self._merged_mode():
            self._merged_step(newly)
            return newly
        if self._prefilling.any():
            self._prefill_chunk_step(newly)
        self._decode_launch(newly)
        return newly

    def pending(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self._slot_req)

    def run_until_drained(self, max_steps: int = 100000) -> List[Request]:
        for _ in range(max_steps):
            if not self.pending():
                break
            self.step()
        else:
            raise RuntimeError(f"not drained after {max_steps} steps")
        return self.finished

    def warmup(self) -> "ServingEngine":
        """Trace + compile the engine's step programs BEFORE the first
        request: one decode-shaped (l=1) and — for chunked archs — one
        chunk-shaped launch with every row idle (`lengths == 0` keeps all
        cache values and positions bitwise intact), so the first real
        request doesn't eat the compile stall. Idempotent; returns self."""
        zeros = jnp.zeros((self.slots,), jnp.int32)
        widths = (1,) if self._merged_mode() else (self.prefill_chunk, 1)
        with self._policy_ctx():
            for w in widths:
                tok = jnp.zeros((self.slots, w), jnp.int32)
                _, self.caches = self._step_fn(self.params, self.caches, tok,
                                               zeros, self.memory)
        return self

    # ---------------------------------------------------------- introspection
    def step_widths(self) -> tuple:
        """Token widths the ONE step program is traced at over the engine's
        lifetime: (1,) for merged-mode engines, else (1, prefill_chunk)."""
        return (1,) if self._merged_mode() else (1, self.prefill_chunk)

    def step_trace(self, width: int):
        """ClosedJaxpr of the engine's step program at token width `width`,
        traced abstractly (no compile, no execution) against the engine's
        live params/caches/memory under its pinned policy — what
        `repro.analysis` audits for host callbacks, donation aliasing and
        quantized-path upcasts."""
        tok = jnp.zeros((self.slots, width), jnp.int32)
        lens = jnp.zeros((self.slots,), jnp.int32)
        with self._policy_ctx():
            return jax.make_jaxpr(
                lambda p, c, t, ln, m: T.decode_step(
                    p, c, t, self.cfg, memory=m, lengths=ln))(
                self.params, self.caches, tok, lens, self.memory)

    def donated_avals(self) -> list:
        """(shape, dtype) of every leaf the step donates (the cache pytree),
        in tree order — the buffers XLA must alias to step outputs."""
        return [(tuple(x.shape), jnp.asarray(x).dtype)
                for x in jax.tree_util.tree_leaves(self.caches)]

    def step_trace_count(self) -> int:
        """Distinct traces the step jit cache currently holds. After warmup
        (or any real traffic) this must equal len(step_widths()) — more
        means a shape leak retracing the hot loop."""
        return self._step_fn._cache_size()

    def weight_route(self) -> str:
        """How the Linear weights reach the matmul plane: "resident-<fmt>"
        (codes pytree through api.ops.matmul_codes), "fake-quant-<fmt>"
        (dense f32 re-quantized per call), or "dense"."""
        rfmt = T.resident_format(self.params)
        if rfmt is not None:
            return f"resident-{rfmt}"
        if self.cfg.quant.weights != "none":
            return f"fake-quant-{self.cfg.quant.weights}"
        return "dense"

    def decode_route(self) -> str:
        """Attention impl the engine's decode steps dispatch to under its
        pinned policy: "pallas-decode" (flash-decode kernel), or "ref"."""
        with self._policy_ctx():
            return api.ops.attention_route(
                lq=1, lk=self.max_len, causal=True, offset_ndim=1,
                quantized=self.cfg.kv_quant, policy=self.policy)

    def prefill_route(self) -> str:
        """Attention impl the engine's admission prefill dispatches to under
        its pinned policy: "pallas-prefill" (varlen flash-prefill kernel;
        any chunk > 1), "pallas-decode" (merged-mode engines — recurrent
        archs and chunk == 1 — whose prefill is l=1 launches), or "ref"."""
        lq = 1 if self._merged_mode() else self.prefill_chunk
        with self._policy_ctx():
            return api.ops.attention_route(
                lq=lq, lk=self.max_len, causal=True, offset_ndim=1,
                quantized=self.cfg.kv_quant, policy=self.policy)

    def occupancy(self) -> List[Optional[dict]]:
        """Per-slot view: None for a free slot, else the resident request's
        {rid, generated, remaining} — the scheduler's utilization signal."""
        return [None if r is None else
                {"rid": r.rid, "generated": len(r.out_tokens),
                 "remaining": int(self._remaining[s])}
                for s, r in enumerate(self._slot_req)]

    def utilization(self) -> float:
        """Fraction of slots currently serving a request."""
        busy = sum(r is not None for r in self._slot_req)
        return busy / self.slots if self.slots else 0.0
