"""Serving engine: continuous per-slot batched decode over the morphable
substrate, with CHUNKED admission prefill and a fault-tolerance layer.

The engine owns `slots` cache rows and runs one decode step per iteration for
the whole batch. Every slot progresses independently — `KVCache.pos` is a
per-row vector — so a finished slot is refilled from the queue IMMEDIATELY
while the other slots keep decoding, instead of the old wave-synchronous
scheme where a whole wave stalled until its slowest member finished. This is
the serving-side analogue of the paper's morphable MAC array: one substrate,
independently progressing lanes.

Admission is CHUNKED: a new prompt advances in fixed `prefill_chunk`-token
right-padded slices, one chunk launch per engine step, INTERLEAVED with the
decode launches — resident slots keep generating while a long prompt admits,
so admission no longer head-of-line-blocks every in-flight request for the
whole prompt. Rows mid-decode pass `lengths == 0` through a chunk launch and
keep their caches; admitted rows advance only by their true token count, so
pad keys sit beyond every row's causal frontier and are never attended. The
chunk shape is FIXED, so prefill traces ONCE instead of once per pow2 bucket
(the old `_bucket` ladder is gone), and under a pallas backend the chunk
dispatches to the varlen flash-prefill kernel, which prunes q-blocks and
KV-blocks to each row's real tokens (`prefill_route()` reports the path).
Greedy outputs are byte-identical to one-shot admission (tested).

With `paged=True` the KV residency is a BLOCK POOL instead of per-slot
stripes: every cache layer holds `pool_blocks` fixed-size KV blocks and a
per-row block table maps each row's logical cache positions onto pool
blocks (the flash kernels indirect through the scalar-prefetched table; the
ref path gathers pages). A host-side refcounted allocator reserves a row's
whole block budget at admission, shares fully-covered prompt-prefix blocks
copy-on-write through a prompt-hash prefix registry (a matching system
prompt prefills ONCE; the one partially-covered boundary block is forked to
a private copy before the row writes into it), evicts cold registry-only
prefixes LRU under pool pressure, and DEFERS admission at the queue head
when the pool cannot hold the reservation — queue backpressure then
surfaces through the same bounded-queue REJECTED path. Greedy outputs are
byte-identical to the per-slot engine (tested: dense, GQA, int8-KV).

Pool pressure degrades GRACEFULLY instead of cliffing into deferral: past
a high watermark (`swap_watermark`, fraction of the pool an admission may
fill), the admission policy PREEMPTS resident rows of strictly lower
priority — victims ordered by (priority, deadline slack, blocks freed) —
and spills each victim's private blocks to a host-side numpy store
(`serving/swap.py`), codes+scales for quantized layouts. Blocks the victim
shares with the prefix registry or other rows are NOT swapped (the shared
bytes stay resident either way); the swap entry keeps their references.
The preempted request moves to a PREEMPTED state that re-admits AHEAD of
fresh admissions: swap-in reserves fresh blocks, scatters the host bytes
back (`write_pool_blocks` — the same fixed-width sentinel-padded scatter
discipline as the CoW fork) and rewinds the row to its saved frontier — no
prefill recompute, greedy output byte-identical to an uncontended run
(tested). Every transfer happens at the already-synchronizing scheduler
boundary; the jitted step stays transfer-free (`repro.analysis` HL206).
Equal priorities never preempt each other — the hysteresis that prevents
two rows from thrashing each other's residency.

Architectures with recurrent state (mamba / mlstm / slstm blocks) advance
strictly one token at a time; their prefill and decode MERGE into a single
l=1 launch per step — prefilling rows feed their next prompt token while
decoding rows feed their last sampled one.

Greedy outputs are byte-identical to serving each request alone (tested),
except MoE archs whose capacity-factor routing couples batch rows by design.

Fault tolerance (the hyperscale-serving posture of §VI):

* The step program carries a fused NUMERIC-HEALTH output — one per-row
  `all(isfinite(logits))` reduction folded into the SAME traced program as
  the decode step, so the guard costs no extra launch and
  `step_trace_count()` stays at the fixed two shapes. Health is fetched only
  at launches whose logits the host was already syncing on (decode, merged,
  finishing prefill); a slot whose logits go non-finite is QUARANTINED: its
  cache row is scrubbed (`scrub_slots` — values AND positions, because a NaN
  riding an additive attention mask is not neutral the way finite stale
  values are) and its request replays from its retained prompt,
  byte-identically, up to `max_replays` times before it fails terminally.
* A kernel-launch failure (a real pallas error, or an injected
  `faults.KernelLaunchError`) DEMOTES the engine: the pinned
  ExecutionPolicy is re-pinned to the reference backend
  (`ExecutionPolicy.demoted()`), the step jits rebuild, and the SAME step
  retries down the safe route — the software analogue of reconfiguring the
  morphable array back to its safe dataflow. `degraded_routes()` reports
  every demotion event.
* Requests carry per-request deadlines: `deadline_steps` (engine steps —
  deterministic) and `ttl_s` (wall clock); expiry finishes them with
  status "TIMEOUT". Admission is BOUNDED: with `max_queue` set, `submit()`
  refuses further requests (returns False, status "REJECTED") instead of
  queueing without limit.
* `snapshot()` / `restore()` persist the whole engine state — cache pytree,
  per-slot bookkeeping, queue, stats — through `repro.checkpoint.store`, so
  a run recovers mid-stream and finishes byte-identically (tested).

All of it is exercised by `repro.serving.faults` — a seeded, deterministic
fault-injection plan armed via `arm_fault_plan()`; production pays zero cost
when no plan is armed (one `is None` check per step).

Multi-tenant serving stacks one engine per tenant on its mesh partition
(tenancy/scheduler.py — the §VI-C scenario); engines report per-slot
occupancy through `occupancy()` for the scheduler's utilization view.
"""
from __future__ import annotations

import bisect
import contextlib
import dataclasses
import hashlib
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import api
from ..models import transformer as T
from ..models.layers import apply_norm
from ..models.transformer import _block_apply, _sinusoid
from . import faults as faultlib
from .swap import HostBlockStore

__all__ = ["Request", "ServingEngine", "EngineStats", "EngineStalledError",
           "TERMINAL_STATES"]

PAD = 0

_RECURRENT_KINDS = ("mamba", "mlstm", "slstm")

# Request.status values once a request leaves the engine for good.
TERMINAL_STATES = ("done", "TIMEOUT", "REJECTED", "FAILED")


def _encode_memory(params, frames, cfg):
    """Run the audio encoder stack once (prefill of the cross-attn memory)."""
    mem = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
    for i in range(cfg.encoder_layers):
        p_i = jax.tree.map(lambda a: a[i], params["encoder"])
        mem, _, _ = _block_apply("enc", p_i, mem, cfg)
    return apply_norm(cfg.norm, params["enc_norm"], mem)


class EngineStalledError(RuntimeError):
    """`run_until_drained` hit its step budget with work still in flight.

    Carries the diagnosis instead of a bare step count: which slots are
    stuck (their occupancy dicts) and how deep the admission queue is."""

    def __init__(self, msg: str, *, stuck=(), queue_depth: int = 0):
        self.stuck = list(stuck)
        self.queue_depth = int(queue_depth)
        super().__init__(
            f"{msg}; {len(self.stuck)} stuck slot(s): {self.stuck!r}; "
            f"queue depth {self.queue_depth}")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                # (L,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False
    # --- lifecycle / fault-tolerance state ---
    status: str = "new"               # queued | active | PREEMPTED ->
    #                                   TERMINAL_STATES
    deadline_steps: Optional[int] = None   # engine steps from submit (determ.)
    ttl_s: Optional[float] = None          # wall seconds from submit
    replays: int = 0                  # quarantine replays consumed so far
    priority: int = 0                 # preemption rank: higher admits first
    #                                   under pressure and may swap out
    #                                   strictly-lower rows; equal never
    #                                   preempts equal
    _submit_step: int = 0
    _submit_t: float = 0.0


@dataclasses.dataclass
class EngineStats:
    """Model-invocation accounting (the serving_bench comparison currency),
    plus the fault-surface counters the bench and launcher surface."""
    prefill_chunk_calls: int = 0      # chunk-shaped batched prefill launches
    prefill_token_steps: int = 0      # merged l=1 launches (recurrent archs)
    prefill_tokens: int = 0           # valid prompt tokens prefilled
    decode_steps: int = 0             # batch decode launches
    generated_tokens: int = 0
    # --- fault counters ---
    quarantines: int = 0              # poisoned slots evicted + scrubbed
    demotions: int = 0                # pallas->ref route demotions
    timeouts: int = 0                 # requests expired (deadline/TTL)
    rejected_submits: int = 0         # submits refused by the bounded queue
    failed_requests: int = 0          # replay budget exhausted -> FAILED
    # --- memory-pressure counters (paged engines) ---
    preemptions: int = 0              # resident rows preempted under pressure
    swap_outs: int = 0                # preemptions that moved blocks to host
    swap_ins: int = 0                 # preempted rows restored byte-identically

    @property
    def model_calls(self) -> int:
        return self.prefill_chunk_calls + self.prefill_token_steps + \
            self.decode_steps


class ServingEngine:
    """Continuous per-slot batching over `slots` preallocated cache rows."""

    def __init__(self, cfg: T.ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 frames: Optional[np.ndarray] = None,
                 policy: Optional[api.ExecutionPolicy] = None,
                 weight_format: Optional[str] = None,
                 prefill_chunk: int = 32,
                 max_queue: Optional[int] = None,
                 max_replays: int = 2,
                 deadline_steps: Optional[int] = None,
                 ttl_s: Optional[float] = None,
                 paged: bool = False,
                 block_size: int = 16,
                 pool_blocks: Optional[int] = None,
                 swap_watermark: float = 1.0):
        """frames: (slots, frontend_len, d_model) audio features for enc-dec
        archs — encoded once, cross-attended by every decode step.

        policy: an ExecutionPolicy governing every op the engine traces
        (backend/format/tiling); one engine = one policy, so the jit caches
        stay coherent. A kernel-launch failure re-pins it to the ref backend
        (`demoted()`) and rebuilds the jits.

        weight_format: make the Linear weights RESIDENT in this AIO format
        (int4/int8/fp8a/fp8b): `quantize_params` converts the pytree once at
        construction and every covered matmul dispatches through
        `api.ops.matmul_codes` — greedy outputs stay byte-identical to the
        fake-quant path (tested). Other format names (incl. "bf16") raise,
        they are not residency formats. The conversion here does NOT donate
        the caller's dense params (they may be shared across engines); the
        serve launcher quantizes with donation before handing the codes
        over.

        prefill_chunk: tokens a new prompt advances per admission launch.
        Small chunks keep resident decode slots generating smoothly (low
        inter-token stall) at the cost of more launches per admitted prompt;
        a chunk >= the longest prompt degenerates to one-shot admission.
        Greedy outputs are identical either way (tested).

        max_queue: bound on the admission queue; beyond it `submit()`
        REJECTS (returns False) instead of queueing — backpressure the
        caller can see. None = unbounded (the historical behavior).

        max_replays: quarantine replays a request may consume before it is
        failed terminally (status "FAILED") instead of re-queued.

        deadline_steps / ttl_s: default per-request deadlines applied at
        submit() to requests that don't carry their own.

        paged / block_size / pool_blocks: block-pool KV residency. Every KV
        cache layer becomes a pool of `pool_blocks` blocks of `block_size`
        tokens (default pool: slots x (max_len / block_size) — the same
        token capacity as the per-slot stripes) plus a (slots, nblk) block
        table the host allocator owns. block_size doubles as the kernels'
        KV tile, so it wants the usual pallas tile alignment; it must
        divide max_len.

        swap_watermark: high-watermark fraction of the pool (0, 1] an
        admission may fill before the engine starts reclaiming: first LRU
        registry eviction, then PREEMPTION of strictly-lower-priority
        resident rows (their private blocks spill to the host block store
        and the request resumes byte-identically on re-admission). 1.0 (the
        default) reclaims only on hard exhaustion; below 1.0 the engine
        keeps `pool*(1-watermark)` blocks of headroom so a priority burst
        admits without deferral. Preemption needs victims of strictly lower
        priority — with uniform priorities the watermark only drives
        registry eviction."""
        if weight_format not in (None, "none"):
            params = T.quantize_params(params, weight_format)
        rfmt = T.resident_format(params)
        if rfmt is not None and (cfg.quant.weights != rfmt
                                 or not cfg.quant.resident):
            # pin the model policy to the residency format so the linears the
            # pass leaves dense fall back to the SAME fake-quant plane
            cfg = dataclasses.replace(
                cfg, quant=dataclasses.replace(cfg.quant, weights=rfmt,
                                               resident=True))
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk ({prefill_chunk}) must be >= 1")
        # a chunk wider than the cache can never fill: clamp so small-cache
        # engines work under the default without the caller minding the knob
        prefill_chunk = min(prefill_chunk, max_len)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.policy = policy
        self.prefill_chunk = prefill_chunk
        self.max_queue = max_queue
        self.max_replays = max_replays
        self.deadline_steps = deadline_steps
        self.ttl_s = ttl_s
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.stats = EngineStats()
        self.memory = None
        if cfg.family == "audio":
            assert frames is not None, "enc-dec serving needs audio frames"
            with self._policy_ctx():
                self.memory = jax.jit(
                    lambda p, f: _encode_memory(p, f, cfg))(params,
                                                            jnp.asarray(frames))
        # chunked prefill only works where every cache is positional (KV);
        # recurrent states advance one token per launch (the merged path)
        self._recurrent = any(k in _RECURRENT_KINDS
                              for k in cfg.block_kinds())
        # --- paged KV pool (block allocator + prefix registry) ---
        self._paged = bool(paged)
        if self._paged:
            if max_len % block_size:
                raise ValueError(
                    f"block_size ({block_size}) must divide max_len "
                    f"({max_len})")
            self._pg_bs = int(block_size)
            self._pg_nblk = max_len // block_size
            self._pg_pool = int(pool_blocks) if pool_blocks is not None \
                else slots * self._pg_nblk
            if self._pg_pool < self._pg_nblk:
                raise ValueError(
                    f"pool_blocks ({self._pg_pool}) cannot hold even one "
                    f"full row ({self._pg_nblk} blocks)")
            self._pg_free: List[int] = list(range(self._pg_pool))
            self._pg_ref = np.zeros(self._pg_pool, np.int64)
            self._pg_rows: List[List[int]] = [[] for _ in range(slots)]
            self._pg_table = np.zeros((slots, self._pg_nblk), np.int32)
            # prefix registry: sha1(prompt) -> {tokens, blocks, reg_tokens,
            # last_used}; entries hold their own block refs so a prefix
            # outlives its donor request until LRU eviction reclaims it
            self._pg_registry: Dict[str, dict] = {}
            self._pg_clock = 0
            self._pg_admits = 0
            self._pg_hits = 0
            self._pg_shared_tokens = 0
            self._pg_cow_copies = 0
            self._pg_evictions = 0
            self._pg_deferred = 0
            self._pg_evict_skips = 0
            if not (0.0 < swap_watermark <= 1.0):
                raise ValueError(
                    f"swap_watermark ({swap_watermark}) must be in (0, 1]")
            self._swap_watermark = float(swap_watermark)
            # free blocks held in reserve past the watermark: an admission
            # that would leave fewer free triggers reclaim (evict/preempt)
            self._pg_headroom = self._pg_pool - int(
                self._swap_watermark * self._pg_pool)
            # blocks a pool_pressure fault is holding off the free list:
            # [release_step | None, [block ids]] per unexpired squeeze
            self._pg_holds: List[list] = []
        # preemption/swap state (live only for paged engines, but always
        # present so pending()/snapshot() can consult it unconditionally).
        # Recurrent archs keep per-row state outside the block pool, so a
        # swapped row could not resume byte-identically — swap stays off.
        self._swap_enabled = self._paged and not self._recurrent
        self._preempted: List[Request] = []
        self._swap_entries: Dict[int, dict] = {}
        self._swap_store = HostBlockStore()
        # slots filled during the CURRENT _admit pass — never preemption
        # victims until their device state has actually materialized
        self._admit_protect: set = set()
        self._build_step_fns()
        # per-slot runtime state
        self.caches = T.init_caches(
            cfg, batch=slots, max_len=max_len,
            paged=(self._pg_pool, self._pg_bs) if self._paged else None)
        self._slot_req: List[Optional[Request]] = [None] * slots
        self._last = np.zeros((slots, 1), np.int32)
        self._remaining = np.zeros(slots, np.int64)
        self._prefilling = np.zeros(slots, bool)
        self._prefill_off = np.zeros(slots, np.int64)
        # fault-tolerance state
        self._step_no = 0
        self._fault_plan: Optional[faultlib.FaultPlan] = None
        self._degraded: List[dict] = []
        self._has_deadlines = deadline_steps is not None or ttl_s is not None

    def _step_program(self, p, c, t, lens, m):
        """The ONE traced step program: decode_step plus the fused numeric-
        health reduction. Health is a (slots,) bool — True where every logit
        of the row is finite — computed INSIDE the same jit so the guard is
        a fused reduction over values already in registers, never an extra
        launch or a host round-trip (`repro.analysis` HL205 pins this)."""
        logits, caches = T.decode_step(p, c, t, self.cfg, memory=m,
                                       lengths=lens)
        health = jnp.all(jnp.isfinite(logits), axis=(1, 2))
        return logits, caches, health

    def _build_step_fns(self):
        """(Re)build the step/reset/scrub jits. ONE traced step program
        serves decode (l=1) and chunk prefill (l=prefill_chunk): both are
        decode_step with a per-row `lengths` validity vector, so the jit
        cache holds exactly the two chunk shapes for the engine's whole
        lifetime. The cache pytree is donated on every call: the engine is
        the sole owner and always rebinds self.caches to the output, so XLA
        updates the (B, Hkv, max_len, D)-per-layer buffers in place instead
        of copying the whole KV residency each step. (On backends without
        donation support this is a no-op.) Called again after a route
        demotion: the policy is read at TRACE time, so a re-pinned policy
        needs fresh jits — a stale compiled step would keep the old route."""
        self._step_fn = jax.jit(self._step_program, donate_argnums=(1,))
        self._reset_fn = jax.jit(T.reset_slots, donate_argnums=(0,))
        self._scrub_fn = jax.jit(T.scrub_slots, donate_argnums=(0,))
        if getattr(self, "_paged", False):
            self._table_fn = jax.jit(T.set_block_tables, donate_argnums=(0,))
            self._cow_fn = jax.jit(T.copy_pool_blocks, donate_argnums=(0,))
            # swap-in scatter: fixed-width (nblk) slabs + sentinel-padded
            # dst, so restores trace once like the CoW copy
            self._swapin_fn = jax.jit(T.write_pool_blocks,
                                      donate_argnums=(0,))

    def _policy_ctx(self):
        return api.policy(self.policy) if self.policy is not None \
            else contextlib.nullcontext()

    def _merged_mode(self) -> bool:
        """Recurrent archs (and chunk=1 engines) advance prefill one token
        per launch — prefill and decode share a single l=1 launch."""
        return self._recurrent or self.prefill_chunk == 1

    # ------------------------------------------------------------ admission
    def submit(self, req: Request) -> bool:
        """Queue a request; True if admitted to the queue.

        Malformed requests raise immediately with a clear diagnostic instead
        of failing later inside a trace: empty or non-1-D prompts and
        non-integer prompt dtypes (ValueError/TypeError), non-int or
        negative max_new_tokens (0 is legal: emit nothing), and requests
        whose prompt + budget can never fit the preallocated cache rows —
        which also covers absurd max_new_tokens values.

        With `max_queue` set, a full queue REJECTS the request: status
        "REJECTED", `submit()` returns False, nothing is queued — the
        backpressure signal callers retry on."""
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1:
            raise ValueError(
                f"request {req.rid}: prompt must be a 1-D token-id vector, "
                f"got shape {tuple(prompt.shape)}")
        if prompt.shape[0] == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if not np.issubdtype(prompt.dtype, np.integer):
            raise TypeError(
                f"request {req.rid}: prompt dtype {prompt.dtype} is not an "
                f"integer token dtype")
        m = req.max_new_tokens
        if isinstance(m, bool) or not isinstance(m, (int, np.integer)):
            raise TypeError(
                f"request {req.rid}: max_new_tokens must be an int, got "
                f"{type(m).__name__} ({m!r})")
        if m < 0:
            raise ValueError(f"request {req.rid}: max_new_tokens < 0")
        p = req.priority
        if isinstance(p, bool) or not isinstance(p, (int, np.integer)):
            raise TypeError(
                f"request {req.rid}: priority must be an int, got "
                f"{type(p).__name__} ({p!r})")
        plen = int(prompt.shape[0])
        if plen + m > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt_len ({plen}) + max_new_tokens "
                f"({m}) exceeds the engine's max_len "
                f"({self.max_len}); shorten the request or grow the cache")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            req.status = "REJECTED"
            req.done = True
            self.stats.rejected_submits += 1
            return False
        req.prompt = prompt
        req.out_tokens = []
        req.done = False
        req.status = "queued"
        if req.deadline_steps is None:
            req.deadline_steps = self.deadline_steps
        if req.ttl_s is None:
            req.ttl_s = self.ttl_s
        req._submit_step = self._step_no
        req._submit_t = time.monotonic()
        if req.deadline_steps is not None or req.ttl_s is not None:
            self._has_deadlines = True
        self.queue.append(req)
        return True

    def _finish(self, slot: int, status: str = "done"):
        req = self._slot_req[slot]
        req.done = True
        req.status = status
        self.finished.append(req)
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        self._prefilling[slot] = False
        if self._paged:
            self._pg_release_row(slot)

    def _admit(self, newly_finished: List[Request]):
        """Assign queued requests to free slots and reset their cache rows.
        NO model call happens here — the prompts advance chunk by chunk in
        subsequent step()s, interleaved with everyone else's decode.

        Paged engines additionally RESERVE each request's whole block budget
        here (prefix-shared blocks counted out), fork the one partial
        boundary block copy-on-write, install the updated block tables and
        rewind the admitted rows to their shared-prefix frontier. A request
        whose reservation cannot be met even after LRU prefix eviction is
        DEFERRED at the queue head — FIFO order is preserved, and sustained
        pressure backs up into the bounded queue's REJECTED path.

        PREEMPTED rows re-admit FIRST, ahead of every fresh admission
        (highest priority first, preemption order within a priority): their
        swap-in reserves fresh blocks for the host-held portion, scatters
        the saved bytes back and rewinds the row to its saved frontier — no
        recompute, byte-identical resume."""
        admitted = []
        new_pos = np.zeros(self.slots, np.int32)
        cow_src: List[int] = []
        cow_dst: List[int] = []
        restores: List[tuple] = []        # (req, entry, dst blocks)
        deferred = False
        self._admit_protect = set()
        for s in range(self.slots):
            if deferred:
                break
            while self._slot_req[s] is None and \
                    (self._preempted or self.queue):
                if self._preempted:
                    i = self._best_preempted()
                    req = self._preempted[i]
                    got = self._pg_swap_in(s, req)
                    if got is None:
                        # still no room: the row keeps its place AHEAD of
                        # fresh admissions, and admission stops entirely
                        self._pg_deferred += 1
                        deferred = True
                        break
                    self._preempted.pop(i)
                    entry, dst = got
                    restores.append((req, entry, dst))
                    req.status = "active"
                    self._slot_req[s] = req
                    self._prefilling[s] = entry["prefilling"]
                    self._prefill_off[s] = entry["prefill_off"]
                    self._remaining[s] = entry["remaining"]
                    self._last[s, 0] = entry["last"]
                    new_pos[s] = entry["pos"]
                    admitted.append(s)
                    self._admit_protect.add(s)
                    continue
                req = self.queue.popleft()
                if req.max_new_tokens == 0:
                    # emit nothing: respect the limit without spending a
                    # single prefill launch on it
                    req.done = True
                    req.status = "done"
                    self.finished.append(req)
                    newly_finished.append(req)
                    continue
                covered = 0
                if self._paged:
                    got = self._pg_admit(s, req)
                    if got is None:
                        # pool can't hold the reservation: put the request
                        # back at the HEAD and stop admitting entirely so
                        # later (smaller) requests can't starve it
                        self.queue.appendleft(req)
                        self._pg_deferred += 1
                        deferred = True
                        break
                    covered, pairs = got
                    new_pos[s] = covered
                    for src, dst in pairs:
                        cow_src.append(src)
                        cow_dst.append(dst)
                req.status = "active"
                self._slot_req[s] = req
                self._prefilling[s] = True
                self._prefill_off[s] = covered
                self._remaining[s] = req.max_new_tokens
                admitted.append(s)
                self._admit_protect.add(s)
        if admitted:
            reset = np.zeros(self.slots, bool)
            reset[admitted] = True
            if self._paged:
                if cow_src:
                    # fixed-width copy vectors (sentinel == pool size pads)
                    # so the jitted copy traces once, not once per fan-out
                    pad = np.full(self.slots, self._pg_pool, np.int32)
                    pad[:len(cow_src)] = cow_src
                    dst = np.full(self.slots, self._pg_pool, np.int32)
                    dst[:len(cow_dst)] = cow_dst
                    self.caches = self._cow_fn(self.caches, jnp.asarray(pad),
                                               jnp.asarray(dst))
                    self._pg_cow_copies += len(cow_src)
                self.caches = self._table_fn(self.caches,
                                             jnp.asarray(self._pg_table))
                self.caches = self._reset_fn(self.caches, jnp.asarray(reset),
                                             jnp.asarray(new_pos))
                # scatter swapped-out bytes back AFTER the table/pos install
                # so the restored frontier bounds exactly the restored bytes
                for req, entry, dst in restores:
                    self._pg_restore_blocks(entry, dst)
                    del self._swap_entries[req.rid]
                    self.stats.swap_ins += 1
            else:
                self.caches = self._reset_fn(self.caches, jnp.asarray(reset))

    # ------------------------------------------------------ paged block pool
    def _pg_key(self, prompt: np.ndarray) -> str:
        return hashlib.sha1(
            np.ascontiguousarray(prompt, np.int32).tobytes()).hexdigest()

    def _pg_release_row(self, slot: int):
        """Drop the slot's references; blocks nobody else holds go back to
        the free list (kept sorted so allocation order is deterministic)."""
        for b in self._pg_rows[slot]:
            self._pg_ref[b] -= 1
            if self._pg_ref[b] == 0:
                bisect.insort(self._pg_free, b)
        self._pg_rows[slot] = []

    def _pg_evict(self, target_free: int, protect=None):
        """LRU-evict registry prefixes until `target_free` blocks are free.
        Only the registry's own references are dropped — blocks still shared
        with an active row stay resident until that row finishes. An entry
        whose blocks are ALL pinned by in-flight sharers is SKIPPED, not
        evicted: dropping it would free nothing now and destroy sharing a
        resident row is actively using, while a colder-but-unpinned prefix
        further down the LRU order can actually yield blocks (skips are
        counted in pool_stats). `protect` shields the entry the current
        admission is about to share from being reclaimed out from under it."""
        order = sorted(self._pg_registry.items(),
                       key=lambda kv: kv[1]["last_used"])
        for key, ent in order:
            if len(self._pg_free) >= target_free:
                break
            if ent is protect:
                continue
            if all(self._pg_ref[b] > 1 for b in ent["blocks"]):
                self._pg_evict_skips += 1
                continue
            for b in ent["blocks"]:
                self._pg_ref[b] -= 1
                if self._pg_ref[b] == 0:
                    bisect.insort(self._pg_free, b)
            del self._pg_registry[key]
            self._pg_evictions += 1

    def _pg_lookup(self, prompt: np.ndarray):
        """Longest usable shared prefix across the registry: (entry, covered)
        with covered capped at prompt_len - 1 so the admitted row always
        prefills at least its last prompt token (its first sampled logits
        must come from its own launch), or (None, 0)."""
        plen = int(prompt.shape[0])
        best, best_cov = None, 0
        for ent in self._pg_registry.values():
            toks = ent["tokens"]
            n = min(len(toks), plen)
            neq = np.flatnonzero(toks[:n] != prompt[:n])
            common = int(neq[0]) if neq.size else n
            cov = min(common, plen - 1, ent["reg_tokens"])
            if cov > best_cov:
                best, best_cov = ent, cov
        return best, best_cov

    def _pg_admit(self, slot: int, req: Request):
        """Reserve the row's whole block budget: shared prefix blocks by
        reference, the partial boundary block by copy-on-write fork, the
        rest fresh. Returns (covered, [(src, dst) copies]) or None when the
        pool cannot hold the reservation even after eviction."""
        bs = self._pg_bs
        prompt = np.asarray(req.prompt)
        plen = int(prompt.shape[0])
        total = -(-(plen + int(req.max_new_tokens)) // bs)
        total = min(total, self._pg_nblk)
        ent, covered = self._pg_lookup(prompt)
        shared_full = covered // bs
        fresh_needed = total - shared_full
        # soft target = the reservation plus the watermark headroom: past
        # the high watermark, reclaim cold registry prefixes first, then
        # preempt strictly-lower-priority residents to host memory. The
        # HARD gate stays fresh_needed — the watermark is best-effort, an
        # admission that fits is never deferred just to keep headroom.
        want_free = fresh_needed + self._pg_headroom
        if len(self._pg_free) < want_free:
            self._pg_evict(want_free, protect=ent)
            if len(self._pg_free) < want_free:
                self._pg_preempt_for(req.priority, want_free)
        if len(self._pg_free) < fresh_needed:
            return None
        blocks: List[int] = []
        pairs: List[tuple] = []
        if ent is not None and covered > 0:
            for b in ent["blocks"][:shared_full]:
                self._pg_ref[b] += 1
                blocks.append(b)
            if covered % bs:
                # the boundary block is only PARTLY covered by the prefix —
                # this row will write positions >= covered into it, so it
                # gets a private fork (the copy-on-write in "prefix sharing
                # is copy-on-write": the one shared block a row would ever
                # write is forked before any write can land)
                src = ent["blocks"][shared_full]
                dst = self._pg_free.pop(0)
                self._pg_ref[dst] = 1
                blocks.append(dst)
                pairs.append((src, dst))
            ent["last_used"] = self._pg_clock
            self._pg_clock += 1
            self._pg_hits += 1
            self._pg_shared_tokens += covered
        while len(blocks) < total:
            b = self._pg_free.pop(0)
            self._pg_ref[b] = 1
            blocks.append(b)
        self._pg_rows[slot] = blocks
        # unreserved tail entries repeat the first block: the kernels never
        # read past the reservation (pos bounds the visited blocks), but
        # scrub derives its block mask from the WHOLE table row, so padding
        # must point at blocks this row owns, never at a neighbour's
        row = np.full(self._pg_nblk, blocks[0], np.int32)
        row[:len(blocks)] = blocks
        self._pg_table[slot] = row
        self._pg_admits += 1
        return covered, pairs

    def _pg_register(self, slot: int):
        """Register a freshly-prefilled prompt in the prefix registry: the
        blocks covering [0, prompt_len) gain a registry reference so the
        prefix survives its donor request. Decode tokens the donor appends
        beyond prompt_len may land in the registered tail block — harmless,
        a future sharer forks that block and re-prefills past `covered`."""
        req = self._slot_req[slot]
        prompt = np.asarray(req.prompt)
        key = self._pg_key(prompt)
        ent = self._pg_registry.get(key)
        if ent is not None:
            ent["last_used"] = self._pg_clock
            self._pg_clock += 1
            return
        nb = -(-int(prompt.shape[0]) // self._pg_bs)
        blocks = list(self._pg_rows[slot][:nb])
        for b in blocks:
            self._pg_ref[b] += 1
        self._pg_registry[key] = {
            "tokens": prompt.astype(np.int32).copy(),
            "blocks": blocks,
            "reg_tokens": int(prompt.shape[0]),
            "last_used": self._pg_clock,
        }
        self._pg_clock += 1

    def _pg_extend_bad(self, bad_slots) -> np.ndarray:
        """Close a quarantine set over block sharing: scrubbing a bad row
        zeroes every block its table references, including prefix blocks
        OTHER rows share — those rows are corrupted too and must replay.
        Registry entries touching a scrubbed block are dropped (their
        values are gone). Returns (closed slot list, scrubbed block set) —
        the caller also invalidates swap entries whose KEPT blocks got
        scrubbed."""
        bad = set(int(s) for s in bad_slots
                  if self._slot_req[int(s)] is not None)
        scrubbed = set()
        for s in bad:
            scrubbed.update(self._pg_rows[s])
        changed = True
        while changed:
            changed = False
            for s in range(self.slots):
                if s in bad or self._slot_req[s] is None:
                    continue
                if scrubbed.intersection(self._pg_rows[s]):
                    bad.add(s)
                    scrubbed.update(self._pg_rows[s])
                    changed = True
        for key in [k for k, ent in self._pg_registry.items()
                    if scrubbed.intersection(ent["blocks"])]:
            ent = self._pg_registry.pop(key)
            for b in ent["blocks"]:
                self._pg_ref[b] -= 1
                if self._pg_ref[b] == 0:
                    bisect.insort(self._pg_free, b)
        return np.asarray(sorted(bad), np.int64), scrubbed

    # --------------------------------------------- swap-out / preemption
    def _pg_row_pos(self, slot: int) -> int:
        """The row's device-side write frontier — read from the first paged
        cache leaf (identical across layers). Ground truth for the resume
        point: works mid-prefill, mid-decode, and for merged-mode steps."""
        for c in jax.tree_util.tree_leaves(
                self.caches, is_leaf=lambda x: isinstance(x, T._PAGED_TYPES)):
            if isinstance(c, T._PAGED_TYPES):
                return int(np.asarray(c.pos)[0, slot])
        raise RuntimeError("paged engine has no paged cache leaf")

    def _pg_swap_template(self):
        """(treedef, leaf avals) of a single-block gather over THIS engine's
        caches — the layout every host-stored block must match. Snapshot
        restore uses it to rebuild (and reject mismatched) swap-store
        contents."""
        t = T.gather_pool_blocks(self.caches, jnp.zeros((1,), jnp.int32))
        return (jax.tree.structure(t),
                [(tuple(a.shape), str(a.dtype)) for a in jax.tree.leaves(t)])

    def _pg_victims(self, prio: int) -> List[int]:
        """Resident rows preemptible by an admission at priority `prio`,
        cheapest-to-evict first. Only STRICTLY lower priorities qualify —
        equal never preempts equal, the hysteresis that keeps two rows from
        thrashing each other in and out of residency. Order: lowest
        priority, then most deadline slack (no deadline sorts as infinite
        slack), then most immediately-freeable blocks."""
        cands = []
        for s in range(self.slots):
            r = self._slot_req[s]
            if r is None or r.priority >= prio:
                continue
            if s in self._admit_protect:
                # admitted IN THIS admission pass: its device state (reset,
                # CoW, restore scatter, prefill) has not materialized yet,
                # so a swap-out would gather stale bytes — and instantly
                # preempting a row just admitted is thrash anyway
                continue
            freeable = sum(1 for b in self._pg_rows[s]
                           if self._pg_ref[b] == 1)
            slack = (float("inf") if r.deadline_steps is None
                     else r.deadline_steps - (self._step_no - r._submit_step))
            cands.append(((r.priority, -slack, -freeable), s))
        return [s for _, s in sorted(cands)]

    def _pg_preempt_for(self, prio: int, want_free: int):
        """Swap out strictly-lower-priority resident rows until `want_free`
        blocks are free or no eligible victims remain."""
        if not self._swap_enabled:
            return
        for s in self._pg_victims(prio):
            if len(self._pg_free) >= want_free:
                break
            self._pg_swap_out(s)

    def _best_preempted(self) -> int:
        """Index of the next PREEMPTED request to re-admit: highest
        priority first, preemption order within a priority."""
        return max(range(len(self._preempted)),
                   key=lambda i: (self._preempted[i].priority, -i))

    def _pg_swap_out(self, slot: int):
        """Preempt the resident row: gather its PRIVATE blocks device->host
        (outside the jitted step — the step trace stays transfer-free,
        HL206) into the host block store and free them; blocks shared with
        the registry or other rows are NOT swapped (their bytes stay
        resident either way — swapping would duplicate them and eviction
        could then tear them from under the sharers), the swap entry just
        keeps holding the row's reference on them. The request parks in
        PREEMPTED state and re-admits ahead of fresh admissions."""
        req = self._slot_req[slot]
        blocks = self._pg_rows[slot]
        kept: List[tuple] = []        # (logical j, physical block)
        priv_j: List[int] = []
        priv_b: List[int] = []
        for j, b in enumerate(blocks):
            if self._pg_ref[b] > 1:
                kept.append((j, int(b)))
            else:
                priv_j.append(j)
                priv_b.append(int(b))
        hids: List[int] = []
        if priv_b:
            ids = jnp.asarray(np.asarray(priv_b, np.int32))
            slabs = jax.device_get(T.gather_pool_blocks(self.caches, ids))
            hids = self._swap_store.put(slabs, len(priv_b))
            self.stats.swap_outs += 1
        self._swap_entries[req.rid] = {
            "kept": kept, "js": priv_j, "hids": hids,
            "total": len(blocks),
            "pos": self._pg_row_pos(slot),
            "prefilling": bool(self._prefilling[slot]),
            "prefill_off": int(self._prefill_off[slot]),
            "remaining": int(self._remaining[slot]),
            "last": int(self._last[slot, 0]),
        }
        for b in priv_b:
            self._pg_ref[b] -= 1
            bisect.insort(self._pg_free, b)
        self._pg_rows[slot] = []
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        self._prefilling[slot] = False
        self._prefill_off[slot] = 0
        req.status = "PREEMPTED"
        self._preempted.append(req)
        self.stats.preemptions += 1

    def _pg_swap_in(self, slot: int, req: Request):
        """Reserve residency for a PREEMPTED row's host-held portion —
        registry eviction, then preemption of rows strictly below
        `req.priority`, may run to make room — and rebuild the row's
        logical block list around the references it kept. Returns
        (entry, dst blocks) for the caller's scatter, or None when the pool
        still can't hold it (the row defers, still ahead of fresh
        admissions)."""
        entry = self._swap_entries[req.rid]
        fresh_needed = len(entry["js"])
        want_free = fresh_needed + self._pg_headroom
        if len(self._pg_free) < want_free:
            self._pg_evict(want_free)
            if len(self._pg_free) < want_free:
                self._pg_preempt_for(req.priority, want_free)
        if len(self._pg_free) < fresh_needed:
            return None
        row_blocks: List[int] = [-1] * entry["total"]
        for j, b in entry["kept"]:
            row_blocks[j] = b
        dst: List[int] = []
        for j in entry["js"]:
            b = self._pg_free.pop(0)
            self._pg_ref[b] = 1
            row_blocks[j] = b
            dst.append(b)
        self._pg_rows[slot] = row_blocks
        row = np.full(self._pg_nblk, row_blocks[0], np.int32)
        row[:len(row_blocks)] = row_blocks
        self._pg_table[slot] = row
        return entry, dst

    def _pg_restore_blocks(self, entry: dict, dst: List[int]):
        """Scatter the host-held block bytes into the freshly reserved
        physical blocks — ONE fixed-width jitted scatter (slabs padded to
        nblk, dst sentinel-padded, same discipline as the CoW copy, so
        restores trace once) — then drop them from the host store."""
        if not dst:
            return
        slabs = self._swap_store.get(entry["hids"])
        pad_n = self._pg_nblk - len(dst)
        if pad_n:
            slabs = jax.tree.map(
                lambda a: np.concatenate(
                    [a, np.zeros(a.shape[:1] + (pad_n,) + a.shape[2:],
                                 a.dtype)], axis=1), slabs)
        dvec = np.full(self._pg_nblk, self._pg_pool, np.int32)
        dvec[:len(dst)] = dst
        self.caches = self._swapin_fn(self.caches, slabs, jnp.asarray(dvec))
        self._swap_store.free(entry["hids"])

    def _drop_swap_entry(self, req: Request):
        """Release everything a PREEMPTED request holds: its kept block
        references and its host-store bytes. Used when the request expires
        or its kept blocks get scrubbed by a quarantine."""
        entry = self._swap_entries.pop(req.rid, None)
        if entry is None:
            return
        for _, b in entry["kept"]:
            self._pg_ref[b] -= 1
            if self._pg_ref[b] == 0:
                bisect.insort(self._pg_free, b)
        self._swap_store.free(entry["hids"])

    def _pg_apply_pressure(self, fault) -> bool:
        """pool_pressure fault: squeeze the effective free list down to
        `fault.blocks` blocks by holding the rest aside (released after
        `fault.duration` steps; None = held forever) — the deterministic
        lever that forces the eviction/preemption/swap path on demand."""
        if not self._paged:
            return False
        keep = max(0, int(fault.blocks))
        n_hold = max(0, len(self._pg_free) - keep)
        if n_hold == 0:
            return False
        # pop from the tail so the held set is deterministic and the
        # low-numbered blocks the allocator prefers stay available
        held = [self._pg_free.pop() for _ in range(n_hold)]
        release = None if fault.duration is None \
            else self._step_no + int(fault.duration)
        self._pg_holds.append([release, held])
        return True

    def _pg_release_pressure(self):
        """Return expired pool_pressure holds to the free list."""
        keep = []
        for release, held in self._pg_holds:
            if release is not None and self._step_no >= release:
                for b in held:
                    bisect.insort(self._pg_free, b)
            else:
                keep.append([release, held])
        self._pg_holds = keep

    def pool_stats(self) -> dict:
        """Block-pool utilization + prefix-sharing counters (the BENCH_kv
        currency). Zeros-shaped dict for non-paged engines so callers can
        report unconditionally."""
        if not self._paged:
            return {"paged": False}
        used = self._pg_pool - len(self._pg_free)
        return {
            "paged": True,
            "pool_blocks": self._pg_pool,
            "block_size": self._pg_bs,
            "used_blocks": used,
            "free_blocks": len(self._pg_free),
            "occupancy": used / self._pg_pool,
            "registry_entries": len(self._pg_registry),
            "admitted": self._pg_admits,
            "prefix_hits": self._pg_hits,
            "prefix_hit_rate": (self._pg_hits / self._pg_admits
                                if self._pg_admits else 0.0),
            "shared_tokens": self._pg_shared_tokens,
            "cow_copies": self._pg_cow_copies,
            "evictions": self._pg_evictions,
            "eviction_skips": self._pg_evict_skips,
            "deferred_admissions": self._pg_deferred,
            # --- memory-pressure / swap surface ---
            "swap_watermark": self._swap_watermark,
            "watermark_blocks": self._pg_pool - self._pg_headroom,
            "preemptions": self.stats.preemptions,
            "swap_outs": self.stats.swap_outs,
            "swap_ins": self.stats.swap_ins,
            "preempted_now": len(self._preempted),
            "host_blocks": len(self._swap_store),
            "host_bytes": self._swap_store.nbytes(),
            "swap_bytes_out": self._swap_store.bytes_out,
            "swap_bytes_in": self._swap_store.bytes_in,
            "pressure_held": sum(len(h) for _, h in self._pg_holds),
        }

    # -------------------------------------------------------- fault surface
    def arm_fault_plan(self, plan: Optional[faultlib.FaultPlan]):
        """Arm (or disarm, with None) a fault-injection plan. The engine
        consults it at step start (latency, kv/weight poison) and at every
        launch (launch faults, logits poison)."""
        self._fault_plan = plan
        return self

    @property
    def step_no(self) -> int:
        """Engine steps taken so far — the fault plan's step coordinate.
        Advances on EVERY step(), including idle ones, so a plan's future
        coordinates are always reachable."""
        return self._step_no

    def degraded_routes(self) -> tuple:
        """Every route-demotion event so far, oldest first: dicts of the
        step, the error, and the decode/prefill routes before and after."""
        return tuple(self._degraded)

    def _inject_pre_step(self, plan: faultlib.FaultPlan, step: int):
        """Host-side faults due before this step's launches: latency stalls
        and device-state poison (KV rows, shared weights)."""
        for f in plan.take("latency", step):
            f.tripped = True
            time.sleep(f.delay_s)
        for f in plan.take("poison", step, target="kv"):
            if f.slot is None:
                continue
            self.caches = faultlib.poison_caches(self.caches, int(f.slot),
                                                 f.value)
            f.tripped = True
        for f in plan.take("poison", step, target="weight"):
            self.params = faultlib.poison_weights(self.params, f.value)
            f.tripped = True
        for f in plan.take("pool_pressure", step):
            f.tripped = self._pg_apply_pressure(f)

    def _launch(self, toks, lens, consumed=None):
        """Every model launch funnels through here: the kernel-launch fault
        boundary, the demote-and-retry recovery, and logits poison.

        Returns (logits, health) DEVICE arrays; rebinds self.caches only on
        a successful launch (a failed trace never consumes the donated
        buffers, so the retry reuses them safely). On failure the engine
        demotes its policy to the ref backend and retries the SAME step
        once; a failure with no safe route left propagates.

        `consumed` is the per-slot "this launch's logits are read for this
        row" mask — logits-poison faults fire only on a consuming launch so
        every injected fault is observable."""
        plan = self._fault_plan
        step = self._step_no
        raise_fault = hook_fault = None
        if plan is not None:
            for f in plan.take("launch", step):
                if f.boundary == "dispatch":
                    hook_fault = f
                else:
                    f.tripped = True
                    raise_fault = f
        for attempt in (0, 1):
            try:
                if raise_fault is not None and attempt == 0:
                    raise faultlib.KernelLaunchError(
                        f"injected kernel-launch failure at step {step} "
                        f"({raise_fault.describe()})")
                ctx = contextlib.nullcontext()
                if hook_fault is not None and attempt == 0:
                    ctx = api.dispatch_intercepted(
                        _dispatch_raiser(hook_fault))
                with ctx, self._policy_ctx():
                    logits, caches, health = self._step_fn(
                        self.params, self.caches, toks, lens, self.memory)
                self.caches = caches
                break
            except Exception as err:
                if attempt == 1 or not self._demote(err):
                    raise
        if plan is not None and consumed is not None:
            poisoned = plan.take_due(
                "poison", step, target="logits",
                pred=lambda f: f.slot is not None and bool(consumed[f.slot]))
            for f in poisoned:
                logits = faultlib.poison_logits(logits, int(f.slot), f.value)
                f.tripped = True
            if poisoned:
                health = jnp.all(jnp.isfinite(logits), axis=(1, 2))
        return logits, health

    def _demote(self, err: Exception) -> bool:
        """Re-pin the engine's policy to the safe (ref) route after a launch
        failure and rebuild the step jits. False when there is no route
        below the current one (already ref) — the caller re-raises."""
        pol = self.policy if self.policy is not None else api.default_policy
        if not pol.use_pallas():
            return False
        event = {
            "step": int(self._step_no),
            "error": f"{type(err).__name__}: {err}",
            "from": {"decode": self.decode_route(),
                     "prefill": self.prefill_route()},
        }
        self.policy = pol.demoted()
        self._build_step_fns()
        event["to"] = {"decode": self.decode_route(),
                       "prefill": self.prefill_route()}
        self._degraded.append(event)
        self.stats.demotions += 1
        return True

    def _quarantine(self, bad_slots, newly: List[Request]):
        """Evict poisoned slots: scrub their cache rows (values AND
        positions — see `scrub_slots`) and replay each request from its
        retained prompt at the FRONT of the queue, byte-identically; a
        request whose replay budget is spent fails terminally instead.

        Paged engines first CLOSE the bad set over block sharing (scrubbing
        a row's blocks corrupts every co-sharing row) and drop registry
        prefixes whose blocks get scrubbed — a quarantined NaN must never
        leak through a shared block into another tenant's row. A PREEMPTED
        request whose KEPT (still-resident shared) blocks get scrubbed
        loses its resume point the same way: its swap entry is dropped and
        it replays from its prompt."""
        scrubbed = set()
        if self._paged:
            bad_slots, scrubbed = self._pg_extend_bad(bad_slots)
        mask = np.zeros(self.slots, bool)
        for s in bad_slots:
            req = self._slot_req[s]
            if req is None:
                continue
            mask[s] = True
            self.stats.quarantines += 1
            self._slot_req[s] = None
            self._remaining[s] = 0
            self._prefilling[s] = False
            self._prefill_off[s] = 0
            self._last[s, 0] = 0
            if self._paged:
                # host bookkeeping only — the DEVICE table still points at
                # the blocks, which is exactly what scrub_slots needs to
                # derive its block mask below
                self._pg_release_row(s)
            req.replays += 1
            if req.replays > self.max_replays:
                req.status = "FAILED"
                req.done = True
                self.stats.failed_requests += 1
                self.finished.append(req)
                newly.append(req)
            else:
                req.out_tokens = []
                req.status = "queued"
                self.queue.appendleft(req)
        if scrubbed:
            for req in [r for r in self._preempted
                        if scrubbed.intersection(
                            b for _, b in self._swap_entries[r.rid]["kept"])]:
                self._preempted.remove(req)
                self._drop_swap_entry(req)
                self.stats.quarantines += 1
                req.replays += 1
                if req.replays > self.max_replays:
                    req.status = "FAILED"
                    req.done = True
                    self.stats.failed_requests += 1
                    self.finished.append(req)
                    newly.append(req)
                else:
                    req.out_tokens = []
                    req.status = "queued"
                    self.queue.appendleft(req)
        if mask.any():
            self.caches = self._scrub_fn(self.caches, jnp.asarray(mask))

    def _expired(self, req: Request, now: float) -> bool:
        if req.deadline_steps is not None and \
                self._step_no - req._submit_step >= req.deadline_steps:
            return True
        if req.ttl_s is not None and now - req._submit_t > req.ttl_s:
            return True
        return False

    def _expire_deadlines(self, newly: List[Request]):
        """Finish expired requests with status TIMEOUT — queued ones (never
        reached a slot in time), resident ones (slot freed, cache row
        reclaimed by the next admit's reset), and PREEMPTED ones (kept
        block references and host-store bytes released)."""
        now = time.monotonic()
        kept_p: List[Request] = []
        for req in self._preempted:
            if self._expired(req, now):
                self._drop_swap_entry(req)
                req.status = "TIMEOUT"
                req.done = True
                self.stats.timeouts += 1
                self.finished.append(req)
                newly.append(req)
            else:
                kept_p.append(req)
        self._preempted = kept_p
        kept: Deque[Request] = deque()
        while self.queue:
            req = self.queue.popleft()
            if self._expired(req, now):
                req.status = "TIMEOUT"
                req.done = True
                self.stats.timeouts += 1
                self.finished.append(req)
                newly.append(req)
            else:
                kept.append(req)
        self.queue = kept
        for s in range(self.slots):
            req = self._slot_req[s]
            if req is not None and self._expired(req, now):
                self.stats.timeouts += 1
                self._finish(s, status="TIMEOUT")
                newly.append(req)

    # -------------------------------------------------------------- stepping
    def _emit_first(self, s: int, tok: int, newly: List[Request]):
        """Record a freshly-completed prefill's first sampled token."""
        req = self._slot_req[s]
        if self._paged:
            # the prompt's K/V is fully resident NOW — register the prefix
            # before the finish check so even a max_new_tokens == 1 request
            # donates its prompt to future admissions
            self._pg_register(s)
        req.out_tokens.append(tok)
        self.stats.generated_tokens += 1
        self._remaining[s] -= 1
        self._last[s, 0] = tok
        if self._remaining[s] <= 0 or (self.eos_id is not None
                                       and tok == self.eos_id):
            self._finish(s)
            newly.append(req)

    def _occupied(self) -> np.ndarray:
        return np.asarray([r is not None for r in self._slot_req])

    def _prefill_chunk_step(self, newly: List[Request]):
        """ONE chunk-shaped prefill launch: every prefilling row advances by
        up to `prefill_chunk` prompt tokens (right-padded, `lengths` marking
        the real count); decoding/free rows ride along with lengths == 0 and
        keep their caches untouched."""
        c = self.prefill_chunk
        toks = np.full((self.slots, c), PAD, np.int32)
        lens = np.zeros(self.slots, np.int32)
        finishing = []
        for s, r in enumerate(self._slot_req):
            if r is None or not self._prefilling[s]:
                continue
            off = int(self._prefill_off[s])
            take = min(c, len(r.prompt) - off)
            toks[s, :take] = r.prompt[off:off + take]
            lens[s] = take
            if off + take >= len(r.prompt):
                finishing.append(s)
        consumed = np.zeros(self.slots, bool)
        consumed[finishing] = True
        logits, health_dev = self._launch(jnp.asarray(toks),
                                          jnp.asarray(lens),
                                          consumed=consumed)
        self.stats.prefill_chunk_calls += 1
        self.stats.prefill_tokens += int(lens.sum())
        bad = np.zeros(self.slots, bool)
        if finishing:
            # only launches that COMPLETE a prompt consume logits; mid-prompt
            # chunks skip the sync + transfer entirely (health rides the same
            # rule: a poisoned row surfaces at its finishing launch, where
            # the NaN has propagated through attention). Gather + argmax run
            # ON DEVICE: only (slots,) int32 crosses to host, never a logits
            # block
            idx = jnp.asarray(np.clip(lens - 1, 0, c - 1))
            last = jnp.take_along_axis(logits, idx[:, None, None],
                                       axis=1)[:, 0]
            first_tok = np.asarray(jnp.argmax(last, axis=-1))
            bad = self._occupied() & ~np.asarray(health_dev)
        for s, r in enumerate(self._slot_req):
            if r is None or not self._prefilling[s]:
                continue
            self._prefill_off[s] += lens[s]
        if bad.any():
            self._quarantine(np.flatnonzero(bad), newly)
        for s in finishing:
            if bad[s] or self._slot_req[s] is None:
                continue
            self._prefilling[s] = False
            self._emit_first(s, int(first_tok[s]), newly)

    def _decode_launch(self, newly: List[Request]):
        """ONE batched decode launch for every mid-generation slot;
        prefilling/free rows pass lengths == 0 and sit the launch out."""
        active = np.asarray(
            [r is not None and not self._prefilling[s]
             for s, r in enumerate(self._slot_req)])
        if not active.any():
            return
        logits, health_dev = self._launch(
            jnp.asarray(self._last), jnp.asarray(active.astype(np.int32)),
            consumed=active)
        self.stats.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        # the guard consumes health at this already-syncing point: any
        # occupied row gone non-finite (its own logits, or a poisoned cache
        # surfacing through a ride-along row) is quarantined, its token
        # never emitted
        bad = self._occupied() & ~np.asarray(health_dev)
        if bad.any():
            self._quarantine(np.flatnonzero(bad), newly)
        for s in range(self.slots):
            req = self._slot_req[s]
            if req is None or not active[s] or bad[s]:
                continue
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self.stats.generated_tokens += 1
            self._remaining[s] -= 1
            if self._remaining[s] <= 0 or (self.eos_id is not None
                                           and tok == self.eos_id):
                self._finish(s)
                newly.append(req)
            else:
                self._last[s, 0] = tok

    def _merged_step(self, newly: List[Request]):
        """Recurrent archs / chunk=1: ONE l=1 launch advances everything —
        prefilling rows feed their next prompt token, decoding rows their
        last sampled one. Counted as a decode step when any row decoded,
        else as a prefill token step."""
        toks = np.full((self.slots, 1), PAD, np.int32)
        lens = np.zeros(self.slots, np.int32)
        consumed = np.zeros(self.slots, bool)
        n_prefill = n_decode = 0
        for s, r in enumerate(self._slot_req):
            if r is None:
                continue
            lens[s] = 1
            if self._prefilling[s]:
                toks[s, 0] = r.prompt[int(self._prefill_off[s])]
                consumed[s] = self._prefill_off[s] + 1 >= len(r.prompt)
                n_prefill += 1
            else:
                toks[s, 0] = self._last[s, 0]
                consumed[s] = True
                n_decode += 1
        logits, health_dev = self._launch(jnp.asarray(toks),
                                          jnp.asarray(lens),
                                          consumed=consumed)
        if n_decode:
            self.stats.decode_steps += 1
        else:
            self.stats.prefill_token_steps += 1
        self.stats.prefill_tokens += n_prefill
        # argmax ON DEVICE: only (slots,) int32 crosses to host — the first
        # token of a finishing prefill row IS its argmax, same as a decode
        # row's, so one vector serves both
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1)).astype(np.int32)
        bad = self._occupied() & ~np.asarray(health_dev)
        if bad.any():
            self._quarantine(np.flatnonzero(bad), newly)
        for s in range(self.slots):
            req = self._slot_req[s]
            if req is None or bad[s]:
                continue
            if self._prefilling[s]:
                self._prefill_off[s] += 1
                if self._prefill_off[s] >= len(req.prompt):
                    self._prefilling[s] = False
                    self._emit_first(s, int(nxt[s]), newly)
                continue
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self.stats.generated_tokens += 1
            self._remaining[s] -= 1
            if self._remaining[s] <= 0 or (self.eos_id is not None
                                           and tok == self.eos_id):
                self._finish(s)
                newly.append(req)
            else:
                self._last[s, 0] = tok

    # --------------------------------------------------------------- driving
    def step(self) -> List[Request]:
        """Admit into free slots, then advance every in-flight request once:
        one chunk-prefill launch for admitting rows (when any) interleaved
        with one batched decode launch for generating rows (when any).
        Returns the requests that finished during this step (including ones
        that TIMED OUT or FAILED). The step counter advances on every call,
        busy or idle."""
        newly: List[Request] = []
        plan = self._fault_plan
        if plan is not None:
            self._inject_pre_step(plan, self._step_no)
        if self._paged and self._pg_holds:
            self._pg_release_pressure()
        if self._has_deadlines:
            self._expire_deadlines(newly)
        self._admit(newly)
        if any(r is not None for r in self._slot_req):
            if self._merged_mode():
                self._merged_step(newly)
            else:
                if self._prefilling.any():
                    self._prefill_chunk_step(newly)
                self._decode_launch(newly)
        self._step_no += 1
        return newly

    def pending(self) -> bool:
        return bool(self.queue) or bool(self._preempted) \
            or any(r is not None for r in self._slot_req)

    def run_until_drained(self, max_steps: int = 100000) -> List[Request]:
        for _ in range(max_steps):
            if not self.pending():
                break
            self.step()
        else:
            raise EngineStalledError(
                f"engine not drained after {max_steps} steps",
                stuck=[o for o in self.occupancy() if o is not None],
                queue_depth=len(self.queue))
        return self.finished

    def warmup(self) -> "ServingEngine":
        """Trace + compile the engine's step programs BEFORE the first
        request: one decode-shaped (l=1) and — for chunked archs — one
        chunk-shaped launch with every row idle (`lengths == 0` keeps all
        cache values and positions bitwise intact), so the first real
        request doesn't eat the compile stall. Idempotent; returns self."""
        zeros = jnp.zeros((self.slots,), jnp.int32)
        widths = (1,) if self._merged_mode() else (self.prefill_chunk, 1)
        with self._policy_ctx():
            for w in widths:
                tok = jnp.zeros((self.slots, w), jnp.int32)
                _, self.caches, _ = self._step_fn(self.params, self.caches,
                                                  tok, zeros, self.memory)
        return self

    # ------------------------------------------------------- snapshot/restore
    def snapshot(self, ckpt_dir, *, step: Optional[int] = None,
                 include_params: bool = False) -> str:
        """Persist the full engine state through `repro.checkpoint.store`:
        the cache pytree as the checkpoint tree (plus the params when
        `include_params` — the recovery lever for weight corruption), and
        every piece of host bookkeeping — per-slot requests, queue, stats,
        last-token vector — as the JSON `extra`. Atomic (tmp dir + rename),
        same as training checkpoints. Returns the checkpoint path."""
        from ..checkpoint import store
        tree = {"caches": self.caches}
        if include_params:
            tree["params"] = self.params

        def reqstate(r: Request) -> dict:
            return {"rid": r.rid, "prompt": np.asarray(r.prompt).tolist(),
                    "max_new_tokens": int(r.max_new_tokens),
                    "out_tokens": list(r.out_tokens or []),
                    "status": r.status, "replays": int(r.replays),
                    "deadline_steps": r.deadline_steps,
                    "ttl_s": r.ttl_s, "priority": int(r.priority),
                    "submit_step": int(r._submit_step)}

        extra = {"engine": {
            "step_no": int(self._step_no),
            "include_params": include_params,
            "last": self._last.tolist(),
            "remaining": self._remaining.tolist(),
            "prefilling": self._prefilling.tolist(),
            "prefill_off": self._prefill_off.tolist(),
            "slots": [reqstate(r) if r is not None else None
                      for r in self._slot_req],
            "queue": [reqstate(r) for r in self.queue],
            "stats": dataclasses.asdict(self.stats),
        }}
        if self._paged:
            extra["engine"]["paged"] = {
                "block_size": self._pg_bs,
                "pool_blocks": self._pg_pool,
                "free": list(self._pg_free),
                "ref": self._pg_ref.tolist(),
                "rows": [list(r) for r in self._pg_rows],
                "table": self._pg_table.tolist(),
                "registry": [
                    {"tokens": ent["tokens"].tolist(),
                     "blocks": list(ent["blocks"]),
                     "reg_tokens": ent["reg_tokens"],
                     "last_used": ent["last_used"]}
                    for ent in self._pg_registry.values()],
                "clock": self._pg_clock,
                "counters": [self._pg_admits, self._pg_hits,
                             self._pg_shared_tokens, self._pg_cow_copies,
                             self._pg_evictions, self._pg_deferred],
                "evict_skips": self._pg_evict_skips,
                "swap_watermark": self._swap_watermark,
                "preempted": [reqstate(r) for r in self._preempted],
                "swap_entries": {
                    str(rid): {"kept": [[j, b] for j, b in e["kept"]],
                               "js": list(e["js"]),
                               "hids": list(e["hids"]),
                               "total": e["total"], "pos": e["pos"],
                               "prefilling": e["prefilling"],
                               "prefill_off": e["prefill_off"],
                               "remaining": e["remaining"],
                               "last": e["last"]}
                    for rid, e in self._swap_entries.items()},
                # the host store PARTICIPATES in the snapshot: preempted
                # rows' spilled bytes round-trip so they can still resume
                # byte-identically after a restore
                "swap_store": self._swap_store.state_dict(),
            }
        return store.save(ckpt_dir,
                          step if step is not None else self._step_no,
                          tree, extra=extra)

    def restore(self, ckpt_dir, step: Optional[int] = None) -> int:
        """Load a `snapshot()` back into THIS engine (same cfg/slots/
        max_len — the cache template must match; shape drift raises).
        In-flight generation resumes byte-identically: caches, positions,
        last tokens and replay/queue bookkeeping all round-trip. Wall-clock
        TTLs restart at restore time (the monotonic clock does not survive a
        process), and `finished` resets — requests completed before the
        snapshot were already delivered to the caller. Returns the restored
        step number."""
        from ..checkpoint import store
        tree, extra, got = store.restore(ckpt_dir, step=step,
                                         tree_like={"caches": self.caches})
        eng = extra["engine"]
        if eng["include_params"]:
            tree, _, _ = store.restore(
                ckpt_dir, step=step,
                tree_like={"caches": self.caches, "params": self.params})
            self.params = jax.tree.map(jnp.asarray, tree["params"])
        self.caches = jax.tree.map(jnp.asarray, tree["caches"])
        if len(eng["last"]) != self.slots:
            raise ValueError(
                f"snapshot has {len(eng['last'])} slots, engine has "
                f"{self.slots}")

        now = time.monotonic()

        def rebuild(st: dict) -> Request:
            r = Request(rid=st["rid"],
                        prompt=np.asarray(st["prompt"], np.int32),
                        max_new_tokens=st["max_new_tokens"],
                        out_tokens=list(st["out_tokens"]),
                        status=st["status"], replays=st["replays"],
                        deadline_steps=st["deadline_steps"],
                        ttl_s=st["ttl_s"],
                        priority=int(st.get("priority", 0)))
            r._submit_step = st["submit_step"]
            r._submit_t = now
            return r

        self._step_no = int(eng["step_no"])
        self._last = np.asarray(eng["last"], np.int32)
        self._remaining = np.asarray(eng["remaining"], np.int64)
        self._prefilling = np.asarray(eng["prefilling"], bool)
        self._prefill_off = np.asarray(eng["prefill_off"], np.int64)
        self._slot_req = [rebuild(st) if st is not None else None
                          for st in eng["slots"]]
        self.queue = deque(rebuild(st) for st in eng["queue"])
        self.finished = []
        self.stats = EngineStats(**eng["stats"])
        self._has_deadlines = self._has_deadlines or any(
            r is not None and (r.deadline_steps is not None
                               or r.ttl_s is not None)
            for r in list(self._slot_req) + list(self.queue))
        pg = eng.get("paged")
        if (pg is not None) != self._paged:
            raise ValueError(
                "snapshot and engine disagree on paged mode: snapshot "
                f"{'has' if pg is not None else 'lacks'} a block pool, "
                f"engine paged={self._paged}")
        if self._paged:
            if (pg["block_size"] != self._pg_bs
                    or pg["pool_blocks"] != self._pg_pool):
                raise ValueError(
                    f"snapshot pool geometry ({pg['pool_blocks']} blocks x "
                    f"{pg['block_size']} tokens) does not match the "
                    f"engine's ({self._pg_pool} x {self._pg_bs})")
            self._pg_free = list(pg["free"])
            self._pg_ref = np.asarray(pg["ref"], np.int64)
            self._pg_rows = [list(r) for r in pg["rows"]]
            self._pg_table = np.asarray(pg["table"], np.int32)
            self._pg_registry = {}
            for ent in pg["registry"]:
                toks = np.asarray(ent["tokens"], np.int32)
                self._pg_registry[self._pg_key(toks)] = {
                    "tokens": toks, "blocks": list(ent["blocks"]),
                    "reg_tokens": int(ent["reg_tokens"]),
                    "last_used": int(ent["last_used"])}
            self._pg_clock = int(pg["clock"])
            (self._pg_admits, self._pg_hits, self._pg_shared_tokens,
             self._pg_cow_copies, self._pg_evictions,
             self._pg_deferred) = [int(x) for x in pg["counters"]]
            self._pg_evict_skips = int(pg.get("evict_skips", 0))
            self._pg_holds = []
            self._preempted = [rebuild(st)
                               for st in pg.get("preempted", [])]
            self._swap_entries = {
                int(rid): {"kept": [(int(j), int(b)) for j, b in e["kept"]],
                           "js": [int(j) for j in e["js"]],
                           "hids": [int(h) for h in e["hids"]],
                           "total": int(e["total"]), "pos": int(e["pos"]),
                           "prefilling": bool(e["prefilling"]),
                           "prefill_off": int(e["prefill_off"]),
                           "remaining": int(e["remaining"]),
                           "last": int(e["last"])}
                for rid, e in pg.get("swap_entries", {}).items()}
            self._swap_store = HostBlockStore()
            st = pg.get("swap_store")
            if st is not None:
                # rebuild against THIS engine's single-block gather layout:
                # a snapshot from a different cache geometry is rejected,
                # not reinterpreted
                treedef, avals = self._pg_swap_template() \
                    if st["blocks"] else (None, None)
                self._swap_store.load_state(st, treedef, avals)
            self._has_deadlines = self._has_deadlines or any(
                r.deadline_steps is not None or r.ttl_s is not None
                for r in self._preempted)
        return got

    # ---------------------------------------------------------- introspection
    def step_widths(self) -> tuple:
        """Token widths the ONE step program is traced at over the engine's
        lifetime: (1,) for merged-mode engines, else (1, prefill_chunk)."""
        return (1,) if self._merged_mode() else (1, self.prefill_chunk)

    def step_trace(self, width: int):
        """ClosedJaxpr of the engine's step program at token width `width`,
        traced abstractly (no compile, no execution) against the engine's
        live params/caches/memory under its pinned policy — what
        `repro.analysis` audits for host callbacks, donation aliasing,
        quantized-path upcasts and the fused numeric-health guard (HL205).
        This traces `_step_program` — the REAL program the engine jits,
        health reduction included — not the bare decode_step."""
        tok = jnp.zeros((self.slots, width), jnp.int32)
        lens = jnp.zeros((self.slots,), jnp.int32)
        with self._policy_ctx():
            return jax.make_jaxpr(self._step_program)(
                self.params, self.caches, tok, lens, self.memory)

    def donated_avals(self) -> list:
        """(shape, dtype) of every leaf the step donates (the cache pytree),
        in tree order — the buffers XLA must alias to step outputs."""
        return [(tuple(x.shape), jnp.asarray(x).dtype)
                for x in jax.tree_util.tree_leaves(self.caches)]

    def step_trace_count(self) -> int:
        """Distinct traces the step jit cache currently holds. After warmup
        (or any real traffic) this must equal len(step_widths()) — more
        means a shape leak retracing the hot loop."""
        return self._step_fn._cache_size()

    def weight_route(self) -> str:
        """How the Linear weights reach the matmul plane: "resident-<fmt>"
        (codes pytree through api.ops.matmul_codes), "fake-quant-<fmt>"
        (dense f32 re-quantized per call), or "dense"."""
        rfmt = T.resident_format(self.params)
        if rfmt is not None:
            return f"resident-{rfmt}"
        if self.cfg.quant.weights != "none":
            return f"fake-quant-{self.cfg.quant.weights}"
        return "dense"

    def decode_route(self) -> str:
        """Attention impl the engine's decode steps dispatch to under its
        pinned policy: "pallas-decode" (flash-decode kernel), or "ref"."""
        with self._policy_ctx():
            return api.ops.attention_route(
                lq=1, lk=self.max_len, causal=True, offset_ndim=1,
                quantized=self.cfg.kv_quant, policy=self.policy)

    def prefill_route(self) -> str:
        """Attention impl the engine's admission prefill dispatches to under
        its pinned policy: "pallas-prefill" (varlen flash-prefill kernel;
        any chunk > 1), "pallas-decode" (merged-mode engines — recurrent
        archs and chunk == 1 — whose prefill is l=1 launches), or "ref"."""
        lq = 1 if self._merged_mode() else self.prefill_chunk
        with self._policy_ctx():
            return api.ops.attention_route(
                lq=lq, lk=self.max_len, causal=True, offset_ndim=1,
                quantized=self.cfg.kv_quant, policy=self.policy)

    def occupancy(self) -> List[Optional[dict]]:
        """Per-slot view: None for a free slot, else the resident request's
        {rid, generated, remaining} — the scheduler's utilization signal."""
        return [None if r is None else
                {"rid": r.rid, "generated": len(r.out_tokens),
                 "remaining": int(self._remaining[s])}
                for s, r in enumerate(self._slot_req)]

    def utilization(self) -> float:
        """Fraction of slots currently serving a request."""
        busy = sum(r is not None for r in self._slot_req)
        return busy / self.slots if self.slots else 0.0


def _dispatch_raiser(fault: faultlib.Fault):
    """The registry hook a dispatch-boundary launch fault installs: raise at
    the first (matching) op dispatch crossed while the step traces."""
    def hook(op_name: str, impl: str):
        if fault.op is not None and op_name != fault.op:
            return
        fault.tripped = True
        raise faultlib.KernelLaunchError(
            f"injected dispatch failure at op {op_name!r} ({impl})")
    return hook
