"""Serving engine: continuous per-slot batched decode over the morphable
substrate.

The engine owns `slots` cache rows and runs one decode step per iteration for
the whole batch. Every slot progresses independently — `KVCache.pos` is a
per-row vector — so a finished slot is refilled from the queue IMMEDIATELY
while the other slots keep decoding, instead of the old wave-synchronous
scheme where a whole wave stalled until its slowest member finished. This is
the serving-side analogue of the paper's morphable MAC array: one substrate,
independently progressing lanes.

Admission prefills the new requests' prompts in ONE batched forward
(right-padded to a power-of-two bucket, with an explicit per-row `lengths`
vector): rows mid-decode pass `lengths == 0` and keep their caches; admitted
rows advance only by their true prompt length, so pad keys sit beyond every
row's causal frontier and are never attended (the pad-mask bug of the old
left-padded prefill cannot recur). Architectures with recurrent state
(mamba / mlstm / slstm blocks) prefill token-by-token with per-step validity
masks — recurrent rows freeze exactly when their prompt is exhausted.

Greedy outputs are byte-identical to serving each request alone (tested),
except MoE archs whose capacity-factor routing couples batch rows by design.

Multi-tenant serving stacks one engine per tenant on its mesh partition
(tenancy/scheduler.py — the §VI-C scenario); engines report per-slot
occupancy through `occupancy()` for the scheduler's utilization view.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import api
from ..models import transformer as T
from ..models.layers import apply_norm
from ..models.transformer import _block_apply, _sinusoid

__all__ = ["Request", "ServingEngine", "EngineStats"]

PAD = 0

_RECURRENT_KINDS = ("mamba", "mlstm", "slstm")


def _encode_memory(params, frames, cfg):
    """Run the audio encoder stack once (prefill of the cross-attn memory)."""
    mem = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
    for i in range(cfg.encoder_layers):
        p_i = jax.tree.map(lambda a: a[i], params["encoder"])
        mem, _, _ = _block_apply("enc", p_i, mem, cfg)
    return apply_norm(cfg.norm, params["enc_norm"], mem)


def _bucket(n: int, lo: int = 8) -> int:
    """Round up to a power of two (>= lo) to bound prefill retraces."""
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                # (L,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    """Model-invocation accounting (the serving_bench comparison currency)."""
    prefill_calls: int = 0            # batched one-shot prefill launches
    prefill_token_steps: int = 0      # token-by-token launches (recurrent)
    prefill_tokens: int = 0           # valid prompt tokens prefilled
    decode_steps: int = 0             # batch decode launches
    generated_tokens: int = 0

    @property
    def model_calls(self) -> int:
        return self.prefill_calls + self.prefill_token_steps + \
            self.decode_steps


class ServingEngine:
    """Continuous per-slot batching over `slots` preallocated cache rows."""

    def __init__(self, cfg: T.ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 frames: Optional[np.ndarray] = None,
                 policy: Optional[api.ExecutionPolicy] = None,
                 weight_format: Optional[str] = None):
        """frames: (slots, frontend_len, d_model) audio features for enc-dec
        archs — encoded once, cross-attended by every decode step.

        policy: an ExecutionPolicy governing every op the engine traces
        (backend/format/tiling); one engine = one policy, so the jit caches
        stay coherent.

        weight_format: make the Linear weights RESIDENT in this AIO format
        (int4/int8/fp8a/fp8b): `quantize_params` converts the pytree once at
        construction and every covered matmul dispatches through
        `api.ops.matmul_codes` — greedy outputs stay byte-identical to the
        fake-quant path (tested). Other format names (incl. "bf16") raise,
        they are not residency formats. The conversion here does NOT donate
        the caller's dense params (they may be shared across engines); the
        serve launcher quantizes with donation before handing the codes
        over."""
        if weight_format not in (None, "none"):
            params = T.quantize_params(params, weight_format)
        rfmt = T.resident_format(params)
        if rfmt is not None and (cfg.quant.weights != rfmt
                                 or not cfg.quant.resident):
            # pin the model policy to the residency format so the linears the
            # pass leaves dense fall back to the SAME fake-quant plane
            cfg = dataclasses.replace(
                cfg, quant=dataclasses.replace(cfg.quant, weights=rfmt,
                                               resident=True))
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.policy = policy
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.stats = EngineStats()
        self.memory = None
        if cfg.family == "audio":
            assert frames is not None, "enc-dec serving needs audio frames"
            with self._policy_ctx():
                self.memory = jax.jit(
                    lambda p, f: _encode_memory(p, f, cfg))(params,
                                                            jnp.asarray(frames))
        # one-shot prefill only works where every cache is positional (KV);
        # recurrent states need the per-token validity masks
        self._recurrent = any(k in _RECURRENT_KINDS
                              for k in cfg.block_kinds())
        # the cache pytree is donated on every traced cache->cache step: the
        # engine is the sole owner and always rebinds self.caches to the
        # output, so XLA updates the (B, Hkv, max_len, D)-per-layer buffers
        # in place instead of copying the whole KV residency each decode
        # step. (On backends without donation support this is a no-op.)
        self._decode_fn = jax.jit(
            lambda p, c, t, m: T.decode_step(p, c, t, cfg, memory=m),
            donate_argnums=(1,))
        self._prefill_fn = jax.jit(
            lambda p, c, t, lens, m: T.decode_step(p, c, t, cfg, memory=m,
                                                   lengths=lens),
            donate_argnums=(1,))
        self._reset_fn = jax.jit(T.reset_slots, donate_argnums=(0,))
        # per-slot runtime state
        self.caches = T.init_caches(cfg, batch=slots, max_len=max_len)
        self._slot_req: List[Optional[Request]] = [None] * slots
        self._last = np.zeros((slots, 1), np.int32)
        self._remaining = np.zeros(slots, np.int64)

    def _policy_ctx(self):
        return api.policy(self.policy) if self.policy is not None \
            else contextlib.nullcontext()

    # ------------------------------------------------------------ admission
    def submit(self, req: Request):
        """Queue a request. Rejects requests that could not fit their prompt
        plus max_new_tokens inside the preallocated cache rows."""
        plen = int(len(req.prompt))
        if plen == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 0:
            raise ValueError(f"request {req.rid}: max_new_tokens < 0")
        if plen + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt_len ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds the engine's max_len "
                f"({self.max_len}); shorten the request or grow the cache")
        req.out_tokens = []
        req.done = False
        self.queue.append(req)

    def _finish(self, slot: int):
        req = self._slot_req[slot]
        req.done = True
        self.finished.append(req)
        self._slot_req[slot] = None
        self._remaining[slot] = 0

    def _admit(self, newly_finished: List[Request]):
        admitted = []
        for s in range(self.slots):
            if self._slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                self._slot_req[s] = req
                admitted.append((s, req))
        if not admitted:
            return
        lens = np.zeros(self.slots, np.int32)
        for s, r in admitted:
            lens[s] = len(r.prompt)
        reset = np.zeros(self.slots, bool)
        reset[[s for s, _ in admitted]] = True
        self.caches = self._reset_fn(self.caches, jnp.asarray(reset))
        last_logits = self._prefill(lens)
        self.stats.prefill_tokens += int(lens.sum())
        for s, r in admitted:
            if r.max_new_tokens == 0:
                self._finish(s)            # emit nothing: respect the limit
                newly_finished.append(r)
                continue
            tok = int(np.argmax(last_logits[s]))
            r.out_tokens.append(tok)
            self.stats.generated_tokens += 1
            self._remaining[s] = r.max_new_tokens - 1
            self._last[s, 0] = tok
            if self._remaining[s] == 0 or (self.eos_id is not None
                                           and tok == self.eos_id):
                self._finish(s)
                newly_finished.append(r)

    def _prefill(self, lens: np.ndarray) -> np.ndarray:
        """Prefill every slot with lens[s] > 0; returns each row's logits at
        its last valid prompt position, (slots, vocab)."""
        lmax = int(lens.max())
        toks = np.full((self.slots, lmax), PAD, np.int32)
        for s, r in enumerate(self._slot_req):
            if r is not None and lens[s]:
                toks[s, :lens[s]] = r.prompt
        if self._recurrent:
            # recurrent states advance strictly one token at a time; rows
            # freeze (lengths=0) once their prompt is exhausted
            out = np.zeros((self.slots, self.cfg.vocab), np.float32)
            for t in range(lmax):
                step_lens = jnp.asarray((t < lens).astype(np.int32))
                with self._policy_ctx():
                    logits, self.caches = self._prefill_fn(
                        self.params, self.caches, jnp.asarray(toks[:, t:t + 1]),
                        step_lens, self.memory)
                self.stats.prefill_token_steps += 1
                for s in np.nonzero(lens == t + 1)[0]:
                    out[s] = np.asarray(logits[s, 0])
            return out
        # one-shot: right-pad to a pow2 bucket (bounds jit retraces); rows
        # with lengths == 0 keep caches/positions, pad keys stay outside every
        # causal frontier
        width = min(self.max_len, _bucket(lmax))
        if width > lmax:
            toks = np.pad(toks, ((0, 0), (0, width - lmax)),
                          constant_values=PAD)
        with self._policy_ctx():
            logits, self.caches = self._prefill_fn(
                self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(lens), self.memory)
        self.stats.prefill_calls += 1
        # gather each row's last valid position ON DEVICE: only (slots, vocab)
        # crosses to host, not the full (slots, width, vocab) block
        idx = jnp.asarray(np.clip(lens - 1, 0, width - 1))
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)
        return np.asarray(last[:, 0])

    # --------------------------------------------------------------- decode
    def step(self) -> List[Request]:
        """Admit into free slots, then run ONE batched decode step. Returns
        the requests that finished during this step."""
        newly: List[Request] = []
        while True:
            self._admit(newly)
            # re-admit only when admission itself freed slots (max_new == 0 /
            # immediate EOS) and work remains queued
            if not (self.queue and any(r is None for r in self._slot_req)):
                break
        if not any(r is not None for r in self._slot_req):
            return newly
        with self._policy_ctx():
            logits, self.caches = self._decode_fn(
                self.params, self.caches, jnp.asarray(self._last), self.memory)
        self.stats.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        for s in range(self.slots):
            req = self._slot_req[s]
            if req is None:
                continue
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self.stats.generated_tokens += 1
            self._remaining[s] -= 1
            if self._remaining[s] <= 0 or (self.eos_id is not None
                                           and tok == self.eos_id):
                self._finish(s)
                newly.append(req)
            else:
                self._last[s, 0] = tok
        return newly

    def pending(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self._slot_req)

    def run_until_drained(self, max_steps: int = 100000) -> List[Request]:
        for _ in range(max_steps):
            if not self.pending():
                break
            self.step()
        else:
            raise RuntimeError(f"not drained after {max_steps} steps")
        return self.finished

    # ---------------------------------------------------------- introspection
    def weight_route(self) -> str:
        """How the Linear weights reach the matmul plane: "resident-<fmt>"
        (codes pytree through api.ops.matmul_codes), "fake-quant-<fmt>"
        (dense f32 re-quantized per call), or "dense"."""
        rfmt = T.resident_format(self.params)
        if rfmt is not None:
            return f"resident-{rfmt}"
        if self.cfg.quant.weights != "none":
            return f"fake-quant-{self.cfg.quant.weights}"
        return "dense"

    def decode_route(self) -> str:
        """Attention impl the engine's decode steps dispatch to under its
        pinned policy: "pallas-decode" (flash-decode kernel), or "ref"."""
        with self._policy_ctx():
            return api.ops.attention_route(
                lq=1, lk=self.max_len, causal=True, offset_ndim=1,
                quantized=self.cfg.kv_quant, policy=self.policy)

    def occupancy(self) -> List[Optional[dict]]:
        """Per-slot view: None for a free slot, else the resident request's
        {rid, generated, remaining} — the scheduler's utilization signal."""
        return [None if r is None else
                {"rid": r.rid, "generated": len(r.out_tokens),
                 "remaining": int(self._remaining[s])}
                for s, r in enumerate(self._slot_req)]

    def utilization(self) -> float:
        """Fraction of slots currently serving a request."""
        busy = sum(r is not None for r in self._slot_req)
        return busy / self.slots if self.slots else 0.0
