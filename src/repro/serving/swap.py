"""Host-side block store: the spill target for preempted rows' live KV.

Under pool pressure past the engine's high watermark, the paged engine
preempts a resident row by gathering its PRIVATE physical blocks off the
device (`transformer.gather_pool_blocks`) and parking the bytes here as
plain numpy buffers keyed by a host block id — codes AND scales for
quantized layouts, so an int8-KV row round-trips bit-exactly. Swap-in
hands the same bytes back (`get`) for the engine's fixed-width
`write_pool_blocks` scatter; nothing is recomputed, so a preempted
request's greedy output is byte-identical to an uncontended run.

Every transfer happens at the engine's already-synchronizing scheduler
boundary — the jitted step program never sees a device<->host move
(`repro.analysis` HL206 pins this).

A stored block is a pytree in `gather_pool_blocks` layout narrowed to one
block: per paged cache leaf, a dict of (n_layers, 1, H, bs, ...) numpy
slabs (None where the cache tree holds non-paged state). The store is
layout-agnostic beyond "axis 1 is the block axis"; the engine owns the
treedef and re-derives it from its own caches when deserializing.
"""
from __future__ import annotations

import base64
from typing import Dict, List

import jax
import numpy as np

__all__ = ["HostBlockStore"]


def _nbytes(tree) -> int:
    return sum(int(a.nbytes) for a in jax.tree.leaves(tree))


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        # extended dtypes (bfloat16, float8_*) register through ml_dtypes,
        # which jax always ships
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _encode(a: np.ndarray) -> dict:
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": base64.b64encode(
                np.ascontiguousarray(a).tobytes()).decode("ascii")}


def _decode(e: dict) -> np.ndarray:
    return np.frombuffer(base64.b64decode(e["data"]),
                         dtype=_np_dtype(e["dtype"])).reshape(e["shape"])


class HostBlockStore:
    """Refcount-free host block store: one entry per swapped-out physical
    block, owned by exactly one PREEMPTED request's swap entry."""

    def __init__(self):
        self._blocks: Dict[int, object] = {}
        self._next = 0
        self.bytes_out = 0      # device -> host (swap-out)
        self.bytes_in = 0       # host -> device (swap-in)

    def __len__(self) -> int:
        return len(self._blocks)

    def nbytes(self) -> int:
        """Bytes currently resident in the store."""
        return sum(_nbytes(b) for b in self._blocks.values())

    # -------------------------------------------------------------- movement
    def put(self, slabs, count: int) -> List[int]:
        """Store `count` blocks from a gathered slab tree (numpy leaves of
        shape (n, count, ...)); returns the host block ids, in slab order."""
        hids = list(range(self._next, self._next + count))
        self._next += count
        for i, h in enumerate(hids):
            blk = jax.tree.map(lambda a: np.ascontiguousarray(a[:, i:i + 1]),
                               slabs)
            self._blocks[h] = blk
            self.bytes_out += _nbytes(blk)
        return hids

    def get(self, hids: List[int]):
        """Reassemble the slab tree for `hids` ((n, len(hids), ...) leaves),
        in order. The blocks stay resident until `free`."""
        blks = [self._blocks[h] for h in hids]
        out = jax.tree.map(lambda *xs: np.concatenate(xs, axis=1), *blks)
        self.bytes_in += _nbytes(out)
        return out

    def free(self, hids: List[int]):
        for h in hids:
            self._blocks.pop(h, None)

    # --------------------------------------------------------- serialization
    def state_dict(self) -> dict:
        """JSON-safe snapshot: per-block leaf list (base64 payloads) in
        deterministic tree order; the treedef is NOT stored — the engine
        re-derives it from its own cache layout on restore, which is also
        the layout guard."""
        return {
            "next": self._next,
            "bytes_out": self.bytes_out,
            "bytes_in": self.bytes_in,
            "blocks": {str(h): [_encode(a) for a in jax.tree.leaves(blk)]
                       for h, blk in self._blocks.items()},
        }

    def load_state(self, state: dict, treedef=None, leaf_avals=None):
        """Inverse of `state_dict`. `treedef`/`leaf_avals` come from the
        restoring engine's own single-block gather template; a stored block
        whose leaves do not match that layout raises — a snapshot from a
        different cache geometry must be rejected, not reinterpreted."""
        self._next = int(state["next"])
        self.bytes_out = int(state["bytes_out"])
        self.bytes_in = int(state["bytes_in"])
        self._blocks = {}
        for h, leaves in state["blocks"].items():
            arrs = [_decode(e) for e in leaves]
            if leaf_avals is not None:
                got = [(tuple(a.shape), str(a.dtype)) for a in arrs]
                if got != list(leaf_avals):
                    raise ValueError(
                        f"snapshot swap-store block {h} layout {got} does "
                        f"not match the engine's cache layout "
                        f"{list(leaf_avals)}")
            self._blocks[int(h)] = jax.tree.unflatten(treedef, arrs)
