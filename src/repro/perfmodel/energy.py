"""Energy model: multiplier energy/op (Table II) + SPM/HBM traffic +
array-power x time (Table III), at 400 MHz.

Two views are reported:
  * bottom-up: MACs x energy/op + bytes x pJ/byte (traffic from a
    weight/input/output tile-reload model),
  * top-down: Table III array power x modeled runtime (the paper's Fig 15
    energy-efficiency view).
"""
from __future__ import annotations

from typing import Dict, List

from .accelerators import (Accelerator, FREQ_HZ, HBM_PJ_PER_BYTE,
                           MULT_ENERGY_PJ, SPM_PJ_PER_BYTE, array_power_w,
                           precision_double)
from .workloads import Op

__all__ = ["op_traffic_bytes", "model_energy_j", "runtime_s",
           "energy_topdown_j"]

_FMT_BYTES = {"bf16": 2, "fp8a": 1, "fp8b": 1, "int8": 1, "int4": 0.5}


def op_traffic_bytes(op: Op, acc: Accelerator, fmt: str) -> Dict[str, float]:
    """SPM traffic for one op under weight-stationary tiling: weights loaded
    once per tile pass, inputs streamed per weight-column tile, outputs
    written once. HBM traffic: one pass of weights + inputs + outputs
    (double-buffered SPM hides reloads when the working set fits 8 MB)."""
    b = _FMT_BYTES[fmt]
    r, c = acc.configs[0]
    d = precision_double(fmt)
    r, c = r * d, c * d
    import math
    if op.kind.startswith("depthwise"):
        w_bytes = op.taps * op.channels * b
        in_bytes = op.s_c * op.channels * b
        out_bytes = op.s_c * op.channels * b
        reloads = 1
    else:
        w_bytes = op.t * op.s_r * b
        in_bytes = op.s_c * op.t * b
        out_bytes = op.s_c * op.s_r * b
        reloads = math.ceil(op.s_r / c)      # inputs re-streamed per col tile
    spm = (w_bytes + in_bytes * reloads + out_bytes) * op.repeat
    working = w_bytes + in_bytes + out_bytes
    hbm = working * op.repeat if working > 8 * 2 ** 20 else \
        (w_bytes + in_bytes + out_bytes) * op.repeat
    return {"spm": spm, "hbm": hbm}


def model_energy_j(ops: List[Op], acc: Accelerator, fmt: str) -> float:
    """Bottom-up: multiplier ops + memory traffic."""
    pj = 0.0
    for op in ops:
        pj += op.macs * MULT_ENERGY_PJ[fmt]
        tr = op_traffic_bytes(op, acc, fmt)
        pj += tr["spm"] * SPM_PJ_PER_BYTE + tr["hbm"] * HBM_PJ_PER_BYTE
    return pj * 1e-12


def runtime_s(cycles: float) -> float:
    return cycles / FREQ_HZ


def energy_topdown_j(cycles: float, acc: Accelerator, fmt: str) -> float:
    """Table III array power x modeled runtime (the paper's ratio basis)."""
    return array_power_w(acc, fmt) * runtime_s(cycles)
