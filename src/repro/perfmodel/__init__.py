"""Cycle-level performance model of the All-rounder vs its baselines."""
from .accelerators import ACCELERATORS, Accelerator  # noqa: F401
from .latency import model_latency, op_latency  # noqa: F401
from .simulate import (gpu_comparison, multi_tenant_scenario,  # noqa: F401
                       speedup_table, utilization_table)
from .workloads import MODELS, inference_ops, training_ops  # noqa: F401
