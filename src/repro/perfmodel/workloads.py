"""Workload extraction: the paper's seven AI benchmarks as layer-op lists.

Each op is the GEMM view the paper's (SCALE-sim-derived) simulator uses:
input {S_C, T} x weight {T, S_R}, plus the op class (Table I). Convs are
im2col'ed (footnote 5); depthwise convs and conv weight-gradients are
UNACCUMULABLE (no C_in reduction); GEMM weight-gradients reduce over B*L so
they stay accumulable — which is exactly why Fig 14 shows ~100% LLM
utilization but a WG-step cliff for CNNs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

__all__ = ["Op", "training_ops", "inference_ops", "MODELS", "llm_ops"]


@dataclasses.dataclass(frozen=True)
class Op:
    name: str
    kind: str          # 'conv' | 'depthwise' | 'fc' | 'gemm' | '*_wg'
    s_c: int           # streamed input rows (B * H_out * W_out or B * L)
    t: int             # contraction (C_in*K^2, d_model, ...)
    s_r: int           # output columns (C_out, d_ff, ...)
    taps: int = 0      # K^2 for convs (unaccumulable mapping parameter)
    channels: int = 0  # channel count for depthwise
    repeat: int = 1    # identical-shape instances (e.g. per-head GEMMs)

    @property
    def macs(self) -> int:
        if self.kind.startswith("depthwise"):
            per = self.s_c * self.taps * self.channels
        else:
            per = self.s_c * self.t * self.s_r
        return per * self.repeat


def conv(name, b, h_out, w_out, c_in, c_out, k, stride=1) -> Op:
    return Op(name, "conv", b * h_out * w_out, c_in * k * k, c_out, taps=k * k)


def dwconv(name, b, h_out, w_out, c, k) -> Op:
    return Op(name, "depthwise", b * h_out * w_out, k * k, c, taps=k * k,
              channels=c)


def fc(name, b, d_in, d_out) -> Op:
    return Op(name, "fc", b, d_in, d_out)


def gemm(name, m, k, n) -> Op:
    return Op(name, "gemm", m, k, n)


# =============================================================================
# CNNs (ImageNet 224x224, batch B)
# =============================================================================

def vgg16(b: int) -> List[Op]:
    cfg = [(224, 3, 64), (224, 64, 64), (112, 64, 128), (112, 128, 128),
           (56, 128, 256), (56, 256, 256), (56, 256, 256),
           (28, 256, 512), (28, 512, 512), (28, 512, 512),
           (14, 512, 512), (14, 512, 512), (14, 512, 512)]
    ops = [conv(f"conv{i}", b, hw, hw, ci, co, 3)
           for i, (hw, ci, co) in enumerate(cfg)]
    ops += [fc("fc1", b, 25088, 4096), fc("fc2", b, 4096, 4096),
            fc("fc3", b, 4096, 1000)]
    return ops


def resnet18(b: int) -> List[Op]:
    ops = [conv("stem", b, 112, 112, 3, 64, 7, 2)]
    stages = [(56, 64, 64, 2), (28, 64, 128, 2), (14, 128, 256, 2),
              (7, 256, 512, 2)]
    for si, (hw, c_in, c_out, blocks) in enumerate(stages):
        for bi in range(blocks):
            ci = c_in if bi == 0 else c_out
            ops.append(conv(f"s{si}b{bi}c1", b, hw, hw, ci, c_out, 3))
            ops.append(conv(f"s{si}b{bi}c2", b, hw, hw, c_out, c_out, 3))
            if bi == 0 and ci != c_out:
                ops.append(conv(f"s{si}b{bi}sc", b, hw, hw, ci, c_out, 1))
    ops.append(fc("fc", b, 512, 1000))
    return ops


def mobilenet_v2(b: int) -> List[Op]:
    """Inverted residual blocks (expansion 1x1 -> 3x3 dw -> projection 1x1)."""
    ops = [conv("stem", b, 112, 112, 3, 32, 3, 2)]
    # (t, c_out, n, stride, hw_in)
    blocks = [(1, 16, 1, 1, 112), (6, 24, 2, 2, 112), (6, 32, 3, 2, 56),
              (6, 64, 4, 2, 28), (6, 96, 3, 1, 14), (6, 160, 3, 2, 14),
              (6, 320, 1, 1, 7)]
    c_in = 32
    for bi, (t, c_out, n, stride, hw_in) in enumerate(blocks):
        for i in range(n):
            s = stride if i == 0 else 1
            hw_o = hw_in // s
            d = c_in * t
            if t != 1:
                ops.append(conv(f"b{bi}_{i}exp", b, hw_in, hw_in, c_in, d, 1))
            ops.append(dwconv(f"b{bi}_{i}dw", b, hw_o, hw_o, d, 3))
            ops.append(conv(f"b{bi}_{i}proj", b, hw_o, hw_o, d, c_out, 1))
            c_in = c_out
            hw_in = hw_o
    ops.append(conv("head", b, 7, 7, 320, 1280, 1))
    ops.append(fc("fc", b, 1280, 1000))
    return ops


def efficientnet_b0(b: int) -> List[Op]:
    """MBConv blocks (expansion, k x k depthwise, SE skipped, projection)."""
    ops = [conv("stem", b, 112, 112, 3, 32, 3, 2)]
    # (expand, c_out, n, stride, k, hw_in)
    blocks = [(1, 16, 1, 1, 3, 112), (6, 24, 2, 2, 3, 112),
              (6, 40, 2, 2, 5, 56), (6, 80, 3, 2, 3, 28),
              (6, 112, 3, 1, 5, 14), (6, 192, 4, 2, 5, 14),
              (6, 320, 1, 1, 3, 7)]
    c_in = 32
    for bi, (t, c_out, n, stride, k, hw_in) in enumerate(blocks):
        for i in range(n):
            s = stride if i == 0 else 1
            hw_o = hw_in // s
            d = c_in * t
            if t != 1:
                ops.append(conv(f"b{bi}_{i}exp", b, hw_in, hw_in, c_in, d, 1))
            ops.append(dwconv(f"b{bi}_{i}dw", b, hw_o, hw_o, d, k))
            ops.append(conv(f"b{bi}_{i}proj", b, hw_o, hw_o, d, c_out, 1))
            c_in = c_out
            hw_in = hw_o
    ops.append(conv("head", b, 7, 7, 320, 1280, 1))
    ops.append(fc("fc", b, 1280, 1000))
    return ops


def convnext_s(b: int) -> List[Op]:
    """ConvNeXt-S: stages [3,3,27,3], dims [96,192,384,768], 7x7 depthwise +
    pointwise MLP (4x)."""
    ops = [conv("stem", b, 56, 56, 3, 96, 4, 4)]
    dims = [96, 192, 384, 768]
    depths = [3, 3, 27, 3]
    hw = 56
    for si, (dim, depth) in enumerate(zip(dims, depths)):
        if si > 0:
            ops.append(conv(f"s{si}down", b, hw // 2, hw // 2, dims[si - 1],
                            dim, 2, 2))
            hw //= 2
        for i in range(depth):
            ops.append(dwconv(f"s{si}b{i}dw", b, hw, hw, dim, 7))
            ops.append(conv(f"s{si}b{i}pw1", b, hw, hw, dim, 4 * dim, 1))
            ops.append(conv(f"s{si}b{i}pw2", b, hw, hw, 4 * dim, dim, 1))
    ops.append(fc("fc", b, 768, 1000))
    return ops


# =============================================================================
# LLMs — the paper's setting: L=512, d_model=4096, d_head=128, B*L=4096
# =============================================================================

def llm_ops(b: int, l: int, d_model: int, d_ff: int, n_layers: int,
            d_head: int = 128, name: str = "llm") -> List[Op]:
    bl = b * l
    n_heads = d_model // d_head
    ops: List[Op] = []
    for i in range(n_layers):
        ops.append(gemm(f"l{i}.qkv", bl, d_model, 3 * d_model))
        # per-head attention GEMMs (paper: per-head K/Q/V are R^{4096 x 128})
        ops.append(Op(f"l{i}.scores", "gemm", bl, d_head, l, repeat=n_heads))
        ops.append(Op(f"l{i}.attnv", "gemm", bl, l, d_head, repeat=n_heads))
        ops.append(gemm(f"l{i}.proj", bl, d_model, d_model))
        ops.append(gemm(f"l{i}.ff1", bl, d_model, d_ff))
        ops.append(gemm(f"l{i}.ff2", bl, d_ff, d_model))
    return ops


def gpt2_small(b: int) -> List[Op]:
    return llm_ops(b, 512, 768, 3072, 12, d_head=64, name="gpt2")


def llama2_7b(b: int) -> List[Op]:
    return llm_ops(b, 512, 4096, 11008, 32, d_head=128, name="llama2")


# transformer for the image-captioning tenant (§VI-C) — a small NLP decoder
def captioning_transformer(b: int) -> List[Op]:
    return llm_ops(b, 196, 512, 2048, 6, d_head=64, name="captioner")


MODELS: Dict[str, Callable[[int], List[Op]]] = {
    "vgg16": vgg16,
    "resnet18": resnet18,
    "mobilenetv2": mobilenet_v2,
    "efficientnet_b0": efficientnet_b0,
    "convnext_s": convnext_s,
    "gpt2": gpt2_small,
    "llama2_7b": llama2_7b,
    "captioner": captioning_transformer,
}


# =============================================================================
# Training-step expansion (FW / BW / WG) per Table I
# =============================================================================

def training_ops(model: str, b: int) -> Dict[str, List[Op]]:
    """FW: as listed. BW (dL/dx): accumulable, contraction flips to S_R.
    WG (dL/dW): conv -> UNACCUMULABLE (taps = K^2); fc/gemm -> accumulable
    with T = batch rows."""
    fw = MODELS[model](b)
    bw: List[Op] = []
    wg: List[Op] = []
    for op in fw:
        if op.kind == "conv":
            bw.append(Op(op.name + ".dx", "conv", op.s_c, op.s_r * op.taps,
                         op.t // op.taps, taps=op.taps))
            # dW: outputs (T x S_R), reduction over S_C — unaccumulable class
            wg.append(Op(op.name + ".dw", "conv_wg", op.s_c, op.t, op.s_r,
                         taps=op.taps, channels=(op.t // op.taps) * op.s_r))
        elif op.kind == "depthwise":
            bw.append(Op(op.name + ".dx", "depthwise", op.s_c, op.taps,
                         op.channels, taps=op.taps, channels=op.channels))
            wg.append(Op(op.name + ".dw", "depthwise_wg", op.s_c, op.taps,
                         op.channels, taps=op.taps, channels=op.channels))
        else:  # fc / gemm: dX = dY W^T ; dW = X^T dY (both accumulable)
            bw.append(Op(op.name + ".dx", op.kind, op.s_c, op.s_r, op.t))
            wg.append(Op(op.name + ".dw", op.kind, op.t, op.s_c, op.s_r))
    return {"FW": fw, "BW": bw, "WG": wg}


def inference_ops(model: str, b: int) -> List[Op]:
    return MODELS[model](b)
