"""Cycle/utilization model: ops (workloads.py) x accelerators (accelerators.py).

Mapping rules (faithful to §II-B/§IV-B; DESIGN.md §2):

ACCUMULABLE (conv FW/BW, fc, gemm, gemm-WG): weight tile (T x S_R) maps onto
the (R x C) array, inputs stream: per-tile latency = S_C + R + C - 2 (fill +
stream + drain), tiles = ceil(T/R) * ceil(S_R/C).

UNACCUMULABLE:
  * 'bus' arrays (rigid SA, SARA, mirroring — Fig 2-b): one output channel
    per column (psums of different channels must not merge), taps down the
    rows -> only `taps` of R rows active; tiles walk the channel dimension.
    Morphable bus arrays (SARA) fission into row-bands of 64 and run
    `bands = R/64` channel tiles concurrently.
  * 'allrounder' (Fig 9): subarray groups of 9 rows hold the taps, the LRMU
    packs floor(64/taps) groups -> ~99% of the block does useful work;
    cycles = MACs / effective-MACs + fill.

Two latency modes:
  * mode='ws'  (default): the self-consistent weight-stationary model above —
    used for cross-accelerator ratios (Fig 14/15 reproductions).
  * mode='eq1': the paper's Eq. (1) *verbatim* —
    (2*S_R + S_C - 2) * ceil(S_R/R) * ceil(S_C/C), with R constrained to the
    tap count for unaccumulable ops on bus arrays (footnote 5's "output bus
    bandwidth constraint"). This reproduces the paper's absolute magnitudes
    (e.g. the 1.05 s TPU-like-SA multi-tenant runtime in §VI-C).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from ..core.mapping import unaccumulable_util_allrounder
from .accelerators import Accelerator, precision_double
from .workloads import Op

__all__ = ["OpResult", "op_latency", "model_latency", "eq1_paper"]


@dataclasses.dataclass
class OpResult:
    name: str
    cycles: float
    utilization: float        # useful MACs / (active cycles * array MACs)
    macs: int


def eq1_paper(s_c: int, s_r: int, r: int, c: int) -> float:
    """Paper Eq. (1), verbatim."""
    return (2 * s_r + s_c - 2) * math.ceil(s_r / r) * math.ceil(s_c / c)


# ---------------------------------------------------------------- ws mode
def _acc_cycles(s_c, t, s_r, r, c) -> Tuple[float, float]:
    tiles = math.ceil(t / r) * math.ceil(s_r / c)
    cycles = tiles * (s_c + r + c - 2)
    util = (t * s_r * s_c) / (tiles * r * c * (s_c + r + c - 2))
    return cycles, util


def _bus_unacc_cycles(op: Op, r, c, bands: int = 1) -> Tuple[float, float]:
    """Rigid mapping for unaccumulable ops: `taps` rows active, one channel
    per column; `bands` row-bands process channel tiles concurrently."""
    taps = max(op.taps, 1)
    if op.kind == "conv_wg":
        channels = op.channels            # (C_in*K^2/K^2) * C_out pairs
        stream = op.s_c
    else:                                 # depthwise family
        channels = op.channels
        stream = op.s_c
    tiles = math.ceil(channels / (c * bands))
    cycles = tiles * (stream + taps + c - 2)
    util = op.macs / (tiles * r * c * (stream + taps + c - 2))
    return cycles, min(util, 1.0)


def _allrounder_unacc_cycles(op: Op, r, c) -> Tuple[float, float]:
    taps = max(op.taps, 1)
    u = unaccumulable_util_allrounder(taps)
    eff = u * r * c
    cycles = math.ceil(op.macs / eff) + r + c - 2
    util = op.macs / (cycles * r * c)
    return cycles, util


# ---------------------------------------------------------------- eq1 mode
def _eq1_cycles(op: Op, acc: Accelerator, r, c) -> Tuple[float, float]:
    if op.kind in ("conv", "fc", "gemm"):
        cycles = eq1_paper(op.s_c, op.s_r, r, c)
        util = op.macs / (cycles * r * c)
        return cycles, min(util, 1.0)
    taps = max(op.taps, 1)
    if acc.unacc_mapping == "allrounder":
        return _allrounder_unacc_cycles(op, r, c)
    # bus arrays: R constrained to the tap count (footnote 5)
    cycles = eq1_paper(op.s_c, op.channels, taps, c)
    util = op.macs / (cycles * r * c)
    return cycles, min(util, 1.0)


def op_latency(op: Op, acc: Accelerator, fmt: str,
               allowed_configs=None, mode: str = "ws") -> OpResult:
    """Best config (morphable arrays minimize over their fusion plans)."""
    d = precision_double(fmt)
    best = None
    for (r0, c0) in (allowed_configs or acc.configs):
        r, c = r0 * d, c0 * d
        if mode == "eq1":
            cycles, util = _eq1_cycles(op, acc, r, c)
        elif op.kind in ("conv", "fc", "gemm"):
            cycles, util = _acc_cycles(op.s_c, op.t, op.s_r, r, c)
        elif op.kind in ("depthwise", "depthwise_wg", "conv_wg"):
            if acc.unacc_mapping == "allrounder":
                cycles, util = _allrounder_unacc_cycles(op, r, c)
            else:
                bands = max(r // 64, 1) if acc.morphable else 1
                cycles, util = _bus_unacc_cycles(op, r, c, bands)
        else:
            raise ValueError(op.kind)
        cycles *= op.repeat
        if best is None or cycles < best[0]:
            best = (cycles, util)
    return OpResult(op.name, best[0], best[1], op.macs)


def model_latency(ops: List[Op], acc: Accelerator, fmt: str,
                  allowed_configs=None, mode: str = "ws") -> Dict:
    """Aggregate a layer list: cycles sum; utilization is the MAC-weighted
    fraction of array capacity over active cycles (the Fig 14 metric)."""
    results = [op_latency(op, acc, fmt, allowed_configs, mode) for op in ops]
    cycles = sum(r.cycles for r in results)
    macs = sum(r.macs for r in results)
    d = precision_double(fmt)
    cap = acc.configs[0][0] * acc.configs[0][1] * d * d
    util = macs / (cycles * cap)
    return {"cycles": cycles, "macs": macs, "utilization": util,
            "per_op": results}
