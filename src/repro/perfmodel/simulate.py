"""Top-level simulation runs reproducing the paper's evaluation sections.

  * utilization_table()   -> Fig 14 (a/b): MAC utilization per model x step
                             x accelerator, bf16 / hybrid-FP8 / INT8 / INT4.
  * speedup_table()       -> Fig 15 (a-f): speedup, area-eff, energy-eff
                             vs the TPU-like SA.
  * multi_tenant_scenario() -> §VI-C: captioning (MobileNetV2+Transformer)
                             + ResNet-18 classification, INT8.
  * gpu_comparison()      -> Table IV: All-rounder bf16 vs RTX 3090 constants.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .accelerators import ACCELERATORS, Accelerator, array_power_w
from .energy import energy_topdown_j, runtime_s
from .latency import model_latency
from .workloads import inference_ops, training_ops

__all__ = ["utilization_table", "speedup_table", "multi_tenant_scenario",
           "gpu_comparison", "TRAIN_MODELS", "CNN_B", "LLM_B"]

TRAIN_MODELS = ["vgg16", "resnet18", "mobilenetv2", "efficientnet_b0",
                "convnext_s", "gpt2", "llama2_7b"]
CNN_B = 128          # paper: batch 128 for CNNs
LLM_B = 8            # paper: batch 8 for LLMs


def _batch(model: str) -> int:
    return LLM_B if model in ("gpt2", "llama2_7b", "captioner") else CNN_B


def _morph_configs(acc: Accelerator, fmt: str):
    """Paper methodology: morphables use R,C in {64,128} (x2 in FP8/INT4);
    non-morphables fixed 128 (x2)."""
    return acc.configs


def utilization_table(fmt: str = "bf16",
                      models: Optional[List[str]] = None) -> Dict:
    """{model: {step: {accelerator: utilization}}} — Fig 14."""
    out: Dict = {}
    for model in models or TRAIN_MODELS:
        b = _batch(model)
        steps = training_ops(model, b)
        out[model] = {}
        for step_name, ops in steps.items():
            row = {}
            for name, acc in ACCELERATORS.items():
                res = model_latency(ops, acc, fmt, _morph_configs(acc, fmt))
                row[name] = res["utilization"]
            out[model][step_name] = row
    return out


def training_cycles(model: str, acc: Accelerator, fmt: str) -> float:
    steps = training_ops(model, _batch(model))
    return sum(model_latency(ops, acc, fmt)["cycles"]
               for ops in steps.values())


def speedup_table(fmt: str = "bf16",
                  models: Optional[List[str]] = None) -> Dict:
    """Fig 15: per model x accelerator — speedup over TPU-SA, area
    efficiency (throughput/mm^2) and energy efficiency (1/J) ratios."""
    out: Dict = {}
    for model in models or TRAIN_MODELS:
        base_cycles = training_cycles(model, ACCELERATORS["tpu_sa"], fmt)
        base_acc = ACCELERATORS["tpu_sa"]
        base_area_eff = 1.0 / (base_cycles * base_acc.area_mm2)
        base_energy = energy_topdown_j(base_cycles, base_acc, fmt)
        row: Dict = {}
        for name, acc in ACCELERATORS.items():
            cycles = training_cycles(model, acc, fmt)
            row[name] = {
                "speedup": base_cycles / cycles,
                "area_eff": (1.0 / (cycles * acc.area_mm2)) / base_area_eff,
                "energy_eff": base_energy / energy_topdown_j(cycles, acc, fmt),
            }
        out[model] = row
    return out


def multi_tenant_scenario(fmt: str = "int8", mode: str = "eq1"
                          ) -> Dict[str, float]:
    """§VI-C: MobileNetV2 + captioning Transformer (one app) and ResNet-18
    (another) run concurrently, batch-1 online inference.

    Partitions: morphables (All-rounder, SARA) fission into two 64x128
    blocks (the configuration the paper reports as fastest); Dataflow
    Mirroring splits COLUMN-wise into two 128x64 halves via its
    opposite-corner bidirectional streaming (rows stay 128, so the
    taps-rows penalty on depthwise is 2x SARA's — the paper's 93.65 vs
    33.33 ms gap); the rigid SA serializes the tenants.
    """
    tenants = {
        "captioning": (inference_ops("mobilenetv2", 1) +
                       inference_ops("captioner", 1)),
        "classification": inference_ops("resnet18", 1),
    }
    out = {}
    for name, acc in ACCELERATORS.items():
        if acc.morphable:
            part_cfg = [(64, 128)]
        elif acc.max_tenants >= 2:                     # mirroring
            part_cfg = [(128, 64)]
        else:
            part_cfg = None
        if part_cfg is not None:
            parts = {t: model_latency(ops, acc, fmt, part_cfg, mode)["cycles"]
                     for t, ops in tenants.items()}
            cycles = max(parts.values())               # run in parallel
        else:                                          # rigid SA: serialize
            cycles = sum(model_latency(ops, acc, fmt, None, mode)["cycles"]
                         for ops in tenants.values())
        out[name] = runtime_s(cycles) * 1e3
    return out


# Table IV constants (NVIDIA RTX 3090, paper's measurements)
GPU_TABLE4 = {
    "alexnet": {"runtime_ms": 46.0, "power_w": 207.7, "gflops_w": 41.1},
    "vgg16": {"runtime_ms": 296.4, "power_w": 326.7, "gflops_w": 61.0},
    "resnet18": {"runtime_ms": 71.4, "power_w": 321.4, "gflops_w": 36.3},
    "mobilenetv2": {"runtime_ms": 65.9, "power_w": 322.7, "gflops_w": 9.8},
    "densenet": {"runtime_ms": 214.0, "power_w": 336.2, "gflops_w": 15.5},
}


def gpu_comparison(models: Optional[List[str]] = None) -> Dict:
    """Table IV: All-rounder bf16 training runtime + GFLOPS/W vs the GPU
    constants (for the benchmarks we model in both)."""
    acc = ACCELERATORS["allrounder"]
    out = {}
    for model in models or ["vgg16", "resnet18", "mobilenetv2"]:
        cycles = training_cycles(model, acc, "bf16")
        t = runtime_s(cycles)
        steps = training_ops(model, _batch(model))
        flops = 2.0 * sum(sum(o.macs for o in ops) for ops in steps.values())
        power = array_power_w(acc, "bf16")
        out[model] = {
            "allrounder_ms": t * 1e3,
            "allrounder_gflops_w": flops / t / power / 1e9,
            "gpu": GPU_TABLE4.get(model),
        }
    return out
