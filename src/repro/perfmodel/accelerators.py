"""The four accelerator designs the paper evaluates (§VI-B baselines).

Constants are the paper's own synthesized numbers (Table II/III) — gate-level
area/power cannot be measured in JAX (DESIGN.md §2); everything DERIVED
(latency, utilization, efficiency ratios) is computed by our model.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["Accelerator", "ACCELERATORS", "ALLROUNDER", "TPU_SA", "SARA",
           "MIRRORING", "MULT_ENERGY_PJ", "array_power_w", "FREQ_HZ"]

FREQ_HZ = 400e6                    # all designs close timing at 400 MHz


@dataclasses.dataclass(frozen=True)
class Accelerator:
    name: str
    # allowed (R, C) array configs in bf16/int8 mode; fp8/int4 double both
    configs: Tuple[Tuple[int, int], ...]
    morphable: bool
    # unaccumulable-op mapping: 'allrounder' (Fig 9 subarray/LRMU grouping)
    # or 'bus' (one channel per column, taps down the rows — Fig 2-b)
    unacc_mapping: str
    max_tenants: int
    area_mm2: float                # Table III
    power_w: dict                  # Table III, keyed by format


ALLROUNDER = Accelerator(
    name="allrounder",
    configs=((128, 128), (64, 128), (128, 64), (64, 64)),
    morphable=True,
    unacc_mapping="allrounder",
    max_tenants=4,
    area_mm2=108.03,
    power_w={"bf16": 5.31, "fp8a": 10.14, "fp8b": 9.19, "int8": 1.73,
             "int4": 1.70},
)

TPU_SA = Accelerator(
    name="tpu_sa",
    configs=((128, 128),),
    morphable=False,
    unacc_mapping="bus",
    max_tenants=1,
    area_mm2=103.55,
    power_w={"bf16": 4.73, "fp8a": 9.57, "fp8b": 8.62, "int8": 1.16,
             "int4": 1.14},
)

SARA = Accelerator(                 # [46]-based: bypassable 4x4 systolic cells
    name="sara",
    configs=((128, 128), (64, 128), (128, 64), (64, 64)),
    morphable=True,
    unacc_mapping="bus",            # morphable but no distinct unacc mapping
    max_tenants=4,
    area_mm2=118.45,
    power_w={"bf16": 6.32, "fp8a": 11.16, "fp8b": 10.21, "int8": 2.75,
             "int4": 2.73},
)

MIRRORING = Accelerator(            # [29]-based: bidirectional dataflow
    name="mirroring",
    configs=((128, 128),),
    morphable=False,
    unacc_mapping="bus",
    max_tenants=2,                  # fine-grained spatial multitasking (2-way)
    area_mm2=105.84,
    power_w={"bf16": 4.92, "fp8a": 9.74, "fp8b": 8.77, "int8": 1.30,
             "int4": 1.28},
)

ACCELERATORS = {a.name: a for a in (ALLROUNDER, TPU_SA, SARA, MIRRORING)}

# Table II: energy per multiply op [pJ] for the all-in-one multiplier.
MULT_ENERGY_PJ = {"bf16": 3.26, "fp8a": 2.83, "fp8b": 2.72, "int8": 3.03,
                  "int4": 2.74}

# memory-system energy constants (CACTI-P-class SPM + HBM2 per JEDEC [23])
SPM_PJ_PER_BYTE = 6.0
HBM_PJ_PER_BYTE = 31.2


def array_power_w(acc: Accelerator, fmt: str) -> float:
    return acc.power_w.get(fmt, acc.power_w["bf16"])


def precision_double(fmt: str) -> int:
    """FP8/INT4 modes yield 4 products per multiplier -> both dims x2
    (Table III: 128x128 acts as 256x256)."""
    return 2 if fmt in ("fp8a", "fp8b", "int4", "uint4") else 1
