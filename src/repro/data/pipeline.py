"""Deterministic synthetic data pipeline with checkpointable state.

Production shape without external deps: an infinite token stream generated
from a counter-based hash (stateless random access => any step is
reproducible), host-sharded by (host_id, n_hosts), double-buffered prefetch,
and a tiny state object (the step counter) that rides inside checkpoints so
restarts resume mid-epoch without replaying data.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "PipelineState", "SyntheticLM", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int                  # GLOBAL batch
    seq: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    frontend: Optional[str] = None     # 'audio' | 'vision'
    frontend_len: int = 0
    d_model: int = 0


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d) -> "PipelineState":
        return cls(step=int(d["step"]))


def _hash_tokens(step: int, host: int, shape, vocab: int, seed: int,
                 salt: int = 0) -> np.ndarray:
    """Counter-based generator: splitmix64 over (seed, step, host, index)."""
    n = int(np.prod(shape))
    with np.errstate(over="ignore"):
        idx = np.arange(n, dtype=np.uint64)
        x = (idx + np.uint64((seed * 0x9E3779B97F4A7C15) % 2**64)
             + np.uint64((step * 0xBF58476D1CE4E5B9) % 2**64)
             + np.uint64((host * 0x94D049BB133111EB) % 2**64)
             + np.uint64((salt * 0xD6E8FEB86659FD93) % 2**64))
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    return (x % np.uint64(vocab)).astype(np.int32).reshape(shape)


class SyntheticLM:
    """Iterator of host-local batches; labels are next-token shifted."""

    def __init__(self, cfg: DataConfig, state: Optional[PipelineState] = None):
        if cfg.batch % cfg.n_hosts:
            raise ValueError("global batch must divide across hosts")
        self.cfg = cfg
        self.state = state or PipelineState()
        self._local_batch = cfg.batch // cfg.n_hosts

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        shape = (self._local_batch, c.seq + 1)
        # learnable structure: 80% of transitions follow the successor rule
        # t[i+1] = t[i]+1 (mod V), 20% jump uniformly — a 1st-order Markov
        # stream whose optimal loss ~ 0.2*ln(V) + H(0.2), so training curves
        # actually descend (uniform i.i.d. tokens would pin loss at ln V).
        base = _hash_tokens(self.state.step, c.host_id, shape, c.vocab, c.seed)
        gate = _hash_tokens(self.state.step, c.host_id, shape, 5, c.seed,
                            salt=7)
        toks = np.empty(shape, np.int32)
        toks[:, 0] = base[:, 0]
        for i in range(1, shape[1]):
            follow = gate[:, i] > 0          # 4/5 of the time
            toks[:, i] = np.where(follow, (toks[:, i - 1] + 1) % c.vocab,
                                  base[:, i])
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.frontend == "audio":
            batch["frames"] = _hash_tokens(
                self.state.step, c.host_id,
                (self._local_batch, c.frontend_len, c.d_model), 2048, c.seed,
                salt=1).astype(np.float32) / 1024.0 - 1.0
        elif c.frontend == "vision":
            batch["patch_embeds"] = _hash_tokens(
                self.state.step, c.host_id,
                (self._local_batch, c.frontend_len, c.d_model), 2048, c.seed,
                salt=2).astype(np.float32) / 1024.0 - 1.0
        self.state.step += 1
        return batch


class Prefetcher:
    """Background-thread prefetch (the double-buffered-SPM analogue at the
    input layer): keeps `depth` batches ready while the step runs."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        except BaseException as e:  # noqa: BLE001
            self._err = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise self._err or StopIteration
        return item

    def close(self):
        self._stop.set()
