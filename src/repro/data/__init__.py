from .pipeline import DataConfig, PipelineState, Prefetcher, SyntheticLM  # noqa: F401
