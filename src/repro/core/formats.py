"""AIO number formats — the software plane of the paper's all-in-one multiplier.

The All-rounder multiplier supports:
  * FP with exponent widths 1..8 bits and mantissa widths 3b or 7b natively
    (FP8-B {1,5,2} is zero-padded into the 4b-significand datapath), with a
    *programmable* exponent bias so exponential scaling factors fold into the
    format instead of needing extra multipliers (paper §III).
  * signed/unsigned INT at 4b and 8b (and 4x8 mixed) via the reconstructed CSM.

This module defines the format algebra: exact round-to-nearest-even
quantization, encode/decode to bit codes, and power-of-two scale folding.
Everything is pure jax.numpy (differentiable fake-quant via STE) plus a numpy
path used by the bit-accurate multiplier model in ``aio_mac.py``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "AIOFormat", "fp_format", "int_format",
    "BF16", "FP8A", "FP8B", "FP16", "INT8", "INT4", "UINT8", "UINT4",
    "REGISTRY", "quantize", "dequantize_code", "encode", "decode",
    "pow2_ceil", "pow2_scale", "quantize_scaled", "fake_quant", "pack_int4",
    "unpack_int4", "QuantWeight", "quantize_weight", "dequantize_weight",
    "RESIDENT_FORMATS",
]

# Mantissa widths the reconstructed CSM supports natively (4b / 8b significands).
_HW_MANTISSA_BITS = (2, 3, 7)
# Exponent widths the programmable exponent adder supports.
_HW_EXPONENT_BITS = tuple(range(1, 9))


@dataclasses.dataclass(frozen=True)
class AIOFormat:
    """A number format the all-in-one multiplier can process.

    kind='fp':  value = (-1)^s * 1.M * 2^(E - bias)   (E=0 -> subnormal)
    kind='int': two's-complement (signed) or plain binary (unsigned) integer.
    """
    name: str
    kind: str                      # 'fp' | 'int'
    ebits: int = 0                 # fp only: exponent field width (1..8)
    mbits: int = 0                 # fp only: mantissa field width
    bias: int = 0                  # fp only: exponent bias (programmable!)
    reserve_specials: bool = False # fp only: top exponent code = inf/nan (IEEE-style)
    bits: int = 0                  # int only: total width (4 or 8)
    signed: bool = True            # int only

    # ---- derived fp properties -------------------------------------------------
    @property
    def emin(self) -> int:
        """Minimum *normal* unbiased exponent."""
        return 1 - self.bias

    @property
    def emax(self) -> int:
        """Maximum unbiased exponent of a finite normal value."""
        top = (1 << self.ebits) - 1
        if self.reserve_specials:
            top -= 1
        return top - self.bias

    @property
    def max_finite(self) -> float:
        if self.kind == "int":
            return float(self.int_max)
        return float((2.0 - 2.0 ** (-self.mbits)) * 2.0 ** self.emax)

    @property
    def min_subnormal(self) -> float:
        return float(2.0 ** (self.emin - self.mbits))

    @property
    def total_bits(self) -> int:
        if self.kind == "int":
            return self.bits
        return 1 + self.ebits + self.mbits

    # ---- derived int properties --------------------------------------------------
    @property
    def int_min(self) -> int:
        return -(1 << (self.bits - 1)) if self.signed else 0

    @property
    def int_max(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.signed else (1 << self.bits) - 1

    @property
    def hw_native(self) -> bool:
        """Does the datapath of the reconstructed CSM support this directly?"""
        if self.kind == "int":
            return self.bits in (4, 8)
        return self.ebits in _HW_EXPONENT_BITS and self.mbits in _HW_MANTISSA_BITS

    @property
    def sig_width(self) -> int:
        """Significand datapath width the CSM uses (4b or 8b lanes)."""
        assert self.kind == "fp"
        return 8 if self.mbits > 3 else 4

    def with_bias(self, bias: int) -> "AIOFormat":
        """Programmable-bias variant (paper: scaling factors fold into bias)."""
        assert self.kind == "fp"
        return dataclasses.replace(self, bias=bias, name=f"{self.name}b{bias}")

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        if self.kind == "fp":
            return f"{self.name}{{s:1,e:{self.ebits},m:{self.mbits},bias:{self.bias}}}"
        return f"{self.name}{{{'s' if self.signed else 'u'}int{self.bits}}}"


def fp_format(name: str, ebits: int, mbits: int, bias: Optional[int] = None,
              reserve_specials: bool = False) -> AIOFormat:
    if not (1 <= ebits <= 8):
        raise ValueError(f"exponent width {ebits} outside the hardware range 1..8")
    if bias is None:
        bias = (1 << (ebits - 1)) - 1   # default bias 2^(E.L-1)-1 (paper §III)
    return AIOFormat(name=name, kind="fp", ebits=ebits, mbits=mbits, bias=bias,
                     reserve_specials=reserve_specials)


def int_format(name: str, bits: int, signed: bool = True) -> AIOFormat:
    if bits not in (2, 4, 8, 16, 32):
        raise ValueError(f"unsupported int width {bits}")
    return AIOFormat(name=name, kind="int", bits=bits, signed=signed)


# The formats the paper evaluates (Table II) + IEEE-ish anchors.
BF16 = fp_format("bf16", 8, 7, reserve_specials=True)
FP16 = fp_format("fp16", 5, 10, reserve_specials=True)   # software-only reference
FP8A = fp_format("fp8a", 4, 3)      # FP8-A {s:1,e:4,m:3}, saturating (HFP8-style)
FP8B = fp_format("fp8b", 5, 2)      # FP8-B {s:1,e:5,m:2}
INT8 = int_format("int8", 8, signed=True)
INT4 = int_format("int4", 4, signed=True)
UINT8 = int_format("uint8", 8, signed=False)
UINT4 = int_format("uint4", 4, signed=False)

REGISTRY = {f.name: f for f in (BF16, FP16, FP8A, FP8B, INT8, INT4, UINT8, UINT4)}


# =============================================================================
# Quantization (value domain): x -> nearest representable value, RNE.
# =============================================================================

def _quantize_fp(x: jax.Array, fmt: AIOFormat) -> jax.Array:
    """Round-to-nearest-even x onto fmt's representable grid (saturating)."""
    x = x.astype(jnp.float32)
    a = jnp.abs(x)
    sgn = jnp.where(jnp.signbit(x), -1.0, 1.0).astype(jnp.float32)
    # frexp is exact: a = frac * 2^e2 with frac in [0.5, 1)
    frac, e2 = jnp.frexp(a)
    del frac
    ebit = e2 - 1                                  # floor(log2 a) for a > 0
    eff = jnp.maximum(ebit, fmt.emin)              # subnormal clamp
    step_exp = eff - fmt.mbits
    q = jnp.ldexp(jnp.round(jnp.ldexp(a, -step_exp)), step_exp)
    q = jnp.minimum(q, fmt.max_finite)             # saturate overflow
    out = sgn * q
    out = jnp.where(a == 0, sgn * 0.0, out)
    if fmt.reserve_specials:
        out = jnp.where(jnp.isinf(x), x, out)
        out = jnp.where(jnp.isnan(x), x, out)
    return out


def _quantize_int(x: jax.Array, fmt: AIOFormat) -> jax.Array:
    x = jnp.round(x.astype(jnp.float32))           # RNE
    return jnp.clip(x, fmt.int_min, fmt.int_max)


def quantize(x: jax.Array, fmt: AIOFormat) -> jax.Array:
    """Project x onto fmt's representable values (returned as float32)."""
    if fmt.kind == "fp":
        return _quantize_fp(x, fmt)
    return _quantize_int(x, fmt)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jax.Array, fmt_name: str):
    """Straight-through-estimator quantization for QAT paths."""
    return quantize(x, REGISTRY[fmt_name])


def _fq_fwd(x, fmt_name):
    return fake_quant(x, fmt_name), None


def _fq_bwd(fmt_name, _, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


# ---- exact numpy/float64 reference (XLA CPU flushes f32 denormals; this
# ---- oracle does not, so it is the ground truth for the bit-accurate tests).

def np_quantize_fp(x: np.ndarray, fmt: AIOFormat) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    a = np.abs(x)
    sgn = np.copysign(1.0, x)
    with np.errstate(divide="ignore", invalid="ignore"):
        _, e2 = np.frexp(a)
    ebit = e2 - 1
    eff = np.maximum(ebit, fmt.emin)
    step_exp = eff - fmt.mbits
    # np.round is RNE
    q = np.ldexp(np.round(np.ldexp(a, -step_exp)), step_exp)
    q = np.minimum(q, fmt.max_finite)
    out = sgn * q
    out = np.where(a == 0, np.copysign(0.0, x), out)
    if fmt.reserve_specials:
        out = np.where(np.isinf(x), x, out)
        out = np.where(np.isnan(x), x, out)
    return out


def np_encode_fp(x: np.ndarray, fmt: AIOFormat) -> np.ndarray:
    q = np_quantize_fp(x, fmt)
    a = np.abs(q)
    sgn = np.signbit(q).astype(np.int64)
    _, e2 = np.frexp(a)
    ebit = e2 - 1
    is_normal = a >= 2.0 ** fmt.emin
    e_code = np.where(is_normal, ebit + fmt.bias, 0).astype(np.int64)
    m_norm = np.round(np.ldexp(a, -ebit) * (1 << fmt.mbits)) - (1 << fmt.mbits)
    m_sub = np.round(np.ldexp(a, -(fmt.emin - fmt.mbits)))
    m_code = np.where(is_normal, m_norm, m_sub).astype(np.int64)
    code = (sgn << (fmt.ebits + fmt.mbits)) | (e_code << fmt.mbits) | m_code
    code = np.where(a == 0, sgn << (fmt.ebits + fmt.mbits), code)
    if fmt.reserve_specials:
        top = (1 << fmt.ebits) - 1
        inf_code = (sgn << (fmt.ebits + fmt.mbits)) | (top << fmt.mbits)
        code = np.where(np.isinf(q), inf_code, code)
        code = np.where(np.isnan(q), inf_code | 1, code)
    return code


def np_decode_fp(code: np.ndarray, fmt: AIOFormat) -> np.ndarray:
    code = np.asarray(code, dtype=np.int64)
    m_mask = (1 << fmt.mbits) - 1
    m_code = code & m_mask
    e_code = (code >> fmt.mbits) & ((1 << fmt.ebits) - 1)
    sgn = np.where((code >> (fmt.ebits + fmt.mbits)) & 1 == 1, -1.0, 1.0)
    normal = e_code > 0
    sig = np.where(normal, (1 << fmt.mbits) + m_code, m_code).astype(np.float64)
    exp = np.where(normal, e_code - fmt.bias, fmt.emin) - fmt.mbits
    val = sgn * np.ldexp(sig, exp)
    if fmt.reserve_specials:
        top = (1 << fmt.ebits) - 1
        val = np.where((e_code == top) & (m_code == 0), sgn * np.inf, val)
        val = np.where((e_code == top) & (m_code != 0), np.nan, val)
    return val


# =============================================================================
# Encode / decode (code domain): float <-> bit patterns.
# =============================================================================

def encode(x: jax.Array, fmt: AIOFormat) -> jax.Array:
    """Quantize and encode to the integer bit pattern (int32 container).

    fp layout: [sign | e_code | m_code]; int: two's complement in `bits`.
    """
    if fmt.kind == "int":
        q = _quantize_int(x, fmt).astype(jnp.int32)
        mask = (1 << fmt.bits) - 1
        return q & mask
    q = _quantize_fp(x, fmt)
    a = jnp.abs(q)
    sgn = (jnp.signbit(q)).astype(jnp.int32)
    frac, e2 = jnp.frexp(a)
    del frac
    ebit = e2 - 1
    is_normal = a >= 2.0 ** fmt.emin
    e_code = jnp.where(is_normal, ebit + fmt.bias, 0).astype(jnp.int32)
    # mantissa code: normal -> (a/2^ebit - 1) * 2^m ; subnormal -> a / 2^(emin-m)
    m_norm = jnp.round(jnp.ldexp(a, -ebit) * (1 << fmt.mbits)) - (1 << fmt.mbits)
    m_sub = jnp.round(jnp.ldexp(a, -(fmt.emin - fmt.mbits)))
    m_code = jnp.where(is_normal, m_norm, m_sub).astype(jnp.int32)
    code = (sgn << (fmt.ebits + fmt.mbits)) | (e_code << fmt.mbits) | m_code
    code = jnp.where(a == 0, sgn << (fmt.ebits + fmt.mbits), code)
    if fmt.reserve_specials:
        top = (1 << fmt.ebits) - 1
        inf_code = (sgn << (fmt.ebits + fmt.mbits)) | (top << fmt.mbits)
        code = jnp.where(jnp.isinf(q), inf_code, code)
        code = jnp.where(jnp.isnan(q), inf_code | 1, code)
    return code


def decode(code: jax.Array, fmt: AIOFormat) -> jax.Array:
    """Integer bit pattern -> float32 value."""
    code = code.astype(jnp.int32)
    if fmt.kind == "int":
        if fmt.signed:
            shift = 32 - fmt.bits
            return ((code << shift) >> shift).astype(jnp.float32)  # sign extend
        return (code & ((1 << fmt.bits) - 1)).astype(jnp.float32)
    m_mask = (1 << fmt.mbits) - 1
    m_code = code & m_mask
    e_code = (code >> fmt.mbits) & ((1 << fmt.ebits) - 1)
    sgn = jnp.where((code >> (fmt.ebits + fmt.mbits)) & 1 == 1, -1.0, 1.0)
    normal = e_code > 0
    sig = jnp.where(normal, (1 << fmt.mbits) + m_code, m_code).astype(jnp.float32)
    exp = jnp.where(normal, e_code - fmt.bias, fmt.emin) - fmt.mbits
    val = sgn * jnp.ldexp(sig, exp)
    if fmt.reserve_specials:
        top = (1 << fmt.ebits) - 1
        val = jnp.where((e_code == top) & (m_code == 0), sgn * jnp.inf, val)
        val = jnp.where((e_code == top) & (m_code != 0), jnp.nan, val)
    return val


def dequantize_code(code: jax.Array, fmt: AIOFormat, scale: jax.Array = None):
    v = decode(code, fmt)
    if scale is not None:
        v = v * scale
    return v


# =============================================================================
# Scale handling — the programmable-bias trick.
# =============================================================================

def pow2_ceil(r: jax.Array) -> jax.Array:
    """Exact 2^ceil(log2(r)) for positive r.

    frexp gives r = frac * 2^e2 with frac in [0.5, 1), so 2^e2 >= r — but at
    r exactly 2^k, frac == 0.5 and e2 == k+1: the naive 2^e2 DOUBLES the
    scale and wastes half the representable range. Detect the exact-power
    case and step the exponent back down.

    Built with ldexp, NOT exp2: XLA's exp2 is a polynomial approximation
    that drifts off the exact power of two for large |exponent| (observed
    one-ulp errors at 2^-64 on CPU, and 2^-126 — the pow2_scale `tiny`
    guard's regime — underflowing to 0.0, i.e. a zero scale). ldexp is an
    exact exponent manipulation all the way down to the subnormal boundary.
    """
    frac, e2 = jnp.frexp(r)
    e2 = jnp.where(frac == 0.5, e2 - 1, e2)        # r == 2^(e2-1) exactly
    return jnp.ldexp(jnp.ones_like(frac, jnp.float32), e2)


def pow2_scale(x: jax.Array, fmt: AIOFormat, axis=None) -> jax.Array:
    """Power-of-two scale mapping max|x| to fmt.max_finite.

    Restricting scales to powers of two lets the hardware fold them into the
    programmable exponent bias (paper §III 'Advantage'): dequantization costs
    an exponent add instead of a multiplier.
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    amax = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny)
    # scale = 2^ceil(log2(amax / max_finite)) so that x/scale fits; at an
    # exact power of two the ratio itself is the scale (|x|/scale hits
    # max_finite exactly — the full range is used).
    return pow2_ceil(amax / fmt.max_finite)


def quantize_scaled(x: jax.Array, fmt: AIOFormat, axis=None, pow2: bool = True):
    """Returns (codes, scale) with x ≈ decode(codes) * scale.

    pow2=True uses the bias-foldable power-of-two scale; pow2=False uses an
    exact fp32 scale (costs a real multiplier on the paper's hardware).
    """
    if pow2:
        scale = pow2_scale(x, fmt, axis=axis)
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
        scale = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / fmt.max_finite
    codes = encode(x / scale, fmt)
    return codes, scale


def bias_for_scale(fmt: AIOFormat, scale_log2: int) -> AIOFormat:
    """Fold a 2^k scale into the format's programmable bias.

    decode(code, fmt.with_bias(bias - k)) == decode(code, fmt) * 2^k
    """
    return fmt.with_bias(fmt.bias - scale_log2)


# =============================================================================
# INT4 lane packing — the throughput-morphing plane (1 result in 8x8 mode,
# 4 results in 4x4 mode) realized as two int4 values per int8 byte.
# =============================================================================

def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int4 codes (int32 container, low nibble valid) pairwise along the
    last axis into int8: out[..., i] = codes[..., 2i] | codes[..., 2i+1] << 4.

    An odd last axis is zero-padded with one phantom nibble (code 0 == value
    0, so it contributes nothing to a dot product); `unpack_int4(..., k=K)`
    restores the original length exactly.
    """
    if codes.shape[-1] % 2:
        pads = [(0, 0)] * (codes.ndim - 1) + [(0, 1)]
        codes = jnp.pad(codes, pads)
    lo = codes[..., 0::2] & 0xF
    hi = codes[..., 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jax.Array, signed: bool = True,
                k: Optional[int] = None) -> jax.Array:
    """Inverse of pack_int4 -> int32 values (sign-extended if signed).

    k: original (possibly odd) last-axis length; trims the phantom nibble
    pack_int4 added, making odd-K packing a bit-exact round trip."""
    p = packed.astype(jnp.int32) & 0xFF
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    if signed:
        lo = (lo << 28) >> 28
        hi = (hi << 28) >> 28
    out = jnp.stack([lo, hi], axis=-1)
    out = out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
    return out if k is None else out[..., :k]


# =============================================================================
# Weight residency — quantized weights as a first-class storage format.
#
# The fake-quant plane (models/layers._maybe_quant) decompresses nothing: the
# dense f32 weight stays resident in HBM and is re-quantized on every call.
# QuantWeight is the residency mirror of the serving engine's QuantKVCache:
# the weight pytree is converted ONCE into codes (int4 packed two-per-byte
# along K) plus per-output-channel power-of-two scales, and matmuls dispatch
# through `api.ops.matmul_codes` so the AIO kernel unpacks/decodes in VMEM —
# no dense weight is ever materialized in HBM again.
# =============================================================================

# Formats a Linear weight can be resident in (bf16 residency is just dtype).
RESIDENT_FORMATS = ("int4", "int8", "fp8a", "fp8b")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantWeight:
    """A Linear weight living as codes + per-output-channel pow2 scales.

    codes: int8. For int8/fp8a/fp8b the raw bit codes with shape
           (..., K, N); for int4 two codes packed per byte along K, shape
           (..., ceil(K/2), N).
    scale: f32 (..., 1, N) power-of-two per-output-channel scales (the
           bias-foldable kind, paper §III).
    fmt:   format name (static aux data — rides jit/scan/vmap untouched).
    k:     unpacked contraction length (static; int4 packing may pad K odd->
           even, and stacked layers slice the leading axis away, so the true
           K must travel with the pytree).

    Registered as a pytree node: codes/scale are leaves (so `lax.scan` over
    stacked per-layer params and `jax.tree.map` slicing work unchanged),
    fmt/k are hashable aux data.
    """
    codes: jax.Array
    scale: jax.Array
    fmt: str
    k: int

    def tree_flatten(self):
        return (self.codes, self.scale), (self.fmt, self.k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def bytes_per_param(self) -> float:
        """HBM bytes per weight element (codes only; scales are N/K smaller)."""
        return 0.5 if self.fmt == "int4" else 1.0


def quantize_weight(w: jax.Array, fmt_name: str) -> QuantWeight:
    """Convert a dense (..., K, N) weight into resident codes, once.

    Per-output-channel pow2 scales over the K axis (axis=-2) — exactly the
    scale geometry `quantize_operands_ref` uses for the w operand, so a
    resident weight fed to the Pallas kernel is bit-identical to quantizing
    the dense weight on the fly. dequantize_weight(quantize_weight(w, f))
    equals the per-channel fake-quant of w bitwise (pow2 division/rescale is
    exact; encode/decode round-trips the RNE grid projection).
    """
    if fmt_name not in RESIDENT_FORMATS:
        raise ValueError(f"weight format {fmt_name!r} not in "
                         f"{RESIDENT_FORMATS}")
    fmt = REGISTRY[fmt_name]
    k = w.shape[-2]
    codes, scale = quantize_scaled(w, fmt, axis=-2, pow2=True)
    if fmt_name == "int4":
        # pack two codes per byte along K (the axis=-2): swap K last, pack,
        # swap back — ceil(K/2) bytes per column, odd K zero-padded
        codes = jnp.swapaxes(pack_int4(jnp.swapaxes(codes, -1, -2)), -1, -2)
    else:
        codes = codes.astype(jnp.int8)
    return QuantWeight(codes=codes, scale=scale.astype(jnp.float32),
                       fmt=fmt_name, k=k)


def dequantize_weight(qw: QuantWeight) -> jax.Array:
    """Resident codes -> dense f32 (..., K, N) weight (the ref-path oracle;
    the Pallas kernel decodes tiles in VMEM instead)."""
    fmt = REGISTRY[qw.fmt]
    if qw.fmt == "int4":
        vals = jnp.swapaxes(
            unpack_int4(jnp.swapaxes(qw.codes, -1, -2), signed=True, k=qw.k),
            -1, -2).astype(jnp.float32)
    else:
        vals = decode(qw.codes, fmt)
    return vals * qw.scale
