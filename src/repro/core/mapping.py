"""Data-mapping schemes and MAC-utilization math (paper §II-B, §IV-B, Fig 9).

Two operation classes (Table I):
  ACCUMULABLE   — MAC results accumulate along C_in (conv, FC, GEMM/GEMV).
  UNACCUMULABLE — no C_in accumulation (depthwise/dilated conv, conv weight
                  gradients dL/dW).

On a rigid systolic array the unaccumulable class is output-bus bound: a column
may only hold one channel's taps (else partial sums of different outputs would
merge), so only K*K of R rows do work. The All-rounder's unaccumulable mapping
instead tiles taps into 9-row subarrays and groups the LRMU 9-at-a-time,
reaching 63/64 + 7*9/63... = >99% of the block (Fig 9).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional

from .morphable import BLOCK, SUBARRAY_ROWS, SUBARRAYS_PER_BLOCK

__all__ = ["OpKind", "GemmShape", "classify", "systolic_latency",
           "accumulable_utilization", "unaccumulable_util_allrounder",
           "unaccumulable_util_rigid", "lrmu_groups"]


class OpKind(enum.Enum):
    ACCUMULABLE = "accumulable"
    UNACCUMULABLE = "unaccumulable"


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """Input {S_C, T} x weight {T, S_R} on an R x C array (paper Eq. 1)."""
    s_c: int    # input rows streamed
    t: int      # contraction
    s_r: int    # output columns / weight columns


def classify(op_type: str) -> OpKind:
    """Classify an op per Table I."""
    unacc = {"depthwise_conv", "dilated_conv", "weight_gradient"}
    acc = {"conv", "fc", "gemm", "gemv", "attention_gemm"}
    if op_type in unacc:
        return OpKind.UNACCUMULABLE
    if op_type in acc:
        return OpKind.ACCUMULABLE
    raise ValueError(f"unknown op type {op_type!r}")


def systolic_latency(shape: GemmShape, rows: int, cols: int) -> int:
    """Paper Eq. (1): (2*S_R + S_C - 2) * ceil(S_R/R) * ceil(S_C/C).

    NOTE: we keep the paper's formula verbatim, including its tile terms; the
    contraction dim T is folded by the caller into S_C when layers are
    im2col'ed (the paper follows SCALE-sim's convention).
    """
    return (2 * shape.s_r + shape.s_c - 2) * (
        math.ceil(shape.s_r / rows) * math.ceil(shape.s_c / cols))


def accumulable_utilization(shape: GemmShape, rows: int, cols: int) -> float:
    """Average fraction of MACs doing useful work for an accumulable GEMM:
    last tile in each dimension may be ragged."""
    tr, tc = math.ceil(shape.t / rows), math.ceil(shape.s_r / cols)
    used = shape.t * shape.s_r
    return used / (tr * tc * rows * cols)


def lrmu_groups(taps: int, lrmu_width: int = BLOCK) -> int:
    """LRMU groups `taps` MACs together: floor(width / taps) groups (Fig 9-b).
    For 3x3 (9 taps): 7 groups -> 63 of 64 MACs active."""
    return lrmu_width // taps


def unaccumulable_util_allrounder(taps: int, c_out: Optional[int] = None) -> float:
    """Block utilization for the All-rounder's unaccumulable mapping.

    Each subarray column-group holds one filter's taps across its 9 rows
    (ceil(taps/9) groups chained when taps > 9); the LRMU packs floor(64/taps)
    groups. For 3x3: (7*9*64 + 63) / 64^2 = 99.97%.
    """
    sub_groups = math.ceil(taps / SUBARRAY_ROWS)
    sub_used_rows = taps / sub_groups                    # of SUBARRAY_ROWS
    sub_util = sub_used_rows / SUBARRAY_ROWS
    sub_macs = SUBARRAYS_PER_BLOCK * SUBARRAY_ROWS * BLOCK * sub_util
    lrmu_macs = lrmu_groups(taps) * taps
    util = (sub_macs + lrmu_macs) / (BLOCK * BLOCK)
    if c_out is not None and c_out < BLOCK:              # ragged channel tile
        util *= c_out / BLOCK
    return util


def unaccumulable_util_rigid(taps: int, rows: int,
                             c_out: Optional[int] = None) -> float:
    """Rigid-SA utilization for unaccumulable ops (Fig 2-b).

    One output channel per column; only `taps` of `rows` rows contribute
    (mapping more would overflow the output bus), so util = taps/rows.
    """
    util = min(taps / rows, 1.0)
    if c_out is not None:
        util *= min(c_out, BLOCK * 2) / (BLOCK * 2) if False else 1.0
    return util
