"""Bit-accurate functional model of the paper's all-in-one multiplier (§III).

Datapath modeled (Fig 7):
  1. XOR bundle            -> product sign
  2. programmable exponent adder bundle -> E_A + E_B - bias (bias is an input!)
  3. reconstructed carry-save multiplier: four 5b x 5b *signed* sub-multipliers
     combined by shift-add (8x8 -> 1 result, 4x8/8x4 -> 2, 4x4 -> 4 results)
  4. normalizer bundle     -> renormalize product into [1, 2)
  5. rounder bundle        -> RNE to the selected output precision

INT modes gate everything except the CSM: the CSM's shift-added output IS the
multiplier output (exact integer product), accumulated downstream in wide int.

Everything is vectorized numpy over int64 so the whole model is testable at
scale against the exact float reference. This module is the *oracle* for the
Pallas kernels: kernels emulate values; this model emulates the hardware.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .formats import AIOFormat

__all__ = [
    "submul_5x5", "csm_multiply_8x8", "csm_multiply_4x4x4", "csm_int",
    "aio_int_multiply", "aio_fp_multiply", "fp_decompose", "fp_compose",
]


# -----------------------------------------------------------------------------
# Reconstructed carry-save multiplier
# -----------------------------------------------------------------------------

def submul_5x5(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """One 5b x 5b signed sub-multiplier (the CSM's atomic unit).

    Inputs must lie in [-16, 15]; output is the exact 10b product. The range
    assert is the hardware contract — violating it means the decomposition
    feeding this unit is wrong.
    """
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    if np.any((a < -16) | (a > 15)) or np.any((b < -16) | (b > 15)):
        raise ValueError("sub-multiplier operand outside signed 5-bit range")
    return a * b


def _split_nibbles_signed(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """x (signed 8b) = hi*16 + lo with hi signed 4b (sign-extended to 5b), lo unsigned."""
    x = np.asarray(x, dtype=np.int64)
    lo = x & 0xF
    hi = (x - lo) >> 4
    return hi, lo


def _split_nibbles_unsigned(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    x = np.asarray(x, dtype=np.int64) & 0xFF
    return (x >> 4) & 0xF, x & 0xF


def csm_multiply_8x8(a: np.ndarray, b: np.ndarray, signed: bool = True) -> np.ndarray:
    """8x8 mode: one product from four sub-multipliers via shift-add fusion."""
    split = _split_nibbles_signed if signed else _split_nibbles_unsigned
    ah, al = split(a)
    bh, bl = split(b)
    # four 5b x 5b sub-multiplications (the "selective adder" sums them in INT/FP mode)
    hh = submul_5x5(ah, bh)
    hl = submul_5x5(ah, bl)
    lh = submul_5x5(al, bh)
    ll = submul_5x5(al, bl)
    return (hh << 8) + ((hl + lh) << 4) + ll


def csm_multiply_4x4x4(a4: np.ndarray, b4: np.ndarray, signed: bool = True) -> np.ndarray:
    """4x4 mode: four *independent* products per multiplier (throughput x4).

    a4, b4: (..., 4) arrays of 4-bit operands. Returns (..., 4) products.
    This is the mode that makes a 128x128 MAC array act as 256x256 (Table III).
    """
    a4 = np.asarray(a4, dtype=np.int64)
    b4 = np.asarray(b4, dtype=np.int64)
    if signed:
        lo_a, lo_b = ((a4 << 60) >> 60), ((b4 << 60) >> 60)   # sign-extend 4b
    else:
        lo_a, lo_b = a4 & 0xF, b4 & 0xF
    return submul_5x5(lo_a, lo_b)


def csm_multiply_4x8(a4: np.ndarray, b8: np.ndarray, signed: bool = True) -> np.ndarray:
    """4x8 / 8x4 mode: two products per multiplier (throughput x2).

    a4: (..., 2) of 4b operands, b8: (..., 2) of 8b operands -> (..., 2)."""
    a4 = np.asarray(a4, dtype=np.int64)
    if signed:
        a = (a4 << 60) >> 60
        bh, bl = _split_nibbles_signed(b8)
    else:
        a = a4 & 0xF
        bh, bl = _split_nibbles_unsigned(b8)
    return (submul_5x5(a, bh) << 4) + submul_5x5(a, bl)


def csm_int(a: np.ndarray, b: np.ndarray, bits_a: int, bits_b: int,
            signed: bool = True) -> np.ndarray:
    """Dispatch to the CSM mode for an INT multiply (paper Fig 5)."""
    if bits_a == 8 and bits_b == 8:
        return csm_multiply_8x8(a, b, signed)
    if bits_a == 4 and bits_b == 4:
        return csm_multiply_4x4x4(a, b, signed)
    if bits_a == 4 and bits_b == 8:
        return csm_multiply_4x8(a, b, signed)
    if bits_a == 8 and bits_b == 4:
        return csm_multiply_4x8(b, a, signed)
    raise ValueError(f"unsupported CSM mode {bits_a}x{bits_b}")


# -----------------------------------------------------------------------------
# INT mode (all bundles except the CSM are gated — Fig 7-(d))
# -----------------------------------------------------------------------------

def aio_int_multiply(a: np.ndarray, b: np.ndarray, fmt_a: AIOFormat,
                     fmt_b: AIOFormat) -> np.ndarray:
    """Exact integer product(s); accumulation happens downstream in wide int."""
    assert fmt_a.kind == fmt_b.kind == "int"
    assert fmt_a.signed == fmt_b.signed, "mixed-signedness not a hardware mode"
    return csm_int(a, b, fmt_a.bits, fmt_b.bits, signed=fmt_a.signed)


# -----------------------------------------------------------------------------
# FP mode
# -----------------------------------------------------------------------------

def fp_decompose(code: np.ndarray, fmt: AIOFormat):
    """code -> (sign, significand integer SA, exponent of SA's LSB).

    value = (-1)^sign * SA * 2^lsb_exp. Subnormals (e_code==0) have no hidden 1.
    """
    code = np.asarray(code, dtype=np.int64)
    m_mask = (1 << fmt.mbits) - 1
    m_code = code & m_mask
    e_code = (code >> fmt.mbits) & ((1 << fmt.ebits) - 1)
    sign = (code >> (fmt.ebits + fmt.mbits)) & 1
    normal = e_code > 0
    sig = np.where(normal, (1 << fmt.mbits) + m_code, m_code)
    lsb_exp = np.where(normal, e_code - fmt.bias, fmt.emin) - fmt.mbits
    return sign, sig, lsb_exp


def _bit_length(p: np.ndarray) -> np.ndarray:
    """Exact bit length of non-negative int64 < 2^53 (0 -> 0)."""
    _, e2 = np.frexp(p.astype(np.float64))
    return e2.astype(np.int64)


def fp_compose(sign: np.ndarray, p: np.ndarray, lsb_exp: np.ndarray,
               out_fmt: AIOFormat) -> np.ndarray:
    """Normalizer + rounder bundles: value (-1)^sign * p * 2^lsb_exp -> out code.

    Integer-exact RNE with guard/round/sticky, subnormal handling, saturation.
    """
    sign = np.asarray(sign, dtype=np.int64)
    p = np.asarray(p, dtype=np.int64)
    lsb_exp = np.asarray(lsb_exp, dtype=np.int64)

    nbits = _bit_length(p)                       # p in [2^(nbits-1), 2^nbits)
    ebit = nbits - 1 + lsb_exp                   # floor(log2 value)
    eff = np.maximum(ebit, out_fmt.emin)
    step_exp = eff - out_fmt.mbits               # LSB weight of the target grid

    shift = step_exp - lsb_exp                   # >0: round; <=0: exact shift-up
    # Cap the right-shift at 62: for p < 2^54 any shift >= 62 already yields
    # q0=0, rem=p < half, i.e. a clean round-to-zero — and numpy's int64 shift
    # is UB beyond 63.
    sh_pos = np.minimum(np.maximum(shift, 0), 62)
    sh_neg = np.maximum(-shift, 0)
    q0 = p >> sh_pos
    rem = p - (q0 << sh_pos)
    half = np.where(sh_pos > 0, np.int64(1) << np.maximum(sh_pos - 1, 0), np.int64(0))
    round_up = (rem > half) | ((rem == half) & (sh_pos > 0) & ((q0 & 1) == 1))
    q = (q0 + round_up.astype(np.int64)) << sh_neg

    # rounding may carry into the next binade: q == 2^(mbits+1) * 2^k — fine,
    # re-derive exponent from q.
    qbits = _bit_length(q)
    out_ebit = qbits - 1 + step_exp

    # saturate (the hardware's FP modes have no inf except IEEE-style bf16)
    max_sig = (1 << (out_fmt.mbits + 1)) - 1     # 1.111..1
    overflow = out_ebit > out_fmt.emax
    q = np.where(overflow, max_sig, q)
    out_ebit = np.where(overflow, out_fmt.emax, out_ebit)
    step_out = np.where(overflow, out_fmt.emax - out_fmt.mbits, step_exp)

    # encode
    is_normal = out_ebit >= out_fmt.emin
    is_zero = q == 0
    # align q so its LSB sits at (out_ebit - mbits) for normals, (emin - mbits) subnormals
    target_lsb = np.where(is_normal, out_ebit - out_fmt.mbits,
                          out_fmt.emin - out_fmt.mbits)
    realign = target_lsb - step_out
    q_al = np.where(realign >= 0, q >> np.maximum(realign, 0),
                    q << np.maximum(-realign, 0))
    e_code = np.where(is_normal, out_ebit + out_fmt.bias, 0)
    m_code = np.where(is_normal, q_al - (1 << out_fmt.mbits), q_al)
    e_code = np.where(is_zero, 0, e_code)
    m_code = np.where(is_zero, 0, m_code)
    return (sign << (out_fmt.ebits + out_fmt.mbits)) | (e_code << out_fmt.mbits) | m_code


def _csm_for_fp(sig_a: np.ndarray, sig_b: np.ndarray, fmt_a: AIOFormat,
                fmt_b: AIOFormat) -> np.ndarray:
    """Route FP significand products through the CSM datapath.

    8b significands (m=7) use 8x8 fusion; 4b significands (m<=3) use the 4x4
    sub-multipliers directly (this is why FP8 gets 4 results/multiplier). FP8-B
    {1,5,2} is zero-padded into the 4b lane (pad at LSB = multiply by 2, which
    we compensate in the caller via lsb_exp).
    """
    wa, wb = fmt_a.sig_width, fmt_b.sig_width
    if wa == 8 and wb == 8:
        return csm_multiply_8x8(sig_a, sig_b, signed=False)
    if wa == 4 and wb == 4:
        return csm_multiply_4x4x4(sig_a, sig_b, signed=False)
    if wa == 4:
        return csm_multiply_4x8(sig_a, sig_b, signed=False)
    return csm_multiply_4x8(sig_b, sig_a, signed=False)


def aio_fp_multiply(code_a: np.ndarray, code_b: np.ndarray, fmt_a: AIOFormat,
                    fmt_b: AIOFormat, out_fmt: AIOFormat,
                    bias_adjust: int = 0) -> np.ndarray:
    """Full FP path: codes in fmt_a/fmt_b -> exact product -> RNE code in out_fmt.

    bias_adjust models the *programmable* bias input: the result is scaled by
    2^bias_adjust at zero hardware cost (paper: scaling factors fold into the
    exponent adder's bias port instead of needing extra multipliers).
    """
    assert fmt_a.kind == fmt_b.kind == "fp" and out_fmt.kind == "fp"
    sa, sig_a, ea = fp_decompose(code_a, fmt_a)
    sb, sig_b, eb = fp_decompose(code_b, fmt_b)

    # zero-pad narrow significands into the 4b/8b CSM lanes (LSB pad => <<1)
    pad_a = fmt_a.sig_width - (fmt_a.mbits + 1)
    pad_b = fmt_b.sig_width - (fmt_b.mbits + 1)
    p = _csm_for_fp(sig_a << pad_a, sig_b << pad_b, fmt_a, fmt_b)

    sign = sa ^ sb                                # XOR bundle
    lsb = ea + eb - pad_a - pad_b + bias_adjust   # programmable exponent adder
    return fp_compose(sign, p, lsb, out_fmt)
