"""Core of the All-rounder reproduction: formats, bit-accurate multiplier,
morphable-array abstractions, mapping math, and the custom ISA."""
from . import aio_mac, formats, isa, mapping, morphable  # noqa: F401
from .formats import (  # noqa: F401
    AIOFormat, BF16, FP8A, FP8B, INT4, INT8, REGISTRY, UINT4, UINT8,
    fake_quant, fp_format, int_format, quantize, quantize_scaled,
)
from .morphable import FusionPlan, enumerate_fusion_plans, plan_for_tenants  # noqa: F401
