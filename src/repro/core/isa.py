"""Customized ISA for the morphable MAC array (paper §V-B, Fig 11).

Four custom instructions (R-type, opcodes 7'b1011011 / 7'b1111011) drive each
array block, always in the order:
    READ_WEIGHTS -> START_COMPUTE -> MATRIX_MULTIPLY -> END_COMPUTE

This module builds and validates instruction streams; the perfmodel costs
them, and the tenancy executor uses them as its schedule IR. The RISC-V host
pipeline itself is not cycle-modeled (DESIGN.md §2 — decode overhead is
negligible at the paper's granularity).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Sequence, Tuple

from .morphable import FusionPlan

__all__ = ["Opcode", "Instr", "read_weights", "start_compute", "matrix_multiply",
           "end_compute", "build_gemm_stream", "validate_stream", "StreamError"]

OPCODE_A = 0b1011011
OPCODE_B = 0b1111011


class Opcode(enum.Enum):
    READ_WEIGHTS = "read_weights"
    START_COMPUTE = "start_compute"
    MATRIX_MULTIPLY = "matrix_multiply"
    END_COMPUTE = "end_compute"


@dataclasses.dataclass(frozen=True)
class Instr:
    op: Opcode
    block_id: int              # target array block (or fused-array leader)
    base_addr: int = 0         # SPM base address
    block_size: int = 0        # '64 x {variable block size}' transfer
    global_ctrl: int = 0       # func3: fuse/split bits (G.C in Fig 11)
    local_ctrl: int = 0        # func7: op mode | precision | data type
    opcode_bits: int = OPCODE_A

    def encode(self) -> int:
        """Pack into a 32-bit R-type-style word (fields per Fig 11)."""
        return ((self.local_ctrl & 0x7F) << 25) | ((self.block_size & 0x1F) << 20) | \
               ((self.base_addr & 0x1F) << 15) | ((self.global_ctrl & 0x7) << 12) | \
               ((self.block_id & 0x1F) << 7) | (self.opcode_bits & 0x7F)


def _local_ctrl(op_mode: int, precision: int, dtype_fp: bool) -> int:
    """func7 = [op_mode:2 | precision:4 | fp/int:1]."""
    return ((op_mode & 0x3) << 5) | ((precision & 0xF) << 1) | int(dtype_fp)


def read_weights(block_id: int, base_addr: int, block_size: int) -> Instr:
    return Instr(Opcode.READ_WEIGHTS, block_id, base_addr, block_size)


def start_compute(block_id: int, fuse_bits: int, op_mode: int, precision: int,
                  dtype_fp: bool) -> Instr:
    return Instr(Opcode.START_COMPUTE, block_id, global_ctrl=fuse_bits,
                 local_ctrl=_local_ctrl(op_mode, precision, dtype_fp),
                 opcode_bits=OPCODE_B)


def matrix_multiply(block_id: int, base_addr: int, block_size: int) -> Instr:
    return Instr(Opcode.MATRIX_MULTIPLY, block_id, base_addr, block_size)


def end_compute(block_id: int, base_addr: int) -> Instr:
    return Instr(Opcode.END_COMPUTE, block_id, base_addr)


class StreamError(ValueError):
    pass


_ORDER = [Opcode.READ_WEIGHTS, Opcode.START_COMPUTE,
          Opcode.MATRIX_MULTIPLY, Opcode.END_COMPUTE]


def validate_stream(stream: Sequence[Instr]) -> None:
    """Enforce the per-block i->ii->iii->iv sequencing of §V-B.

    MATRIX_MULTIPLY may repeat (input re-streaming over the same weights).
    """
    state = {}
    for i, ins in enumerate(stream):
        cur = state.get(ins.block_id)
        if ins.op == Opcode.READ_WEIGHTS:
            if cur not in (None, Opcode.END_COMPUTE):
                raise StreamError(f"@{i}: READ_WEIGHTS while block {ins.block_id} "
                                  f"mid-sequence ({cur})")
        elif ins.op == Opcode.START_COMPUTE:
            if cur != Opcode.READ_WEIGHTS:
                raise StreamError(f"@{i}: START_COMPUTE without READ_WEIGHTS")
        elif ins.op == Opcode.MATRIX_MULTIPLY:
            if cur not in (Opcode.START_COMPUTE, Opcode.MATRIX_MULTIPLY):
                raise StreamError(f"@{i}: MATRIX_MULTIPLY before START_COMPUTE")
        elif ins.op == Opcode.END_COMPUTE:
            if cur not in (Opcode.START_COMPUTE, Opcode.MATRIX_MULTIPLY):
                raise StreamError(f"@{i}: END_COMPUTE before compute started")
        state[ins.block_id] = ins.op
    for b, cur in state.items():
        if cur != Opcode.END_COMPUTE:
            raise StreamError(f"block {b} left mid-sequence ({cur})")


def build_gemm_stream(plan: FusionPlan, tenant_tiles: Sequence[Tuple[int, int]],
                      precision: int = 7, dtype_fp: bool = True,
                      op_mode: int = 0) -> List[Instr]:
    """Emit the instruction stream for one GEMM (tile loop) per partition.

    tenant_tiles[p] = (n_weight_tiles, n_input_tiles) executed on partition p.
    fuse_bits encodes the plan's global bridges: bit b set = block b fused to
    its leader.
    """
    stream: List[Instr] = []
    for p, arr in enumerate(plan.arrays):
        if p >= len(tenant_tiles):
            break
        leader = arr.blocks[0]
        fuse_bits = 0
        for b in arr.blocks[1:]:
            fuse_bits |= 1 << (b % 3)
        n_w, n_x = tenant_tiles[p]
        addr = 0
        for _ in range(n_w):
            stream.append(read_weights(leader, addr, 16))
            stream.append(start_compute(leader, fuse_bits, op_mode, precision,
                                        dtype_fp))
            for _ in range(max(n_x, 1)):
                stream.append(matrix_multiply(leader, addr + 1, 16))
            stream.append(end_compute(leader, addr + 2))
            addr += 4
    validate_stream(stream)
    return stream
