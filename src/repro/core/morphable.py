"""Morphable MAC-array abstractions (paper §IV, Fig 8).

The physical array: 128x128 MAC units = 4 array blocks of 64x64, each block =
7 subarrays (9x64) + 1 LRMU (1x64). Global bridge logics fuse blocks into
bigger arrays; local bridges connect subarrays/LRMU inside a block.

These abstractions are shared by three consumers:
  * perfmodel/   — cycle model picks a FusionPlan per workload (Fig 8 e-h),
  * tenancy/     — the mesh-level analogue fissions a device mesh per tenant,
  * kernels/grouped_matmul — the Pallas grid is partitioned like array blocks.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "BLOCK", "ARRAY_BLOCKS", "SUBARRAY_ROWS", "SUBARRAYS_PER_BLOCK",
    "FusedArray", "FusionPlan", "enumerate_fusion_plans", "plan_for_tenants",
    "precision_morph",
]

BLOCK = 64                 # array block is 64x64 MACs
ARRAY_BLOCKS = 4           # blocks 0..3, arranged 2x2: [[0, 1], [2, 3]]
SUBARRAY_ROWS = 9          # subarray is 9x64
SUBARRAYS_PER_BLOCK = 7    # 7 subarrays + 1 LRMU row = 64 rows

# 2x2 placement of the blocks (row, col) — fusions must be contiguous rectangles.
_BLOCK_POS = {0: (0, 0), 1: (0, 1), 2: (1, 0), 3: (1, 1)}


@dataclasses.dataclass(frozen=True)
class FusedArray:
    """A rectangle of fused array blocks acting as one (rows x cols) MAC array."""
    blocks: Tuple[int, ...]
    rows: int
    cols: int

    @property
    def n_macs(self) -> int:
        return self.rows * self.cols


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    """A partition of the 4 array blocks into fused rectangles."""
    arrays: Tuple[FusedArray, ...]

    @property
    def n_partitions(self) -> int:
        return len(self.arrays)

    def describe(self) -> str:
        return " + ".join(f"{a.rows}x{a.cols}" for a in self.arrays)


def _rect_of(blocks: Sequence[int]) -> Optional[Tuple[int, int]]:
    """If `blocks` form a contiguous rectangle in the 2x2 grid, return
    (rows, cols) in units of BLOCK, else None."""
    pos = [_BLOCK_POS[b] for b in blocks]
    rs = {r for r, _ in pos}
    cs = {c for _, c in pos}
    if len(pos) != len(rs) * len(cs):
        return None
    want = {(r, c) for r in rs for c in cs}
    if set(pos) != want:
        return None
    return len(rs), len(cs)


def enumerate_fusion_plans() -> List[FusionPlan]:
    """All legal fuse/fission configurations of the 4 blocks (Fig 8 e-h +
    their symmetric variants)."""
    plans = []
    ids = list(range(ARRAY_BLOCKS))

    def partitions(rest: Tuple[int, ...]):
        if not rest:
            yield []
            return
        first = rest[0]
        others = rest[1:]
        for r in range(len(others) + 1):
            for combo in itertools.combinations(others, r):
                group = (first,) + combo
                remaining = tuple(x for x in others if x not in combo)
                for tail in partitions(remaining):
                    yield [group] + tail

    seen = set()
    for part in partitions(tuple(ids)):
        arrays = []
        ok = True
        for group in part:
            rect = _rect_of(group)
            if rect is None:
                ok = False
                break
            arrays.append(FusedArray(tuple(sorted(group)),
                                     rect[0] * BLOCK, rect[1] * BLOCK))
        if not ok:
            continue
        key = tuple(sorted((a.blocks for a in arrays)))
        if key in seen:
            continue
        seen.add(key)
        plans.append(FusionPlan(tuple(sorted(arrays, key=lambda a: a.blocks))))
    return plans


def precision_morph(rows: int, cols: int, fmt_name: str) -> Tuple[int, int]:
    """Throughput morphing: in FP8/INT4 modes each multiplier yields 4 results,
    so an RxC array acts as 2Rx2C (Table III: 128x128 -> 256x256)."""
    low = fmt_name in ("fp8a", "fp8b", "int4", "uint4")
    f = 2 if low else 1
    return rows * f, cols * f


def plan_for_tenants(tenant_shapes: Sequence[Tuple[int, int]],
                     fmt_name: str = "bf16") -> Tuple[FusionPlan, Dict[int, int]]:
    """Pick the fusion plan minimizing total tile count for the tenants.

    tenant_shapes: per-tenant (S_R, S_C) — the stationary (weight) matrix dims
    it needs. Returns (plan, assignment tenant_idx -> partition idx). Tenants
    share partitions round-robin if there are more tenants than partitions.
    """
    best = None
    for plan in enumerate_fusion_plans():
        if len(tenant_shapes) > 1 and plan.n_partitions < min(len(tenant_shapes), 2):
            continue
        cost, assign = _assign_cost(tenant_shapes, plan, fmt_name)
        if best is None or cost < best[0]:
            best = (cost, plan, assign)
    assert best is not None
    return best[1], best[2]


def _assign_cost(tenant_shapes, plan: FusionPlan, fmt_name: str):
    """Greedy: each tenant picks the partition minimizing its own tile count;
    cost = sum of per-tenant ceil-tile products (proxy for Eq. 1 latency)."""
    import math
    assign = {}
    loads = [0.0] * plan.n_partitions
    for t, (sr, sc) in enumerate(tenant_shapes):
        best_p, best_c = 0, None
        for p, arr in enumerate(plan.arrays):
            r, c = precision_morph(arr.rows, arr.cols, fmt_name)
            tiles = math.ceil(sr / r) * math.ceil(sc / c)
            # Eq. (1)-shaped proxy: pipeline fill + tiles, plus current load
            est = (2 * sr + sc - 2) * tiles + loads[p]
            if best_c is None or est < best_c:
                best_p, best_c = p, est
        assign[t] = best_p
        loads[best_p] += best_c
    return sum(loads), assign
