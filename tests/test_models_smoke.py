"""Per-architecture smoke tests: reduced config, one forward + one train step
+ one decode step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import (decode_step, forward, init_caches, init_params,
                          loss_fn)

B, L = 2, 32


def make_batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, L), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, L), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, jax.random.key(1))
    logits, aux = jax.jit(
        lambda p, b: forward(p, b["tokens"], cfg,
                             prefix_embeds=b.get("patch_embeds"),
                             frames=b.get("frames")))(params, batch)
    assert logits.shape == (B, L, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_descends(arch):
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, jax.random.key(1))

    @jax.jit
    def step(p, b):
        (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b, cfg)
        p = jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g)
        return p, l

    p1, l1 = step(params, batch)
    _, l2 = step(p1, batch)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    assert float(l2) < float(l1), f"{arch}: loss did not decrease ({l1}->{l2})"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    caches = init_caches(cfg, batch=B, max_len=64)
    memory = None
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.key(2),
                                   (B, cfg.frontend_len, cfg.d_model))
        # encode once (prefill of the audio memory)
        from repro.models.transformer import _block_apply, _sinusoid
        from repro.models.layers import apply_norm
        mem = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
        for i in range(cfg.encoder_layers):
            p_i = jax.tree.map(lambda a: a[i], params["encoder"])
            mem, _, _ = _block_apply("enc", p_i, mem, cfg)
        memory = apply_norm(cfg.norm, params["enc_norm"], mem)
    token = jnp.zeros((B, 1), jnp.int32)
    logits, caches = jax.jit(
        lambda p, c, t: decode_step(p, c, t, cfg, memory=memory))(
            params, caches, token)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # a second step must advance cache positions
    logits2, caches2 = jax.jit(
        lambda p, c, t: decode_step(p, c, t, cfg, memory=memory))(
            params, caches, token)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["zamba2_2p7b", "xlstm_1p3b", "qwen2_1p5b"])
def test_prefill_decode_equivalence(arch):
    """Teacher-forced decode must reproduce the full-sequence forward —
    the cache path and the parallel path are the same function."""
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(3), (1, 12), 0, cfg.vocab)
    full, _ = forward(params, tokens, cfg)
    # f32 caches: this test checks math equivalence, not bf16 cache rounding
    caches = init_caches(cfg, batch=1, max_len=16, dtype=jnp.float32)
    outs = []
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    for t in range(tokens.shape[1]):
        logits, caches = step(params, caches, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3,
                               atol=2e-3)


def test_shared_attention_is_shared():
    """zamba2: the shared_attn block must hold exactly ONE weight copy."""
    cfg = get_smoke("zamba2_2p7b")
    params = init_params(jax.random.key(0), cfg)
    seg = params["segments"][0]
    shared_keys = [k for k in seg if k.endswith("shared_attn")]
    assert len(shared_keys) == 1
    w = seg[shared_keys[0]]["attn"]["q"]["w"]
    assert w.ndim == 2      # un-stacked: one copy for all invocations
    mamba_keys = [k for k in seg if k.endswith("mamba")]
    assert seg[mamba_keys[0]]["mamba"]["in_proj"]["w"].ndim == 3  # stacked


def test_param_counts_full_configs():
    """Full-config param counts must be in the right ballpark (N for the
    roofline's MODEL_FLOPS = 6*N*D)."""
    from repro.configs import get_config
    from repro.models.transformer import init_params as ip
    expectations = {
        "olmo_1b": (0.9e9, 1.6e9),
        "qwen2_1p5b": (1.2e9, 2.0e9),
        # our mLSTM uses dense (not block-diagonal) qkv projections —
        # documented deviation in configs/xlstm_1p3b.py; params land at 3.6B
        "xlstm_1p3b": (3.0e9, 4.2e9),
        "zamba2_2p7b": (2.0e9, 3.4e9),
        "olmoe_1b_7b": (6.0e9, 8.0e9),
    }
    for arch, (lo, hi) in expectations.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda k: ip(k, cfg), jax.random.key(0))
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
