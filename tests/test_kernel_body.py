"""Kernel-body checker (KB4xx): every code must fire on a seeded toy
kernel and stay quiet on its fixed counterpart; the current tree must pass
the full sweep clean with zero KB430 coverage gaps."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import (check_body, check_kernel_bodies,
                            stratified_grid_points)
from repro.api import BlockContract, KernelRegistry, LaunchContract

M, N = 64, 128                                 # toy output array
BM = 32                                        # toy block rows


def _out_block(index_map, revisits=()):
    return BlockContract("o", (M, N), (BM, N), index_map, is_output=True,
                         revisits=revisits)


def _launch(kernel, *, grid, blocks, nsp=0, scalars=(), scratch_shapes=(),
            out_dtype=jnp.float32):
    """LaunchContract whose body assembles the matching real pallas_call."""
    outs = [b for b in blocks if b.is_output]
    assert len(outs) == 1

    def body():
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=nsp,
                grid=grid,
                in_specs=[pl.BlockSpec(b.block_shape, b.index_map)
                          for b in blocks if not b.is_output],
                out_specs=pl.BlockSpec(outs[0].block_shape,
                                       outs[0].index_map),
                scratch_shapes=list(scratch_shapes)),
            out_shape=jax.ShapeDtypeStruct(outs[0].array_shape, out_dtype),
        )(*[np.asarray(s) for s in scalars],
          *[jnp.zeros(b.array_shape,
                      jnp.int8 if b.quant else jnp.float32)
            for b in blocks if not b.is_output])

    return LaunchContract(grid=grid, blocks=tuple(blocks),
                          num_scalar_prefetch=nsp,
                          scalars=tuple(np.asarray(s) for s in scalars),
                          body=body)


# =========================================================== KB400 / KB401
def _store_row(o_ref, row):
    o_ref[row, :] = jnp.zeros((N,), jnp.float32)


def test_unguarded_oob_dynamic_store_fires_kb400():
    # grid (4,) but the block has only BM rows; all points write block 0
    # (declared revisits) so only the in-body index is at fault
    def kernel(o_ref):
        _store_row(o_ref, pl.program_id(0) * BM)

    lc = _launch(kernel, grid=(4,),
                 blocks=[_out_block(lambda i: (0, 0), revisits=(0,))])
    rep = check_body(lc, "t")
    assert [f.code for f in rep.findings] == ["KB400"], rep.render()


def test_in_bounds_dynamic_store_passes():
    def kernel(o_ref):
        _store_row(o_ref, pl.program_id(0) % BM)

    lc = _launch(kernel, grid=(4,),
                 blocks=[_out_block(lambda i: (0, 0), revisits=(0,))])
    rep = check_body(lc, "t")
    assert rep.ok() and not rep.findings, rep.render()


def test_noncovering_when_guard_fires_kb401():
    # i in [0, 3]; the guard only proves i < BM + 1 — one row past the block
    def kernel(o_ref):
        i = pl.program_id(0) * BM

        @pl.when(i < BM + 1)
        def _():
            _store_row(o_ref, i)

    lc = _launch(kernel, grid=(4,),
                 blocks=[_out_block(lambda i: (0, 0), revisits=(0,))])
    rep = check_body(lc, "t")
    assert [f.code for f in rep.findings] == ["KB401"], rep.render()


def test_covering_when_guard_passes():
    def kernel(o_ref):
        i = pl.program_id(0) * BM

        @pl.when(i < BM)
        def _():
            _store_row(o_ref, i)

    lc = _launch(kernel, grid=(4,),
                 blocks=[_out_block(lambda i: (0, 0), revisits=(0,))])
    rep = check_body(lc, "t")
    assert rep.ok() and not rep.findings, rep.render()


def test_prefetch_scalar_bounds_prove_dynamic_index():
    """A pos-vector load indexes the block: provable only because the
    checker reads the concrete prefetch operand's min/max."""
    def kernel(pos_ref, o_ref):
        _store_row(o_ref, pos_ref[pl.program_id(0)])

    good = _launch(kernel, grid=(4,), nsp=1,
                   scalars=(np.asarray([0, 5, 17, BM - 1], np.int32),),
                   blocks=[_out_block(lambda i, p: (0, 0), revisits=(0,))])
    assert check_body(good, "t").ok()

    bad = _launch(kernel, grid=(4,), nsp=1,
                  scalars=(np.asarray([0, 5, 17, BM], np.int32),),
                  blocks=[_out_block(lambda i, p: (0, 0), revisits=(0,))])
    rep = check_body(bad, "t")
    assert [f.code for f in rep.findings] == ["KB400"], rep.render()


# =========================================================== KB410 / KB411
def _const_store(o_ref):
    o_ref[...] = jnp.zeros((BM, N), jnp.float32)


def test_undeclared_output_revisit_fires_kb410():
    lc = _launch(_const_store, grid=(4,),
                 blocks=[_out_block(lambda i: (i // 2, 0))])
    rep = check_body(lc, "t")
    assert [f.code for f in rep.errors] == ["KB410"], rep.render()


def test_declared_revisit_dim_passes():
    lc = _launch(_const_store, grid=(4,),
                 blocks=[_out_block(lambda i: (i // 2, 0), revisits=(0,))])
    rep = check_body(lc, "t")
    assert rep.ok() and not rep.findings, rep.render()


def test_race_detector_separates_reduction_dim_from_racing_dim():
    """2-D grid: dim 1 is a declared K-style loop, dim 0 collides
    undeclared — the finding must name dim 0 only."""
    lc = LaunchContract(
        grid=(2, 3),
        blocks=(BlockContract("o", (M, N), (BM, N), lambda i, k: (0, 0),
                              is_output=True, revisits=(1,)),))
    rep = check_body(lc, "t")
    assert [f.code for f in rep.errors] == ["KB410"]
    assert "dim(s) [0]" in rep.errors[0].message


def test_stale_revisits_declaration_fires_kb411():
    # bijective map: dim 0 never revisits although declared and grid > 1
    lc = _launch(_const_store, grid=(2,),
                 blocks=[_out_block(lambda i: (i, 0), revisits=(0,))])
    rep = check_body(lc, "t")
    assert [f.code for f in rep.findings] == ["KB411"], rep.render()
    assert rep.ok()                            # warning severity


def test_bijective_output_map_without_revisits_passes():
    lc = _launch(_const_store, grid=(2,),
                 blocks=[_out_block(lambda i: (i, 0))])
    rep = check_body(lc, "t")
    assert rep.ok() and not rep.findings, rep.render()


# =========================================================== KB420 (dequant)
def _quant_blocks():
    return [
        BlockContract("codes", (M, N), (BM, N), lambda i: (i, 0),
                      dtype_bytes=1, quant="int8"),
        BlockContract("scale", (M, 1), (BM, 1), lambda i: (i, 0),
                      scale_for="codes"),
        _out_block(lambda i: (i, 0)),
    ]


def test_unscaled_dequant_store_fires_kb420():
    def kernel(c_ref, s_ref, o_ref):
        o_ref[...] = c_ref[...].astype(jnp.float32)

    rep = check_body(_launch(kernel, grid=(2,), blocks=_quant_blocks()), "t")
    assert [f.code for f in rep.findings] == ["KB420"], rep.render()


def test_scaled_dequant_store_passes():
    def kernel(c_ref, s_ref, o_ref):
        o_ref[...] = c_ref[...].astype(jnp.float32) * s_ref[...]

    rep = check_body(_launch(kernel, grid=(2,), blocks=_quant_blocks()), "t")
    assert rep.ok() and not rep.findings, rep.render()


def test_raw_codes_store_fires_kb420():
    def kernel(c_ref, s_ref, o_ref):
        o_ref[...] = c_ref[...] + jnp.zeros((BM, N), jnp.int8)

    rep = check_body(_launch(kernel, grid=(2,), blocks=_quant_blocks(),
                             out_dtype=jnp.int8), "t")
    assert [f.code for f in rep.findings] == ["KB420"], rep.render()
    assert "raw quantized codes" in rep.findings[0].message


def test_dequant_taint_round_trips_through_vmem_scratch():
    """The int8-matmul pattern: codes land in a scratch accumulator first;
    the taint must survive the ref round-trip so the unscaled store still
    fires — and the scale multiply on the way out must clear it."""
    def unscaled(c_ref, s_ref, o_ref, acc_ref):
        acc_ref[...] = c_ref[...].astype(jnp.float32)
        o_ref[...] = acc_ref[...]

    def scaled(c_ref, s_ref, o_ref, acc_ref):
        acc_ref[...] = c_ref[...].astype(jnp.float32)
        o_ref[...] = acc_ref[...] * s_ref[...]

    scratch = (pltpu.VMEM((BM, N), jnp.float32),)
    bad = _launch(unscaled, grid=(2,), blocks=_quant_blocks(),
                  scratch_shapes=scratch)
    assert [f.code for f in check_body(bad, "t").findings] == ["KB420"]
    ok = _launch(scaled, grid=(2,), blocks=_quant_blocks(),
                 scratch_shapes=scratch)
    assert not check_body(ok, "t").findings


# ================================================ KB421 (declaration audit)
def _decl_launch(*blocks):
    return LaunchContract(grid=(1,), blocks=tuple(blocks))


def test_unknown_quant_format_fires_kb421():
    rep = check_body(_decl_launch(
        BlockContract("c", (M, N), (M, N), lambda i: (0, 0), quant="fp3"),
        BlockContract("s", (M, 1), (M, 1), lambda i: (0, 0),
                      scale_for="c")), "t")
    assert [f.code for f in rep.errors] == ["KB421"]
    assert "fp3" in rep.errors[0].message


def test_quant_block_without_scale_operand_fires_kb421():
    rep = check_body(_decl_launch(
        BlockContract("c", (M, N), (M, N), lambda i: (0, 0),
                      quant="int8")), "t")
    assert [f.code for f in rep.errors] == ["KB421"]
    assert "no scale operand" in rep.errors[0].message


def test_dangling_scale_for_fires_kb421():
    rep = check_body(_decl_launch(
        BlockContract("s", (M, 1), (M, 1), lambda i: (0, 0),
                      scale_for="ghost")), "t")
    assert [f.code for f in rep.errors] == ["KB421"]


def test_scale_for_unquantized_block_fires_kb421():
    rep = check_body(_decl_launch(
        BlockContract("c", (M, N), (M, N), lambda i: (0, 0)),
        BlockContract("s", (M, 1), (M, 1), lambda i: (0, 0),
                      scale_for="c")), "t")
    assert [f.code for f in rep.errors] == ["KB421"]
    assert "no quant= format" in rep.errors[0].message


def test_bad_scale_plane_length_fires_kb421():
    rep = check_body(_decl_launch(
        BlockContract("c", (M, N), (M, N), lambda i: (0, 0), quant="int8"),
        BlockContract("s", (M, 7), (M, 7), lambda i: (0, 0),
                      scale_for="c")), "t")
    assert [f.code for f in rep.errors] == ["KB421"]
    assert "neither 1 nor" in rep.errors[0].message


# ================================================== KB430 / KB431 coverage
def _fake_reg():
    reg = KernelRegistry()
    reg._loaded = True
    return reg


def test_contract_without_body_fires_kb430():
    reg = _fake_reg()

    @reg.register("op", "pallas")
    def impl(*, policy):
        pass

    @reg.register_contract("op", "pallas", cases=({},))
    def contract(case, policy):
        return LaunchContract(grid=(1,), blocks=(
            _out_block(lambda i: (0, 0)),))

    rep = check_kernel_bodies(reg)
    assert [f.code for f in rep.findings] == ["KB430"]
    assert rep.ok()                            # warning: strict still passes


def test_raising_body_fires_kb431():
    def body():
        raise RuntimeError("boom")

    lc = LaunchContract(grid=(1,), blocks=(_out_block(lambda i: (0, 0)),),
                        body=body)
    rep = check_body(lc, "t")
    assert [f.code for f in rep.errors] == ["KB431"]
    assert "boom" in rep.errors[0].message


def test_grid_drift_between_body_and_contract_fires_kb431():
    lc = _launch(_const_store, grid=(2,),
                 blocks=[_out_block(lambda i: (i, 0))])
    drifted = LaunchContract(grid=(4,), blocks=lc.blocks, body=lc.body)
    rep = check_body(drifted, "t")
    assert any(f.code == "KB431" and "grid" in f.message
               for f in rep.errors), rep.render()


def test_block_shape_drift_fires_kb431():
    lc = _launch(_const_store, grid=(2,),
                 blocks=[_out_block(lambda i: (i, 0))])
    drifted = LaunchContract(
        grid=(2,),
        blocks=(BlockContract("o", (M, N), (BM, N // 2), lambda i: (i, 0),
                              is_output=True),),
        body=lc.body)
    rep = check_body(drifted, "t")
    assert any(f.code == "KB431" and "drifted" in f.message
               for f in rep.errors), rep.render()


def test_noncontiguous_output_blocks_fire_kb431():
    lc = LaunchContract(grid=(1,), blocks=(
        _out_block(lambda i: (0, 0)),
        BlockContract("x", (M, N), (M, N), lambda i: (0, 0))))
    rep = check_body(lc, "t")
    assert [f.code for f in rep.errors] == ["KB431"]
    assert "contiguous suffix" in rep.errors[0].message


# ======================================================== stratified sample
def test_stratified_sample_keeps_first_and_last_block_every_dim():
    points, truncated = stratified_grid_points((100000, 3), 1000)
    assert truncated
    pts = list(points)
    assert len(pts) <= 1000
    dim0 = {p[0] for p in pts}
    assert {0, 99999} <= dim0                  # endpoints always sampled
    assert {p[1] for p in pts} == {0, 1, 2}    # small dims stay exhaustive


def test_small_grid_is_swept_exhaustively():
    points, truncated = stratified_grid_points((4, 4), 1000)
    assert not truncated and len(list(points)) == 16


# ====================================================== current-tree gates
def test_current_tree_kernel_bodies_pass_strict():
    rep = check_kernel_bodies()
    assert rep.ok(), rep.render()
    assert not rep.findings, rep.render()


def test_current_tree_has_zero_kb430_coverage_gaps():
    rep = check_kernel_bodies()
    assert not rep.by_code("KB430"), rep.render()
