"""Varlen flash-prefill kernel parity suite: the Pallas prefill kernel
(interpret mode) vs the mha_ref oracle over GQA ratios, window/softcap,
mixed per-row (position, length) pairs incl. zero-length rows, the fused
int8-KV path (bit-exact vs dequant-then-dense), q-block/KV-block pruning
accounting, the pallas-prefill routing rules, and end-to-end CHUNKED
admission: chunked greedy serving byte-identical to one-shot admission
across dense/GQA/window/softcap/int8-KV engines, incl. admit-while-decoding
traffic, plus warmup() and the chunk-call stats accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_smoke
from repro.kernels.flash_attention import (flash_prefill_pallas,
                                           flash_prefill_quant_pallas,
                                           mha_ref, prefill_block_visits)
from repro.models import init_params
from repro.models.attention import _dq8, _q8
from repro.serving import Request, ServingEngine

RNG = np.random.RandomState(17)
MAX_LEN = 256
LQ = 20                                   # chunk width under test


def randn(*shape, scale=1.0):
    return jnp.asarray(RNG.randn(*shape).astype(np.float32) * scale)


def qkv(b, hq, hkv, lq, lk, d):
    return (randn(b, hq, lq, d, scale=0.5), randn(b, hkv, lk, d, scale=0.5),
            randn(b, hkv, lk, d))


# mixed per-row (cache position, valid chunk length): a fresh full chunk, a
# short tail chunk mid-cache, an idle row (lengths == 0), and a chunk ending
# exactly at the last cache slot
MIXED_POS = [0, 37, 128, MAX_LEN - LQ]
MIXED_LEN = [LQ, 5, 0, LQ]


def assert_valid_close(got, ref, lens):
    """Rows compare only over their valid chunk prefix; the pad tail of the
    kernel output must be exact zeros (deterministic, never consumed)."""
    got, ref = np.asarray(got), np.asarray(ref)
    for b, ln in enumerate(np.asarray(lens)):
        np.testing.assert_allclose(got[b, :, :ln], ref[b, :, :ln],
                                   rtol=2e-5, atol=2e-5)
        assert not got[b, :, ln:].any(), f"row {b}: pad tail not zero"


# ============================================================ kernel parity
@pytest.mark.parametrize("group", [1, 2, 4])
def test_prefill_varlen_gqa_vs_ref(group):
    hkv = 2
    q, k, v = qkv(4, hkv * group, hkv, LQ, MAX_LEN, 64)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    lens = jnp.asarray(MIXED_LEN, jnp.int32)
    ref = mha_ref(q, k, v, causal=True, offset=pos)
    got = flash_prefill_pallas(q, k, v, pos=pos, lengths=lens, bq=8,
                               bkv=64, interpret=True)
    assert_valid_close(got, ref, lens)


@pytest.mark.parametrize("window,softcap", [(None, None), (40, None),
                                            (None, 30.0), (40, 30.0)])
def test_prefill_window_softcap_vs_ref(window, softcap):
    q, k, v = qkv(4, 8, 2, LQ, MAX_LEN, 64)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    lens = jnp.asarray(MIXED_LEN, jnp.int32)
    ref = mha_ref(q, k, v, causal=True, offset=pos, window=window,
                  softcap=softcap)
    got = flash_prefill_pallas(q, k, v, pos=pos, lengths=lens, bq=8, bkv=64,
                               interpret=True, window=window, softcap=softcap)
    assert_valid_close(got, ref, lens)


def test_prefill_default_lengths_fully_valid():
    """lengths=None means every chunk position is real — full parity, and a
    scalar pos broadcasts like the decode kernel's."""
    q, k, v = qkv(3, 6, 3, LQ, MAX_LEN, 64)
    ref = mha_ref(q, k, v, causal=True, offset=100)
    got = flash_prefill_pallas(q, k, v, pos=100, bq=8, bkv=64,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_prefill_unaligned_shapes():
    """Lk not a bkv multiple and Lq not a bq multiple: both pad tails must
    stay invisible."""
    q, k, v = qkv(2, 4, 2, 13, 200, 64)
    pos = jnp.asarray([187, 64], jnp.int32)
    lens = jnp.asarray([13, 7], jnp.int32)
    ref = mha_ref(q, k, v, causal=True, offset=pos)
    got = flash_prefill_pallas(q, k, v, pos=pos, lengths=lens, bq=8,
                               bkv=128, interpret=True)
    assert got.shape == q.shape
    assert_valid_close(got, ref, lens)


# ============================================================== int8-KV path
def test_prefill_int8_fused_bit_exact_vs_dequant():
    """The fused in-VMEM dequant must be BIT-IDENTICAL to materializing the
    dequantized cache and running the dense kernel (it rounds through the
    q dtype exactly like models.attention._dq8)."""
    q, k, v = qkv(4, 8, 2, LQ, MAX_LEN, 64)
    kc, ks = _q8(k)
    vc, vs = _q8(v)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    lens = jnp.asarray(MIXED_LEN, jnp.int32)
    for kw in (dict(), dict(window=40, softcap=30.0)):
        fused = flash_prefill_quant_pallas(q, kc, ks, vc, vs, pos=pos,
                                           lengths=lens, bq=8, bkv=64,
                                           interpret=True, **kw)
        dense = flash_prefill_pallas(q, _dq8(kc, ks, q.dtype),
                                     _dq8(vc, vs, q.dtype), pos=pos,
                                     lengths=lens, bq=8, bkv=64,
                                     interpret=True, **kw)
        assert jnp.array_equal(fused, dense), kw
        assert_valid_close(fused, mha_ref(q, _dq8(kc, ks, q.dtype),
                                          _dq8(vc, vs, q.dtype), causal=True,
                                          offset=pos, **kw), lens)


# ============================================================ block pruning
def test_prefill_block_pruning_visits():
    """The kernel must VISIT only each row's frontier blocks: q-blocks past
    the row's valid length are skipped outright, and each surviving q-block
    scans KV only up to its own causal frontier — work scales with REAL
    prompt tokens, not the chunk width x max_len."""
    b, hkv, bq, bkv = 4, 2, 8, 64
    q, k, v = qkv(b, 4, hkv, LQ, MAX_LEN, 64)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    lens = jnp.asarray(MIXED_LEN, jnp.int32)
    out, vis = flash_prefill_pallas(q, k, v, pos=pos, lengths=lens, bq=bq,
                                    bkv=bkv, interpret=True,
                                    debug_visits=True)
    vis = np.asarray(vis).reshape(b, hkv, -1)           # (B, Hkv, nq*nk)
    # per-row expectation straight from the frontier arithmetic, identical
    # across the row's kv-heads
    for row in range(b):
        exp_row, _ = prefill_block_visits(pos[row:row + 1],
                                          lens[row:row + 1], LQ, MAX_LEN,
                                          bq=bq, bkv=bkv)
        for h in range(hkv):
            assert int(vis[row, h].sum()) == exp_row, (row, h)
    visited, total = prefill_block_visits(pos, lens, LQ, MAX_LEN, bq=bq,
                                          bkv=bkv)
    assert visited == int(vis.sum()) // hkv
    assert int(vis.sum()) < total * hkv       # pruning actually happened
    # the idle row (lengths == 0) does zero block visits
    assert int(vis[2].sum()) == 0
    # pruned output still exact over the valid region
    assert_valid_close(out, mha_ref(q, k, v, causal=True, offset=pos), lens)


def test_prefill_window_prunes_old_blocks():
    """A sliding window adds a LOWER bound per q-block: a chunk landing deep
    in a long-resident row visits only the window's blocks."""
    b, hkv, bq, bkv, window = 2, 2, 8, 32, 40
    q, k, v = qkv(b, 4, hkv, LQ, MAX_LEN, 64)
    pos = jnp.asarray([MAX_LEN - LQ, 0], jnp.int32)
    lens = jnp.asarray([LQ, LQ], jnp.int32)
    out, vis = flash_prefill_pallas(q, k, v, pos=pos, lengths=lens, bq=bq,
                                    bkv=bkv, window=window, interpret=True,
                                    debug_visits=True)
    measured = int(np.asarray(vis).sum())
    visited, total = prefill_block_visits(pos, lens, LQ, MAX_LEN, bq=bq,
                                          bkv=bkv, window=window)
    no_win, _ = prefill_block_visits(pos, lens, LQ, MAX_LEN, bq=bq, bkv=bkv)
    assert measured == visited * hkv
    assert visited < no_win                   # the lower bound pruned blocks
    assert_valid_close(out, mha_ref(q, k, v, causal=True, offset=pos,
                                    window=window), lens)


# ================================================================== routing
def test_prefill_route_rules():
    pallas = api.ExecutionPolicy(backend="pallas")
    route = api.ops.attention_route
    # causal multi-token vector-offset chunks (what chunked admission
    # launches) hit the varlen prefill kernel — dense or quantized
    for lq in (2, 8, 32, 200):
        assert route(lq=lq, policy=pallas, offset_ndim=1) == "pallas-prefill"
    assert route(lq=32, policy=pallas, offset_ndim=1,
                 quantized=True) == "pallas-prefill"
    # single-token decode keeps the decode kernel
    assert route(lq=1, policy=pallas, offset_ndim=1) == "pallas-decode"
    # non-causal and ref/default backends never hit it
    assert route(lq=32, policy=pallas, offset_ndim=1, causal=False) == "ref"
    assert route(lq=32, offset_ndim=1, backend="ref") == "ref"
    assert route(lq=32, offset_ndim=1) == "ref"


def test_api_attention_prefill_dispatch_matches_ref():
    """api.ops.attention under backend='pallas' must dispatch varlen chunk
    shapes to the prefill kernel and agree with the ref backend on the valid
    region — dense and int8-KV."""
    q, k, v = qkv(4, 8, 4, LQ, MAX_LEN, 64)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    lens = jnp.asarray(MIXED_LEN, jnp.int32)
    ref = api.ops.attention(q, k, v, offset=pos, backend="ref")
    got = api.ops.attention(q, k, v, offset=pos, lengths=lens, bq=8,
                            backend="pallas", interpret=True)
    assert_valid_close(got, ref, lens)

    kc, ks = _q8(k)
    vc, vs = _q8(v)
    refq = api.ops.attention(q, kc, vc, offset=pos, k_scale=ks, v_scale=vs,
                             backend="ref")
    gotq = api.ops.attention(q, kc, vc, offset=pos, lengths=lens, bq=8,
                             k_scale=ks, v_scale=vs, backend="pallas",
                             interpret=True)
    assert_valid_close(gotq, refq, lens)


# ==================================================== chunked admission e2e
PALLAS_POLICY = api.ExecutionPolicy(backend="pallas", interpret=True)


def _serve(cfg, params, spec, policy, *, chunk, slots=2, max_len=64):
    eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                        policy=policy, prefill_chunk=chunk)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    done = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    return [done[i] for i in range(len(spec))], eng


def _spec(cfg, lens, outs, seed):
    rng = np.random.RandomState(seed)
    return [(rng.randint(1, cfg.vocab, l).astype(np.int32), m)
            for l, m in zip(lens, outs)]


@pytest.mark.parametrize("arch,policy", [
    ("qwen2_1p5b", None),                    # dense GQA, ref path
    ("qwen2_1p5b", PALLAS_POLICY),           # dense GQA, varlen kernel
    ("gemma2_27b", PALLAS_POLICY),           # sliding window + softcap
])
def test_chunked_vs_oneshot_byte_identical(arch, policy):
    """Greedy outputs of chunked admission (chunk smaller than the prompts,
    not dividing them) must be byte-identical to one-shot admission (chunk
    covering every prompt in a single launch)."""
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(21), cfg)
    spec = _spec(cfg, [3, 20, 5, 17], [6, 4, 8, 5], seed=21)
    want, one = _serve(cfg, params, spec, policy, chunk=32)
    got, chk = _serve(cfg, params, spec, policy, chunk=5)
    assert chk.stats.prefill_chunk_calls > one.stats.prefill_chunk_calls
    if policy is not None:
        assert chk.prefill_route() == "pallas-prefill"
        assert chk.decode_route() == "pallas-decode"
    assert got == want


def test_chunked_int8_kv_byte_identical():
    """The fused int8-KV prefill path end to end: QuantKVCache codes+scales
    reach the varlen kernel unmaterialized, chunked == one-shot == ref."""
    cfg = dataclasses.replace(get_smoke("qwen2_1p5b"), kv_quant=True)
    params = init_params(jax.random.key(22), cfg)
    spec = _spec(cfg, [4, 15, 7], [5, 3, 6], seed=22)
    want, _ = _serve(cfg, params, spec, None, chunk=32)
    got, eng = _serve(cfg, params, spec, PALLAS_POLICY, chunk=6)
    assert eng.prefill_route() == "pallas-prefill"
    assert got == want


@pytest.mark.parametrize("policy", [None, PALLAS_POLICY])
def test_admit_while_decoding_interleaved(policy):
    """A LONG prompt admitted while another slot is mid-generation: the
    resident slot must keep emitting DURING the admission (the head-of-line
    stall chunking removes), and both requests reproduce their solo
    outputs."""
    cfg = get_smoke("qwen2_1p5b")
    params = init_params(jax.random.key(23), cfg)
    rng = np.random.RandomState(23)
    short = rng.randint(1, cfg.vocab, 4).astype(np.int32)
    long_ = rng.randint(1, cfg.vocab, 40).astype(np.int32)

    def solo(p, m):
        out, _ = _serve(cfg, params, [(p, m)], policy, chunk=8, slots=1)
        return out[0]

    want_short, want_long = solo(short, 12), solo(long_, 4)

    eng = ServingEngine(cfg, params, slots=2, max_len=64, policy=policy,
                        prefill_chunk=8)
    eng.submit(Request(0, short, max_new_tokens=12))
    eng.step()                                # rid 0 admitted + first tokens
    generated_before = len(eng._slot_req[0].out_tokens)
    eng.submit(Request(1, long_, max_new_tokens=4))
    # the 40-token prompt needs 5 chunk launches; drive exactly that many
    # steps and watch rid 0 generate through every one of them
    for _ in range(5):
        eng.step()
    occ = eng.occupancy()
    assert occ[0] is not None and occ[1] is not None
    # rid 0 advanced one token per step DURING rid 1's admission
    assert occ[0]["generated"] == generated_before + 5
    # rid 1 finished admission on the last chunk launch (first token) and
    # joined the same step's decode launch (second token)
    assert occ[1]["generated"] == 2
    done = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    assert done[0] == want_short and done[1] == want_long


def test_zamba2_merged_prefill_matches_solo():
    """Recurrent archs take the merged l=1 path: prefilling rows feed prompt
    tokens in the same launch decoding rows generate through — outputs stay
    byte-identical to solo serving."""
    cfg = get_smoke("zamba2_2p7b")
    params = init_params(jax.random.key(24), cfg)
    spec = _spec(cfg, [3, 12, 6], [4, 3, 5], seed=24)
    want = [_serve(cfg, params, [s], None, chunk=8, slots=1)[0][0]
            for s in spec]
    got, eng = _serve(cfg, params, spec, None, chunk=8)
    assert got == want
    # merged launches: no chunk-shaped calls, token steps counted instead
    assert eng.stats.prefill_chunk_calls == 0
    assert eng.stats.prefill_token_steps + eng.stats.decode_steps == \
        eng.stats.model_calls


# ============================================================ warmup + stats
def test_warmup_is_stateless_and_traces_once():
    """warmup() must leave every cache leaf bitwise intact, spend no stats,
    and pre-trace BOTH step shapes so serving adds no new compilations."""
    cfg = get_smoke("qwen2_1p5b")
    params = init_params(jax.random.key(25), cfg)
    eng = ServingEngine(cfg, params, slots=2, max_len=64, prefill_chunk=8)
    before = jax.tree.map(np.asarray, eng.caches)
    eng.warmup()
    after = jax.tree.map(np.asarray, eng.caches)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)
    assert eng.stats.model_calls == 0 and eng.stats.generated_tokens == 0
    n_traces = eng._step_fn._cache_size()
    assert n_traces == 2                      # chunk-shaped + decode-shaped
    # the fixed chunk shape means serving NEVER retraces: mixed prompt
    # lengths (the old pow2 ladder would have traced 3 widths here) reuse
    # the two warmed programs
    for rid, (p, m) in enumerate(_spec(cfg, [3, 9, 21], [3, 2, 2], seed=25)):
        eng.submit(Request(rid, p, max_new_tokens=m))
    eng.run_until_drained()
    assert eng._step_fn._cache_size() == n_traces
    # warmed engine output identical to an unwarmed twin
    twin = ServingEngine(cfg, params, slots=2, max_len=64, prefill_chunk=8)
    for rid, (p, m) in enumerate(_spec(cfg, [3, 9, 21], [3, 2, 2], seed=25)):
        twin.submit(Request(rid, p, max_new_tokens=m))
    twin.run_until_drained()
    assert {r.rid: r.out_tokens for r in eng.finished} == \
        {r.rid: r.out_tokens for r in twin.finished}


def test_prefill_chunk_calls_accounting():
    """EngineStats must count chunk launches distinctly: slots=1 serialises
    admissions, so the count is exactly sum(ceil(plen / chunk))."""
    cfg = get_smoke("qwen2_1p5b")
    params = init_params(jax.random.key(26), cfg)
    chunk = 4
    plens, outs = [5, 3, 9], [2, 1, 3]
    spec = _spec(cfg, plens, outs, seed=26)
    _, eng = _serve(cfg, params, spec, None, chunk=chunk, slots=1)
    expect = sum(-(-p // chunk) for p in plens)
    assert eng.stats.prefill_chunk_calls == expect
    assert eng.stats.prefill_tokens == sum(plens)
    assert eng.stats.model_calls == eng.stats.prefill_chunk_calls + \
        eng.stats.decode_steps
    assert eng.stats.generated_tokens == sum(outs)


def test_prefill_chunk_validation():
    cfg = get_smoke("qwen2_1p5b")
    params = init_params(jax.random.key(27), cfg)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(cfg, params, slots=1, max_len=32, prefill_chunk=0)
    # wider than the cache clamps (the default stays usable on small caches)
    eng = ServingEngine(cfg, params, slots=1, max_len=16, prefill_chunk=64)
    assert eng.prefill_chunk == 16
