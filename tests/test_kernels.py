"""Per-kernel allclose tests vs pure-jnp oracles: shape/dtype/mode sweeps
(interpret=True executes the kernel bodies on CPU)."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import common
from repro.kernels.aio_matmul import (aio_matmul, aio_matmul_codes,
                                      aio_matmul_ref, quantize_operands_ref)
from repro.kernels.aio_quant import aio_quant_ref, aio_quantize
from repro.kernels.depthwise import depthwise_conv, depthwise_ref
from repro.kernels.flash_attention import (chunked_attention,
                                           flash_attention_pallas, mha_ref)
from repro.kernels.grouped_matmul import (grouped_matmul, make_group_ids,
                                          morphable_multi_gemm)

RNG = np.random.RandomState(42)


def randn(*shape, scale=1.0):
    return jnp.asarray(RNG.randn(*shape).astype(np.float32) * scale)


# ======================================================================
# aio_matmul
# ======================================================================
@pytest.mark.parametrize("mode", ["bf16", "fp8a", "fp8b", "int8", "int4"])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128),
                                   (160, 200, 130), (64, 512, 96)])
def test_aio_matmul_modes_shapes(mode, shape):
    m, k, n = shape
    x, w = randn(m, k), randn(k, n)
    xq, wq, xs, ws = quantize_operands_ref(x, w, mode)
    ref = aio_matmul_ref(xq, wq, xs, ws, mode=mode)
    got = aio_matmul_codes(xq, wq, xs, ws, mode=mode)
    if mode in ("int8", "int4"):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5 * float(jnp.abs(ref).max()))


@pytest.mark.parametrize("mode", ["int8", "fp8a"])
def test_aio_matmul_dispatch_paths_agree(mode):
    x, w = randn(130, 140), randn(140, 150)
    plain = aio_matmul(x, w, mode=mode, prefer_pallas=False)
    with common.use_pallas():
        pall = aio_matmul(x, w, mode=mode)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(pall),
                               rtol=2e-5, atol=1e-4)


def test_aio_matmul_quant_error_bounded():
    """Quantized matmul must track the f32 result within format error."""
    x, w = randn(128, 256, scale=0.5), randn(256, 128, scale=0.5)
    exact = np.asarray(x) @ np.asarray(w)
    out8 = np.asarray(aio_matmul(x, w, mode="int8", prefer_pallas=False))
    rel = np.abs(out8 - exact).max() / np.abs(exact).max()
    assert rel < 0.05, rel
    out4 = np.asarray(aio_matmul(x, w, mode="int4", prefer_pallas=False))
    rel4 = np.abs(out4 - exact).max() / np.abs(exact).max()
    assert rel4 < 0.5, rel4
    assert rel < rel4   # more bits, less error


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["int8", "fp8a", "int4"]),
       st.integers(1, 3), st.integers(1, 4), st.integers(1, 3))
def test_property_aio_matmul_random_shapes(mode, mi, ki, ni):
    m, k, n = mi * 64 + 7, ki * 64, ni * 64 + 3
    x, w = randn(m, k), randn(k, n)
    xq, wq, xs, ws = quantize_operands_ref(x, w, mode)
    ref = aio_matmul_ref(xq, wq, xs, ws, mode=mode)
    got = aio_matmul_codes(xq, wq, xs, ws, mode=mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5 * float(jnp.abs(ref).max() + 1))


# ======================================================================
# aio_quant
# ======================================================================
@pytest.mark.parametrize("fmt", ["fp8a", "fp8b", "int8", "int4"])
@pytest.mark.parametrize("shape", [(128, 128), (200, 300), (64, 500)])
def test_aio_quant_bit_exact(fmt, shape):
    x = randn(*shape, scale=13.0)
    rc, rs = aio_quant_ref(x, fmt_name=fmt)
    with common.use_pallas():
        pc, ps = aio_quantize(x, fmt_name=fmt)
    np.testing.assert_array_equal(np.asarray(rc).astype(np.uint8),
                                  np.asarray(pc).astype(np.uint8))
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(ps))


def test_aio_quant_scale_is_pow2():
    x = randn(128, 128, scale=100.0)
    _, s = aio_quantize(x, fmt_name="fp8a", prefer_pallas=True)
    l2 = np.log2(np.asarray(s))
    np.testing.assert_array_equal(l2, np.round(l2))


# ======================================================================
# grouped_matmul
# ======================================================================
def test_grouped_matmul_vs_loop():
    x = randn(512, 200)
    w = randn(4, 200, 130)
    sizes = [128, 256, 0, 128]
    with common.use_pallas():
        got = np.asarray(grouped_matmul(x, w, sizes))
    xs = np.asarray(x)
    ws = np.asarray(w)
    ref = np.concatenate([xs[:128] @ ws[0], xs[128:384] @ ws[1],
                          xs[384:] @ ws[3]])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)


def test_grouped_matmul_rejects_unaligned():
    with pytest.raises(ValueError):
        make_group_ids([100, 156], bm=128)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 2))
def test_property_grouped_matmul(g, ki, ni):
    k, n = ki * 128, ni * 128
    sizes = [128 * RNG.randint(0, 3) for _ in range(g)]
    t = sum(sizes)
    if t == 0:
        sizes[0] = 128
        t = 128
    x = randn(t, k)
    w = randn(g, k, n)
    with common.use_pallas():
        got = np.asarray(grouped_matmul(x, w, sizes))
    row = 0
    for gi, size in enumerate(sizes):
        if size == 0:
            continue
        ref = np.asarray(x)[row:row + size] @ np.asarray(w)[gi]
        np.testing.assert_allclose(got[row:row + size], ref, rtol=1e-5,
                                   atol=1e-4)
        row += size


def test_morphable_multi_gemm_tenants():
    """Fig 3 scenario: two NLP GEMMs share one launch; results exact,
    utilization reported."""
    tenants = [(randn(100, 64), randn(64, 96)),
               (randn(300, 120), randn(120, 50)),
               (randn(60, 256), randn(256, 256))]
    with common.use_pallas():
        res, util = morphable_multi_gemm(tenants)
    for (x, w), r in zip(tenants, res):
        np.testing.assert_allclose(np.asarray(r),
                                   np.asarray(x) @ np.asarray(w),
                                   rtol=1e-5, atol=1e-4)
    assert 0 < util <= 1


# ======================================================================
# depthwise
# ======================================================================
@pytest.mark.parametrize("shape", [(2, 16, 20, 96, 3), (1, 8, 8, 130, 5),
                                   (2, 9, 7, 64, 3), (1, 14, 14, 256, 3)])
def test_depthwise_vs_lax(shape):
    n, h, w, c, kk = shape
    x = randn(n, h, w, c)
    f = randn(kk, kk, c)
    with common.use_pallas():
        got = depthwise_conv(x, f)
    ref = depthwise_ref(x, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.integers(4, 12), st.integers(4, 12),
       st.sampled_from([32, 64, 130]), st.sampled_from([3, 5]))
def test_property_depthwise(n, h, w, c, kk):
    x = randn(n, h, w, c)
    f = randn(kk, kk, c)
    with common.use_pallas():
        got = depthwise_conv(x, f)
    ref = depthwise_ref(x, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-4)


# ======================================================================
# flash attention
# ======================================================================
CASES = [
    dict(b=2, hq=4, hkv=2, lq=128, lk=128, d=64),
    dict(b=1, hq=8, hkv=2, lq=256, lk=300, d=64, causal=True),
    dict(b=1, hq=4, hkv=4, lq=128, lk=256, d=64, causal=True, window=100),
    dict(b=1, hq=4, hkv=2, lq=128, lk=256, d=64, causal=True, softcap=30.0),
    dict(b=1, hq=4, hkv=2, lq=128, lk=384, d=64, causal=True, offset=256),
    dict(b=1, hq=2, hkv=1, lq=128, lk=128, d=128, causal=True, window=64,
         softcap=50.0),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_attention_vs_ref(case):
    case = dict(case)
    b, hq, hkv = case.pop("b"), case.pop("hq"), case.pop("hkv")
    lq, lk, d = case.pop("lq"), case.pop("lk"), case.pop("d")
    q = randn(b, hq, lq, d, scale=0.5)
    k = randn(b, hkv, lk, d, scale=0.5)
    v = randn(b, hkv, lk, d)
    ref = mha_ref(q, k, v, **case)
    got = flash_attention_pallas(q, k, v, interpret=True, **case)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
    chk = chunked_attention(q, k, v, chunk=64, **case)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_attention_decode_single_token():
    """Decode: Lq=1 against a long cache — chunked path must agree."""
    q = randn(2, 8, 1, 64)
    k = randn(2, 4, 511, 64, scale=0.5)
    v = randn(2, 4, 511, 64)
    ref = mha_ref(q, k, v, causal=True, offset=510)
    chk = chunked_attention(q, k, v, causal=True, offset=510, chunk=128)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([1, 2]), st.sampled_from([(4, 2), (8, 8), (6, 1)]),
       st.sampled_from([128, 256]), st.sampled_from([128, 200, 384]),
       st.booleans())
def test_property_flash_attention(b, heads, lq, lk, causal):
    hq, hkv = heads
    q = randn(b, hq, lq, 64, scale=0.5)
    k = randn(b, hkv, lk, 64, scale=0.5)
    v = randn(b, hkv, lk, 64)
    # causal with lq > lk would mask whole rows; keep lk >= lq then
    if causal and lk < lq:
        lk = lq
        k = randn(b, hkv, lk, 64, scale=0.5)
        v = randn(b, hkv, lk, 64)
    ref = mha_ref(q, k, v, causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
