"""Bit-accuracy tests for the all-in-one multiplier model (core/aio_mac.py).

The hardware contract: the reconstructed CSM's shift-add fusion must equal the
direct product, the FP path (CSM + programmable exponent adder + normalizer +
rounder) must equal exact-multiply-then-RNE, and the 4b modes must yield 4
independent products (the throughput morphing behind Table III's 256x256).
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import aio_mac as M
from repro.core import formats as F


# ---------------------------------------------------------------- CSM integer
def test_csm_8x8_signed_exhaustive():
    a = np.arange(-128, 128).repeat(256)
    b = np.tile(np.arange(-128, 128), 256)
    np.testing.assert_array_equal(M.csm_multiply_8x8(a, b, signed=True), a * b)


def test_csm_8x8_unsigned_exhaustive():
    a = np.arange(0, 256).repeat(256)
    b = np.tile(np.arange(0, 256), 256)
    np.testing.assert_array_equal(M.csm_multiply_8x8(a, b, signed=False), a * b)


def test_csm_4x4_four_independent_products():
    rng = np.random.RandomState(0)
    a4 = rng.randint(-8, 8, (1000, 4))
    b4 = rng.randint(-8, 8, (1000, 4))
    out = M.csm_multiply_4x4x4(a4, b4, signed=True)
    np.testing.assert_array_equal(out, a4 * b4)
    assert out.shape == (1000, 4)   # 4 results per multiplier per cycle


def test_csm_4x8_two_products():
    rng = np.random.RandomState(1)
    a4 = rng.randint(-8, 8, (1000, 2))
    b8 = rng.randint(-128, 128, (1000, 2))
    np.testing.assert_array_equal(M.csm_multiply_4x8(a4, b8, signed=True), a4 * b8)


def test_submultiplier_range_contract():
    with pytest.raises(ValueError):
        M.submul_5x5(np.array([16]), np.array([1]))


@settings(max_examples=300, deadline=None)
@given(st.integers(-128, 127), st.integers(-128, 127))
def test_property_csm_signed(a, b):
    assert int(M.csm_multiply_8x8(np.array([a]), np.array([b]))[0]) == a * b


# ---------------------------------------------------------------- INT dispatch
@pytest.mark.parametrize("fa,fb", [(F.INT8, F.INT8), (F.INT4, F.INT4),
                                   (F.UINT8, F.UINT8), (F.UINT4, F.UINT4)])
def test_aio_int_multiply(fa, fb):
    rng = np.random.RandomState(2)
    shape = (512, 4) if fa.bits == 4 else (2048,)
    a = rng.randint(fa.int_min, fa.int_max + 1, shape)
    b = rng.randint(fb.int_min, fb.int_max + 1, shape)
    np.testing.assert_array_equal(M.aio_int_multiply(a, b, fa, fb), a * b)


# ---------------------------------------------------------------- FP path
def _ref_fp_mult(code_a, code_b, fa, fb, out_fmt, bias_adjust=0):
    """Oracle: decode -> exact f64 product -> quantize -> encode (all f64;
    XLA CPU flushes f32 denormals so the jnp path is not exact enough here)."""
    va = F.np_decode_fp(code_a, fa)
    vb = F.np_decode_fp(code_b, fb)
    prod = va * vb * 2.0 ** bias_adjust      # exact in f64 for <=8b significands
    return F.np_encode_fp(prod, out_fmt)


def _all_finite_codes(fmt):
    codes = np.arange(1 << fmt.total_bits)
    if fmt.reserve_specials:
        e_code = (codes >> fmt.mbits) & ((1 << fmt.ebits) - 1)
        codes = codes[e_code != (1 << fmt.ebits) - 1]
    return codes


@pytest.mark.parametrize("fmt,out", [(F.FP8A, F.BF16), (F.FP8B, F.BF16),
                                     (F.FP8A, F.FP8A), (F.FP8B, F.FP8B)])
def test_fp8_multiply_exhaustive(fmt, out):
    """Every FP8 x FP8 pair, bit-exact against decode-multiply-RNE."""
    codes = _all_finite_codes(fmt)
    a = codes.repeat(len(codes))
    b = np.tile(codes, len(codes))
    got = M.aio_fp_multiply(a, b, fmt, fmt, out)
    want = _ref_fp_mult(a, b, fmt, fmt, out)
    neq = got != want
    assert not neq.any(), (
        f"{neq.sum()} mismatches; first: a={a[neq][0]:#x} b={b[neq][0]:#x} "
        f"got={got[neq][0]:#x} want={want[neq][0]:#x}")


def test_bf16_multiply_random():
    rng = np.random.RandomState(3)
    vals_a = (rng.randn(20000) * 2.0 ** rng.randint(-20, 20, 20000)).astype(np.float32)
    vals_b = (rng.randn(20000) * 2.0 ** rng.randint(-20, 20, 20000)).astype(np.float32)
    ca = np.asarray(F.encode(jnp.asarray(vals_a), F.BF16))
    cb = np.asarray(F.encode(jnp.asarray(vals_b), F.BF16))
    got = M.aio_fp_multiply(ca, cb, F.BF16, F.BF16, F.BF16)
    want = _ref_fp_mult(ca, cb, F.BF16, F.BF16, F.BF16)
    np.testing.assert_array_equal(got, want)


def test_programmable_bias_adjust():
    """bias_adjust=k multiplies the product by 2^k with no extra hardware —
    the paper's scaling-factor argument, validated bit-exactly."""
    fmt = F.FP8A
    codes = _all_finite_codes(fmt)
    a = codes.repeat(len(codes))
    b = np.tile(codes, len(codes))
    for k in (-3, 2):
        got = M.aio_fp_multiply(a, b, fmt, fmt, F.BF16, bias_adjust=k)
        want = _ref_fp_mult(a, b, fmt, fmt, F.BF16, bias_adjust=k)
        np.testing.assert_array_equal(got, want)


def test_mixed_format_fp8a_x_fp8b():
    ca = _all_finite_codes(F.FP8A)
    cb = _all_finite_codes(F.FP8B)
    a = ca.repeat(len(cb))
    b = np.tile(cb, len(ca))
    got = M.aio_fp_multiply(a, b, F.FP8A, F.FP8B, F.BF16)
    want = _ref_fp_mult(a, b, F.FP8A, F.FP8B, F.BF16)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 8), st.integers(0, 255), st.integers(0, 255))
def test_property_narrow_exponent_formats(ebits, rawa, rawb):
    """Exponent widths 1..8 all flow through the programmable adder."""
    fmt = F.fp_format("t", ebits, 3)
    mask = (1 << fmt.total_bits) - 1
    a, b = np.array([rawa & mask]), np.array([rawb & mask])
    got = M.aio_fp_multiply(a, b, fmt, fmt, F.BF16)
    want = _ref_fp_mult(a, b, fmt, fmt, F.BF16)
    np.testing.assert_array_equal(got, want)
