"""Flash-decode kernel parity suite: the Pallas decode kernel (interpret
mode) vs the mha_ref oracle over GQA ratios, window/softcap, mixed per-row
cache positions (incl. pos=0 and pos=max_len-1), the fused int8-KV path
(bit-exact vs dequant-then-dense), block-pruning accounting, the
api.ops.attention routing rules, and end-to-end serving byte-identity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_smoke
from repro.kernels.flash_attention import (decode_block_visits,
                                           flash_decode_pallas,
                                           flash_decode_quant_pallas,
                                           mha_ref)
from repro.models import init_params
from repro.models.attention import _dq8, _q8
from repro.serving import Request, ServingEngine

RNG = np.random.RandomState(7)
MAX_LEN = 256


def randn(*shape, scale=1.0):
    return jnp.asarray(RNG.randn(*shape).astype(np.float32) * scale)


def qkv(b, hq, hkv, lq, lk, d):
    return (randn(b, hq, lq, d, scale=0.5), randn(b, hkv, lk, d, scale=0.5),
            randn(b, hkv, lk, d))


# mixed per-row positions: an empty cache row, short rows, a block-boundary
# row and the last valid slot of the cache
MIXED_POS = [0, 5, 128, MAX_LEN - 1]


def assert_close(got, ref):
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# ============================================================ kernel parity
@pytest.mark.parametrize("group", [1, 2, 4])
def test_decode_gqa_vs_ref(group):
    hkv = 2
    q, k, v = qkv(4, hkv * group, hkv, 1, MAX_LEN, 64)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    ref = mha_ref(q, k, v, causal=True, offset=pos)
    got = flash_decode_pallas(q, k, v, pos=pos, interpret=True)
    assert_close(got, ref)


@pytest.mark.parametrize("window,softcap", [(None, None), (40, None),
                                            (None, 30.0), (40, 30.0)])
def test_decode_window_softcap_vs_ref(window, softcap):
    q, k, v = qkv(4, 8, 2, 1, MAX_LEN, 64)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    ref = mha_ref(q, k, v, causal=True, offset=pos, window=window,
                  softcap=softcap)
    got = flash_decode_pallas(q, k, v, pos=pos, interpret=True,
                              window=window, softcap=softcap)
    assert_close(got, ref)


@pytest.mark.parametrize("lq", [2, 3, 8])
def test_decode_short_query_packed_vs_ref(lq):
    """Short multi-token queries (the narrow prefill buckets) with the GQA
    group packed into the q tile — row b queries positions pos[b]+i."""
    q, k, v = qkv(3, 6, 3, lq, MAX_LEN, 64)
    pos = jnp.asarray([0, 77, MAX_LEN - lq], jnp.int32)
    ref = mha_ref(q, k, v, causal=True, offset=pos)
    got = flash_decode_pallas(q, k, v, pos=pos, interpret=True)
    assert_close(got, ref)


def test_decode_scalar_offset_broadcasts():
    q, k, v = qkv(2, 4, 2, 1, MAX_LEN, 64)
    ref = mha_ref(q, k, v, causal=True, offset=100)
    got = flash_decode_pallas(q, k, v, pos=100, interpret=True)
    assert_close(got, ref)


def test_decode_unaligned_cache_length():
    """Lk not a bkv multiple: the pad tail must stay invisible."""
    q, k, v = qkv(2, 4, 2, 1, 200, 64)
    pos = jnp.asarray([199, 64], jnp.int32)
    ref = mha_ref(q, k, v, causal=True, offset=pos)
    got = flash_decode_pallas(q, k, v, pos=pos, interpret=True, bkv=128)
    assert_close(got, ref)


# ============================================================== int8-KV path
def test_decode_int8_fused_bit_exact_vs_dequant():
    """The fused in-VMEM dequant must be BIT-IDENTICAL to materializing the
    dequantized cache and running the dense kernel (it rounds through the
    q dtype exactly like models.attention._dq8)."""
    q, k, v = qkv(4, 8, 2, 1, MAX_LEN, 64)
    kc, ks = _q8(k)
    vc, vs = _q8(v)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    for kw in (dict(), dict(window=40, softcap=30.0)):
        fused = flash_decode_quant_pallas(q, kc, ks, vc, vs, pos=pos,
                                          interpret=True, **kw)
        dense = flash_decode_pallas(q, _dq8(kc, ks, q.dtype),
                                    _dq8(vc, vs, q.dtype), pos=pos,
                                    interpret=True, **kw)
        assert jnp.array_equal(fused, dense), kw
        assert_close(fused, mha_ref(q, _dq8(kc, ks, q.dtype),
                                    _dq8(vc, vs, q.dtype), causal=True,
                                    offset=pos, **kw))


# ============================================================ block pruning
def test_decode_block_pruning_visits():
    """The kernel must VISIT only the KV blocks inside each row's causal
    frontier — work scales with resident context, not max_len."""
    b, hkv, bkv = 4, 2, 64
    q, k, v = qkv(b, 4, hkv, 1, MAX_LEN, 64)
    pos = jnp.asarray([0, 63, 64, MAX_LEN - 1], jnp.int32)
    out, vis = flash_decode_pallas(q, k, v, pos=pos, interpret=True, bkv=bkv,
                                   debug_visits=True)
    vis = np.asarray(vis).reshape(b, hkv, -1)
    nk = MAX_LEN // bkv
    # per-row expectation: blocks 0..pos//bkv inclusive, identical per kv-head
    expect_rows = (np.asarray(pos) // bkv) + 1
    for row in range(b):
        for h in range(hkv):
            got_blocks = int(vis[row, h].sum())
            assert got_blocks == int(expect_rows[row]), (row, h)
    visited, total = decode_block_visits(pos, 1, MAX_LEN, bkv)
    assert visited == int(vis.sum()) // hkv
    assert int(vis.sum()) < b * hkv * nk          # pruning actually happened
    # pruned output still exact
    assert_close(out, mha_ref(q, k, v, causal=True, offset=pos))


def test_decode_window_prunes_old_blocks():
    """Sliding window adds a LOWER bound: a full-residency row visits only
    the window's blocks, so local-layer decode work scales with the window,
    not with how long the row has been resident."""
    b, hkv, bkv, window = 3, 2, 64, 80
    q, k, v = qkv(b, 4, hkv, 1, MAX_LEN, 64)
    pos = jnp.asarray([0, 130, MAX_LEN - 1], jnp.int32)
    out, vis = flash_decode_pallas(q, k, v, pos=pos, interpret=True, bkv=bkv,
                                   window=window, debug_visits=True)
    vis = np.asarray(vis).reshape(b, hkv, -1)
    first = np.maximum(np.asarray(pos) - (window - 1), 0) // bkv
    last = np.asarray(pos) // bkv
    for row in range(b):
        got = np.nonzero(vis[row, 0])[0]
        np.testing.assert_array_equal(
            got, np.arange(first[row], last[row] + 1), f"row={row}")
    visited, total = decode_block_visits(pos, 1, MAX_LEN, bkv, window=window)
    assert visited == int(vis.sum()) // hkv < total
    # the pos=MAX_LEN-1 row visits only ceil-ish window/bkv blocks
    assert int(vis[2, 0].sum()) <= window // bkv + 1
    assert_close(out, mha_ref(q, k, v, causal=True, offset=pos,
                              window=window))


# ================================================================== routing
def test_attention_route_rules():
    pallas = api.ExecutionPolicy(backend="pallas")
    route = api.ops.attention_route
    # cache-shaped decode (short Lq, causal, cache longer than query or a
    # per-row offset vector) hits the decode kernel — dense or quantized
    for kw in (dict(offset_ndim=1), dict(lk=512, offset_ndim=0),
               dict(offset_ndim=1, quantized=True)):
        assert route(lq=1, policy=pallas, **kw) == "pallas-decode", kw
    # multi-token vector-offset chunks (the engine's chunked admission
    # prefill) go to the varlen prefill kernel — dense or quantized
    assert route(lq=8, lk=512, policy=pallas,
                 offset_ndim=1) == "pallas-prefill"
    assert route(lq=256, policy=pallas, offset_ndim=1) == "pallas-prefill"
    assert route(lq=32, policy=pallas, offset_ndim=1,
                 quantized=True) == "pallas-prefill"
    # legacy scalar-offset short queries over a longer cache keep the
    # decode kernel's packed-group route
    assert route(lq=8, lk=512, policy=pallas) == "pallas-decode"
    # plain short SELF-attention (lk == lq, scalar offset) stays on the
    # differentiable ref path — the decode kernel has no VJP
    assert route(lq=4, lk=4, policy=pallas) == "ref"
    # long aligned prefill keeps the prefill flash kernel
    assert route(lq=256, policy=pallas) == "pallas"
    # unaligned / quantized scalar-offset prefill falls back to ref
    assert route(lq=100, policy=pallas) == "ref"
    assert route(lq=256, policy=pallas, quantized=True) == "ref"
    # non-causal never hits the decode kernel
    assert route(lq=1, lk=512, policy=pallas, causal=False) == "ref"
    # ref / default backends never route to kernels
    assert route(lq=1, lk=512, backend="ref") == "ref"
    assert route(lq=1, lk=512) == "ref"


def test_short_self_attention_stays_differentiable_under_pallas():
    """Regression: a tiny training forward (lq == lk <= 8) under
    backend='pallas' must keep taking grads — it routes to ref, not to the
    VJP-less decode kernel."""
    q, k, v = qkv(1, 4, 2, 4, 4, 32)

    def loss(q):
        return api.ops.attention(q, k, v, causal=True, backend="pallas",
                                 interpret=True).sum()

    g = jax.grad(loss)(q)
    assert g.shape == q.shape and bool(jnp.isfinite(g).all())


def test_api_attention_decode_dispatch_matches_ref():
    """api.ops.attention under backend='pallas' must dispatch decode shapes
    to the kernel and agree with the ref backend — dense and int8-KV."""
    q, k, v = qkv(4, 8, 4, 1, MAX_LEN, 64)
    pos = jnp.asarray(MIXED_POS, jnp.int32)
    ref = api.ops.attention(q, k, v, offset=pos, backend="ref")
    got = api.ops.attention(q, k, v, offset=pos, backend="pallas",
                            interpret=True)
    assert_close(got, ref)

    kc, ks = _q8(k)
    vc, vs = _q8(v)
    refq = api.ops.attention(q, kc, vc, offset=pos, k_scale=ks, v_scale=vs,
                             backend="ref")
    gotq = api.ops.attention(q, kc, vc, offset=pos, k_scale=ks, v_scale=vs,
                             backend="pallas", interpret=True)
    assert_close(gotq, refq)
    # the ref impl's dequant matches the old materialize-then-attend exactly
    np.testing.assert_array_equal(
        np.asarray(refq),
        np.asarray(api.ops.attention(q, _dq8(kc, ks, q.dtype),
                                     _dq8(vc, vs, q.dtype), offset=pos,
                                     backend="ref")))


# ==================================================== serving byte-identity
DECODE_POLICY = api.ExecutionPolicy(backend="pallas", interpret=True)


def _serve(cfg, params, spec, policy, slots=2, max_len=64):
    eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                        policy=policy)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    done = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    return [done[i] for i in range(len(spec))], eng


@pytest.mark.parametrize("arch", ["qwen2_1p5b", "gemma2_27b"])
def test_serving_decode_kernel_byte_identical(arch):
    """Greedy serving with the decode kernel enabled must emit byte-identical
    tokens to the ref engine. gemma2 exercises sliding window (its smoke
    window of 16 is crossed), softcap and sandwich norms."""
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(11), cfg)
    rng = np.random.RandomState(11)
    spec = [(rng.randint(1, cfg.vocab, l).astype(np.int32), m)
            for l, m in zip([3, 20, 5, 18], [6, 4, 8, 5])]
    want, ref_eng = _serve(cfg, params, spec, None)
    got, pal_eng = _serve(cfg, params, spec, DECODE_POLICY)
    assert pal_eng.decode_route() == "pallas-decode"
    assert ref_eng.decode_route() == "ref"
    assert got == want


def test_serving_decode_kernel_int8_kv_byte_identical():
    """The fused int8-KV decode path end to end: QuantKVCache codes+scales
    reach the kernel unmaterialized, outputs byte-identical to ref."""
    cfg = dataclasses.replace(get_smoke("qwen2_1p5b"), kv_quant=True)
    params = init_params(jax.random.key(12), cfg)
    rng = np.random.RandomState(12)
    spec = [(rng.randint(1, cfg.vocab, l).astype(np.int32), m)
            for l, m in zip([4, 13, 7], [5, 3, 6])]
    want, _ = _serve(cfg, params, spec, None)
    got, eng = _serve(cfg, params, spec, DECODE_POLICY)
    assert eng.decode_route() == "pallas-decode"
    assert got == want
