"""Paged (block-pool) KV cache: kernel bit-identity through the block-table
indirection, engine byte-identity vs the per-slot layout (dense / GQA /
int8-KV), copy-on-write prefix sharing, pool-pressure eviction + REJECTED
backpressure, quarantine containment of a poisoned SHARED block, and
snapshot/restore over pooled state."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.kernels.flash_attention import (flash_decode_paged_pallas,
                                           flash_decode_pallas,
                                           flash_prefill_paged_pallas,
                                           flash_prefill_pallas)
from repro.models import init_params
from repro.serving import FaultPlan, Request, ServingEngine

MAX_LEN = 64
NAN = float("nan")


def _params(arch="qwen2_1p5b", seed=0, kv_quant=False):
    cfg = get_smoke(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    return cfg, init_params(jax.random.key(seed), cfg)


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("prefill_chunk", 8)
    return ServingEngine(cfg, params, **kw)


def _prefix_spec(vocab, n=5, head=18, seed=0):
    """Prompts sharing an 18-token head (> one 16-token block) + distinct
    tails — the shape that exercises registry hits and boundary-block CoW."""
    rng = np.random.RandomState(seed)
    shared = rng.randint(1, vocab, head).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.randint(1, vocab, 2 + i % 4).astype(np.int32)
        out.append((np.concatenate([shared, tail]), 3 + i % 3))
    return out


def _drain(eng, spec):
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    return {r.rid: r.out_tokens for r in eng.run_until_drained()}


def _pool(kv, bs, seed=0):
    """Scatter (B, Hkv, L, D) into a shuffled (P, Hkv, bs, D) pool +
    (B, nblk) table so the indirection is genuinely non-identity."""
    b, hkv, lk, d = kv.shape
    nblk = lk // bs
    table = np.random.RandomState(seed).permutation(b * nblk) \
        .reshape(b, nblk).astype(np.int32)
    pool = np.empty((b * nblk, hkv, bs, d), kv.dtype)
    for i in range(b):
        for j in range(nblk):
            pool[table[i, j]] = kv[i, :, j * bs:(j + 1) * bs, :]
    return jnp.asarray(pool), jnp.asarray(table)


# ==================================================== kernel bit-identity
def test_paged_decode_kernel_bitwise_matches_dense():
    """At bs == bkv the paged launch visits the same logical blocks with the
    same masks as the dense kernel — outputs must be BITWISE identical, at
    ragged positions including a fresh row (pos 0) and a full one."""
    b, hq, hkv, d, max_len, bs = 3, 4, 2, 64, 256, 128
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, hq, 1, d).astype(np.float32) * 0.5)
    k = rng.randn(b, hkv, max_len, d).astype(np.float32) * 0.5
    v = rng.randn(b, hkv, max_len, d).astype(np.float32)
    kp, table = _pool(k, bs, seed=1)
    vp, _ = _pool(v, bs, seed=1)
    pos = jnp.asarray([0, 37, max_len - 1], jnp.int32)
    want = flash_decode_pallas(q, jnp.asarray(k), jnp.asarray(v), pos=pos,
                               bkv=bs, interpret=True)
    got = flash_decode_paged_pallas(q, kp, vp, table=table, pos=pos,
                                    interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_paged_prefill_kernel_bitwise_matches_dense():
    """Varlen prefill through the table: mixed real lengths (full chunk,
    3-token tail, idle row) over scattered pool blocks, bitwise vs dense."""
    b, hq, hkv, d, max_len, bs, chunk = 3, 4, 2, 64, 256, 128, 32
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(b, hq, chunk, d).astype(np.float32) * 0.5)
    k = rng.randn(b, hkv, max_len, d).astype(np.float32) * 0.5
    v = rng.randn(b, hkv, max_len, d).astype(np.float32)
    kp, table = _pool(k, bs, seed=2)
    vp, _ = _pool(v, bs, seed=2)
    pos = jnp.asarray([0, 70, max_len - chunk], jnp.int32)
    lens = jnp.asarray([chunk, 3, 0], jnp.int32)
    want = flash_prefill_pallas(q, jnp.asarray(k), jnp.asarray(v), pos=pos,
                                lengths=lens, bq=16, bkv=bs, interpret=True)
    got = flash_prefill_paged_pallas(q, kp, vp, table=table, pos=pos,
                                     lengths=lens, bq=16, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ==================================================== engine byte-identity
@pytest.mark.timeout(600)
@pytest.mark.parametrize("arch,kv_quant", [("llama2_7b", False),
                                           ("qwen2_1p5b", False),
                                           ("qwen2_1p5b", True)],
                         ids=["dense", "gqa", "int8-kv"])
def test_paged_engine_matches_flat(arch, kv_quant):
    """Greedy outputs of the block-pool engine must be byte-identical to the
    per-slot engine over a prefix-heavy mix — across MHA, GQA and int8-KV
    cache layouts — while actually sharing blocks (registry hits + CoW)."""
    cfg, params = _params(arch, kv_quant=kv_quant)
    spec = _prefix_spec(cfg.vocab)
    want = _drain(_engine(cfg, params), spec)

    eng = _engine(cfg, params, paged=True, block_size=16)
    got = _drain(eng, spec)
    assert got == want
    st = eng.pool_stats()
    assert st["prefix_hits"] > 0 and st["shared_tokens"] > 0
    assert st["cow_copies"] > 0


# ============================================== pool pressure: evict/REJECT
def test_pool_exhaustion_evicts_registry_blocks():
    """When a reservation exceeds the free list, cold registry-held blocks
    are LRU-evicted to make room — the request still completes in full."""
    cfg, params = _params(seed=2)
    rng = np.random.RandomState(2)
    a = rng.randint(1, cfg.vocab, 9).astype(np.int32)
    b = rng.randint(1, cfg.vocab, 10).astype(np.int32)

    eng = _engine(cfg, params, slots=1, max_len=32, paged=True,
                  block_size=8, pool_blocks=5)
    eng.submit(Request(0, a, max_new_tokens=4))    # 2 blocks, registered
    eng.run_until_drained()
    assert eng.pool_stats()["registry_entries"] == 1
    eng.submit(Request(1, b, max_new_tokens=16))   # needs 4 of 3 free
    done = {r.rid: r for r in eng.run_until_drained()}
    st = eng.pool_stats()
    assert st["evictions"] >= 1
    assert done[1].status == "done" and len(done[1].out_tokens) == 16


def test_pool_pressure_defers_then_rejects():
    """A reservation that cannot be satisfied defers at the queue head (FIFO
    preserved) and the backpressure surfaces through the bounded queue's
    REJECTED path; the deferred request completes once blocks free up."""
    cfg, params = _params(seed=3)
    rng = np.random.RandomState(3)
    mk = lambda n: rng.randint(1, cfg.vocab, n).astype(np.int32)

    # pool = exactly one row's worth: the second admission MUST wait
    eng = _engine(cfg, params, slots=2, max_len=32, paged=True,
                  block_size=8, pool_blocks=4, max_queue=2)
    assert eng.submit(Request(0, mk(9), max_new_tokens=20))   # 4 blocks
    assert eng.submit(Request(1, mk(9), max_new_tokens=4))    # queued
    eng.step()   # admits rid 0; rid 1's reservation defers at the head
    extra = [Request(2 + i, mk(5), max_new_tokens=2) for i in range(3)]
    accepts = [eng.submit(r) for r in extra]
    assert accepts == [True, False, False]    # queue refilled, then bounded
    assert all(r.status == "REJECTED" for r in extra[1:])

    done = {r.rid: r for r in eng.run_until_drained()}
    assert eng.pool_stats()["deferred_admissions"] >= 1
    assert done[0].status == "done" and done[1].status == "done"
    assert len(done[1].out_tokens) == 4


# ================================================== CoW fork correctness
def test_cow_fork_isolates_sharers():
    """Rows admitted off the same registered prefix fork the partially-
    covered boundary block before writing: each sharer's divergent tail must
    not bleed into the donor's blocks or each other's outputs."""
    cfg, params = _params(seed=4)
    spec = _prefix_spec(cfg.vocab, n=4, seed=4)
    want = _drain(_engine(cfg, params), spec)

    # slots=1 serializes the sharers through the same pool blocks — any
    # missed fork shows up as a byte diff on a later request
    eng = _engine(cfg, params, slots=1, paged=True, block_size=16)
    got = _drain(eng, spec)
    assert got == want
    st = eng.pool_stats()
    assert st["cow_copies"] >= 1 and st["prefix_hits"] >= 1


# ===================================== shared-block poison -> quarantine
@pytest.mark.timeout(600)
def test_poisoned_shared_block_quarantines_all_sharers():
    """KV poison lands in the victim slot's FIRST mapped block — which is
    prefix-shared here, so the corruption is visible to another tenant's
    row. Transitive quarantine must scrub and replay EVERY sharer; the NaN
    must not leak into any final output, which stays byte-identical to the
    un-faulted run."""
    cfg, params = _params(seed=5)
    vocab = cfg.vocab
    rng = np.random.RandomState(5)
    shared = rng.randint(1, vocab, 18).astype(np.int32)
    spec = [(np.concatenate([shared, rng.randint(1, vocab, 3
                                                 + i).astype(np.int32)]), 6)
            for i in range(2)]
    want = _drain(_engine(cfg, params, paged=True, block_size=16), spec)

    eng = _engine(cfg, params, paged=True, block_size=16)
    # rid 0 prefills and registers its prefix FIRST, so rid 1's admission
    # hits the registry and maps the same physical block 0
    eng.submit(Request(0, spec[0][0], max_new_tokens=spec[0][1]))
    while not eng.stats.generated_tokens:
        eng.step()
    eng.submit(Request(1, spec[1][0], max_new_tokens=spec[1][1]))
    eng.step()                                  # rid 1 admitted into slot 1
    assert eng.pool_stats()["prefix_hits"] >= 1
    eng.arm_fault_plan(FaultPlan.single("poison", step=eng.step_no, slot=1,
                                        target="kv", value=NAN))
    got = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    assert got == want
    assert eng.stats.quarantines >= 2     # BOTH sharers, not just the victim
    assert all(np.isfinite(np.asarray(t)).all()
               for t in got.values() if len(t))


# ======================================================= snapshot/restore
def test_paged_snapshot_restore_midstream(tmp_path):
    """Snapshot a busy paged engine (rows mid-decode, registry populated,
    blocks shared), restore into a FRESH paged engine, finish: outputs must
    be byte-identical to the original continuing."""
    cfg, params = _params(seed=6)
    spec = _prefix_spec(cfg.vocab, n=4, seed=6)

    a = _engine(cfg, params, paged=True, block_size=16)
    for rid, (p, m) in enumerate(spec):
        a.submit(Request(rid, p, max_new_tokens=m))
    for _ in range(3):
        a.step()
    a.snapshot(tmp_path)
    want = {r.rid: r.out_tokens for r in a.run_until_drained()}

    b = _engine(cfg, params, paged=True, block_size=16)
    b.restore(tmp_path)
    got = {r.rid: r.out_tokens for r in b.run_until_drained()}
    for rid in want:
        assert got.get(rid, want[rid]) == want[rid]
    assert b.pool_stats()["block_size"] == 16


def test_paged_snapshot_layout_mismatch_raises(tmp_path):
    """A paged snapshot cannot silently restore into a per-slot engine (or
    vice versa) — the cache layouts are incompatible."""
    cfg, params = _params(seed=7)
    rng = np.random.RandomState(7)
    eng = _engine(cfg, params, paged=True, block_size=16)
    eng.submit(Request(0, rng.randint(1, cfg.vocab, 5).astype(np.int32),
                       max_new_tokens=2))
    eng.step()
    eng.snapshot(tmp_path)
    flat = _engine(cfg, params)
    with pytest.raises(ValueError):
        flat.restore(tmp_path)


# ======================================================== pool accounting
def test_pool_stats_accounting():
    """Occupancy reflects live + registry-held blocks and frees on release;
    the non-paged engine reports paged=False instead of fake numbers."""
    cfg, params = _params(seed=8)
    rng = np.random.RandomState(8)
    eng = _engine(cfg, params, slots=2, max_len=32, paged=True,
                  block_size=8, pool_blocks=8)
    assert eng.pool_stats()["used_blocks"] == 0
    eng.submit(Request(0, rng.randint(1, cfg.vocab, 9).astype(np.int32),
                       max_new_tokens=4))
    eng.step()
    mid = eng.pool_stats()
    assert mid["used_blocks"] == 2 and 0 < mid["occupancy"] <= 1
    eng.run_until_drained()
    end = eng.pool_stats()
    # the finished row's non-prompt block is back on the free list; the
    # prompt blocks stay pinned by the prefix registry until evicted
    assert end["used_blocks"] == 2 and end["registry_entries"] == 1

    assert _engine(cfg, params).pool_stats() == {"paged": False}
