"""repro.dist contract tests: spec builders and the ambient-mesh helpers."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.dist import (batch_specs, cache_specs, constrain, ctx_dp_axes,
                        opt_state_specs, param_specs, set_mesh)
from repro.launch.mesh import make_local_mesh


def _mesh():
    return make_local_mesh()


def test_param_specs_match_tree_structure():
    mesh = _mesh()
    tree = {"embed": {"table": jax.ShapeDtypeStruct((256, 32), jnp.float32)},
            "attn": {"q": {"w": jax.ShapeDtypeStruct((32, 64), jnp.float32)},
                     "o": {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}}}
    specs = param_specs(tree, mesh)
    assert jax.tree.structure(specs) == jax.tree.structure(tree)
    for s in jax.tree.leaves(specs):
        assert isinstance(s, NamedSharding)


def test_param_specs_device_put_roundtrip():
    mesh = _mesh()
    params = {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}
    placed = jax.device_put(params, param_specs(params, mesh))
    np.testing.assert_array_equal(np.asarray(placed["w"]), np.ones((8, 8)))


def test_opt_state_specs_mirror_params():
    from repro.optim import adamw_init
    mesh = _mesh()
    params = {"w": jnp.ones((4, 4))}
    opt = jax.eval_shape(adamw_init, jax.eval_shape(lambda: params))
    specs = opt_state_specs(opt, mesh)
    assert type(specs).__name__ == "AdamWState"
    assert isinstance(specs.mu["w"], NamedSharding)
    placed = jax.device_put(adamw_init(params), specs)
    assert int(placed.step) == 0


def test_batch_specs_shard_leading_axis():
    mesh = _mesh()
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = batch_specs(batch, mesh)
    assert set(specs) == {"tokens", "labels"}
    for s in specs.values():
        assert isinstance(s, NamedSharding)


def test_cache_specs_handle_none_leaves():
    mesh = _mesh()
    caches = [{"0_dense": {"k": jax.ShapeDtypeStruct((2, 4, 1, 8, 16),
                                                     jnp.bfloat16),
               "pos": jax.ShapeDtypeStruct((2,), jnp.int32)},
               "1_none": None}]
    specs = cache_specs(caches, mesh)
    assert specs[0]["1_none"] is None
    assert isinstance(specs[0]["0_dense"]["pos"], NamedSharding)


def test_ctx_dp_axes_empty_without_mesh():
    assert ctx_dp_axes() == ()


def test_ctx_dp_axes_inside_mesh_context():
    mesh = _mesh()
    with set_mesh(mesh):
        assert ctx_dp_axes() == ("data",)
    assert ctx_dp_axes() == ()


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert constrain(x, "model", None) is x


def test_constrain_under_jit_with_mesh():
    mesh = _mesh()
    with set_mesh(mesh):
        y = jax.jit(lambda a: constrain(a, ("data",), "model"))(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(y), np.ones((4, 4)))


def test_constrain_drops_axes_missing_from_mesh():
    mesh = _mesh()
    with set_mesh(mesh):
        # "pod" is not on the local mesh: entry must be dropped, not error
        y = constrain(jnp.ones((2, 2)), ("pod", "data"), "nonexistent")
    np.testing.assert_array_equal(np.asarray(y), np.ones((2, 2)))
