"""Property + unit tests for the AIO format algebra (core/formats.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F


ALL_FP = [F.BF16, F.FP8A, F.FP8B, F.fp_format("e1m3", 1, 3), F.fp_format("e8m3", 8, 3)]
ALL_INT = [F.INT8, F.INT4, F.UINT8, F.UINT4]


def representable_values(fmt: F.AIOFormat) -> np.ndarray:
    """Enumerate every finite value of a (small) fp format."""
    vals = [0.0]
    for e_code in range(0, (1 << fmt.ebits) - (1 if fmt.reserve_specials else 0)):
        for m_code in range(1 << fmt.mbits):
            if e_code == 0:
                v = m_code * 2.0 ** (fmt.emin - fmt.mbits)
            else:
                v = (1 + m_code * 2.0 ** -fmt.mbits) * 2.0 ** (e_code - fmt.bias)
            vals.append(v)
    vals = np.array(sorted(set(vals)))
    return np.concatenate([-vals[::-1], vals])


@pytest.mark.parametrize("fmt", [F.FP8A, F.FP8B, F.fp_format("e2m3", 2, 3)])
def test_quantize_idempotent_on_grid(fmt):
    grid = representable_values(fmt)
    q = np.asarray(F.quantize(jnp.asarray(grid, jnp.float32), fmt))
    np.testing.assert_array_equal(q, grid.astype(np.float32))


@pytest.mark.parametrize("fmt", [F.FP8A, F.FP8B])
def test_quantize_nearest_even_exhaustive(fmt):
    """Brute-force RNE check: quantize(x) must be the nearest grid point,
    ties to even mantissa."""
    grid = representable_values(fmt)
    rng = np.random.RandomState(0)
    xs = rng.uniform(-fmt.max_finite * 1.5, fmt.max_finite * 1.5, 4096).astype(np.float32)
    # include exact midpoints
    mids = ((grid[:-1] + grid[1:]) / 2).astype(np.float32)
    xs = np.concatenate([xs, mids, grid.astype(np.float32)])
    q = np.asarray(F.quantize(jnp.asarray(xs), fmt))
    for x, qv in zip(xs, q):
        d = np.abs(grid - x)
        best = d.min()
        cands = grid[d == best]
        assert qv in cands, (x, qv, cands)
        if len(cands) == 2:  # midpoint: check ties-to-even (even mantissa code)
            codes = [int(np.asarray(F.encode(jnp.float32(c), fmt))) for c in cands]
            evens = [c for c, cd in zip(cands, codes) if (cd & 1) == 0]
            if evens:
                assert qv in evens, (x, qv, cands)


@pytest.mark.parametrize("fmt", ALL_FP)
def test_encode_decode_roundtrip(fmt):
    rng = np.random.RandomState(1)
    xs = rng.randn(4096).astype(np.float32) * rng.choice(
        [2.0 ** k for k in range(-12, 12)], 4096)
    q = F.quantize(jnp.asarray(xs), fmt)
    codes = F.encode(jnp.asarray(xs), fmt)
    back = F.decode(codes, fmt)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(back))


@pytest.mark.parametrize("fmt", ALL_INT)
def test_int_encode_decode(fmt):
    xs = jnp.asarray(np.random.RandomState(2).uniform(-300, 300, 2048), jnp.float32)
    q = F.quantize(xs, fmt)
    assert float(jnp.max(q)) <= fmt.int_max and float(jnp.min(q)) >= fmt.int_min
    back = F.decode(F.encode(xs, fmt), fmt)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(back))


def test_bf16_matches_jnp_bfloat16():
    xs = np.random.RandomState(3).randn(8192).astype(np.float32) * \
        np.random.RandomState(4).choice([2.0 ** k for k in range(-30, 30)], 8192)
    ours = np.asarray(F.quantize(jnp.asarray(xs), F.BF16))
    jaxs = np.asarray(jnp.asarray(xs).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(ours, jaxs)


def test_programmable_bias_equals_pow2_scale():
    """decode(code, fmt.with_bias(bias-k)) == decode(code, fmt) * 2^k — the
    paper's claim that exponential scaling factors are free."""
    fmt = F.FP8A
    codes = jnp.arange(256, dtype=jnp.int32)
    for k in (-4, -1, 1, 3, 8):
        lhs = F.decode(codes, fmt.with_bias(fmt.bias - k))
        rhs = F.decode(codes, fmt) * 2.0 ** k
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=0)


@pytest.mark.parametrize("fmt", [F.FP8A, F.INT8])
def test_pow2_scale_exact_power_boundary(fmt):
    """frexp off-by-one regression: when amax / max_finite is EXACTLY 2^k the
    scale must be 2^k, not 2^(k+1) — the doubled scale silently wasted half
    the representable range (top code never emitted)."""
    for k in (-3, 0, 2, 7):
        x = jnp.asarray([fmt.max_finite * 2.0 ** k], jnp.float32)
        scale = float(F.pow2_scale(x, fmt))
        assert scale == 2.0 ** k, (k, scale)
        # bit-exact roundtrip at the boundary: |x|/scale == max_finite, whose
        # code is the top finite code, and decode * scale reproduces x
        codes, s = F.quantize_scaled(x, fmt, pow2=True)
        back = float((F.decode(codes, fmt) * s)[0])
        assert back == float(x[0]), (k, back, float(x[0]))
    # just above a power of two still rounds UP (x/scale must fit)
    x = jnp.asarray([fmt.max_finite * 2.0 * (1 + 2 ** -20)], jnp.float32)
    assert float(F.pow2_scale(x, fmt)) == 4.0


def test_pow2_ceil_matches_exact_log2():
    r = jnp.asarray([0.75, 1.0, 1.5, 2.0, 2 ** -9, 3 * 2 ** 4], jnp.float32)
    got = np.asarray(F.pow2_ceil(r))
    want = 2.0 ** np.ceil(np.log2(np.asarray(r)))
    np.testing.assert_array_equal(got, want)


def test_quantize_scaled_pow2_roundtrip():
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(64, 128).astype(np.float32) * 37.0)
    codes, scale = F.quantize_scaled(x, F.FP8A, axis=-1, pow2=True)
    # scale is a power of two
    l2 = np.log2(np.asarray(scale))
    np.testing.assert_array_equal(l2, np.round(l2))
    back = F.decode(codes, F.FP8A) * scale
    # max quantization error <= half ULP of the largest magnitude per row
    err = np.abs(np.asarray(back - x))
    bound = np.asarray(scale) * F.FP8A.max_finite * 2.0 ** (-F.FP8A.mbits)
    assert (err <= bound + 1e-7).all()


def test_pack_unpack_int4():
    rng = np.random.RandomState(6)
    vals = jnp.asarray(rng.randint(-8, 8, (16, 32)), jnp.float32)
    codes = F.encode(vals, F.INT4)
    packed = F.pack_int4(codes)
    assert packed.shape == (16, 16) and packed.dtype == jnp.int8
    un = F.unpack_int4(packed, signed=True)
    np.testing.assert_array_equal(np.asarray(un), np.asarray(vals).astype(np.int32))


def test_pack_unpack_int4_odd_k_roundtrip():
    """Odd last axis: pack_int4 appends one zero phantom nibble; unpack with
    k= restores the original values bit-exactly (the resident int4 weight
    path relies on this for odd d_in)."""
    rng = np.random.RandomState(7)
    for k in (1, 3, 31, 97):
        vals = jnp.asarray(rng.randint(-8, 8, (5, k)), jnp.float32)
        codes = F.encode(vals, F.INT4)
        packed = F.pack_int4(codes)
        assert packed.shape == (5, (k + 1) // 2) and packed.dtype == jnp.int8
        un = F.unpack_int4(packed, signed=True, k=k)
        np.testing.assert_array_equal(np.asarray(un),
                                      np.asarray(vals).astype(np.int32))
        # the phantom nibble is exactly zero (contributes 0 to a dot)
        full = np.asarray(F.unpack_int4(packed, signed=True))
        np.testing.assert_array_equal(full[:, k:], 0)


def test_pow2_ceil_exact_near_subnormal_boundary():
    """pow2_ceil must stay exact down to the smallest normal f32 exponents —
    the regime pow2_scale's `tiny` guard lands in for all-(near-)zero
    tensors. (True f32 subnormals are excluded: XLA CPU flushes them, see
    the FTZ note on the property test.)"""
    for e in (-126, -125, -124, -64, 127):
        r = jnp.asarray([2.0 ** e], jnp.float32)
        got = float(F.pow2_ceil(r)[0])
        assert got == 2.0 ** e, (e, got)          # exact power: NOT doubled
    # smallest normal scaled just above a power of two rounds UP exactly
    for e in (-125, -64):
        r = jnp.asarray([np.nextafter(np.float32(2.0 ** e),
                                      np.float32(np.inf))], jnp.float32)
        got = float(F.pow2_ceil(r)[0])
        assert got == 2.0 ** (e + 1), (e, got)
    # the pow2_scale guard value itself (finfo.tiny == 2^-126)
    tiny = float(np.finfo(np.float32).tiny)
    assert float(F.pow2_ceil(jnp.float32(tiny))) == tiny


def test_fake_quant_gradient_is_ste():
    x = jnp.asarray([0.3, -2.7, 100.0], jnp.float32)
    g = jax.grad(lambda v: jnp.sum(F.fake_quant(v, "fp8a")))(x)
    np.testing.assert_array_equal(np.asarray(g), np.ones(3, np.float32))


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 8), st.sampled_from([2, 3, 7]),
       st.floats(-1e4, 1e4, allow_nan=False, width=32))
def test_property_quantize_error_bound(ebits, mbits, x):
    if 0 < abs(x) < 1.2e-38:
        return   # f32 denormal input: XLA CPU flushes to zero (FTZ)
    fmt = F.fp_format("t", ebits, mbits)
    q = float(F.quantize(jnp.float32(x), fmt))
    assert abs(q) <= fmt.max_finite
    if abs(x) <= fmt.max_finite:
        if abs(x) >= 2.0 ** fmt.emin:
            assert abs(q - x) <= abs(x) * 2.0 ** (-fmt.mbits - 1) * 1.0000001
        else:
            assert abs(q - x) <= fmt.min_subnormal / 2 * 1.0000001


@settings(max_examples=100, deadline=None)
@given(st.sampled_from(["int8", "int4", "uint8", "uint4"]),
       st.floats(-500, 500, allow_nan=False, width=32))
def test_property_int_quantize(fmt_name, x):
    fmt = F.REGISTRY[fmt_name]
    q = float(F.quantize(jnp.float32(x), fmt))
    assert fmt.int_min <= q <= fmt.int_max
    assert q == np.round(np.clip(np.float32(x), fmt.int_min, fmt.int_max)) or \
        abs(q - np.clip(x, fmt.int_min, fmt.int_max)) <= 0.5
