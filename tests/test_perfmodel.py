"""Perfmodel tests: latency math, utilization, and the paper's claims
(directional + ratio structure — see EXPERIMENTS.md for the full comparison)."""
import math

import pytest

from repro.perfmodel.accelerators import ACCELERATORS, precision_double
from repro.perfmodel.latency import eq1_paper, model_latency, op_latency
from repro.perfmodel.simulate import (gpu_comparison, multi_tenant_scenario,
                                      speedup_table, utilization_table)
from repro.perfmodel.workloads import MODELS, Op, training_ops


def test_eq1_verbatim():
    # Eq. (1): (2*S_R + S_C - 2) * ceil(S_R/R) * ceil(S_C/C)
    assert eq1_paper(s_c=300, s_r=256, r=128, c=128) == \
        (512 + 298) * 2 * math.ceil(300 / 128)


def test_precision_doubling_table3():
    assert precision_double("bf16") == 1
    assert precision_double("int8") == 1
    assert precision_double("fp8a") == 2     # 128x128 acts as 256x256
    assert precision_double("int4") == 2


def test_accumulable_full_tiles_high_util():
    op = Op("g", "gemm", 4096, 1024, 1024)
    r = op_latency(op, ACCELERATORS["tpu_sa"], "bf16")
    assert r.utilization > 0.9


def test_depthwise_allrounder_beats_rigid():
    op = Op("dw", "depthwise", 128 * 56 * 56, 9, 96, taps=9, channels=96)
    ar = op_latency(op, ACCELERATORS["allrounder"], "bf16")
    sa = op_latency(op, ACCELERATORS["tpu_sa"], "bf16")
    assert ar.cycles < sa.cycles
    assert ar.utilization > 10 * sa.utilization


def test_morphable_helps_ragged_gemm():
    """Fig 3: tall/wide GEMMs fit 64-wide partitions better."""
    op = Op("g", "gemm", 4096, 64, 64)
    ar = op_latency(op, ACCELERATORS["allrounder"], "bf16")
    sa = op_latency(op, ACCELERATORS["tpu_sa"], "bf16")
    assert ar.utilization > sa.utilization


def test_fig14_wg_cliff_for_cnns_not_llms():
    u = utilization_table("bf16", ["vgg16", "llama2_7b"])
    # CNN weight-gradient: All-rounder keeps high utilization, rigid falls
    assert u["vgg16"]["WG"]["allrounder"] > 0.95
    assert u["vgg16"]["WG"]["tpu_sa"] < u["vgg16"]["FW"]["tpu_sa"]
    assert u["vgg16"]["WG"]["allrounder"] > 1.5 * u["vgg16"]["WG"]["sara"]
    # LLM GEMMs stay ~uniform across accelerators (paper: ~100% in bf16)
    for step in ("FW", "BW", "WG"):
        row = u["llama2_7b"][step]
        assert min(row.values()) > 0.9 * max(row.values())
        assert row["allrounder"] > 0.85


def test_fig14_depthwise_models_gap():
    u = utilization_table("bf16", ["mobilenetv2", "efficientnet_b0"])
    for model in ("mobilenetv2", "efficientnet_b0"):
        for step in ("FW", "BW", "WG"):
            assert u[model][step]["allrounder"] >= \
                u[model][step]["tpu_sa"] - 1e-9


def test_fig15_allrounder_dominates():
    t = speedup_table("bf16", ["vgg16", "mobilenetv2", "convnext_s"])
    for model, row in t.items():
        assert row["allrounder"]["speedup"] >= 1.0
        assert row["allrounder"]["speedup"] >= row["mirroring"]["speedup"]


def test_vic_multitenant_ordering():
    ms = multi_tenant_scenario("int8", mode="eq1")
    # the paper's §VI-C ordering among the flexible designs
    assert ms["allrounder"] < ms["sara"] <= ms["mirroring"]
    # All-rounder absolute within 2x of the paper's 30.30 ms
    assert 15 < ms["allrounder"] < 60


def test_table4_energy_efficiency_gain():
    t = gpu_comparison(["vgg16", "resnet18", "mobilenetv2"])
    for model, row in t.items():
        # paper: 81x average efficiency gain; ours must be >10x per model
        assert row["allrounder_gflops_w"] > 10 * row["gpu"]["gflops_w"] / 3


def test_training_ops_cover_three_steps():
    for model in MODELS:
        steps = training_ops(model, 8)
        assert set(steps) == {"FW", "BW", "WG"}
        fw_macs = sum(o.macs for o in steps["FW"])
        bw_macs = sum(o.macs for o in steps["BW"])
        assert 0.2 * fw_macs < bw_macs <= 1.5 * fw_macs


@pytest.mark.parametrize("fmt", ["bf16", "fp8a", "int8", "int4"])
def test_all_formats_run_through_model(fmt):
    ops = MODELS["resnet18"](8)
    for acc in ACCELERATORS.values():
        r = model_latency(ops, acc, fmt)
        assert r["cycles"] > 0 and 0 < r["utilization"] <= 1.0
