"""Continuous per-slot batching: greedy-identity vs solo serving, pad-mask
regression, request-limit handling, and the wave-path step-count win."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import decode_step, init_caches, init_params
from repro.serving import Request, ServingEngine

MAX_LEN = 64


def _params(arch, seed=0):
    cfg = get_smoke(arch)
    return cfg, init_params(jax.random.key(seed), cfg)


def _solo(cfg, params, prompt, max_new):
    eng = ServingEngine(cfg, params, slots=1, max_len=MAX_LEN)
    eng.submit(Request(0, prompt, max_new_tokens=max_new))
    (req,) = eng.run_until_drained()
    return req.out_tokens


def _mixed_requests(vocab, lens, outs, seed=0):
    rng = np.random.RandomState(seed)
    return [(rng.randint(1, vocab, l).astype(np.int32), m)
            for l, m in zip(lens, outs)]


# ============================================================ greedy identity
@pytest.mark.parametrize("arch", ["qwen2_1p5b", "zamba2_2p7b", "gemma2_27b"])
def test_mixed_batch_matches_solo(arch):
    """Per-request greedy outputs must be byte-identical to single-request
    serving — per-slot positions keep rows fully independent. zamba2
    exercises the recurrent token-by-token prefill with validity masks;
    gemma2 exercises the per-row SLIDING-WINDOW frontier (its smoke window of
    16 is crossed by these lengths) plus softcap and sandwich norms."""
    cfg, params = _params(arch)
    spec = _mixed_requests(cfg.vocab, [3, 9, 5, 14, 7], [4, 2, 6, 1, 3])
    want = [_solo(cfg, params, p, m) for p, m in spec]

    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    done = eng.run_until_drained()
    assert len(done) == len(spec)
    got = {r.rid: r.out_tokens for r in done}
    for rid in range(len(spec)):
        assert got[rid] == want[rid], f"{arch} rid={rid}"


def test_interleaved_admit_matches_solo():
    """Requests submitted MID-FLIGHT land in freed slots and still reproduce
    their solo outputs — the continuous-batching determinism guarantee."""
    cfg, params = _params("qwen2_1p5b", seed=1)
    spec = _mixed_requests(cfg.vocab, [4, 11, 6, 3], [2, 8, 3, 5], seed=1)
    want = [_solo(cfg, params, p, m) for p, m in spec]

    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN)
    for rid in (0, 1):
        eng.submit(Request(rid, *spec[rid][:1], max_new_tokens=spec[rid][1]))
    for _ in range(3):                      # rid 0 (2 tokens) finishes here
        eng.step()
    for rid in (2, 3):                      # admitted into freed slots
        eng.submit(Request(rid, *spec[rid][:1], max_new_tokens=spec[rid][1]))
    done = eng.run_until_drained()
    got = {r.rid: r.out_tokens for r in done}
    assert sorted(got) == [0, 1, 2, 3]
    for rid in range(4):
        assert got[rid] == want[rid], f"rid={rid}"


def test_quantized_cache_per_slot_matches_solo():
    """Per-slot positions through the QuantKVCache variant (int8 KV codes are
    quantized per row, so slots stay independent)."""
    cfg = dataclasses.replace(get_smoke("qwen2_1p5b"), kv_quant=True)
    params = init_params(jax.random.key(2), cfg)
    spec = _mixed_requests(cfg.vocab, [3, 8], [4, 2], seed=2)
    want = [_solo(cfg, params, p, m) for p, m in spec]
    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    got = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    assert [got[0], got[1]] == want


# ========================================================= pad-mask regression
def test_padded_prefill_logits_match_solo():
    """Regression for the left-padded-prefill bug: a short prompt sharing a
    prefill batch with a longer one must see NO pad keys — its last-position
    logits must match the same prompt prefilled alone."""
    cfg, params = _params("qwen2_1p5b")
    short = np.asarray([3, 5, 7], np.int32)
    long_ = np.arange(1, 12, dtype=np.int32)

    solo_c = init_caches(cfg, batch=1, max_len=MAX_LEN)
    solo_logits, _ = decode_step(params, solo_c, jnp.asarray(short)[None], cfg)

    toks = np.zeros((2, len(long_)), np.int32)
    toks[0, :len(short)] = short
    toks[1] = long_
    c = init_caches(cfg, batch=2, max_len=MAX_LEN)
    logits, c = decode_step(params, c, jnp.asarray(toks), cfg,
                            lengths=jnp.asarray([len(short), len(long_)]))
    np.testing.assert_array_equal(np.asarray(logits[0, len(short) - 1]),
                                  np.asarray(solo_logits[0, -1]))
    # positions advanced by true lengths, not the padded width
    np.testing.assert_array_equal(np.asarray(c[0]["0_dense"].pos[0]),
                                  [len(short), len(long_)])


def test_wave_padded_member_matches_solo_engine():
    """End-to-end pad regression: a short request served alongside a longer
    one emits exactly its solo tokens (the old wave engine attended pad K/V
    and could diverge here)."""
    cfg, params = _params("olmo_1b")
    short = np.asarray([3, 5, 7, 11], np.int32)
    long_ = np.arange(2, 17, dtype=np.int32)
    want = _solo(cfg, params, short, 5)
    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN)
    eng.submit(Request(0, short, max_new_tokens=5))
    eng.submit(Request(1, long_, max_new_tokens=5))
    got = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    assert got[0] == want


# ============================================================= request limits
def test_max_new_tokens_zero_emits_nothing():
    cfg, params = _params("qwen2_1p5b")
    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN)
    eng.submit(Request(0, np.asarray([1, 2, 3], np.int32), max_new_tokens=0))
    eng.submit(Request(1, np.asarray([4, 5], np.int32), max_new_tokens=2))
    done = {r.rid: r for r in eng.run_until_drained()}
    assert done[0].done and done[0].out_tokens == []
    assert len(done[1].out_tokens) == 2


def test_submit_rejects_cache_overflow():
    cfg, params = _params("qwen2_1p5b")
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, np.arange(1, 28, dtype=np.int32),
                           max_new_tokens=6))        # 27 + 6 > 32
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(1, np.zeros(0, np.int32)))
    # exact fit is accepted and served to completion
    eng.submit(Request(2, np.arange(1, 27, dtype=np.int32),
                       max_new_tokens=6))            # 26 + 6 == 32
    (req,) = eng.run_until_drained()
    assert len(req.out_tokens) == 6


# ====================================================== wave-path comparison
def test_continuous_beats_wave_decode_steps():
    """Acceptance: on a mixed prompt/output-length set the continuous engine
    needs strictly fewer decode steps (and model launches) than the
    wave-synchronous baseline."""
    from benchmarks.serving_bench import WaveEngine, make_requests
    cfg, params = _params("qwen2_1p5b")
    spec = make_requests(cfg.vocab, n=6, prompt_hi=12, out_hi=8, seed=3)

    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    eng.run_until_drained()

    wave = WaveEngine(cfg, params, slots=2, max_len=MAX_LEN)
    wave.serve([Request(rid, p, max_new_tokens=m)
                for rid, (p, m) in enumerate(spec)])
    assert eng.stats.decode_steps < wave.decode_steps
    assert eng.stats.model_calls < \
        wave.prefill_token_steps + wave.decode_steps


# ================================================================ donation
def test_cache_buffers_are_donated():
    """The engine's traced cache->cache steps must DONATE the cache pytree
    (decode stops copying the whole KV residency every step on TPU)."""
    cfg, params = _params("qwen2_1p5b")
    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN)
    tok = jnp.zeros((2, 1), jnp.int32)
    lens = jnp.zeros((2,), jnp.int32)
    lowered = eng._step_fn.lower(params, eng.caches, tok, lens, None)
    # args_info order mirrors (params, caches, token, lengths, memory):
    # every cache leaf is donated, no param/token/lengths leaf is
    flags = [a.donated for a in jax.tree.leaves(lowered.args_info)]
    n_params = len(jax.tree.leaves(params))
    n_caches = len(jax.tree.leaves(eng.caches))
    assert not any(flags[:n_params])
    assert all(flags[n_params:n_params + n_caches])
    assert not any(flags[n_params + n_caches:])


def test_refilled_slot_after_donation_matches_solo():
    """Donation regression: a slot that finishes, is reset and refilled must
    decode its new request byte-identically — slots=1 forces every request
    through the same donated cache row."""
    cfg, params = _params("qwen2_1p5b", seed=3)
    spec = _mixed_requests(cfg.vocab, [5, 3, 7], [6, 2, 4], seed=3)
    want = [_solo(cfg, params, p, m) for p, m in spec]
    eng = ServingEngine(cfg, params, slots=1, max_len=MAX_LEN)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    got = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    for rid in range(len(spec)):
        assert got[rid] == want[rid], f"rid={rid}"


# ================================================================= occupancy
def test_occupancy_reporting():
    cfg, params = _params("qwen2_1p5b")
    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN)
    assert eng.occupancy() == [None, None] and eng.utilization() == 0.0
    eng.submit(Request(7, np.asarray([1, 2, 3], np.int32), max_new_tokens=4))
    eng.step()
    (occ0, occ1) = eng.occupancy()
    assert occ1 is None and occ0["rid"] == 7 and occ0["generated"] == 2
    assert eng.utilization() == 0.5
    eng.run_until_drained()
    assert eng.utilization() == 0.0
