"""repro.analysis: every checker must fire on seeded violations and stay
quiet on the current tree (the --strict CI gate)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (check_engine, check_format_matrix,
                            check_kernel_contracts, check_launch)
from repro.analysis.format_matrix import FormatClaim
from repro.analysis.hotloop import (audit_donation, audit_health_guard,
                                    audit_step_jaxpr, audit_swap_hygiene,
                                    audit_trace_count)
from repro.api import (BlockContract, ExecutionPolicy, LaunchContract,
                       KernelRegistry)
from repro.configs import get_smoke
from repro.models import init_params, quantize_params
from repro.serving import ServingEngine


# ========================================================== kernel contracts
def _launch(index_map, *, grid=(4,), array=(128,), block=(32,), nsp=0,
            scalars=(), masked=False):
    return LaunchContract(
        grid=grid,
        blocks=(BlockContract("x", array, block, index_map,
                              masked_tail=masked),),
        num_scalar_prefetch=nsp, scalars=scalars)


def test_clean_identity_launch_passes():
    rep = check_launch(_launch(lambda i: (i,)), "t")
    assert rep.ok() and not rep.findings


def test_oob_index_fires_kc102():
    rep = check_launch(_launch(lambda i: (i + 1,)), "t")
    assert [f.code for f in rep.errors] == ["KC102"]


def test_arity_mismatch_fires_kc101():
    rep = check_launch(_launch(lambda i, j: (i,)), "t")
    assert rep.by_code("KC101")


def test_scalar_count_mismatch_fires_kc101():
    rep = check_launch(_launch(lambda i, s: (i,), nsp=2,
                               scalars=(np.zeros(2, np.int32),)), "t")
    assert rep.by_code("KC101")


def test_nondividing_block_without_mask_fires_kc103():
    rep = check_launch(_launch(lambda i: (i,), array=(100,)), "t")
    assert rep.by_code("KC103")


def test_nondividing_block_with_masked_tail_passes():
    rep = check_launch(_launch(lambda i: (i,), array=(100,), masked=True), "t")
    assert not rep.by_code("KC103")


def test_vmem_overcommit_fires_kc104():
    big = 8 * 1024 * 1024                      # x2 double-buffer x4 B > 16 MB
    rep = check_launch(_launch(lambda i: (i,), grid=(1,), array=(big,),
                               block=(big,)), "t")
    assert rep.by_code("KC104")


def test_decode_clamp_overruns_cache_one_block_short():
    """The REAL decode index maps against a row whose windowed frontier sits
    past the padded cache (e.g. an engine writing pos beyond max_len): the
    clamp lands on a block that does not exist, and the out-of-trace sweep
    must catch it as KC102 — this overrun class is invisible to interpret-
    mode numerics tests."""
    from repro.kernels.flash_attention.decode import decode_index_maps
    bkv, lk_pad = 16, 128                      # blocks [0, 8)
    pos = np.asarray([200], np.int32)          # first block = 193//16 = 12
    _, kv_index = decode_index_maps(lq=1, hkv=1, bkv=bkv, window=8)
    lc = LaunchContract(
        grid=(1, lk_pad // bkv),
        blocks=(BlockContract("k", (1, lk_pad, 8), (1, bkv, 8), kv_index),),
        num_scalar_prefetch=1, scalars=(pos,))
    rep = check_launch(lc, "decode-short-cache")
    assert [f.code for f in rep.errors] == ["KC102"]


def test_rank_mismatch_does_not_suppress_oob_dedup_regression():
    """One index map with TWO distinct defects: a rank mismatch at grid
    point 0 and an out-of-bounds block index elsewhere. The dedup keys are
    per-(block, kind), so BOTH must be reported — the old shared key let
    the first rank finding swallow every later KC102."""
    rep = check_launch(
        _launch(lambda i: (i, i) if i == 0 else (99,)), "t")
    codes = sorted(f.code for f in rep.errors)
    assert codes == ["KC101", "KC102"], rep.render()


def test_stratified_sweep_reaches_far_corner_oob():
    """A grid too large for an exhaustive sweep whose only bad point is the
    LAST block: the stratified sample pins first/last along every dim, so
    the KC102 must still fire (plus the KC105 sampling warning)."""
    g = 100000                                 # > MAX_GRID_POINTS
    rep = check_launch(
        _launch(lambda i: (i,) if i < g - 1 else (g,),
                grid=(g,), array=(32 * g,)), "t")
    assert [f.code for f in rep.errors] == ["KC102"], rep.render()
    assert rep.by_code("KC105")                # sampling disclosed as warning
    assert not any(f.code == "KC105" for f in rep.errors)


def _fake_reg():
    reg = KernelRegistry()
    reg._loaded = True                         # no kernel autoload
    return reg


def test_pallas_impl_without_contract_fires_kc100():
    reg = _fake_reg()

    @reg.register("op", "pallas")
    def impl(*, policy):
        pass

    rep = check_kernel_contracts(reg)
    assert [f.code for f in rep.findings] == ["KC100"]
    assert not rep.errors                      # warning: strict still passes


def test_contract_builder_error_fires_kc105():
    reg = _fake_reg()

    @reg.register("op", "pallas")
    def impl(*, policy):
        pass

    @reg.register_contract("op", "pallas", cases=({},))
    def contract(case, policy):
        raise RuntimeError("boom")

    rep = check_kernel_contracts(reg)
    assert [f.code for f in rep.errors] == ["KC105"]


def test_checker_crosses_cases_with_policy_tile_sweep():
    reg = _fake_reg()
    seen = []

    @reg.register("op", "pallas")
    def impl(*, policy):
        pass

    @reg.register_contract("op", "pallas", cases=({"m": 128},),
                           sweep_fields=("bm",))
    def contract(case, policy):
        seen.append((case["m"], policy.bm))
        return LaunchContract(
            grid=(case["m"] // policy.bm,),
            blocks=(BlockContract("x", (case["m"],), (policy.bm,),
                                  lambda i: (i,)),))

    rep = check_kernel_contracts(reg)
    assert rep.ok(), rep.render()
    assert seen == [(128, 128), (128, 64)]     # REPRESENTATIVE_TILES["bm"]


def test_current_tree_contracts_cover_all_pallas_impls_and_pass():
    rep = check_kernel_contracts()
    assert rep.ok(), rep.render()
    assert not rep.by_code("KC100")            # every pallas impl declared one


# ================================================================= hot loop
def test_host_callback_in_step_fires_hl201():
    def step(x):
        y = jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1.0

    closed = jax.make_jaxpr(step)(jnp.zeros((4, 4)))
    rep = audit_step_jaxpr(closed, "t")
    assert [f.code for f in rep.errors] == ["HL201"]


def test_pure_math_step_is_quiet():
    closed = jax.make_jaxpr(
        lambda x: jax.lax.scan(lambda c, v: (c + v, c), x, x)[0])(
        jnp.zeros((4,)))
    rep = audit_step_jaxpr(closed, "t")
    assert not rep.findings


def test_materialized_dequant_fires_hl203_warning():
    codes = jnp.zeros((512, 512), jnp.int8)
    closed = jax.make_jaxpr(lambda c: c.astype(jnp.float32) * 2.0)(codes)
    rep = audit_step_jaxpr(closed, "t", quantized=True)
    assert rep.by_code("HL203") and rep.ok()   # warning severity


def test_block_sized_dequant_is_quiet():
    codes = jnp.zeros((16, 64), jnp.int8)
    closed = jax.make_jaxpr(lambda c: c.astype(jnp.float32) * 2.0)(codes)
    rep = audit_step_jaxpr(closed, "t", quantized=True)
    assert not rep.findings


def test_dropped_donation_fires_hl202():
    donated = [((4, 8), jnp.dtype("float32"))]
    outs = [jax.ShapeDtypeStruct((2, 8), jnp.float32)]
    rep = audit_donation(donated, outs, "t")
    assert [f.code for f in rep.errors] == ["HL202"]


def test_matching_donation_passes():
    donated = [((4, 8), jnp.dtype("float32"))] * 2
    outs = [jax.ShapeDtypeStruct((4, 8), jnp.float32) for _ in range(3)]
    assert audit_donation(donated, outs, "t").ok()


def test_trace_count_mismatch_fires_hl204():
    rep = audit_trace_count(3, 2, "t")
    assert [f.code for f in rep.errors] == ["HL204"]


def test_missing_health_output_fires_hl205():
    """A step program without the (slots,) bool health output — the bare
    decode_step shape the engine used to trace — is an HL205 error."""
    closed = jax.make_jaxpr(lambda x: (x * 2.0, x + 1.0))(jnp.zeros((2, 4)))
    rep = audit_health_guard(closed, "t")
    assert [f.code for f in rep.errors] == ["HL205"]


def test_unfused_health_output_fires_hl205():
    """A bool output that is NOT the is_finite+reduce_and reduction (here a
    comparison) does not count as the guard."""
    closed = jax.make_jaxpr(
        lambda x: (x * 2.0, jnp.max(x, axis=1) > 0.0))(jnp.zeros((2, 4)))
    rep = audit_health_guard(closed, "t")
    assert [f.code for f in rep.errors] == ["HL205"]


def test_slab_output_escaping_step_fires_hl206():
    """A step program that returns gathered pool slabs (a rank-5 output
    that aliases no donated cache buffer) is swap traffic inside the hot
    loop — every token would ship whole KV blocks device->host."""
    cache = jnp.zeros((2, 8, 4, 16, 8))          # (layers, P, H, bs, D)

    def step(c, ids):
        slabs = jnp.take(c, ids, axis=1)         # swap gather IN the step
        return c, slabs

    closed = jax.make_jaxpr(step)(cache, jnp.zeros((2,), jnp.int32))
    donated = [(cache.shape, cache.dtype)]
    rep = audit_swap_hygiene(closed, donated, "t")
    assert [f.code for f in rep.errors] == ["HL206"]


def test_donated_cache_outputs_pass_hl206():
    """The legitimate step shape: caches flow through via donation aliases,
    logits/health are the only non-cache outputs."""
    cache = jnp.zeros((2, 8, 4, 16, 8))

    def step(c, x):
        logits = jnp.zeros((2, 1, 32)) + x
        health = jnp.all(jnp.isfinite(logits), axis=(1, 2))
        return logits, c * 1.0, health

    closed = jax.make_jaxpr(step)(cache, jnp.float32(0.0))
    donated = [(cache.shape, cache.dtype)]
    rep = audit_swap_hygiene(closed, donated, "t")
    assert rep.ok() and not rep.findings


def test_fused_health_guard_passes_hl205():
    closed = jax.make_jaxpr(
        lambda x: (x * 2.0, jnp.all(jnp.isfinite(x), axis=1)))(
        jnp.zeros((2, 4)))
    assert audit_health_guard(closed, "t").ok()
    assert not audit_health_guard(closed, "t").findings


def test_engine_step_trace_carries_health_guard():
    """The live engine's traced step program (what `repro.analysis` audits)
    must satisfy HL205 at every lifetime width — the guard is part of the
    ONE step program, not a side computation."""
    cfg = get_smoke("qwen2_1p5b")
    eng = ServingEngine(cfg, init_params(jax.random.key(0), cfg),
                        slots=2, max_len=32, prefill_chunk=4)
    for w in eng.step_widths():
        assert audit_health_guard(eng.step_trace(w), "t").ok()


def test_quantized_pallas_smoke_engine_hot_loop_is_clean():
    """The engine configuration the audit exists to protect: pallas-routed,
    int8 KV cache, int8-resident weights — no host sync, every cache leaf
    donated-and-aliased, trace count pinned to the two lifetime widths."""
    cfg = dataclasses.replace(get_smoke("qwen2_1p5b"), kv_quant=True)
    params = quantize_params(init_params(jax.random.key(0), cfg), "int8")
    eng = ServingEngine(
        cfg, params, slots=2, max_len=32, prefill_chunk=4,
        policy=ExecutionPolicy(backend="pallas", format="int8"))
    rep = check_engine(eng)
    assert rep.ok(), rep.render()
    assert eng.step_trace_count() == len(eng.step_widths()) == 2


# ============================================================ format matrix
def test_format_matrix_matches_current_tree():
    rep = check_format_matrix()
    assert rep.ok(), rep.render()
    assert {f.code for f in rep.findings} == {"FM306"}   # documented gaps


def test_registry_format_missing_from_matrix_fires_fm301():
    from repro.core import formats
    rep = check_format_matrix(
        registry_names=set(formats.REGISTRY) | {"fp6"})
    assert any(f.code == "FM301" and "fp6" in f.where for f in rep.errors)


def test_unclaimed_matmul_mode_fires_fm303():
    from repro.kernels.aio_matmul import MODES
    rep = check_format_matrix(matmul_modes=set(MODES) | {"fp16"})
    assert any(f.code == "FM303" and "fp16" in f.where for f in rep.errors)


def test_residency_without_mode_fires_fm308():
    matrix = (FormatClaim("xx", paper=False, matmul_mode=False,
                          residency=True, perf_model=False, routable=False),)
    rep = check_format_matrix(
        matrix, registry_names={"xx"}, routable_names=set(),
        matmul_modes=set(), resident_names={"xx"}, perf_names=set())
    assert [f.code for f in rep.errors] == ["FM308"]


# ==================================================================== CLI
def test_cli_json_artifact_and_zero_exit(tmp_path, capsys):
    from repro.analysis.run import main
    out = tmp_path / "report.json"
    rc = main(["--check", "format-matrix", "--strict", "--json", str(out)])
    assert rc == 0
    data = json.loads(out.read_text())
    assert data["counts"]["error"] == 0
    assert any(f["code"] == "FM306" for f in data["findings"])


def test_cli_strict_exits_nonzero_on_seeded_error(monkeypatch):
    from repro.analysis import run as run_mod

    def seeded(report):
        report.add("XX999", "error", "test", "t", "seeded failure")
        return report

    monkeypatch.setitem(run_mod.CHECKERS, "format-matrix", seeded)
    assert run_mod.main(["--check", "format-matrix", "--strict"]) == 1
    assert run_mod.main(["--check", "format-matrix"]) == 0   # non-strict


def test_cli_list_codes_prints_every_family(capsys):
    from repro.analysis import run as run_mod
    assert run_mod.main(["--list-codes"]) == 0
    out = capsys.readouterr().out
    for checker, table in run_mod.CODE_TABLES:
        for code, (severity, _) in table.items():
            assert code in out and checker in out
            assert severity in out
    assert out.index("KC100") < out.index("KB400") < out.index("HL201") \
        < out.index("FM301")                   # family order preserved


def test_cli_baseline_ratchet_roundtrip(tmp_path, capsys):
    from repro.analysis.run import main
    base = tmp_path / "base.json"
    assert main(["--check", "format-matrix",
                 "--write-baseline", str(base)]) == 0
    data = json.loads(base.read_text())
    assert data["counts_by_code"] == {"FM306": 2}
    # the counts it just wrote must pass the ratchet
    assert main(["--check", "format-matrix", "--baseline", str(base)]) == 0


def test_cli_baseline_fails_on_new_finding(tmp_path, monkeypatch, capsys):
    from repro.analysis import run as run_mod
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"counts_by_code": {"FM306": 2}}))

    def noisier(report):
        check_format_matrix(report=report)
        report.add("FM306", "info", "format-matrix", "t", "one extra")
        return report

    monkeypatch.setitem(run_mod.CHECKERS, "format-matrix", noisier)
    assert run_mod.main(["--check", "format-matrix",
                         "--baseline", str(base)]) == 1
    assert "baseline allows 2" in capsys.readouterr().out


def test_cli_baseline_fails_on_fixed_finding_until_regenerated(tmp_path,
                                                               capsys):
    """Fixing a warning without ratcheting the committed baseline down is
    also a failure — the baseline never goes stale."""
    from repro.analysis.run import main
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"counts_by_code": {"FM306": 3}}))
    assert main(["--check", "format-matrix", "--baseline", str(base)]) == 1
    assert "regenerating" in capsys.readouterr().out


def test_committed_baseline_is_well_formed():
    import pathlib
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "analysis_baseline.json"
    data = json.loads(path.read_text())
    assert isinstance(data["counts_by_code"], dict)
    for code, n in data["counts_by_code"].items():
        assert isinstance(n, int) and n > 0, (code, n)
