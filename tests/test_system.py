"""End-to-end system behaviour tests.

These exercise the whole stack the way a user would: train with
checkpointing, kill, restart, resume — and serve with the quantized format
plane — plus the dry-run machinery on a small in-process mesh.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke, shape_support
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models import forward, init_params
from repro.runtime import Trainer, TrainerConfig


def test_train_kill_restart_resume_bitexact(tmp_path):
    """The fault-tolerance contract: a run that checkpoints at step 4, dies,
    and restarts must produce the same params as an uninterrupted run."""
    cfg = get_smoke("olmo_1b")
    mesh = make_local_mesh()

    def data():
        return iter(SyntheticLM(DataConfig(vocab=cfg.vocab, batch=4, seq=32,
                                           seed=11)))

    def tcfg(d):
        return TrainerConfig(ckpt_dir=str(d), ckpt_every=4, total_steps=8,
                             base_lr=1e-3, warmup=2)

    # uninterrupted: 8 steps
    t_full = Trainer(cfg, tcfg(tmp_path / "full"), mesh, key=jax.random.key(7))
    t_full.run(data(), 8)

    # interrupted: 4 steps, "crash", restart, 4 more with resumed data state
    t_a = Trainer(cfg, tcfg(tmp_path / "int"), mesh, key=jax.random.key(7))
    src = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=4, seq=32, seed=11))
    t_a.attach_pipeline(src.state)
    t_a.run(iter(src), 4)
    t_a.ckpt.wait()
    del t_a                                        # crash

    t_b = Trainer(cfg, tcfg(tmp_path / "int"), mesh, key=jax.random.key(99))
    step = t_b.maybe_restore()
    assert step == 4
    assert t_b.pipeline_state.step == 4            # data position restored
    # resume the data stream from the checkpointed pipeline state
    src2 = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=4, seq=32, seed=11),
                       t_b.pipeline_state)
    t_b.attach_pipeline(src2.state)
    t_b.run(iter(src2), 4)

    for a, b in zip(jax.tree.leaves(t_full.params), jax.tree.leaves(t_b.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_loss_decreases_on_learnable_stream():
    """The synthetic stream has Markov structure; 30 steps must beat the
    starting loss by a clear margin."""
    cfg = get_smoke("olmo_1b")
    mesh = make_local_mesh()
    tr = Trainer(cfg, TrainerConfig(ckpt_dir="/tmp/sys_learn", ckpt_every=10**9,
                                    total_steps=60, base_lr=1e-2, warmup=5),
                 mesh, key=jax.random.key(0))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=8, seq=64, seed=5))
    tr.run(iter(data), 60)
    first = tr.metrics_log[0]["loss"]
    last = min(m["loss"] for m in tr.metrics_log[-5:])
    assert last < first - 0.3, f"no learning: {first} -> {last}"


def test_quantized_serving_matches_fp_argmax_mostly():
    """PTQ int8 weights must keep greedy decisions for a majority of tokens
    (the inference-format premise of the paper)."""
    from repro.core import formats as F
    cfg = get_smoke("qwen2_1p5b")
    params = init_params(jax.random.key(0), cfg)

    def q(leaf):
        if leaf.ndim >= 2 and leaf.shape[-1] >= 8:
            codes, scale = F.quantize_scaled(leaf, F.INT8, axis=-1, pow2=True)
            return F.decode(codes, F.INT8) * scale
        return leaf
    qparams = jax.tree.map(q, params)
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, cfg.vocab)
    lf, _ = forward(params, toks, cfg)
    lq, _ = forward(qparams, toks, cfg)
    agree = float(jnp.mean(jnp.argmax(lf, -1) == jnp.argmax(lq, -1)))
    assert agree > 0.7, agree


def test_dryrun_lowering_small_mesh_subprocess():
    """The dry-run machinery end-to-end on an 8-device in-process mesh:
    lower+compile a train cell, parse collectives, sane record."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import dataclasses, jax
from repro.configs import SHAPES, get_smoke
from repro.launch.dryrun import _lower_one, _costs
from repro.launch.mesh import make_mesh_compat
cfg = dataclasses.replace(get_smoke("qwen2_1p5b"), scan_unroll=10**6)
mesh = make_mesh_compat((2, 4), ("data", "model"))
cell = dataclasses.replace(SHAPES["train_4k"], batch=8, seq=64)
c = _costs(_lower_one(cfg, cell, mesh))
assert c["flops"] > 0 and c["bytes"] > 0, c
print("DRYRUN_SMALL_OK", c["flops"])
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420, cwd="/root/repo")
    assert "DRYRUN_SMALL_OK" in out.stdout, out.stderr[-2000:]


def test_all_arch_shape_grid_is_self_describing():
    """Every assigned arch declares support for all four cells; skips carry
    reasons; exactly the two sub-quadratic archs run long_500k."""
    long_runners = []
    for arch in ARCH_IDS:
        if arch in ("gpt2_small", "llama2_7b"):
            continue
        sup = shape_support(arch)
        assert set(sup) == {"train_4k", "prefill_32k", "decode_32k",
                            "long_500k"}
        for shape, reason in sup.items():
            assert reason is None or isinstance(reason, str)
        if sup["long_500k"] is None:
            long_runners.append(arch)
    assert sorted(long_runners) == ["xlstm_1p3b", "zamba2_2p7b"]


def test_int8_kv_cache_decode_agrees_with_bf16():
    """QuantKVCache (the format plane on cache residency, §Perf it7) must
    keep greedy decode decisions."""
    import dataclasses
    cfg = get_smoke("internlm2_20b")
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    from repro.models import decode_step, init_caches
    c_fp = init_caches(cfg, 2, 16, dtype=jnp.float32)
    c_q = init_caches(qcfg, 2, 16)
    agree = 0
    sf = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    sq = jax.jit(lambda p, c, t: decode_step(p, c, t, qcfg))
    for t in range(8):
        lf, c_fp = sf(params, c_fp, toks[:, t:t + 1])
        lq, c_q = sq(params, c_q, toks[:, t:t + 1])
        agree += int((jnp.argmax(lf[:, -1], -1) ==
                      jnp.argmax(lq[:, -1], -1)).all())
    assert agree >= 7, agree
