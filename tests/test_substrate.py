"""Substrate tests: data pipeline, checkpointing, trainer fault tolerance,
optimizer, gradient compression, serving engine, tenancy planning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save, gc_old
from repro.checkpoint.store import AsyncCheckpointer
from repro.configs import get_smoke
from repro.data import DataConfig, PipelineState, Prefetcher, SyntheticLM
from repro.models import init_params
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         global_norm)
from repro.runtime import StragglerAbort, Trainer, TrainerConfig
from repro.serving import Request, ServingEngine
from repro.launch.mesh import make_local_mesh


# ===================================================================== data
def test_data_determinism_and_resume():
    cfg = DataConfig(vocab=1000, batch=8, seq=16, seed=3)
    it1 = SyntheticLM(cfg)
    batches = [next(it1) for _ in range(5)]
    # resume from step 3 must reproduce batch 3
    it2 = SyntheticLM(cfg, PipelineState(step=3))
    b3 = next(it2)
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(batches[0]["labels"][:, :-1],
                                  batches[0]["tokens"][:, 1:])


def test_data_host_sharding_disjoint():
    c0 = DataConfig(vocab=1000, batch=8, seq=16, host_id=0, n_hosts=2)
    c1 = DataConfig(vocab=1000, batch=8, seq=16, host_id=1, n_hosts=2)
    b0, b1 = next(SyntheticLM(c0)), next(SyntheticLM(c1))
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetcher():
    cfg = DataConfig(vocab=100, batch=4, seq=8)
    pf = Prefetcher(SyntheticLM(cfg), depth=2)
    ref = SyntheticLM(DataConfig(vocab=100, batch=4, seq=8))
    for _ in range(4):
        np.testing.assert_array_equal(next(pf)["tokens"], next(ref)["tokens"])
    pf.close()


def test_frontend_batches():
    cfg = DataConfig(vocab=100, batch=2, seq=8, frontend="audio",
                     frontend_len=5, d_model=16)
    b = next(SyntheticLM(cfg))
    assert b["frames"].shape == (2, 5, 16)
    assert np.isfinite(b["frames"]).all()


# ================================================================ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    save(tmp_path, 7, tree, extra={"pipeline": {"step": 9}})
    assert latest_step(tmp_path) == 7
    like = jax.eval_shape(lambda: tree)
    got, extra, step = restore(tmp_path, like)
    assert step == 7 and extra["pipeline"]["step"] == 9
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_gc_and_atomicity(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        save(tmp_path, s, tree)
    gc_old(tmp_path, keep=2)
    assert latest_step(tmp_path) == 4
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [3, 4]
    # no tmp dirs left behind
    assert not [d for d in tmp_path.iterdir() if d.name.startswith(".tmp")]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    tree = {"x": jnp.arange(4.0)}
    ck.save(10, tree)
    ck.wait()
    got, _, step = restore(tmp_path, jax.eval_shape(lambda: tree))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(got["x"]), np.arange(4.0))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save(tmp_path, 1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        restore(tmp_path, jax.eval_shape(lambda: {"x": jnp.zeros((5,))}))


# ================================================================== optimizer
def test_adamw_reduces_loss():
    w_true = jnp.asarray([2.0, -3.0])
    x = jax.random.normal(jax.random.key(0), (64, 2))
    y = x @ w_true

    params = {"w": jnp.zeros((2,))}
    state = adamw_init(params)
    def loss(p):
        return jnp.mean((x @ p["w"] - y) ** 2)
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, lr=0.05, wd=0.0)
    assert float(loss(params)) < l0 * 0.05


def test_adamw_master_weights_bf16_params():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.master["w"].dtype == jnp.float32
    g = {"w": jnp.full((8,), 1e-3, jnp.float32)}
    p2, s2, _ = adamw_update(g, state, params, lr=1e-4)
    assert p2["w"].dtype == jnp.bfloat16
    # master moved even if bf16 rounding would hide it
    assert float(jnp.abs(s2.master["w"] - 1.0).max()) > 0


def test_cosine_schedule_shape():
    s = cosine_schedule(jnp.asarray(0), base_lr=1.0, warmup=10, total=100)
    assert float(s) == 0.0
    s_mid = cosine_schedule(jnp.asarray(10), base_lr=1.0, warmup=10, total=100)
    assert float(s_mid) == pytest.approx(1.0)
    s_end = cosine_schedule(jnp.asarray(100), base_lr=1.0, warmup=10,
                            total=100)
    assert float(s_end) == pytest.approx(0.1, abs=1e-3)


def test_grad_clip():
    big = {"a": jnp.full((4,), 100.0)}
    from repro.optim import clip_by_global_norm
    clipped, norm = clip_by_global_norm(big, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# =============================================================== compression
def test_compressed_roundtrip_error_bounded():
    from repro.optim.grad_compress import _roundtrip
    from repro.core import formats as F
    x = jax.random.normal(jax.random.key(1), (128,)) * 5
    for fmt_name in ("int8", "fp8a"):
        fmt = F.REGISTRY[fmt_name]
        rt = _roundtrip(x, fmt)
        rel = float(jnp.abs(rt - x).max() / jnp.abs(x).max())
        assert rel < (0.02 if fmt_name == "int8" else 0.15)


# ================================================================== trainer
def _mini_trainer(tmp_path, total=5, ckpt_every=2):
    cfg = get_smoke("olmo_1b")
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                         total_steps=total, base_lr=1e-3, warmup=1)
    mesh = make_local_mesh()
    return Trainer(cfg, tcfg, mesh, key=jax.random.key(0)), cfg


def test_trainer_runs_and_checkpoints(tmp_path):
    tr, cfg = _mini_trainer(tmp_path)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=4, seq=16))
    tr.run(iter(data), 4)
    tr.ckpt.wait()
    assert latest_step(tmp_path) in (2, 4)
    losses = [m["loss"] for m in tr.metrics_log]
    assert all(np.isfinite(l) for l in losses)


def test_trainer_restart_resumes(tmp_path):
    tr, cfg = _mini_trainer(tmp_path)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=4, seq=16))
    tr.run(iter(data), 2)
    tr.ckpt.wait()
    step_before = int(tr.opt_state.step)

    tr2, _ = _mini_trainer(tmp_path)
    resumed = tr2.maybe_restore()
    assert resumed == 2
    assert int(tr2.opt_state.step) == step_before
    # params identical to the checkpointed ones
    a = jax.tree.leaves(tr.params)[0]
    b = jax.tree.leaves(tr2.params)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog():
    tr = Trainer.__new__(Trainer)
    tr.tcfg = TrainerConfig(ckpt_dir="/tmp/unused", straggler_factor=2.0,
                            max_straggler_strikes=3, min_timing_samples=4)
    tr.step_times = [0.1] * 10
    tr.straggler_strikes = 0
    tr._watchdog(0.1)
    assert tr.straggler_strikes == 0
    with pytest.raises(StragglerAbort):
        for _ in range(5):
            tr._watchdog(1.0)     # 10x median


# ================================================================== serving
def test_serving_engine_drains():
    cfg = get_smoke("qwen2_1p5b")
    params = init_params(jax.random.key(0), cfg)
    eng = ServingEngine(cfg, params, slots=2, max_len=64)
    rng = np.random.RandomState(0)
    for rid in range(3):
        eng.submit(Request(rid, rng.randint(1, cfg.vocab, 5).astype(np.int32),
                           max_new_tokens=4))
    done = eng.run_until_drained()
    assert len(done) == 3
    for r in done:
        assert r.done and len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_serving_greedy_matches_forward():
    """Engine decode must agree with argmax over the full forward."""
    from repro.models import forward
    cfg = get_smoke("olmo_1b")
    params = init_params(jax.random.key(1), cfg)
    prompt = np.asarray([3, 5, 7, 11], np.int32)
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(Request(0, prompt, max_new_tokens=1))
    (req,) = eng.run_until_drained()
    logits, _ = forward(params, jnp.asarray(prompt)[None], cfg)
    want = int(jnp.argmax(logits[0, -1]))
    assert req.out_tokens[0] == want


# ================================================================== tenancy
def test_tenancy_planning_two_tenants():
    from repro.tenancy import MorphableScheduler, Tenant
    import numpy as np
    sched = MorphableScheduler(devices=np.array(jax.devices() * 4
                                                )[:4].reshape(2, 2))
    parts = sched.reconfigure([Tenant("captioning", 64, 512),
                               Tenant("classification", 64, 768)])
    assert len(parts) >= 2
    names = [t for p in parts for t in p.tenants]
    assert set(names) == {"captioning", "classification"}
    assert sched.partition_of("captioning") is not None


def test_tenancy_single_tenant_fuses():
    from repro.tenancy import MorphableScheduler, Tenant
    sched = MorphableScheduler(devices=np.array(jax.devices() * 4
                                                )[:4].reshape(2, 2))
    parts = sched.reconfigure([Tenant("big", 4096, 4096)])
    assert len(parts) == 1
    assert parts[0].mesh.devices.size == 4


def test_serving_engine_encdec_whisper():
    """Enc-dec serving: whisper decodes against encoded audio memory."""
    cfg = get_smoke("whisper_tiny")
    params = init_params(jax.random.key(0), cfg)
    frames = np.random.RandomState(0).randn(
        2, cfg.frontend_len, cfg.d_model).astype(np.float32)
    eng = ServingEngine(cfg, params, slots=2, max_len=64, frames=frames)
    for rid in range(2):
        eng.submit(Request(rid, np.asarray([3, 5, 7], np.int32),
                           max_new_tokens=4))
    done = eng.run_until_drained()
    assert len(done) == 2
    assert all(len(r.out_tokens) == 4 for r in done)


def test_compressed_allreduce_ef_converges():
    """EF-SGD sanity: int8-compressed grad sync must reach (near) the same
    quadratic optimum as exact sync — the error-feedback guarantee."""
    import jax
    from repro.optim.grad_compress import compressed_grad_allreduce, init_error_state
    mesh = make_local_mesh()
    w_true = jnp.asarray([1.5, -2.0, 0.5, 3.0])
    x = jax.random.normal(jax.random.key(0), (64, 4))
    y = x @ w_true
    params = {"w": jnp.zeros((4,))}
    err = init_error_state(params)

    def loss(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        g, err = compressed_grad_allreduce(g, err, mesh, fmt_name="int8",
                                           dp_axis="data")
        params = jax.tree.map(lambda w, gw: w - 0.05 * gw, params, g)
    assert float(loss(params)) < 1e-2, float(loss(params))
