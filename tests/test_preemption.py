"""Graceful degradation under memory pressure: priority preemption with
host-swap of live KV blocks and byte-identical resume.

The contract under test: when the block pool cannot hold a higher-priority
admission, the engine preempts strictly-lower-priority resident rows —
private blocks spill device->host into the HostBlockStore, registry-shared
blocks stay resident with the swap entry holding the row's reference — and
the preempted request later resumes from the exact saved frontier with NO
recompute, so its greedy output is byte-identical to an uncontended run.
Also covered: the equal-priority hysteresis (no preemption between peers),
the pool_pressure fault lever, pinned-registry eviction skips, and
snapshot/restore while requests sit in PREEMPTED state."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params
from repro.serving import (FaultPlan, HostBlockStore, Request, ServingEngine,
                           drive_with_plan)

MAX_LEN = 64


def _params(arch="qwen2_1p5b", seed=0, kv_quant=False):
    cfg = get_smoke(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    return cfg, init_params(jax.random.key(seed), cfg)


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 16)
    return ServingEngine(cfg, params, **kw)


def _contended_spec(vocab, n=6, seed=0, max_new=12):
    """Prompts of 18-30 tokens (2 blocks each at bs=16) whose full budget is
    3 blocks — two of them cannot coexist in a 4-block pool, so alternating
    priorities force preempt/swap/resume cycles as slots turn over."""
    rng = np.random.RandomState(seed)
    return [(rng.randint(1, vocab, rng.randint(18, 30)).astype(np.int32),
             max_new) for _ in range(n)]


def _drain(eng, spec, prios=None):
    for rid, (p, m) in enumerate(spec):
        prio = prios[rid] if prios else 0
        assert eng.submit(Request(rid, p, max_new_tokens=m, priority=prio))
    return {r.rid: tuple(r.out_tokens or ()) for r in
            eng.run_until_drained(max_steps=4000)}


# =================================== preempt -> swap -> resume byte-identity
@pytest.mark.timeout(600)
@pytest.mark.parametrize("arch,kv_quant", [("llama2_7b", False),
                                           ("qwen2_1p5b", False),
                                           ("qwen2_1p5b", True)],
                         ids=["dense", "gqa", "int8-kv"])
def test_preempted_rows_resume_byte_identical(arch, kv_quant):
    """A 4-block pool with alternating priorities forces real preemptions
    (verified by the counters); every request must still complete and every
    output — preempted or not — must match the uncontended 12-block run
    byte for byte, across dense / GQA / int8-KV paged layouts."""
    cfg, params = _params(arch, kv_quant=kv_quant)
    spec = _contended_spec(cfg.vocab)
    prios = [0, 1, 0, 1, 0, 1]
    want = _drain(_engine(cfg, params, pool_blocks=12), spec, prios)

    eng = _engine(cfg, params, pool_blocks=4)
    got = _drain(eng, spec, prios)
    assert got == want
    st = eng.pool_stats()
    assert st["preemptions"] >= 1 and st["swap_outs"] >= 1
    assert st["swap_ins"] >= 1
    assert st["swap_bytes_out"] > 0
    assert st["swap_bytes_in"] == st["swap_bytes_out"]   # full round-trip
    assert st["host_blocks"] == 0 and st["host_bytes"] == 0   # all drained
    assert all(len(t) == 12 for t in got.values())


def test_equal_priority_never_preempts():
    """Hysteresis: with uniform priorities the same contended pool must
    serialize through DEFERRAL only — equal never preempts equal, so two
    peers can't thrash each other in and out of residency."""
    cfg, params = _params()
    spec = _contended_spec(cfg.vocab)
    want = _drain(_engine(cfg, params, pool_blocks=12), spec)

    eng = _engine(cfg, params, pool_blocks=4)
    got = _drain(eng, spec)
    assert got == want
    st = eng.pool_stats()
    assert st["preemptions"] == 0 and st["swap_outs"] == 0
    assert st["deferred_admissions"] >= 1


# ==================================== prefix sharing: kept blocks stay home
@pytest.mark.timeout(600)
def test_preempting_prefix_sharer_keeps_registry_blocks_resident():
    """Preempt a row whose prefix blocks are shared with the registry and a
    live sibling: only its PRIVATE (forked/decode) blocks may spill to the
    host — the shared block stays resident with the swap entry holding the
    reference, and the pinned registry entry is SKIPPED by eviction, not
    destroyed. Resume is still byte-identical."""
    cfg, params = _params(seed=11)
    rng = np.random.RandomState(11)
    prompt = rng.randint(1, cfg.vocab, 22).astype(np.int32)   # blocks 0..1
    big = rng.randint(1, cfg.vocab, 30).astype(np.int32)
    spec = [(prompt, 10), (prompt, 20), (big, 32)]
    want = _drain(_engine(cfg, params, slots=3, pool_blocks=16), spec,
                  prios=[0, 0, 1])

    eng = _engine(cfg, params, slots=3, pool_blocks=6)
    eng.submit(Request(0, spec[0][0], max_new_tokens=spec[0][1], priority=0))
    while not eng.stats.generated_tokens:    # rid 0 prefills + registers
        eng.step()
    eng.submit(Request(1, spec[1][0], max_new_tokens=spec[1][1], priority=0))
    eng.step()
    st = eng.pool_stats()
    assert st["prefix_hits"] >= 1            # rid 1 shares rid 0's prefix
    reg_blocks = {b for ent in eng._pg_registry.values()
                  for b in ent["blocks"]}

    # rid 2's 4-block reservation: eviction must SKIP the pinned registry
    # entry (all its blocks ref>1), then preempt rid 1 (the cheapest
    # strictly-lower-priority victim — most freeable blocks)
    eng.submit(Request(2, spec[2][0], max_new_tokens=spec[2][1], priority=1))
    eng.step()
    st = eng.pool_stats()
    assert st["preemptions"] == 1 and st["eviction_skips"] >= 1
    assert st["evictions"] == 0 and st["registry_entries"] >= 1
    entry = eng._swap_entries[1]
    assert entry["kept"], "shared prefix block must stay resident"
    assert all(b in reg_blocks for _, b in entry["kept"])
    assert len(entry["hids"]) == entry["total"] - len(entry["kept"])
    assert st["host_blocks"] == len(entry["hids"]) >= 1
    # the live sibling (rid 0) was NOT preempted — it shares the prefix too
    assert any(r is not None and r.rid == 0 for r in eng._slot_req)

    got = {r.rid: tuple(r.out_tokens or ()) for r in
           eng.run_until_drained(max_steps=4000)}
    assert got == want


# ======================================= preempt in the middle of a prefill
def test_preempt_during_chunked_prefill_resumes_mid_prompt():
    """A row preempted while still admitting (prefill chunk 1 of 3 done)
    must save its prefill frontier, spill every private block, and resume
    the REMAINING chunks after swap-in — output byte-identical, no chunk
    recomputed from scratch."""
    cfg, params = _params(seed=12)
    rng = np.random.RandomState(12)
    spec = [(rng.randint(1, cfg.vocab, 24).astype(np.int32), 8),
            (rng.randint(1, cfg.vocab, 24).astype(np.int32), 8)]
    kw = dict(max_len=32, block_size=8)      # 4-block rows
    want = _drain(_engine(cfg, params, pool_blocks=10, **kw), spec,
                  prios=[0, 1])

    # pool of 5: rid 0 reserves 4, rid 1's 4-block reservation must preempt
    eng = _engine(cfg, params, pool_blocks=5, **kw)
    eng.submit(Request(0, spec[0][0], max_new_tokens=8, priority=0))
    eng.step()                               # admit + first 8-token chunk
    assert eng._prefilling[0] and eng._prefill_off[0] == 8
    eng.submit(Request(1, spec[1][0], max_new_tokens=8, priority=1))
    eng.step()                               # rid 1's reservation preempts
    req0 = next(r for r in eng.finished + eng._preempted if r.rid == 0) \
        if eng._preempted else None
    assert req0 is not None and req0.status == "PREEMPTED"
    entry = eng._swap_entries[0]
    assert entry["prefilling"] and entry["prefill_off"] == 8
    assert entry["pos"] == 8
    assert not entry["kept"] and len(entry["hids"]) == entry["total"]

    got = {r.rid: tuple(r.out_tokens or ()) for r in
           eng.run_until_drained(max_steps=4000)}
    assert got == want
    assert eng.pool_stats()["swap_ins"] >= 1


# ======================================================= pool_pressure fault
def test_pool_pressure_fault_squeezes_then_releases():
    """The deterministic pressure lever: at its step the fault holds the
    free list down to `blocks` remaining for `duration` steps — admissions
    defer against the squeeze, the hold releases on schedule, and every
    request completes byte-identical to the un-faulted run."""
    cfg, params = _params(seed=13)
    spec = _contended_spec(cfg.vocab, n=4, seed=13, max_new=4)
    want = _drain(_engine(cfg, params, pool_blocks=8), spec)

    eng = _engine(cfg, params, pool_blocks=8)
    plan = FaultPlan.single("pool_pressure", step=2, blocks=0, duration=12)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    finished, rejections = drive_with_plan(eng, plan)
    assert not rejections
    got = {r.rid: tuple(r.out_tokens or ()) for r in finished}
    assert got == want
    assert plan.faults[0].tripped
    st = eng.pool_stats()
    # admissions hit the squeeze: reclaimed via registry eviction or
    # deferred until the hold released
    assert st["evictions"] + st["deferred_admissions"] >= 1
    # the hold releases on schedule — if the engine drained while still
    # squeezed, a few idle steps must cross the release boundary
    for _ in range(plan.faults[0].duration + 1):
        if not eng.pool_stats()["pressure_held"]:
            break
        eng.step()
    assert eng.pool_stats()["pressure_held"] == 0


def test_pool_pressure_fault_in_seeded_plans():
    """pool_pressure is a first-class chaos kind: seeded plans draw it
    deterministically (same seed -> same plan) with bounded squeeze
    parameters, so chaos sweeps can't deadlock an engine forever."""
    plans = [FaultPlan.seeded(7, steps=20, slots=2,
                              kinds=("pool_pressure",)) for _ in range(2)]
    assert [f.describe() for f in plans[0].faults] == \
        [f.describe() for f in plans[1].faults]
    for f in plans[0].faults:
        assert f.kind == "pool_pressure"
        assert 0 <= f.blocks <= 2 and 2 <= f.duration <= 7


# ======================================== eviction skips pinned registry
def test_evict_skips_fully_pinned_registry_entry():
    """Regression: an entry whose blocks are ALL held by in-flight sharers
    (ref>1) must be SKIPPED by eviction — destroying it frees nothing now
    and tears sharing out from under a resident row. The skip is counted;
    with no other reclaim available the admission defers instead."""
    cfg, params = _params(seed=14)
    rng = np.random.RandomState(14)
    prompt = rng.randint(1, cfg.vocab, 8).astype(np.int32)   # one full block
    eng = _engine(cfg, params, slots=2, max_len=32, block_size=8,
                  pool_blocks=5)
    eng.submit(Request(0, prompt, max_new_tokens=4))
    eng.run_until_drained()                  # registers the 1-block prefix
    assert eng.pool_stats()["registry_entries"] == 1
    # the sharer EXTENDS the registered prompt, so the full registered
    # block is shared by reference (an identical prompt would cap coverage
    # at plen-1 and fork instead of pinning)
    longer = np.concatenate([prompt,
                             rng.randint(1, cfg.vocab, 4).astype(np.int32)])
    eng.submit(Request(1, longer, max_new_tokens=20))   # live sharer
    eng.step()
    assert eng.pool_stats()["prefix_hits"] >= 1
    # needs 4 blocks; free < 4 and the only registry entry is fully pinned
    eng.submit(Request(2, rng.randint(1, cfg.vocab, 17).astype(np.int32),
                       max_new_tokens=8))
    eng.step()
    st = eng.pool_stats()
    assert st["eviction_skips"] >= 1
    # nothing was torn down: the pinned entries survive (rid 1's own prefill
    # completion registered a second one alongside the original)
    assert st["evictions"] == 0 and st["registry_entries"] >= 1
    done = {r.rid: r for r in eng.run_until_drained(max_steps=4000)}
    assert done[2].status == "done" and len(done[2].out_tokens) == 8


# ============================================== snapshot/restore mid-preempt
@pytest.mark.timeout(600)
def test_snapshot_restore_with_preempted_rows(tmp_path):
    """Snapshot while a request sits in PREEMPTED state (its KV bytes split
    between the device pool and the host store), restore into a FRESH
    engine: the host store round-trips through the checkpoint and the
    preempted row still resumes byte-identically."""
    cfg, params = _params(seed=15)
    spec = _contended_spec(cfg.vocab, seed=15)
    prios = [0, 1, 0, 1, 0, 1]
    want = _drain(_engine(cfg, params, pool_blocks=12), spec, prios)

    a = _engine(cfg, params, pool_blocks=4)
    for rid, (p, m) in enumerate(spec):
        a.submit(Request(rid, p, max_new_tokens=m, priority=prios[rid]))
    for _ in range(4000):
        a.step()
        if a._preempted and a._swap_store.nbytes() > 0:
            break
    assert a._preempted, "scenario must catch a request mid-preemption"
    a.snapshot(tmp_path)
    want_rest = {r.rid: tuple(r.out_tokens or ()) for r in
                 a.run_until_drained(max_steps=4000)}
    assert want_rest == want

    b = _engine(cfg, params, pool_blocks=4)
    b.restore(tmp_path)
    assert b._preempted and b._swap_store.nbytes() > 0
    got = {r.rid: tuple(r.out_tokens or ()) for r in
           b.run_until_drained(max_steps=4000)}
    for rid, toks in got.items():
        assert toks == want[rid]


def test_swap_store_rejects_layout_mismatch():
    """A snapshot's host-stored block must match the restoring engine's own
    single-block gather layout — a different cache geometry is rejected,
    never reinterpreted."""
    store = HostBlockStore()
    slabs = {"k": np.zeros((2, 1, 4, 16, 8), np.float32),
             "v": np.zeros((2, 1, 4, 16, 8), np.float32)}
    store.put(slabs, 1)
    state = store.state_dict()

    other = HostBlockStore()
    treedef = jax.tree.structure(slabs)
    good = [((2, 1, 4, 16, 8), "float32")] * 2
    other.load_state(state, treedef=treedef, leaf_avals=good)
    assert len(other) == 1

    bad = [((2, 1, 4, 8, 8), "float32")] * 2   # wrong block_size
    with pytest.raises(ValueError, match="layout"):
        HostBlockStore().load_state(state, treedef=treedef, leaf_avals=bad)
