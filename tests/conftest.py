"""Shared test config.

If `hypothesis` is unavailable (bare CI/container environments), install a
minimal stand-in whose `@given` marks the property-based tests as skipped —
the rest of each module still collects and runs.
"""
import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def _strategy_stub(*_args, **_kwargs):
        return None

    strategies = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "composite", "data", "text"):
        setattr(strategies, _name, _strategy_stub)

    shim = types.ModuleType("hypothesis")
    shim.given = given
    shim.settings = settings
    shim.strategies = strategies
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout(seconds): fail the test if it exceeds the wall-clock "
        "budget (SIGALRM stand-in for pytest-timeout, which this "
        "environment does not ship)")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    """Arm a SIGALRM around @pytest.mark.timeout(N) tests so a hung engine
    sweep fails with a traceback instead of stalling the whole suite."""
    import signal
    marker = item.get_closest_marker("timeout")
    if marker is None or not hasattr(signal, "SIGALRM"):
        yield
        return
    seconds = int(marker.args[0]) if marker.args \
        else int(marker.kwargs.get("seconds", 60))

    def _on_alarm(signum, frame):
        raise TimeoutError(f"test exceeded {seconds}s wall-clock budget")

    old = signal.signal(signal.SIGALRM, _on_alarm)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
