"""Shared test config.

If `hypothesis` is unavailable (bare CI/container environments), install a
minimal stand-in whose `@given` marks the property-based tests as skipped —
the rest of each module still collects and runs.
"""
import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def _strategy_stub(*_args, **_kwargs):
        return None

    strategies = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "composite", "data", "text"):
        setattr(strategies, _name, _strategy_stub)

    shim = types.ModuleType("hypothesis")
    shim.given = given
    shim.settings = settings
    shim.strategies = strategies
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
