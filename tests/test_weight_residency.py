"""Quantized-resident weights: QuantWeight round trips, the matmul_codes
dispatch path, the quantize_params pass, and greedy-serving byte-identity
against the fake-quant reference path (the PR-4 acceptance gate)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_smoke
from repro.core import formats as F
from repro.models import (QuantPolicy, init_params, quantize_params,
                          resident_format)
from repro.models import transformer as T
from repro.models.layers import _maybe_quant_weight
from repro.serving import Request, ServingEngine

FORMATS = ("int4", "int8", "fp8a", "fp8b")


# =============================================================================
# QuantWeight: codes + scales round trips
# =============================================================================

@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("k", [64, 97])
def test_dequantize_matches_per_channel_fake_quant_bitwise(fmt, k):
    """dequantize_weight(quantize_weight(w)) must equal the per-output-
    channel fake-quant of w BITWISE — this is what makes resident and
    fake-quant serving byte-identical."""
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(k, 48).astype(np.float32) * 3.0)
    qw = F.quantize_weight(w, fmt)
    assert qw.fmt == fmt and qw.k == k
    np.testing.assert_array_equal(np.asarray(F.dequantize_weight(qw)),
                                  np.asarray(_maybe_quant_weight(w, fmt)))


def test_int4_residency_packs_two_per_byte_and_roundtrips_bit_exact():
    rng = np.random.RandomState(1)
    for k in (32, 33):
        w = jnp.asarray(rng.randn(k, 16).astype(np.float32))
        qw = F.quantize_weight(w, "int4")
        assert qw.codes.shape == ((k + 1) // 2, 16)
        assert qw.codes.dtype == jnp.int8
        assert qw.bytes_per_param == 0.5
        # unpack -> repack is the identity on the stored bytes
        unpacked = F.unpack_int4(jnp.swapaxes(qw.codes, -1, -2), k=k)
        repacked = jnp.swapaxes(F.pack_int4(unpacked & 0xF), -1, -2)
        np.testing.assert_array_equal(np.asarray(repacked),
                                      np.asarray(qw.codes))


def test_quant_weight_is_a_pytree_with_static_aux():
    w = jnp.ones((8, 4), jnp.float32)
    qw = F.quantize_weight(w, "int8")
    leaves, treedef = jax.tree.flatten(qw)
    assert len(leaves) == 2                       # codes + scale only
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert rebuilt.fmt == "int8" and rebuilt.k == 8
    # leading-axis slicing (what lax.scan does to stacked layer params)
    stacked = jax.tree.map(lambda a: jnp.stack([a, a]), qw)
    sliced = jax.tree.map(lambda a: a[0], stacked)
    assert isinstance(sliced, F.QuantWeight) and sliced.k == 8


def test_rejects_non_resident_formats():
    w = jnp.ones((8, 4), jnp.float32)
    with pytest.raises(ValueError):
        F.quantize_weight(w, "bf16")
    with pytest.raises(ValueError):
        T.quantize_params({"w": w}, "fp16")


# =============================================================================
# api.ops.matmul_codes dispatch
# =============================================================================

@pytest.mark.parametrize("fmt", FORMATS)
def test_matmul_codes_ref_byte_identical_to_fake_quant_dense(fmt):
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(3, 5, 96).astype(np.float32))
    w = jnp.asarray(rng.randn(96, 64).astype(np.float32))
    qw = F.quantize_weight(w, fmt)
    got = api.ops.matmul_codes(x, qw, backend="ref")
    want = jnp.einsum("...d,df->...f", x, _maybe_quant_weight(w, fmt),
                      preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("k", [256, 131])
def test_matmul_codes_pallas_bit_identical_to_on_the_fly_kernel(fmt, k):
    """Skipping the weight half of the quantize-operands stage is purely a
    residency optimization: the Pallas kernel result on stored codes must be
    bit-identical to quantizing the dense weight on the fly (incl. odd K,
    where the int4 phantom nibble meets the zero-padded activations)."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(16, k).astype(np.float32))
    w = jnp.asarray(rng.randn(k, 40).astype(np.float32))
    qw = F.quantize_weight(w, fmt)
    got = api.ops.matmul_codes(x, qw, backend="pallas", interpret=True)
    want = api.ops.matmul(x, w, format=fmt, backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_matmul_codes_rejects_mismatched_k():
    qw = F.quantize_weight(jnp.ones((8, 4), jnp.float32), "int8")
    with pytest.raises(ValueError, match="resident weight K"):
        api.ops.matmul_codes(jnp.ones((2, 7), jnp.float32), qw)


# =============================================================================
# quantize_params pass + model forward/decode
# =============================================================================

def test_quantize_params_coverage_and_accounting():
    cfg = get_smoke("llama2_7b")
    params = init_params(jax.random.key(0), cfg)
    qparams = quantize_params(params, "int4")
    assert resident_format(params) is None
    assert resident_format(qparams) == "int4"
    seg = qparams["segments"][0]
    blk = seg[next(iter(seg))]
    assert isinstance(blk["attn"]["q"]["w"], F.QuantWeight)   # stacked codes
    assert blk["attn"]["q"]["w"].codes.ndim == 3
    assert isinstance(blk["mlp"]["down"]["w"], F.QuantWeight)
    # outside fake-quant coverage -> stays dense (byte-identity requires it)
    assert not isinstance(qparams["lm_head"]["w"], F.QuantWeight)
    assert "table" in qparams["embed"]            # embeddings untouched
    # qkv biases survive conversion next to the codes (qwen2 smoke has them)
    cfg_b = get_smoke("qwen2_1p5b")
    qp_b = quantize_params(init_params(jax.random.key(0), cfg_b), "int8")
    seg_b = qp_b["segments"][0]
    blk_b = seg_b[next(iter(seg_b))]
    assert isinstance(blk_b["attn"]["q"]["w"], F.QuantWeight)
    assert "b" in blk_b["attn"]["q"]


@pytest.mark.parametrize("arch", ["llama2_7b", "qwen2_1p5b"])
@pytest.mark.parametrize("fmt", ["int8", "fp8a"])
def test_forward_byte_identical_resident_vs_fake_quant(arch, fmt):
    cfg = get_smoke(arch)
    cfg_fq = dataclasses.replace(cfg, quant=QuantPolicy(weights=fmt))
    cfg_res = dataclasses.replace(cfg, quant=QuantPolicy(weights=fmt,
                                                         resident=True))
    params = init_params(jax.random.key(0), cfg)
    qparams = quantize_params(params, fmt)
    toks = jnp.asarray(np.random.RandomState(0).randint(
        1, cfg.vocab, (2, 9)), jnp.int32)
    lf, _ = jax.jit(lambda p, t: T.forward(p, t, cfg_fq))(params, toks)
    lr, _ = jax.jit(lambda p, t: T.forward(p, t, cfg_res))(qparams, toks)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lr))


# =============================================================================
# Serving byte-identity: resident codes vs fake-quant reference engine
# =============================================================================

def _serve(cfg, params, reqs, **kw):
    eng = ServingEngine(cfg, params, slots=2, max_len=64, **kw)
    for rid, (p, m) in enumerate(reqs):
        eng.submit(Request(rid, p, max_new_tokens=m))
    done = eng.run_until_drained()
    return eng, {r.rid: r.out_tokens for r in done}


# llama2 smoke is dense MHA (n_kv == n_heads); qwen2 smoke is GQA (4q/2kv)
@pytest.mark.parametrize("arch", ["llama2_7b", "qwen2_1p5b"])
@pytest.mark.parametrize("fmt", ["int8", "fp8a"])
def test_greedy_serving_byte_identical(arch, fmt):
    cfg = get_smoke(arch)
    cfg = dataclasses.replace(cfg, quant=QuantPolicy(weights=fmt))
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.RandomState(0)
    reqs = [(rng.randint(1, cfg.vocab, rng.randint(3, 10)).astype(np.int32),
             int(rng.randint(2, 7))) for _ in range(5)]
    ref_eng, ref_out = _serve(cfg, params, reqs)
    res_eng, res_out = _serve(cfg, params, reqs, weight_format=fmt)
    assert ref_eng.weight_route() == f"fake-quant-{fmt}"
    assert res_eng.weight_route() == f"resident-{fmt}"
    assert res_out == ref_out


def test_engine_rejects_non_resident_weight_format():
    cfg = get_smoke("llama2_7b")
    params = init_params(jax.random.key(0), cfg)
    with pytest.raises(ValueError, match="bf16"):
        ServingEngine(cfg, params, slots=2, max_len=64, weight_format="bf16")


def test_engine_pins_residency_policy_onto_cfg():
    """Handing the engine a pre-quantized pytree (the serve launcher's
    donated load path) must pin cfg.quant to the matching resident policy so
    uncovered linears fall back to the SAME fake-quant plane."""
    cfg = get_smoke("llama2_7b")
    params = quantize_params(init_params(jax.random.key(0), cfg), "int8")
    eng = ServingEngine(cfg, params, slots=2, max_len=64)
    assert eng.cfg.quant.resident and eng.cfg.quant.weights == "int8"
    assert eng.weight_route() == "resident-int8"
