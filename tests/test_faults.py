"""Fault-tolerance matrix: every injected fault class either recovers to
byte-identical greedy output or fails loudly with the right terminal state.

The recoverable rows (logits/KV poison, kernel-launch demotion, latency) must
converge to EXACTLY the un-faulted outputs — quarantine replays the retained
prompt, demotion lands on the byte-identical ref route. The unrecoverable
rows (weight poison) must fail requests terminally and then recover through
snapshot/restore. Hostile submissions must be rejected at `submit()` with a
diagnostic, never inside a trace."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.api import ExecutionPolicy
from repro.configs import get_smoke
from repro.models import init_params
from repro.serving import (EngineStalledError, Fault, FaultPlan,
                           KernelLaunchError, Request, ServingEngine,
                           drive_with_plan)

MAX_LEN = 64
NAN = float("nan")
INF = float("inf")


def _params(seed=0, kv_quant=False):
    cfg = get_smoke("qwen2_1p5b")
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    return cfg, init_params(jax.random.key(seed), cfg)


def _spec(vocab, lens, outs, seed=0):
    rng = np.random.RandomState(seed)
    return [(rng.randint(1, vocab, l).astype(np.int32), m)
            for l, m in zip(lens, outs)]


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("prefill_chunk", 8)
    return ServingEngine(cfg, params, **kw)


def _baseline(cfg, params, spec, **kw):
    eng = _engine(cfg, params, **kw)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    return {r.rid: r.out_tokens for r in eng.run_until_drained()}


def _drain_with(cfg, params, spec, plan, **kw):
    eng = _engine(cfg, params, **kw)
    eng.arm_fault_plan(plan)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    eng.run_until_drained()
    return eng


# ======================================================== poison -> quarantine
@pytest.mark.parametrize("value", [NAN, INF, -INF])
def test_logits_poison_quarantines_and_replays(value):
    """A slot whose logits go non-finite mid-decode is quarantined and its
    request replayed byte-identically from the retained prompt; the other
    slot never notices. The jit cache still holds exactly the two lifetime
    widths — the guard and the replay added no traced shapes."""
    cfg, params = _params()
    spec = _spec(cfg.vocab, [4, 9], [6, 4])
    want = _baseline(cfg, params, spec)

    plan = FaultPlan.single("poison", step=3, slot=0, target="logits",
                            value=value)
    eng = _drain_with(cfg, params, spec, plan)
    got = {r.rid: r.out_tokens for r in eng.finished}
    assert got == want
    assert eng.stats.quarantines == 1
    assert all(r.status == "done" for r in eng.finished)
    assert plan.exhausted() and plan.faults[0].tripped
    assert eng.step_trace_count() == len(eng.step_widths()) == 2


@pytest.mark.parametrize("kv_quant", [False, True],
                         ids=["dense-kv", "int8-kv"])
def test_kv_poison_recovers(kv_quant):
    """Cache corruption (bf16 K rows, or the f32 scales of the int8
    QuantKVCache) surfaces through attention as non-finite logits at the
    slot's next consuming launch; quarantine scrubs the row and the replay
    converges to the un-faulted output."""
    cfg, params = _params(seed=1, kv_quant=kv_quant)
    spec = _spec(cfg.vocab, [5, 11], [5, 3], seed=1)
    want = _baseline(cfg, params, spec)

    plan = FaultPlan.single("poison", step=2, slot=1, target="kv", value=NAN)
    eng = _drain_with(cfg, params, spec, plan)
    got = {r.rid: r.out_tokens for r in eng.finished}
    assert got == want
    assert eng.stats.quarantines >= 1
    assert all(r.status == "done" for r in eng.finished)


def test_replay_budget_exhaustion_fails_request():
    """With the replay budget at zero, the first quarantine is terminal:
    status FAILED, counted, and the engine still drains the healthy slot."""
    cfg, params = _params()
    spec = _spec(cfg.vocab, [4, 6], [5, 5])
    plan = FaultPlan.single("poison", step=3, slot=0, target="logits")
    eng = _drain_with(cfg, params, spec, plan, max_replays=0)
    by_status = {r.status for r in eng.finished}
    assert by_status == {"done", "FAILED"}
    assert eng.stats.failed_requests == 1
    assert len(eng.finished) == 2


# ==================================================== launch-fault -> demotion
def test_launch_fault_demotes_to_ref_byte_identically():
    """An injected kernel-launch failure on a pallas-pinned engine re-pins
    the policy to the ref backend, rebuilds the jits and retries the SAME
    step — outputs match a ref engine exactly, and `degraded_routes()`
    records the before/after routes."""
    cfg, params = _params(seed=2)
    spec = _spec(cfg.vocab, [3, 7], [4, 3], seed=2)
    want = _baseline(cfg, params, spec,
                     policy=ExecutionPolicy(backend="ref"))

    plan = FaultPlan.single("launch", step=0)
    eng = _drain_with(cfg, params, spec, plan,
                      policy=ExecutionPolicy(backend="pallas"))
    got = {r.rid: r.out_tokens for r in eng.finished}
    assert got == want
    assert eng.stats.demotions == 1
    assert eng.policy.backend == "ref"
    (event,) = eng.degraded_routes()
    assert "KernelLaunchError" in event["error"]
    assert event["from"]["decode"].startswith("pallas")
    assert event["to"] == {"decode": "ref", "prefill": "ref"}


def test_dispatch_boundary_fault_demotes_unwarmed_engine():
    """A dispatch-boundary fault fires inside the registry hook the first
    time the step TRACES (the lowering-failure stand-in); the engine demotes
    and the retry traces straight down the ref route."""
    cfg, params = _params(seed=2)
    spec = _spec(cfg.vocab, [3], [3], seed=2)
    want = _baseline(cfg, params, spec,
                     policy=ExecutionPolicy(backend="ref"))

    plan = FaultPlan.single("launch", step=0, boundary="dispatch")
    eng = _drain_with(cfg, params, spec, plan,
                      policy=ExecutionPolicy(backend="pallas"))
    assert {r.rid: r.out_tokens for r in eng.finished} == want
    assert eng.stats.demotions == 1
    assert plan.faults[0].tripped


def test_launch_fault_on_ref_engine_raises():
    """No route below ref: the failure propagates instead of demoting."""
    cfg, params = _params()
    eng = _engine(cfg, params, policy=ExecutionPolicy(backend="ref"))
    eng.arm_fault_plan(FaultPlan.single("launch", step=0))
    eng.submit(Request(0, np.asarray([1, 2, 3], np.int32), max_new_tokens=2))
    with pytest.raises(KernelLaunchError):
        eng.run_until_drained()
    assert eng.stats.demotions == 0


# ================================================================== latency
def test_latency_fault_delays_but_never_corrupts():
    cfg, params = _params()
    spec = _spec(cfg.vocab, [4, 6], [3, 3])
    want = _baseline(cfg, params, spec)

    plan = FaultPlan.single("latency", step=1, delay_s=0.2)
    t0 = time.monotonic()
    eng = _drain_with(cfg, params, spec, plan)
    assert time.monotonic() - t0 >= 0.2
    assert {r.rid: r.out_tokens for r in eng.finished} == want
    assert plan.faults[0].tripped
    assert eng.stats.quarantines == eng.stats.demotions == 0


# ========================================================== malformed inputs
def test_malformed_matrix_rejected_cleanly():
    """Every hostile-submission defect is turned away at submit() with a
    ValueError/TypeError diagnostic; the well-formed request in flight is
    untouched."""
    from repro.serving.faults import MALFORMED_KINDS
    cfg, params = _params()
    spec = _spec(cfg.vocab, [5], [4])
    want = _baseline(cfg, params, spec)

    plan = FaultPlan([Fault("malformed", step=i, target=d)
                      for i, d in enumerate(MALFORMED_KINDS)])
    eng = _engine(cfg, params)
    eng.submit(Request(0, spec[0][0], max_new_tokens=spec[0][1]))
    finished, rejections = drive_with_plan(eng, plan)
    assert len(rejections) == len(MALFORMED_KINDS)
    assert all(msg for _, _, msg in rejections)
    assert plan.exhausted()
    assert {r.rid: r.out_tokens for r in finished} == want


def test_max_new_tokens_zero_still_legal():
    """0 is a valid budget (emit nothing) — hardening must not break it."""
    cfg, params = _params()
    eng = _engine(cfg, params)
    assert eng.submit(Request(7, np.asarray([1, 2], np.int32),
                              max_new_tokens=0))
    (req,) = eng.run_until_drained()
    assert req.rid == 7 and req.out_tokens == [] and req.status == "done"


# ============================================================== seeded sweep
def test_seeded_plan_is_deterministic_and_recovers():
    """Same seed -> same plan; a seeded mix of recoverable faults converges
    to the un-faulted outputs."""
    kinds = ("poison", "latency")
    assert (FaultPlan.seeded(11, steps=10, slots=2, kinds=kinds).describe()
            == FaultPlan.seeded(11, steps=10, slots=2,
                                kinds=kinds).describe())
    cfg, params = _params(seed=3)
    spec = _spec(cfg.vocab, [4, 8, 5], [5, 3, 4], seed=3)
    want = _baseline(cfg, params, spec)
    plan = FaultPlan.seeded(11, steps=10, slots=2, kinds=kinds, n_faults=4)
    eng = _drain_with(cfg, params, spec, plan, max_replays=8)
    assert {r.rid: r.out_tokens for r in eng.finished} == want
    assert all(r.status == "done" for r in eng.finished)


# ===================================== weight poison -> snapshot/restore
def test_weight_poison_fails_over_to_snapshot_restore(tmp_path):
    """Weight corruption hits every slot at once — quarantine cannot help,
    so requests burn their replay budget and FAIL; restoring the pre-fault
    snapshot (params included) replays the stream byte-identically."""
    cfg, params = _params(seed=4)
    spec = _spec(cfg.vocab, [4, 9], [6, 5], seed=4)
    want = _baseline(cfg, params, spec, weight_format="int8")

    eng = _engine(cfg, params, weight_format="int8", max_replays=1)
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m))
    eng.step()
    eng.step()
    eng.snapshot(tmp_path, include_params=True)

    eng.arm_fault_plan(FaultPlan.single(
        "poison", step=eng.step_no, target="weight", value=NAN))
    eng.run_until_drained()
    assert all(r.status == "FAILED" for r in eng.finished)
    assert eng.stats.failed_requests == len(spec)
    assert eng.stats.quarantines >= len(spec)

    eng.arm_fault_plan(None)
    eng.restore(tmp_path)
    got = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    assert got == want
    assert all(r.status == "done" for r in eng.finished)


# =============================================== snapshot/restore round trips
@pytest.mark.parametrize("variant", ["dense", "int8-kv", "resident-int8"])
def test_snapshot_restore_midstream_byte_identical(variant, tmp_path):
    """Snapshot a busy engine mid-stream (rows mid-prefill AND mid-decode),
    restore into a FRESH engine, and finish: the restored engine's outputs
    must be byte-identical to the original continuing — across the dense,
    quantized-KV and resident-weight cache/param layouts."""
    cfg, params = _params(seed=5, kv_quant=(variant == "int8-kv"))
    kw = {"weight_format": "int8"} if variant == "resident-int8" else {}
    spec = _spec(cfg.vocab, [4, 10, 6], [5, 4, 6], seed=5)

    a = _engine(cfg, params, **kw)
    for rid, (p, m) in enumerate(spec):
        a.submit(Request(rid, p, max_new_tokens=m))
    for _ in range(3):
        a.step()
    pre = {r.rid for r in a.finished}
    a.snapshot(tmp_path)

    b = _engine(cfg, params, **kw)
    assert b.restore(tmp_path) == 3
    got_b = {r.rid: r.out_tokens for r in b.run_until_drained()}

    a.run_until_drained()
    got_a = {r.rid: r.out_tokens for r in a.finished if r.rid not in pre}
    assert got_b == got_a
    assert set(got_b) | pre == set(range(len(spec)))


def test_restore_rejects_geometry_mismatch(tmp_path):
    """A snapshot only restores into a same-shaped engine: cache-shape
    drift (different max_len here) raises instead of silently mixing."""
    cfg, params = _params()
    _engine(cfg, params, slots=2).snapshot(tmp_path)
    with pytest.raises(ValueError):
        _engine(cfg, params, slots=2,
                max_len=MAX_LEN // 2).restore(tmp_path)


# ===================================================== deadlines / timeouts
def test_deadline_steps_times_out_resident_request():
    """A per-request step deadline finishes the request with status TIMEOUT
    (deterministic — counted in engine steps, not wall clock)."""
    cfg, params = _params()
    eng = _engine(cfg, params)
    eng.submit(Request(0, np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=40, deadline_steps=3))
    eng.submit(Request(1, np.asarray([4, 5], np.int32), max_new_tokens=2))
    done = eng.run_until_drained()
    by = {r.rid: r for r in done}
    assert by[0].status == "TIMEOUT" and by[0].done
    assert len(by[0].out_tokens) < 40
    assert by[1].status == "done" and len(by[1].out_tokens) == 2
    assert eng.stats.timeouts == 1


def test_ttl_times_out_queued_request():
    """A wall-clock TTL expires a request that never reached a slot."""
    cfg, params = _params()
    eng = _engine(cfg, params, slots=1)
    eng.submit(Request(0, np.asarray([1, 2], np.int32), max_new_tokens=3))
    eng.submit(Request(1, np.asarray([3, 4], np.int32), max_new_tokens=3,
                       ttl_s=0.0))
    time.sleep(0.01)
    done = eng.run_until_drained()
    by = {r.rid: r for r in done}
    assert by[1].status == "TIMEOUT" and by[1].out_tokens == []
    assert by[0].status == "done"
    assert eng.stats.timeouts == 1


# ================================================= backpressure / stall
def test_bounded_queue_backpressure():
    """max_queue bounds admission: the overflowing submit returns False,
    marks the request REJECTED, and queues nothing; a later submit (after
    the queue drains into a slot) is accepted again."""
    cfg, params = _params()
    eng = _engine(cfg, params, slots=1, max_queue=1)
    a = Request(0, np.asarray([1, 2, 3], np.int32), max_new_tokens=2)
    b = Request(1, np.asarray([4, 5], np.int32), max_new_tokens=2)
    c = Request(2, np.asarray([6, 7], np.int32), max_new_tokens=2)
    assert eng.submit(a) is True
    assert eng.submit(b) is False
    assert b.status == "REJECTED" and eng.stats.rejected_submits == 1
    eng.step()                      # a admitted; queue has room again
    assert eng.submit(c) is True
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 2]


def test_stalled_drain_raises_diagnostic():
    """run_until_drained over budget raises EngineStalledError carrying the
    stuck occupancy and queue depth instead of a bare step count."""
    cfg, params = _params()
    eng = _engine(cfg, params, slots=1)
    eng.submit(Request(0, np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=30))
    eng.submit(Request(1, np.asarray([4, 5], np.int32), max_new_tokens=5))
    with pytest.raises(EngineStalledError) as ei:
        eng.run_until_drained(max_steps=3)
    assert ei.value.stuck and ei.value.stuck[0]["rid"] == 0
    assert ei.value.queue_depth == 1
    assert "stuck slot" in str(ei.value)


# ============================================================ submit hygiene
@pytest.mark.parametrize("defect,exc", [
    ("empty-prompt", ValueError), ("float-prompt", TypeError),
    ("2d-prompt", ValueError), ("negative-max-new", ValueError),
    ("float-max-new", TypeError), ("absurd-max-new", ValueError)])
def test_submit_rejects_each_defect(defect, exc):
    from repro.serving.faults import malformed_request
    cfg, params = _params()
    eng = _engine(cfg, params)
    with pytest.raises(exc):
        eng.submit(malformed_request(defect))
    assert not eng.pending()
