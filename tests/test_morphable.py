"""Tests for the morphable-array abstractions, mapping math, and custom ISA."""
import math

import pytest

from repro.core import isa, mapping, morphable
from repro.core.mapping import GemmShape


# ------------------------------------------------------------- fusion plans
def test_fig8_plans_present():
    plans = morphable.enumerate_fusion_plans()
    descs = {tuple(sorted((a.rows, a.cols) for a in p.arrays)) for p in plans}
    # Fig 8 (e): four 64x64
    assert tuple(sorted([(64, 64)] * 4)) in descs
    # Fig 8 (f): two 64x128
    assert tuple(sorted([(64, 128)] * 2)) in descs
    # Fig 8 (g): one 128x64 + two 64x64
    assert tuple(sorted([(128, 64), (64, 64), (64, 64)])) in descs
    # Fig 8 (h): one 128x128
    assert ((128, 128),) in descs


def test_all_plans_are_partitions():
    for plan in morphable.enumerate_fusion_plans():
        blocks = [b for a in plan.arrays for b in a.blocks]
        assert sorted(blocks) == [0, 1, 2, 3]
        assert sum(a.n_macs for a in plan.arrays) == 128 * 128


def test_no_L_shaped_fusions():
    # {0,1,2} is an L — must never appear as one fused array.
    for plan in morphable.enumerate_fusion_plans():
        for a in plan.arrays:
            assert len(a.blocks) in (1, 2, 4)


def test_precision_morph():
    assert morphable.precision_morph(128, 128, "bf16") == (128, 128)
    assert morphable.precision_morph(128, 128, "int8") == (128, 128)
    # Table III: FP8/INT4 double each dimension
    assert morphable.precision_morph(128, 128, "fp8a") == (256, 256)
    assert morphable.precision_morph(64, 128, "int4") == (128, 256)


def test_plan_for_two_wide_tenants_fissions():
    """Fig 3's failure case: two wide GEMMs must land on separate partitions."""
    plan, assign = morphable.plan_for_tenants([(64, 512), (64, 768)])
    assert plan.n_partitions >= 2
    assert assign[0] != assign[1]


def test_plan_for_single_square_tenant_fuses():
    plan, assign = morphable.plan_for_tenants([(4096, 4096)])
    assert plan.n_partitions == 1
    assert plan.arrays[0].rows == plan.arrays[0].cols == 128


# ------------------------------------------------------------- mapping math
def test_eq1_latency_matches_paper_formula():
    s = GemmShape(s_c=300, t=128, s_r=256)
    want = (2 * 256 + 300 - 2) * math.ceil(256 / 128) * math.ceil(300 / 128)
    assert mapping.systolic_latency(s, 128, 128) == want


def test_depthwise_3x3_block_utilization_exceeds_99pct():
    """Paper §IV-B: 7*9*64 + 63 of 4096 MACs -> >99%."""
    u = mapping.unaccumulable_util_allrounder(taps=9)
    assert u > 0.99
    assert u == pytest.approx((7 * 9 * 64 + 63) / 4096)


def test_depthwise_rigid_sa_is_bus_bound():
    # 3x3 depthwise on a 128-row rigid SA: 9/128 ~ 7%
    u = mapping.unaccumulable_util_rigid(taps=9, rows=128)
    assert u == pytest.approx(9 / 128)
    assert mapping.unaccumulable_util_allrounder(9) / u > 10


def test_lrmu_grouping():
    assert mapping.lrmu_groups(9) == 7      # Fig 9-(b): 7 groups of 9 = 63
    assert mapping.lrmu_groups(25) == 2


def test_accumulable_utilization_full_tiles():
    s = GemmShape(s_c=1024, t=256, s_r=512)
    assert mapping.accumulable_utilization(s, 128, 128) == pytest.approx(1.0)


def test_accumulable_utilization_ragged():
    s = GemmShape(s_c=1024, t=130, s_r=514)
    u = mapping.accumulable_utilization(s, 128, 128)
    assert u == pytest.approx((130 * 514) / (2 * 128 * 5 * 128))


def test_classify():
    assert mapping.classify("depthwise_conv") is mapping.OpKind.UNACCUMULABLE
    assert mapping.classify("weight_gradient") is mapping.OpKind.UNACCUMULABLE
    assert mapping.classify("gemm") is mapping.OpKind.ACCUMULABLE
    with pytest.raises(ValueError):
        mapping.classify("fft")


# ------------------------------------------------------------- ISA
def test_instruction_stream_roundtrip_and_order():
    plan, _ = morphable.plan_for_tenants([(256, 256), (128, 128)])
    stream = isa.build_gemm_stream(plan, [(2, 3), (1, 2)])
    isa.validate_stream(stream)  # should not raise
    words = [i.encode() for i in stream]
    assert all(0 <= w < 2 ** 32 for w in words)
    # opcodes use the RISC-V custom fields
    assert {w & 0x7F for w in words} <= {isa.OPCODE_A, isa.OPCODE_B}


def test_stream_validation_rejects_out_of_order():
    bad = [isa.matrix_multiply(0, 0, 16)]
    with pytest.raises(isa.StreamError):
        isa.validate_stream(bad)
    bad2 = [isa.read_weights(0, 0, 16), isa.matrix_multiply(0, 0, 16)]
    with pytest.raises(isa.StreamError):
        isa.validate_stream(bad2)
    # unterminated block
    bad3 = [isa.read_weights(0, 0, 16),
            isa.start_compute(0, 0, 0, 7, True)]
    with pytest.raises(isa.StreamError):
        isa.validate_stream(bad3)
