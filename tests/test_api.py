"""repro.api contract tests: registry dispatch, ExecutionPolicy resolution,
and bit-for-bit equivalence with the legacy per-kernel kwarg surface."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import api
from repro.api.registry import KernelRegistry
from repro.kernels import common
from repro.kernels.aio_matmul import aio_matmul
from repro.kernels.aio_quant import aio_quantize
from repro.kernels.depthwise import depthwise_conv
from repro.kernels.flash_attention import attention
from repro.kernels.grouped_matmul import grouped_matmul, morphable_multi_gemm

RNG = np.random.RandomState(7)


def randn(*shape, scale=1.0):
    return jnp.asarray(RNG.randn(*shape).astype(np.float32) * scale)


# ======================================================================
# ExecutionPolicy semantics
# ======================================================================

def test_policy_defaults_and_validation():
    pol = api.ExecutionPolicy()
    assert pol.format == "bf16" and pol.backend == "auto"
    assert not pol.use_pallas()                     # auto + flag off -> ref
    with pytest.raises(ValueError):
        api.ExecutionPolicy(backend="cuda")
    with pytest.raises(ValueError):
        api.ExecutionPolicy(format="fp64")


def test_policy_is_hashable_static_arg():
    a = api.ExecutionPolicy(format="int8", backend="ref")
    b = api.ExecutionPolicy(format="int8", backend="ref")
    assert a == b and hash(a) == hash(b)
    assert a != api.ExecutionPolicy(format="int4", backend="ref")


def test_policy_context_nesting_inherits_unset_fields():
    with api.policy(format="int8"):
        assert api.current_policy().format == "int8"
        with api.policy(backend="ref", bm=64):
            inner = api.current_policy()
            assert inner.format == "int8"           # inherited from outer
            assert inner.backend == "ref" and inner.bm == 64
        assert api.current_policy().backend == "auto"   # popped
    assert api.current_policy() == api.default_policy


def test_policy_auto_backend_defers_to_legacy_flag():
    assert api.ExecutionPolicy().impl() == "ref"
    with common.use_pallas():
        assert api.ExecutionPolicy().impl() == "pallas"
    assert api.ExecutionPolicy(backend="pallas").impl() == "pallas"


def test_policy_object_installable_verbatim():
    pol = api.ExecutionPolicy(format="fp8a", backend="ref", bk=64)
    with api.policy(pol):
        assert api.current_policy() == pol
    with api.policy(pol, format="int8"):
        assert api.current_policy() == pol.override(format="int8")


# ======================================================================
# Registry dispatch
# ======================================================================

def test_registry_lists_all_six_ops_with_both_impls():
    ops = api.registry.ops()
    assert ops == ["attention", "depthwise_conv", "grouped_matmul",
                   "matmul", "matmul_codes", "quantize"]
    for op in ops:
        want = ["pallas", "pallas-decode", "pallas-prefill", "ref"] \
            if op == "attention" else ["pallas", "ref"]
        assert api.registry.implementations(op) == want


def test_registry_unknown_key_raises_with_catalog():
    with pytest.raises(KeyError, match="matmul"):
        api.registry.lookup("matmul", "cuda")


def test_fresh_registry_dispatches_by_key():
    reg = KernelRegistry()
    reg._loaded = True                              # no kernel autoload

    @reg.register("op", "ref")
    def ref_impl(*, policy):
        return ("ref", policy.format)

    @reg.register("op", "pallas")
    def pallas_impl(*, policy):
        return ("pallas", policy.format)

    pol = api.ExecutionPolicy(format="int8")
    assert reg.dispatch("op", "ref", policy=pol) == ("ref", "int8")
    assert reg.dispatch("op", "pallas", policy=pol) == ("pallas", "int8")


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("op,args", [
    ("matmul", lambda: (randn(64, 64), randn(64, 64))),
    ("quantize", lambda: (randn(32, 64),)),
])
def test_dispatch_reaches_selected_impl(op, args, impl, monkeypatch):
    """`backend=` must route to exactly the registered (op, impl) callable."""
    sentinel = {}
    real = api.registry.lookup(op, impl)

    def spy(*a, **kw):
        sentinel["impl"] = impl
        return real(*a, **kw)

    monkeypatch.setitem(api.registry._impls, (op, impl), spy)
    backend = "pallas" if impl == "pallas" else "ref"
    getattr(api.ops, op)(*args(), backend=backend)
    assert sentinel.get("impl") == impl


# ======================================================================
# (op x format x impl) parity with the legacy kwarg surface
# ======================================================================

@pytest.mark.parametrize("fmt", ["bf16", "fp8a", "fp8b", "int8", "int4"])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_matmul_matches_legacy_prefer_pallas(fmt, impl):
    x, w = randn(96, 80), randn(80, 72)
    prefer = impl == "pallas"
    legacy = aio_matmul(x, w, mode=fmt, prefer_pallas=prefer)
    with api.policy(format=fmt, backend=impl):
        new = api.ops.matmul(x, w)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))


@pytest.mark.parametrize("fmt", ["fp8a", "int8", "int4"])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_quantize_matches_legacy(fmt, impl):
    x = randn(48, 96, scale=2.0)
    prefer = impl == "pallas"
    lc, ls = aio_quantize(x, fmt_name=fmt, prefer_pallas=prefer)
    with api.policy(format=fmt, backend=impl):
        nc, ns = api.ops.quantize(x)
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(nc))
    np.testing.assert_array_equal(np.asarray(ls), np.asarray(ns))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_attention_matches_legacy(impl):
    q = randn(1, 4, 128, 32, scale=0.3)
    k = randn(1, 2, 128, 32, scale=0.3)
    v = randn(1, 2, 128, 32)
    prefer = impl == "pallas"
    legacy = attention(q, k, v, causal=True, prefer_pallas=prefer)
    with api.policy(backend=impl):
        new = api.ops.attention(q, k, v, causal=True)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))


def test_attention_pallas_falls_back_on_unaligned_lq():
    q, k, v = randn(1, 2, 100, 16), randn(1, 2, 100, 16), randn(1, 2, 100, 16)
    with api.policy(backend="pallas"):
        out = api.ops.attention(q, k, v)           # Lq % 128 != 0 -> ref
    ref = api.ops.attention(q, k, v, backend="ref")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_depthwise_matches_legacy(impl):
    x, f = randn(1, 12, 10, 24), randn(3, 3, 24)
    prefer = impl == "pallas"
    legacy = depthwise_conv(x, f, prefer_pallas=prefer)
    with api.policy(backend=impl):
        new = api.ops.depthwise_conv(x, f)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_grouped_matmul_matches_legacy(impl):
    x = randn(256, 40)
    w = randn(2, 40, 48)
    prefer = impl == "pallas"
    legacy = grouped_matmul(x, w, (128, 128), prefer_pallas=prefer)
    with api.policy(backend=impl):
        new = api.ops.grouped_matmul(x, w, (128, 128))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_morphable_multi_gemm_matches_legacy(impl):
    tenants = [(randn(100, 64), randn(64, 96)), (randn(60, 40), randn(40, 30))]
    prefer = impl == "pallas"
    legacy_res, legacy_util = morphable_multi_gemm(tenants,
                                                   prefer_pallas=prefer)
    with api.policy(backend=impl):
        new_res, new_util = api.ops.morphable_multi_gemm(tenants)
    assert legacy_util == new_util
    for a, b in zip(legacy_res, new_res):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ======================================================================
# One policy drives every op (the acceptance-criterion scenario)
# ======================================================================

def test_one_policy_changes_every_op_without_per_call_kwargs():
    x, w = randn(64, 64), randn(64, 64)
    gx, gw = randn(128, 32), randn(1, 32, 32)
    with api.policy(format="int4", backend="ref"):
        out_mm = api.ops.matmul(x, w)
        out_q, _ = api.ops.quantize(x)
        out_g = api.ops.grouped_matmul(gx, gw, (128,))
    # matmul really ran int4: identical to explicitly-int4, not to bf16
    np.testing.assert_array_equal(
        np.asarray(out_mm),
        np.asarray(aio_matmul(x, w, mode="int4", prefer_pallas=False)))
    assert not np.allclose(
        np.asarray(out_mm),
        np.asarray(aio_matmul(x, w, mode="bf16", prefer_pallas=False)))
    # quantize really ran int4: codes identical to the explicit-int4 path
    ref_q, _ = aio_quantize(x, fmt_name="int4", prefer_pallas=False)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(ref_q))
    assert out_g.shape == (128, 32)


def test_irrelevant_policy_fields_do_not_retrace_matmul():
    """Dispatch reduces the policy to the fields the op consumes, so e.g. an
    attention-only `chunk` override must not recompile matmuls."""
    from repro.kernels.aio_matmul.ops import _matmul_ref
    x, w = jnp.ones((16, 16)), jnp.ones((16, 16))
    api.ops.matmul(x, w, backend="ref")
    before = _matmul_ref._cache_size()
    with api.policy(backend="ref", chunk=4096, bh=4):   # matmul-irrelevant
        api.ops.matmul(x, w)
    assert _matmul_ref._cache_size() == before
    with api.policy(backend="ref", bk=64):              # matmul-relevant
        api.ops.matmul(x, w)
    assert _matmul_ref._cache_size() == before + 1


def test_per_call_override_beats_ambient_policy():
    x, w = randn(64, 64), randn(64, 64)
    with api.policy(format="bf16", backend="ref"):
        out = api.ops.matmul(x, w, format="int8")
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(aio_matmul(x, w, mode="int8", prefer_pallas=False)))


# ===================================================== lookup error messages
def test_lookup_unknown_op_lists_registered_ops():
    with pytest.raises(KeyError, match="unknown op 'convolve3d'") as ei:
        api.registry.lookup("convolve3d", "pallas")
    msg = str(ei.value)
    assert "attention" in msg and "matmul" in msg and "quantize" in msg


def test_lookup_unknown_impl_lists_available_impls():
    with pytest.raises(KeyError, match="no 'cuda' implementation") as ei:
        api.registry.lookup("attention", "cuda")
    msg = str(ei.value)
    assert "pallas-decode" in msg and "pallas-prefill" in msg and "ref" in msg


# ========================================================== policy nesting
def test_policy_stack_pops_on_exception():
    base = api.current_policy()
    with pytest.raises(RuntimeError, match="boom"):
        with api.policy(format="int4"):
            assert api.current_policy().format == "int4"
            raise RuntimeError("boom")
    assert api.current_policy() == base


def test_policy_stack_unwinds_nested_exception_to_outer_level():
    with api.policy(format="int8"):
        with pytest.raises(ValueError, match="inner"):
            with api.policy(bm=64):
                raise ValueError("inner")
        assert api.current_policy().format == "int8"
        assert api.current_policy().bm == 128         # inner level gone
    assert api.current_policy() == api.default_policy


def test_override_ignores_none_and_leaves_original_frozen():
    p = api.ExecutionPolicy(format="int8")
    q = p.override(bm=64, bn=None)
    assert (q.bm, q.bn, q.format) == (64, 128, "int8")
    assert p.bm == 128                                # p untouched
    assert p.override() is p                          # no-op returns self


def test_current_policy_defaults_outside_any_context():
    assert api.current_policy() == api.default_policy
    assert api.current_policy().backend == "auto"


# ============================================================ policy sweep
def test_policy_sweep_is_cartesian_product_of_tile_values():
    pols = api.policy_sweep(("bm", "bkv"))
    assert {(p.bm, p.bkv) for p in pols} == {
        (128, 128), (128, 16), (64, 128), (64, 16)}
    assert all(p.bn == 128 for p in pols)             # unswept stays default


def test_policy_sweep_empty_fields_yields_base_only():
    (p,) = api.policy_sweep(())
    assert p == api.default_policy


def test_policy_sweep_rejects_non_tile_field():
    with pytest.raises(ValueError, match="format"):
        api.policy_sweep(("format",))


def test_policy_sweep_custom_values_on_custom_base():
    base = api.ExecutionPolicy(format="int4")
    pols = api.policy_sweep(("bm",), base=base, values={"bm": (32, 16)})
    assert [p.bm for p in pols] == [32, 16]
    assert all(p.format == "int4" for p in pols)
