"""Fig 15 — training-step speedup / area efficiency / energy efficiency of
each accelerator over the TPU-like SA, bf16 and hybrid FP8."""
import math

from repro.perfmodel.simulate import speedup_table


def _avg(table, acc, key):
    vals = [row[acc][key] for row in table.values()]
    return math.exp(sum(math.log(max(v, 1e-12)) for v in vals) / len(vals))


def run():
    rows = []
    for fmt in ("bf16", "fp8a"):
        t = speedup_table(fmt)
        for model, accs in t.items():
            rows.append((f"fig15.{fmt}.{model}", 0.0,
                         "|".join(f"{a}:spd={v['speedup']:.2f},"
                                  f"ae={v['area_eff']:.2f},"
                                  f"ee={v['energy_eff']:.2f}"
                                  for a, v in accs.items() if a != "tpu_sa")))
        for key, label in (("speedup", "speedup"), ("area_eff", "area_eff"),
                           ("energy_eff", "energy_eff")):
            rows.append((f"fig15.{fmt}.avg_allrounder_{label}", 0.0,
                         f"{_avg(t, 'allrounder', key):.2f}x_vs_tpu"))
    return rows
