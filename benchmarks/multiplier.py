"""Table II — the all-in-one multiplier vs dedicated-multiplier baselines.

Hardware area/energy are the paper's synthesized constants; what we measure
here is the FUNCTIONAL plane: bit-exact coverage of every supported format by
the one datapath (the paper's point: one CSM serves all formats), plus the
wall-clock of the software emulation (quantize+matmul per format).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import aio_mac as M
from repro.core import formats as F

# Paper Table II constants (synthesis, 28nm)
TABLE2 = {
    "area_um2": {"ours": 1132.33, "baseline1": 1555.16, "baseline2": 1822.77},
    "freq_mhz": {"ours": 429, "baseline1": 435, "baseline2": 435},
    "energy_pj": {
        "bf16": {"ours": 3.26, "baseline1": 3.58, "baseline2": 3.62},
        "fp8a": {"ours": 2.83, "baseline1": 3.03, "baseline2": 3.06},
        "fp8b": {"ours": 2.72, "baseline1": 2.72, "baseline2": 2.74},
        "int8": {"ours": 3.03, "baseline1": 3.34, "baseline2": 3.34},
        "int4": {"ours": 2.74, "baseline1": 3.03, "baseline2": 3.06},
    },
}


def _check_bit_exact(fmt, out_fmt):
    codes = np.arange(1 << fmt.total_bits)
    if fmt.reserve_specials:
        e = (codes >> fmt.mbits) & ((1 << fmt.ebits) - 1)
        codes = codes[e != (1 << fmt.ebits) - 1]
    rng = np.random.RandomState(0)
    a = rng.choice(codes, 4096)
    b = rng.choice(codes, 4096)
    got = M.aio_fp_multiply(a, b, fmt, fmt, out_fmt)
    va, vb = F.np_decode_fp(a, fmt), F.np_decode_fp(b, fmt)
    want = F.np_encode_fp(va * vb, out_fmt)
    return int((got != want).sum())


def run():
    rows = []
    # functional coverage: every FP mode through the single reconstructed CSM
    mism = 0
    for name in ("bf16", "fp8a", "fp8b"):
        mism += _check_bit_exact(F.REGISTRY[name], F.BF16)
    for ebits in range(1, 9):
        mism += _check_bit_exact(F.fp_format("t", ebits, 3), F.BF16)
    rng = np.random.RandomState(1)
    for fmt in (F.INT8, F.INT4, F.UINT8, F.UINT4):
        shape = (2048, 4) if fmt.bits == 4 else (8192,)
        a = rng.randint(fmt.int_min, fmt.int_max + 1, shape)
        b = rng.randint(fmt.int_min, fmt.int_max + 1, shape)
        mism += int((M.aio_int_multiply(a, b, fmt, fmt) != a * b).sum())
    rows.append(("table2.bit_exact_all_formats", 0.0, f"mismatches={mism}"))

    # area/energy ratios (paper constants -> the claims in §VI-A)
    a = TABLE2["area_um2"]
    rows.append(("table2.area_ratio_vs_baseline1", 0.0,
                 f"{a['baseline1'] / a['ours']:.2f}x_smaller"))
    rows.append(("table2.area_ratio_vs_baseline2", 0.0,
                 f"{a['baseline2'] / a['ours']:.2f}x_smaller"))

    # emulation throughput per format (jit'd quantized matmul, CPU wall time)
    x = jnp.asarray(np.random.RandomState(2).randn(256, 256), jnp.float32)
    w = jnp.asarray(np.random.RandomState(3).randn(256, 256), jnp.float32)
    for mode in ("bf16", "fp8a", "fp8b", "int8", "int4"):
        f = jax.jit(lambda x, w, m=mode: api.ops.matmul(x, w, format=m,
                                                        backend="ref"))
        f(x, w).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            f(x, w).block_until_ready()
        us = (time.perf_counter() - t0) / 20 * 1e6
        rows.append((f"table2.emulated_matmul_{mode}", round(us, 1),
                     f"energy_pj_per_op={TABLE2['energy_pj'][mode]['ours']}"))
    return rows
