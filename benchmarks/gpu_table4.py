"""Table IV — All-rounder (bf16 training) vs NVIDIA RTX 3090 constants.

The paper scales its 28nm numbers to the GPU's 8nm node per [12]; we apply
an approximate Dennard-limited 28->8nm scaling (freq x1.5, power x0.5) and
report both raw and scaled figures."""
from repro.perfmodel.simulate import gpu_comparison

FREQ_SCALE_8NM = 1.5
POWER_SCALE_8NM = 0.5


def run():
    rows = []
    t = gpu_comparison(["vgg16", "resnet18", "mobilenetv2"])
    for model, r in t.items():
        gpu = r["gpu"]
        ms_8nm = r["allrounder_ms"] / FREQ_SCALE_8NM
        # throughput/W: x freq for throughput, / power for the denominator
        gw_8nm = r["allrounder_gflops_w"] * FREQ_SCALE_8NM / POWER_SCALE_8NM
        ratio = (gw_8nm / gpu["gflops_w"]) if gpu else 0
        rows.append((f"table4.{model}", round(ms_8nm * 1e3, 1),
                     f"ar_ms_28nm={r['allrounder_ms']:.1f}"
                     f"|ar_ms_8nm={ms_8nm:.1f}"
                     f"|ar_gflops_w_8nm={gw_8nm:.0f}"
                     f"|gpu_ms={gpu['runtime_ms']}|gpu_gflops_w={gpu['gflops_w']}"
                     f"|eff_gain={ratio:.1f}x"))
    return rows
