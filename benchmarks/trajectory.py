"""Perf-trajectory gate: compare fresh BENCH_*.json against the committed
baselines in benchmarks/baselines/ and fail on regressions.

Gated metrics (matched on the flattened dot-path key's leaf name):

- ``*tok_s``       higher-is-better: fresh must be >= baseline / (1 + tol).
                   Baselines below 1.0 tok/s are noise-dominated and skipped.
- ``*_us/_ms/_s``  lower-is-better: fresh must be <= baseline * (1 + tol)
                   plus an absolute noise floor per unit (200us / 20ms /
                   0.5s) so near-zero timings can't trip the relative gate.
- booleans         correctness flags (``*matches*``, ``identical``, ...)
                   that were true at baseline must stay true — tolerance
                   never applies.

Everything else (visit counts, occupancy, hit rates, shapes) is carried as
informational context, not gated: those change legitimately whenever the
workload definition changes, and the benches themselves hard-fail on the
correctness invariants that matter.

The default tolerance is deliberately loose (100%): CI runners are shared
and interpret-mode wall-clock is noisy; the gate exists to catch order-of-
magnitude cliffs (an accidentally retraced jit, a dropped donation, a dense
fallback), not 10% jitter.

Run:  PYTHONPATH=src python -m benchmarks.trajectory            # gate
      PYTHONPATH=src python -m benchmarks.trajectory --write-baseline
          # ratchet: copy the fresh results over the committed baselines
"""
import argparse
import json
import os
import shutil
import sys

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
DEFAULT_FILES = ("BENCH_decode.json", "BENCH_prefill.json", "BENCH_wq.json",
                 "BENCH_faults.json", "BENCH_kv.json")
# absolute slack added on top of the relative tolerance for lower-is-better
# timings: interpret-mode microbenches jitter by this much run to run
NOISE_FLOOR = {"_us": 200.0, "_ms": 20.0, "_s": 0.5}
MIN_TOK_S = 1.0  # tok/s baselines below this are noise, not signal


def _flatten(obj, prefix=""):
    """{'a': {'b': 1}} -> {'a.b': 1}; lists index as a.0, a.1, ..."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(_flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = obj
    return out


def _classify(key):
    """Return the gate class for a flattened metric key, or None."""
    leaf = key.rsplit(".", 1)[-1]
    if leaf.endswith("tok_s"):
        return "higher"
    for suf in ("_us", "_ms", "_s"):
        if leaf.endswith(suf) or leaf == suf[1:]:
            return "lower", suf
    return None


def compare(baseline, fresh, tol):
    """-> (violations, checked, info) comparing two flattened dicts."""
    violations, checked, info = [], 0, []
    for key, base in baseline.items():
        if key not in fresh:
            info.append(f"  ~ {key}: dropped from fresh results")
            continue
        cur = fresh[key]
        if isinstance(base, bool):
            checked += 1
            if base and not cur:
                violations.append(f"  ! {key}: was True, now {cur!r}")
            continue
        if not isinstance(base, (int, float)) or \
                not isinstance(cur, (int, float)):
            continue
        cls = _classify(key)
        if cls == "higher":
            if base < MIN_TOK_S:
                continue
            checked += 1
            floor = base / (1.0 + tol)
            if cur < floor:
                violations.append(
                    f"  ! {key}: {cur:.2f} tok/s < floor {floor:.2f} "
                    f"(baseline {base:.2f}, tol {tol:.0%})")
        elif isinstance(cls, tuple):
            checked += 1
            ceil = base * (1.0 + tol) + NOISE_FLOOR[cls[1]]
            if cur > ceil:
                violations.append(
                    f"  ! {key}: {cur:.1f} > ceiling {ceil:.1f} "
                    f"(baseline {base:.1f}, tol {tol:.0%})")
    return violations, checked, info


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--files", nargs="*", default=list(DEFAULT_FILES),
                    help="fresh BENCH_*.json files to gate (cwd-relative)")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="relative slack (1.0 = 100%%) before a timing or "
                         "tok/s drift counts as a regression")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy the fresh files over the committed baselines "
                         "instead of gating")
    args = ap.parse_args(argv)

    if args.write_baseline:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for path in args.files:
            if not os.path.exists(path):
                print(f"[trajectory] skip {path}: not found")
                continue
            dst = os.path.join(args.baseline_dir, os.path.basename(path))
            shutil.copyfile(path, dst)
            print(f"[trajectory] baseline <- {path}")
        return 0

    failed = False
    for path in args.files:
        name = os.path.basename(path)
        base_path = os.path.join(args.baseline_dir, name)
        if not os.path.exists(base_path):
            print(f"[trajectory] {name}: no committed baseline — skipped "
                  f"(run --write-baseline to start gating it)")
            continue
        if not os.path.exists(path):
            print(f"[trajectory] {name}: FRESH RESULT MISSING "
                  f"(baseline exists — did the bench fail to run?)")
            failed = True
            continue
        with open(base_path) as f:
            base = _flatten(json.load(f))
        with open(path) as f:
            fresh = _flatten(json.load(f))
        violations, checked, info = compare(base, fresh, args.tolerance)
        status = "FAIL" if violations else "ok"
        print(f"[trajectory] {name}: {status} "
              f"({checked} gated metrics, tol {args.tolerance:.0%})")
        for line in info + violations:
            print(line)
        failed = failed or bool(violations)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
