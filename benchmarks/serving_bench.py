"""Continuous vs wave-synchronous serving at mixed prompt/output lengths.

`ServingEngine` (continuous per-slot batching, PR 2; chunked admission
prefill, PR 5) is measured against `WaveEngine` — a faithful
re-implementation of the removed wave path: admit up to `slots` requests,
left-pad, prefill token-by-token, then decode the whole wave lock-step until
its SLOWEST member finishes. The wave path wastes steps two ways: idle slots
ride along until the wave drains, and its prefill launches one model call
per prompt token. The comparison currency is model launches (chunked prefill
calls + decode steps) plus wall-clock tokens/sec, and per-request
INTER-TOKEN LATENCY p50/p95 — the stall metric chunked admission improves:
a resident slot keeps emitting while a long prompt admits chunk by chunk
instead of waiting out the whole prompt.

The fault matrix (`--fault-plan`, `bench_faults`) measures the RECOVERY
surface: each fault class — logits/KV poison, kernel-launch demotion,
latency — injected into its own engine, drained, and checked byte-identical
against the un-faulted run, reporting the recovery cost (extra steps, extra
wall-clock) and the engine's fault counters.

Run:  PYTHONPATH=src python -m benchmarks.serving_bench [--quick]
          [--prefill-chunk N] [--fault-plan smoke|SEED]
      PYTHONPATH=src python -m benchmarks.run --only serving
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import get_smoke
from repro.models import init_params
from repro.models import transformer as T
from repro.serving import (EngineStats, FaultPlan, Request, ServingEngine)

# the decode-kernel engine: every decode step's attention runs the Pallas
# flash-decode path (interpret mode off-TPU), byte-identical greedy outputs
DECODE_POLICY = api.ExecutionPolicy(backend="pallas", interpret=True)


# one shared scale per mode so `benchmarks.run --only serving` and the CLI
# always measure the same workload
QUICK_KW = dict(n_requests=8, prompt_hi=16, out_hi=8, max_len=64)
FULL_KW = dict(n_requests=24, prompt_hi=64, out_hi=32, max_len=128)


class WaveEngine:
    """The retired wave-synchronous path, kept here as the benchmark baseline
    (it also retains the old left-padded prefill, whose pad keys leak into
    attention — outputs are the OLD engine's, not a reference)."""

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 512):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.prefill_token_steps = 0
        self.decode_steps = 0
        self.generated = 0
        self._fn = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))

    def serve(self, requests: List[Request]) -> List[Request]:
        queue = list(requests)
        done: List[Request] = []
        while queue:
            wave, queue = queue[:self.slots], queue[self.slots:]
            caches = T.init_caches(self.cfg, batch=self.slots,
                                   max_len=self.max_len)
            lmax = max(len(r.prompt) for r in wave)
            toks = np.zeros((self.slots, lmax), np.int32)
            for s, r in enumerate(wave):
                toks[s, lmax - len(r.prompt):] = r.prompt      # left pad
            logits = None
            for t in range(lmax):
                logits, caches = self._fn(self.params, caches,
                                          jnp.asarray(toks[:, t:t + 1]))
                self.prefill_token_steps += 1
            last = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            remaining = np.array([r.max_new_tokens for r in wave] +
                                 [0] * (self.slots - len(wave)))
            for s, r in enumerate(wave):
                r.out_tokens = [int(last[s, 0])]
                remaining[s] -= 1
                self.generated += 1
            while remaining.max() > 0:
                logits, caches = self._fn(self.params, caches, last)
                self.decode_steps += 1
                nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
                for s, r in enumerate(wave):
                    if remaining[s] > 0:
                        r.out_tokens.append(int(nxt[s]))
                        remaining[s] -= 1
                        self.generated += 1
                last = jnp.asarray(nxt)[:, None].astype(jnp.int32)
            done += [r for r in wave]
        return done


def make_requests(vocab: int, n: int, prompt_hi: int, out_hi: int,
                  seed: int = 0) -> List[Tuple[np.ndarray, int]]:
    """Mixed-length set: prompts 4..prompt_hi tokens, outputs 1..out_hi."""
    rng = np.random.RandomState(seed)
    return [(rng.randint(1, vocab, rng.randint(4, prompt_hi + 1))
             .astype(np.int32), int(rng.randint(1, out_hi + 1)))
            for _ in range(n)]


def drive(eng: ServingEngine) -> Tuple[float, List[float]]:
    """Drain the engine step by step, timestamping every token emission;
    returns (seconds, inter-token-latency samples in ms). ITL gaps are
    measured per request between consecutive emissions — the per-user stall
    a head-of-line-blocking admission shows up in."""
    counts: dict = {}
    times: dict = {}

    def note(rid, n, t):
        prev = counts.get(rid, 0)
        if n > prev:
            times.setdefault(rid, []).extend([t] * (n - prev))
            counts[rid] = n

    t0 = time.perf_counter()
    while eng.pending():
        newly = eng.step()
        t = time.perf_counter()
        for o in eng.occupancy():
            if o is not None:
                note(o["rid"], o["generated"], t)
        for r in newly:
            note(r.rid, len(r.out_tokens), t)
    dt = time.perf_counter() - t0
    itl: List[float] = []
    for ts in times.values():
        itl.extend(float(d) * 1e3 for d in np.diff(ts))
    return dt, itl


def _pctl(itl: List[float]) -> Tuple[float, float]:
    if not itl:
        return 0.0, 0.0
    return (float(np.percentile(itl, 50)), float(np.percentile(itl, 95)))


def bench(arch: str = "qwen2_1p5b", n_requests: int = 12, slots: int = 4,
          prompt_hi: int = 64, out_hi: int = 32, max_len: int = 128,
          seed: int = 0) -> dict:
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(seed), cfg)
    spec = make_requests(cfg.vocab, n_requests, prompt_hi, out_hi, seed)

    def submit_all(eng):
        for rid, (p, m) in enumerate(spec):
            eng.submit(Request(rid, p, max_new_tokens=m))

    # warmup() compiles both fixed step shapes up front (the chunk shape is
    # static, so there are no per-width buckets to warm any more); one
    # untimed drain additionally warms the host-side gather/argmax paths
    def timed_continuous(policy):
        eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                            policy=policy).warmup()
        submit_all(eng)
        eng.run_until_drained()
        eng.finished.clear()
        eng.stats = EngineStats()
        submit_all(eng)
        dt, itl = drive(eng)
        return eng, {r.rid: r.out_tokens for r in eng.finished}, dt, itl

    cont, cont_out, dt_cont, itl_cont = timed_continuous(None)
    pall, pall_out, dt_pall, _ = timed_continuous(DECODE_POLICY)

    def wave_reqs():
        return [Request(rid, p, max_new_tokens=m)
                for rid, (p, m) in enumerate(spec)]
    wave = WaveEngine(cfg, params, slots=slots, max_len=max_len)
    wave.serve(wave_reqs())
    wave.prefill_token_steps = wave.decode_steps = wave.generated = 0
    t0 = time.time()
    wave.serve(wave_reqs())
    dt_wave = time.time() - t0

    st = cont.stats
    cont_calls = st.model_calls
    wave_calls = wave.prefill_token_steps + wave.decode_steps
    p50, p95 = _pctl(itl_cont)
    return {
        "tokens": st.generated_tokens,
        "cont_decode_steps": st.decode_steps,
        "wave_decode_steps": wave.decode_steps,
        "cont_model_calls": cont_calls,
        "cont_prefill_chunk_calls": st.prefill_chunk_calls,
        "wave_model_calls": wave_calls,
        "cont_tok_s": st.generated_tokens / max(dt_cont, 1e-9),
        "wave_tok_s": wave.generated / max(dt_wave, 1e-9),
        "cont_s": dt_cont,
        "wave_s": dt_wave,
        # per-request inter-token latency of the continuous engine (the
        # stall metric chunked admission bounds)
        "itl_p50_ms": round(p50, 3),
        "itl_p95_ms": round(p95, 3),
        # kernel engine: routes + greedy-identity + wall-clock (on CPU the
        # kernels run via the interpret-mode emulation, so tok/s is a
        # correctness-path number, not TPU perf)
        "decode_route": pall.decode_route(),
        "prefill_route": pall.prefill_route(),
        "ref_route": cont.decode_route(),
        "pallas_tok_s": pall.stats.generated_tokens / max(dt_pall, 1e-9),
        "pallas_matches_ref": pall_out == cont_out,
    }


def bench_weight_format(arch: str, weight_format: str, n_requests: int = 8,
                        slots: int = 4, prompt_hi: int = 16, out_hi: int = 8,
                        max_len: int = 64, seed: int = 0) -> dict:
    """Quantized-serving smoke: an engine with RESIDENT `weight_format`
    weights (codes pytree through api.ops.matmul_codes) vs the fake-quant
    reference engine (dense f32 re-quantized per call). Greedy outputs must
    be byte-identical — the residency acceptance gate — and the resident
    engine reports its weight route + wall-clock."""
    import dataclasses

    from repro.models.layers import QuantPolicy

    cfg = dataclasses.replace(get_smoke(arch),
                              quant=QuantPolicy(weights=weight_format))
    params = init_params(jax.random.key(seed), cfg)
    spec = make_requests(cfg.vocab, n_requests, prompt_hi, out_hi, seed)

    def timed(weight_fmt):
        eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                            weight_format=weight_fmt)
        for warm in (True, False):
            for rid, (p, m) in enumerate(spec):
                eng.submit(Request(rid, p, max_new_tokens=m))
            if warm:
                eng.run_until_drained()
                eng.finished.clear()
                eng.stats = EngineStats()
        t0 = time.time()
        done = eng.run_until_drained()
        return eng, {r.rid: r.out_tokens for r in done}, time.time() - t0

    fq, fq_out, dt_fq = timed(None)
    res, res_out, dt_res = timed(weight_format)
    return {
        "weight_format": weight_format,
        "fakequant_route": fq.weight_route(),
        "resident_route": res.weight_route(),
        "tokens": res.stats.generated_tokens,
        "fakequant_tok_s": fq.stats.generated_tokens / max(dt_fq, 1e-9),
        "resident_tok_s": res.stats.generated_tokens / max(dt_res, 1e-9),
        "resident_matches_fakequant": res_out == fq_out,
    }


def bench_prefill_chunk(arch: str, chunk: int, n_requests: int = 8,
                        slots: int = 4, prompt_hi: int = 16, out_hi: int = 8,
                        max_len: int = 64, seed: int = 0) -> dict:
    """Chunked-admission smoke: an engine advancing prompts in
    `chunk`-token slices vs a one-shot-equivalent engine (chunk covering
    every prompt in a single launch). Greedy outputs must be byte-identical
    — the chunking acceptance gate — and both report inter-token latency
    p50/p95 plus the prefill route the chunks dispatch to."""
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(seed), cfg)
    spec = make_requests(cfg.vocab, n_requests, prompt_hi, out_hi, seed)

    def timed(prefill_chunk, policy):
        eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                            policy=policy,
                            prefill_chunk=prefill_chunk).warmup()
        for warm in (True, False):
            for rid, (p, m) in enumerate(spec):
                eng.submit(Request(rid, p, max_new_tokens=m))
            if warm:
                eng.run_until_drained()
                eng.finished.clear()
                eng.stats = EngineStats()
        dt, itl = drive(eng)
        return eng, {r.rid: r.out_tokens for r in eng.finished}, itl

    oneshot = min(max(prompt_hi, 1), max_len)
    one, one_out, one_itl = timed(oneshot, None)
    chk, chk_out, chk_itl = timed(chunk, None)
    pal, pal_out, _ = timed(chunk, DECODE_POLICY)
    c50, c95 = _pctl(chk_itl)
    o50, o95 = _pctl(one_itl)
    return {
        "chunk": chunk,
        "oneshot_chunk": oneshot,
        "tokens": chk.stats.generated_tokens,
        "chunk_prefill_calls": chk.stats.prefill_chunk_calls,
        "oneshot_prefill_calls": one.stats.prefill_chunk_calls,
        "chunk_itl_p50_ms": round(c50, 3),
        "chunk_itl_p95_ms": round(c95, 3),
        "oneshot_itl_p50_ms": round(o50, 3),
        "oneshot_itl_p95_ms": round(o95, 3),
        "prefill_route": pal.prefill_route(),
        "chunked_matches_oneshot": chk_out == one_out,
        "pallas_matches_oneshot": pal_out == one_out,
    }


def _paged_spec(vocab: int, n: int, prompt_hi: int, out_hi: int,
                seed: int = 0) -> List[Tuple[np.ndarray, int]]:
    """A prefix-heavy request mix: two shared "system prompts" (half the
    prompt budget each) with per-request tails — the workload where the
    paged engine's prefix registry earns its keep."""
    rng = np.random.RandomState(seed)
    half = max(prompt_hi // 2, 4)
    sys_a = rng.randint(1, vocab, half).astype(np.int32)
    sys_b = rng.randint(1, vocab, half).astype(np.int32)
    spec = []
    for i in range(n):
        head = sys_a if i % 3 else sys_b
        tail = rng.randint(1, vocab,
                           rng.randint(1, half + 1)).astype(np.int32)
        spec.append((np.concatenate([head, tail]),
                     int(rng.randint(1, out_hi + 1))))
    return spec


def bench_paged(n_requests: int = 8, prompt_hi: int = 16, out_hi: int = 8,
                max_len: int = 64, block_size: int = 16, slots: int = 4,
                seed: int = 0) -> dict:
    """Paged-KV acceptance + metrics (the BENCH_kv.json currency): for a
    dense-head, a GQA and an int8-KV config, greedy serving on the
    block-pool engine must be byte-identical to the per-slot engine over a
    prefix-heavy mix; reports peak pool occupancy, prefix-hit rate, shared
    tokens, CoW forks and evictions, plus a pallas-kernel run (the paged
    flash kernels end-to-end, interpret mode off-TPU) on the GQA config."""
    import dataclasses

    variants = (("llama2_7b", False), ("qwen2_1p5b", False),
                ("qwen2_1p5b", True))
    out: dict = {"block_size": block_size, "archs": {}}
    for arch, kvq in variants:
        cfg = get_smoke(arch)
        if kvq:
            cfg = dataclasses.replace(cfg, kv_quant=True)
        params = init_params(jax.random.key(seed), cfg)
        spec = _paged_spec(cfg.vocab, n_requests, prompt_hi, out_hi, seed)

        def run_engine(paged, policy=None):
            eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                                policy=policy, paged=paged,
                                block_size=block_size).warmup()
            for rid, (p, m) in enumerate(spec):
                eng.submit(Request(rid, p, max_new_tokens=m))
            peak = 0.0
            t0 = time.perf_counter()
            while eng.pending():
                eng.step()
                if paged:
                    peak = max(peak, eng.pool_stats()["occupancy"])
            dt = time.perf_counter() - t0
            return eng, {r.rid: r.out_tokens for r in eng.finished}, dt, peak

        flat, flat_out, dt_flat, _ = run_engine(False)
        pgd, pgd_out, dt_pgd, peak = run_engine(True)
        st = pgd.pool_stats()
        key = arch + ("+int8kv" if kvq else "")
        out["archs"][key] = {
            "paged_matches_flat": pgd_out == flat_out,
            "tokens": pgd.stats.generated_tokens,
            "flat_tok_s": flat.stats.generated_tokens / max(dt_flat, 1e-9),
            "paged_tok_s": pgd.stats.generated_tokens / max(dt_pgd, 1e-9),
            "peak_occupancy": round(peak, 4),
            "prefix_hit_rate": round(st["prefix_hit_rate"], 4),
            "shared_tokens": st["shared_tokens"],
            "cow_copies": st["cow_copies"],
            "evictions": st["evictions"],
            "deferred_admissions": st["deferred_admissions"],
            "pool_blocks": st["pool_blocks"],
        }
        if arch == "qwen2_1p5b" and not kvq:
            pal, pal_out, _, _ = run_engine(True, policy=DECODE_POLICY)
            out["pallas"] = {
                "arch": arch,
                "paged_pallas_matches_flat": pal_out == flat_out,
                "decode_route": pal.decode_route(),
                "prefill_route": pal.prefill_route(),
            }
    return out


def _overload_spec(vocab: int, n: int, seed: int = 0,
                   max_new: int = 12) -> List[Tuple[np.ndarray, int]]:
    """Prompts of 18-30 tokens whose full budget is 3 blocks at bs=16 — two
    of them cannot coexist in the overload pool, so lower-priority rows get
    preempted and swapped as higher-priority work admits."""
    rng = np.random.RandomState(seed)
    return [(rng.randint(1, vocab, rng.randint(18, 30)).astype(np.int32),
             max_new) for _ in range(n)]


def bench_overload(arch: str = "qwen2_1p5b", n_requests: int = 6,
                   slots: int = 2, max_len: int = 64, block_size: int = 16,
                   pool_blocks: int = 4, seed: int = 0) -> dict:
    """Memory-pressure acceptance (the graceful-degradation gate): a block
    pool sized BELOW the workload's aggregate demand, mixed priorities.
    Every request must complete — zero REJECTED for high-priority rows —
    with greedy outputs byte-identical to an uncontended (big-pool) run,
    while the engine visibly preempts, swaps out and swaps back in.
    Reports the swap counters plus inter-token latency p50/p95 split by
    priority class (preemption should tax the LOW class, not the high)."""
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(seed), cfg)
    spec = _overload_spec(cfg.vocab, n_requests, seed)
    prios = [i % 2 for i in range(n_requests)]
    demand = sum(-(-(len(p) + m) // block_size) for p, m in spec)

    def run_engine(pool):
        eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                            paged=True, block_size=block_size,
                            pool_blocks=pool).warmup()
        for rid, (p, m) in enumerate(spec):
            eng.submit(Request(rid, p, max_new_tokens=m,
                               priority=prios[rid]))
        dt, _ = drive(eng)
        return eng, {r.rid: r.out_tokens for r in eng.finished}, dt

    _, want, _ = run_engine(slots * (max_len // block_size) + demand)

    # timed overloaded run with per-request token timestamps for the
    # per-priority-class ITL split
    eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                        paged=True, block_size=block_size,
                        pool_blocks=pool_blocks).warmup()
    for rid, (p, m) in enumerate(spec):
        eng.submit(Request(rid, p, max_new_tokens=m, priority=prios[rid]))
    counts: dict = {}
    times: dict = {}

    def note(rid, n, t):
        if n > counts.get(rid, 0):
            times.setdefault(rid, []).extend([t] * (n - counts.get(rid, 0)))
            counts[rid] = n

    t0 = time.perf_counter()
    while eng.pending():
        newly = eng.step()
        t = time.perf_counter()
        for o in eng.occupancy():
            if o is not None:
                note(o["rid"], o["generated"], t)
        for r in newly:
            note(r.rid, len(r.out_tokens), t)
    dt = time.perf_counter() - t0

    itl_by_prio: dict = {0: [], 1: []}
    for rid, ts in times.items():
        itl_by_prio[prios[rid]].extend(float(d) * 1e3 for d in np.diff(ts))
    got = {r.rid: r.out_tokens for r in eng.finished}
    by_status: dict = {}
    for r in eng.finished:
        by_status.setdefault(r.status, []).append(r.rid)
    st = eng.pool_stats()
    lo50, lo95 = _pctl(itl_by_prio[0])
    hi50, hi95 = _pctl(itl_by_prio[1])
    return {
        "pool_blocks": pool_blocks,
        "aggregate_demand_blocks": demand,
        "completed": sum(len(v) for v in by_status.values()),
        "statuses": {k: len(v) for k, v in sorted(by_status.items())},
        "rejected_high_priority": sum(
            1 for r in eng.finished
            if r.priority > 0 and r.status == "REJECTED"),
        "byte_identical_vs_uncontended": got == want,
        "preemptions": st["preemptions"],
        "swap_outs": st["swap_outs"],
        "swap_ins": st["swap_ins"],
        "swap_bytes_out": st["swap_bytes_out"],
        "swap_bytes_in": st["swap_bytes_in"],
        "eviction_skips": st["eviction_skips"],
        "deferred_admissions": st["deferred_admissions"],
        "overload_tok_s": eng.stats.generated_tokens / max(dt, 1e-9),
        "itl_low_p50_ms": round(lo50, 3),
        "itl_low_p95_ms": round(lo95, 3),
        "itl_high_p50_ms": round(hi50, 3),
        "itl_high_p95_ms": round(hi95, 3),
    }


FAULT_CLASSES = ("logits-poison", "kv-poison", "launch-demote", "latency",
                 "pool-pressure")


def _plan_for(klass: str) -> FaultPlan:
    return {
        "logits-poison": lambda: FaultPlan.single(
            "poison", step=3, slot=0, target="logits"),
        "kv-poison": lambda: FaultPlan.single(
            "poison", step=3, slot=1, target="kv"),
        "launch-demote": lambda: FaultPlan.single("launch", step=0),
        "latency": lambda: FaultPlan.single("latency", step=2,
                                            delay_s=0.005),
        "pool-pressure": lambda: FaultPlan.single("pool_pressure", step=2,
                                                  blocks=0, duration=6),
    }[klass]()


def bench_faults(arch: str = "qwen2_1p5b", n_requests: int = 6,
                 slots: int = 4, prompt_hi: int = 16, out_hi: int = 8,
                 max_len: int = 64, seed: int = 0,
                 plan_seed: int = None) -> dict:
    """Per-fault-class recovery measurement. Every class gets a fresh
    engine, one injected fault, and a full drain; "recovered" means the
    outputs are byte-identical to the un-faulted engine's, and the recovery
    cost is the extra engine steps / wall-clock the replay or demote-retry
    spent. `plan_seed` adds a seeded multi-fault sweep (recoverable kinds)
    on top of the fixed matrix."""
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(seed), cfg)
    # floor the output budgets so every slot is still busy when the fixed
    # fault coordinates fire — a poison landing on a freed row is a silent
    # no-op, not a recovery measurement
    spec = [(p, max(m, 6))
            for p, m in make_requests(cfg.vocab, n_requests, prompt_hi,
                                      out_hi, seed)]

    def fresh(policy=None, warm=True, **kw):
        eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                            policy=policy, **kw)
        if warm:
            eng.warmup()
        for rid, (p, m) in enumerate(spec):
            eng.submit(Request(rid, p, max_new_tokens=m))
        return eng

    base = fresh()
    t0 = time.perf_counter()
    base.run_until_drained()
    base_s = time.perf_counter() - t0
    want = {r.rid: r.out_tokens for r in base.finished}
    base_steps = base.step_no

    def faulted(plan, policy=None, **kw):
        # a launch fault demotes and rebuilds the jits, so warming the
        # pallas traces first would only measure compile time twice
        eng = fresh(policy=policy, warm=policy is None, **kw)
        eng.arm_fault_plan(plan)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        got = {r.rid: r.out_tokens for r in eng.finished}
        st = eng.stats
        return {
            "recovered_byte_identical": got == want,
            "recovery_extra_steps": eng.step_no - base_steps,
            "recovery_extra_ms": round(max(dt - base_s, 0.0) * 1e3, 3),
            "quarantines": st.quarantines, "demotions": st.demotions,
            "timeouts": st.timeouts, "failed": st.failed_requests,
            "rejected_submits": st.rejected_submits,
            "faults_tripped":
                f"{sum(f.tripped for f in plan.faults)}/{len(plan.faults)}",
            "plan": plan.describe(),
        }

    classes = {}
    for klass in FAULT_CLASSES:
        policy = DECODE_POLICY if klass == "launch-demote" else None
        # the pressure lever only bites a block-pool engine — paged greedy
        # outputs are byte-identical to the per-slot baseline, so the same
        # `want` still gates recovery
        kw = {"paged": True, "block_size": 16} \
            if klass == "pool-pressure" else {}
        classes[klass] = faulted(_plan_for(klass), policy=policy, **kw)
    if plan_seed is not None:
        classes[f"seeded-{plan_seed}"] = faulted(
            FaultPlan.seeded(plan_seed, steps=base_steps, slots=slots,
                             kinds=("poison", "latency")),
            max_replays=8)
    return {"classes": classes, "baseline_s": round(base_s, 4),
            "baseline_steps": base_steps}


def run(quick: bool = True):
    """Rows for benchmarks.run: smoke-scale continuous vs wave comparison."""
    r = bench(**(QUICK_KW if quick else FULL_KW))
    rows = [
        ("serving.continuous.decode_steps", r["cont_decode_steps"],
         f"tok_s={r['cont_tok_s']:.1f}|model_calls={r['cont_model_calls']}"),
        ("serving.wave.decode_steps", r["wave_decode_steps"],
         f"tok_s={r['wave_tok_s']:.1f}|model_calls={r['wave_model_calls']}"),
        ("serving.continuous_fewer_decode_steps", 0.0,
         str(r["cont_decode_steps"] < r["wave_decode_steps"])),
        ("serving.model_call_ratio",
         round(r["wave_model_calls"] / max(r["cont_model_calls"], 1), 2),
         "wave/continuous"),
        ("serving.inter_token_latency_ms", r["itl_p50_ms"],
         f"p95={r['itl_p95_ms']}"),
        ("serving.decode_attention_route", 0.0,
         f"{r['decode_route']}|prefill={r['prefill_route']}"
         f"|ref_engine={r['ref_route']}"
         f"|greedy_identical={r['pallas_matches_ref']}"),
    ]
    f = bench_faults(**(QUICK_KW if quick else FULL_KW))
    for klass, c in f["classes"].items():
        rows.append((
            f"serving.faults.{klass}", c["recovery_extra_steps"],
            f"recovered={c['recovered_byte_identical']}"
            f"|extra_ms={c['recovery_extra_ms']}"
            f"|quarantines={c['quarantines']}|demotions={c['demotions']}"))
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke scale (CI): 8 requests, short prompts")
    ap.add_argument("--arch", default="qwen2_1p5b")
    ap.add_argument("--weight-format", default="none",
                    choices=("none", "int4", "int8", "fp8a", "fp8b"),
                    help="run ONLY the quantized-serving smoke: resident "
                         "weights in this format vs the fake-quant engine, "
                         "greedy outputs must match byte-for-byte")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="run ONLY the chunked-admission smoke: prompts "
                         "advance in this many tokens per launch vs a "
                         "one-shot-equivalent engine, greedy outputs must "
                         "match byte-for-byte; reports inter-token latency "
                         "p50/p95 and the prefill route")
    ap.add_argument("--paged", action="store_true",
                    help="run ONLY the paged-KV smoke: block-pool engine vs "
                         "per-slot engine over a prefix-heavy mix (dense, "
                         "GQA, int8-KV), greedy outputs must match byte-"
                         "for-byte; writes pool occupancy + prefix-hit-rate "
                         "metrics to BENCH_kv.json")
    ap.add_argument("--overload", action="store_true",
                    help="run ONLY the memory-pressure smoke: block pool "
                         "sized below aggregate demand, mixed priorities — "
                         "every request must complete (zero REJECTED at "
                         "high priority) byte-identical to an uncontended "
                         "run, with real preempt/swap-out/swap-in traffic; "
                         "merges swap counters + per-priority ITL into "
                         "BENCH_kv.json")
    ap.add_argument("--kv-json", default="BENCH_kv.json",
                    help="where the --paged/--overload metrics land")
    ap.add_argument("--fault-plan", default="",
                    help='run ONLY the fault-injection smoke: "smoke" runs '
                         'the fixed per-class matrix, an integer seed adds a '
                         'seeded recoverable-fault sweep on top; writes '
                         'BENCH_faults.json and exits nonzero unless every '
                         'class recovers byte-identically')
    args = ap.parse_args()
    if args.overload:
        import json
        import os
        r = bench_overload()
        print(f"[serving_bench] overload (pool {r['pool_blocks']} blocks vs "
              f"{r['aggregate_demand_blocks']} demanded):")
        print(f"  completed={r['completed']} statuses={r['statuses']} "
              f"rejected_high_priority={r['rejected_high_priority']}")
        print(f"  byte_identical_vs_uncontended="
              f"{r['byte_identical_vs_uncontended']}")
        print(f"  preemptions={r['preemptions']} "
              f"swap out/in={r['swap_outs']}/{r['swap_ins']} "
              f"bytes out/in={r['swap_bytes_out']}/{r['swap_bytes_in']} "
              f"eviction_skips={r['eviction_skips']} "
              f"deferred={r['deferred_admissions']}")
        print(f"  ITL p50/p95: high {r['itl_high_p50_ms']}/"
              f"{r['itl_high_p95_ms']} ms, low {r['itl_low_p50_ms']}/"
              f"{r['itl_low_p95_ms']} ms (preemption taxes the low class); "
              f"{r['overload_tok_s']:.1f} tok/s under pressure")
        merged = {}
        if os.path.exists(args.kv_json):
            with open(args.kv_json) as fh:
                merged = json.load(fh)
        merged["overload"] = r
        with open(args.kv_json, "w") as fh:
            json.dump(merged, fh, indent=2, sort_keys=True)
        print(f"  merged into {args.kv_json}")
        ok = (r["byte_identical_vs_uncontended"]
              and r["rejected_high_priority"] == 0
              and r["statuses"].get("done", 0) == r["completed"]
              and r["preemptions"] >= 1 and r["swap_ins"] >= 1)
        if not ok:
            raise SystemExit(1)
        return
    if args.paged:
        import json
        kw = QUICK_KW if args.quick else FULL_KW
        r = bench_paged(n_requests=kw["n_requests"],
                        prompt_hi=kw["prompt_hi"], out_hi=kw["out_hi"],
                        max_len=kw["max_len"])
        print(f"[serving_bench] paged KV (block_size={r['block_size']}):")
        for key, a in r["archs"].items():
            print(f"  {key:20s} identical={a['paged_matches_flat']} "
                  f"peak_occupancy={a['peak_occupancy']} "
                  f"hit_rate={a['prefix_hit_rate']} "
                  f"shared={a['shared_tokens']} cow={a['cow_copies']} "
                  f"evictions={a['evictions']} "
                  f"paged {a['paged_tok_s']:.1f} tok/s vs flat "
                  f"{a['flat_tok_s']:.1f}")
        p = r["pallas"]
        print(f"  pallas kernels ({p['arch']}): "
              f"identical={p['paged_pallas_matches_flat']} "
              f"decode={p['decode_route']} prefill={p['prefill_route']} "
              f"(interpret-mode emulation off-TPU)")
        with open(args.kv_json, "w") as fh:
            json.dump(r, fh, indent=2, sort_keys=True)
        print(f"  wrote {args.kv_json}")
        ok = all(a["paged_matches_flat"] for a in r["archs"].values()) \
            and p["paged_pallas_matches_flat"]
        if not ok:
            raise SystemExit(1)
        return
    if args.fault_plan:
        import json
        kw = QUICK_KW if args.quick else FULL_KW
        plan_seed = None if args.fault_plan == "smoke" \
            else int(args.fault_plan)
        r = bench_faults(args.arch, n_requests=min(kw["n_requests"], 8),
                         prompt_hi=kw["prompt_hi"], out_hi=kw["out_hi"],
                         max_len=kw["max_len"], plan_seed=plan_seed)
        print(f"[serving_bench:{args.arch}] fault matrix "
              f"(baseline {r['baseline_steps']} steps, "
              f"{r['baseline_s']:.2f}s):")
        for klass, c in r["classes"].items():
            print(f"  {klass:16s} recovered={c['recovered_byte_identical']} "
                  f"extra_steps={c['recovery_extra_steps']} "
                  f"extra_ms={c['recovery_extra_ms']} "
                  f"quarantines={c['quarantines']} "
                  f"demotions={c['demotions']} failed={c['failed']} "
                  f"tripped={c['faults_tripped']}")
        with open("BENCH_faults.json", "w") as fh:
            json.dump(r, fh, indent=2, sort_keys=True)
        print("  wrote BENCH_faults.json")
        if not all(c["recovered_byte_identical"]
                   for c in r["classes"].values()):
            raise SystemExit(1)
        return
    if args.prefill_chunk:
        kw = QUICK_KW if args.quick else FULL_KW
        r = bench_prefill_chunk(args.arch, args.prefill_chunk,
                                n_requests=kw["n_requests"],
                                prompt_hi=kw["prompt_hi"],
                                out_hi=kw["out_hi"], max_len=kw["max_len"])
        print(f"[serving_bench:{args.arch}] chunked admission "
              f"(chunk={r['chunk']} vs one-shot {r['oneshot_chunk']}): "
              f"{r['tokens']} tokens")
        print(f"  prefill launches: {r['chunk_prefill_calls']} chunked vs "
              f"{r['oneshot_prefill_calls']} one-shot; route under pallas: "
              f"{r['prefill_route']}")
        print(f"  inter-token latency p50/p95: {r['chunk_itl_p50_ms']}/"
              f"{r['chunk_itl_p95_ms']} ms chunked vs "
              f"{r['oneshot_itl_p50_ms']}/{r['oneshot_itl_p95_ms']} ms "
              f"one-shot (CPU correctness-path numbers, not TPU perf)")
        print(f"  greedy identical: chunked={r['chunked_matches_oneshot']} "
              f"pallas-chunked={r['pallas_matches_oneshot']}")
        # chunk == 1 takes the merged single-token path, which rides the
        # decode kernel; every wider chunk must hit the varlen kernel
        want_route = "pallas-prefill" if args.prefill_chunk > 1 \
            else "pallas-decode"
        if not (r["chunked_matches_oneshot"] and r["pallas_matches_oneshot"]
                and r["prefill_route"] == want_route):
            raise SystemExit(1)
        return
    if args.weight_format != "none":
        kw = QUICK_KW if args.quick else FULL_KW
        r = bench_weight_format(args.arch, args.weight_format,
                                n_requests=kw["n_requests"],
                                prompt_hi=kw["prompt_hi"],
                                out_hi=kw["out_hi"], max_len=kw["max_len"])
        print(f"[serving_bench:{args.arch}] quantized serving "
              f"({args.weight_format}): {r['tokens']} tokens")
        print(f"  weight routes: {r['resident_route']} vs "
              f"{r['fakequant_route']}; greedy outputs identical: "
              f"{r['resident_matches_fakequant']}")
        print(f"  resident {r['resident_tok_s']:.1f} tok/s, fake-quant "
              f"{r['fakequant_tok_s']:.1f} tok/s (CPU correctness-path "
              f"numbers, not TPU perf)")
        if not r["resident_matches_fakequant"] or \
                r["resident_route"] != f"resident-{args.weight_format}":
            raise SystemExit(1)
        return
    r = bench(arch=args.arch, **(QUICK_KW if args.quick else FULL_KW))
    print(f"[serving_bench:{args.arch}] {r['tokens']} tokens")
    print(f"  continuous: {r['cont_decode_steps']} decode steps, "
          f"{r['cont_model_calls']} model calls "
          f"({r['cont_prefill_chunk_calls']} chunked prefills), "
          f"{r['cont_tok_s']:.1f} tok/s, inter-token latency p50/p95 "
          f"{r['itl_p50_ms']}/{r['itl_p95_ms']} ms")
    print(f"  wave:       {r['wave_decode_steps']} decode steps, "
          f"{r['wave_model_calls']} model calls, {r['wave_tok_s']:.1f} tok/s")
    print(f"  kernel routes in use: decode={r['decode_route']} "
          f"prefill={r['prefill_route']} (ref engine: {r['ref_route']}); "
          f"greedy outputs identical: {r['pallas_matches_ref']}; "
          f"{r['pallas_tok_s']:.1f} tok/s (interpret-mode emulation off-TPU)")
    better = (r["cont_decode_steps"] < r["wave_decode_steps"]
              and r["cont_model_calls"] < r["wave_model_calls"])
    print(f"  continuous fewer steps AND calls: {better}")
    if not better or r["decode_route"] != "pallas-decode" \
            or not r["pallas_matches_ref"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
