"""Fig 14 — MAC utilization per model x training step x accelerator,
bf16 and hybrid-FP8 (+ the INT8/INT4 inference averages quoted in §VI-B)."""
from repro.perfmodel.simulate import TRAIN_MODELS, utilization_table
from repro.perfmodel.latency import model_latency
from repro.perfmodel.accelerators import ACCELERATORS
from repro.perfmodel.workloads import inference_ops


def _geo_ratio(u, a, b):
    """average utilization ratio accelerator a / accelerator b."""
    import math
    vals = []
    for model, steps in u.items():
        for step, row in steps.items():
            if row[b] > 0:
                vals.append(row[a] / row[b])
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def run():
    rows = []
    for fmt in ("bf16", "fp8a"):
        u = utilization_table(fmt)
        for model, steps in u.items():
            for step, row in steps.items():
                rows.append((f"fig14.{fmt}.{model}.{step}", 0.0,
                             "|".join(f"{k}={v:.4f}" for k, v in row.items())))
        rows.append((f"fig14.{fmt}.avg_allrounder_over_sara", 0.0,
                     f"{_geo_ratio(u, 'allrounder', 'sara'):.2f}x"))
        rows.append((f"fig14.{fmt}.avg_allrounder_over_tpu", 0.0,
                     f"{_geo_ratio(u, 'allrounder', 'tpu_sa'):.2f}x"))

    # §VI-B INT8/INT4 inference utilization improvements
    for fmt in ("int8", "int4"):
        import math
        ratios = {"tpu_sa": [], "sara": [], "mirroring": []}
        for model in TRAIN_MODELS:
            b = 8 if model in ("gpt2", "llama2_7b") else 128
            ops = inference_ops(model, b)
            ar = model_latency(ops, ACCELERATORS["allrounder"], fmt)["utilization"]
            for base in ratios:
                bu = model_latency(ops, ACCELERATORS[base], fmt)["utilization"]
                ratios[base].append(ar / bu)
        for base, vals in ratios.items():
            g = math.exp(sum(math.log(v) for v in vals) / len(vals))
            rows.append((f"vib.{fmt}.allrounder_util_over_{base}", 0.0,
                         f"{g:.2f}x"))
    return rows
