"""Pallas kernel wall-clock (interpret mode on CPU — correctness-path timing,
not TPU perf; TPU perf is the §Roofline analysis) + morphable-GEMM
utilization, the kernel-level Fig 8 analogue.

The decode-attention section tracks the flash-decode kernel's perf
trajectory from PR 3 onward: dense + int8-KV variants at a short (pos~64)
vs long (pos~max_len) resident context. Block pruning means the short rows
visit a fraction of the KV blocks — both the visit counts (measured by the
kernel's debug output) and wall-clock land in BENCH_decode.json.

The varlen-prefill section (PR 5) tracks the flash-prefill kernel the
engine's CHUNKED admission dispatches to: a mixed batch of rows at
different cache positions with different real token counts, vs the same
launch with every row full (what a pow2-bucketed one-shot prefill would
compute). Q-block + KV-block pruning means the varlen launch visits a
fraction of the (q-block, KV-block) pairs — counts and wall-clock land in
BENCH_prefill.json.

The weight-quant GEMM section (PR 4) tracks the RESIDENT-weight matmul
plane: int4/int8/fp8 weights stored once as packed codes and multiplied
through `api.ops.matmul_codes` (skipping the per-call weight quantization),
vs quantize-on-the-fly and dense f32 baselines. HBM bytes/param and
wall-clock land in BENCH_wq.json — the perf-trajectory artifact CI uploads
next to BENCH_decode.json.

Run:  PYTHONPATH=src python -m benchmarks.kernels_bench [--quick] [--json P]
          [--wq-json P] [--prefill-json P]
      PYTHONPATH=src python -m benchmarks.run --only kernels
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import formats as F
from repro.kernels.flash_attention import (chunked_attention,
                                           decode_block_visits,
                                           flash_decode_paged_pallas,
                                           flash_decode_pallas,
                                           flash_decode_quant_pallas,
                                           flash_prefill_paged_pallas,
                                           flash_prefill_pallas,
                                           flash_prefill_quant_pallas,
                                           prefill_block_visits)


def _paged_pool(kv: np.ndarray, bs: int, seed: int = 0):
    """Scatter a (B, Hkv, L, D) cache into a shuffled (P, Hkv, bs, D) block
    pool + (B, nblk) table, P = B * nblk — the paged kernels' operand
    layout, with a non-identity map so the indirection is really exercised."""
    b, hkv, lk, d = kv.shape
    nblk = lk // bs
    perm = np.random.RandomState(seed).permutation(b * nblk)
    table = perm.reshape(b, nblk).astype(np.int32)
    pool = np.empty((b * nblk, hkv, bs, d), kv.dtype)
    for i in range(b):
        for j in range(nblk):
            pool[table[i, j]] = kv[i, :, j * bs:(j + 1) * bs, :]
    return jnp.asarray(pool), jnp.asarray(table)


def _time(f, *args, reps=5):
    # sync the warmup too: otherwise its async dispatch bleeds into rep 1
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


# one shared scale per mode so `benchmarks.run --only kernels` and the CLI
# measure the same decode workload
DECODE_QUICK = dict(b=4, hq=8, hkv=4, d=64, lq=1, max_len=1024, bkv=128,
                    short_pos=64)
DECODE_FULL = dict(b=8, hq=16, hkv=8, d=128, lq=1, max_len=4096, bkv=128,
                   short_pos=64)


def decode_rows(quick: bool = True):
    """(csv_rows, metrics) for the flash-decode kernel: dense + int8 KV,
    short vs long resident context, wall-clock + measured KV-block visits."""
    cfg = DECODE_QUICK if quick else DECODE_FULL
    b, hq, hkv, d = cfg["b"], cfg["hq"], cfg["hkv"], cfg["d"]
    lq, max_len, bkv = cfg["lq"], cfg["max_len"], cfg["bkv"]
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, hq, lq, d).astype(np.float32) * 0.5)
    k = jnp.asarray(rng.randn(b, hkv, max_len, d).astype(np.float32) * 0.5)
    v = jnp.asarray(rng.randn(b, hkv, max_len, d).astype(np.float32))
    from repro.models.attention import _q8
    kc, ks = _q8(k)
    vc, vs = _q8(v)

    dense = jax.jit(lambda q, k, v, pos: flash_decode_pallas(
        q, k, v, pos=pos, bkv=bkv, interpret=True))
    # the cache rides as jit ARGUMENTS (device buffers), not closure
    # constants baked into the jaxpr
    quant = jax.jit(lambda q, kc, ks, vc, vs, pos: flash_decode_quant_pallas(
        q, kc, ks, vc, vs, pos=pos, bkv=bkv, interpret=True))

    # interpret mode emulates every grid step's DMA with a copy whether or
    # not the block was pruned, so wall-clock is copy-bound and roughly flat
    # on CPU — the visit counts are the work metric that carries to TPU,
    # where the clamped index map skips the HBM fetch outright
    rows, metrics = [], {"shape": dict(cfg), "variants": {},
                         "cost_metric": "visited_blocks",
                         "note": "interpret-mode wall-clock is DMA-emulation "
                                 "bound; visited_blocks measures the work "
                                 "that scales with resident context"}
    contexts = (("short", cfg["short_pos"]), ("long", max_len - lq))
    for variant in ("dense", "int8kv"):
        vm = {}
        for label, p in contexts:
            pos = jnp.full((b,), p, jnp.int32)
            visited, total = decode_block_visits(pos, lq, max_len, bkv)
            # measured visits from the kernel's own debug output (per
            # kv-head row), cross-checking the analytic count — from the
            # SAME variant that is being timed
            if variant == "dense":
                us = _time(dense, q, k, v, pos)
                _, vis = flash_decode_pallas(q, k, v, pos=pos, bkv=bkv,
                                             interpret=True,
                                             debug_visits=True)
            else:
                us = _time(quant, q, kc, ks, vc, vs, pos)
                _, vis = flash_decode_quant_pallas(
                    q, kc, ks, vc, vs, pos=pos, bkv=bkv, interpret=True,
                    debug_visits=True)
            measured = int(np.asarray(vis).sum())
            rows.append((f"kernels.flash_decode_{variant}_pos{p}",
                         round(us, 1),
                         f"kv_blocks={measured}/{total * hkv}"))
            vm[label] = {"pos": int(p), "us": round(us, 1),
                         "visited_blocks": measured,
                         "expected_blocks": visited * hkv,
                         "total_blocks": total * hkv}
        vm["long_over_short_us"] = round(
            vm["long"]["us"] / max(vm["short"]["us"], 1e-9), 2)
        vm["long_over_short_blocks"] = round(
            vm["long"]["visited_blocks"] /
            max(vm["short"]["visited_blocks"], 1), 2)
        metrics["variants"][variant] = vm

    # sliding-window pruning: a full-residency row visits only the window's
    # blocks, not the whole cache
    win = 2 * bkv
    pos = jnp.full((b,), max_len - lq, jnp.int32)
    _, vis = flash_decode_pallas(q, k, v, pos=pos, bkv=bkv, window=win,
                                 interpret=True, debug_visits=True)
    measured = int(np.asarray(vis).sum())
    _, total = decode_block_visits(pos, lq, max_len, bkv)
    rows.append((f"kernels.flash_decode_dense_win{win}_pos{max_len - lq}",
                 0.0, f"kv_blocks={measured}/{total * hkv}"))
    metrics["windowed"] = {"window": win, "pos": int(max_len - lq),
                           "visited_blocks": measured,
                           "total_blocks": total * hkv}

    # paged variant: same workload through the block-pool indirection at
    # bs == bkv — the table lookup is the only extra work, and the output
    # must stay bitwise-identical to the dense kernel
    kp, table = _paged_pool(np.asarray(k), bkv, seed=1)
    vp, _ = _paged_pool(np.asarray(v), bkv, seed=1)
    paged = jax.jit(lambda q, kp, vp, tbl, pos: flash_decode_paged_pallas(
        q, kp, vp, table=tbl, pos=pos, interpret=True))
    pm = {"block_size": bkv, "pool_blocks": int(kp.shape[0])}
    for label, p in contexts:
        pos = jnp.full((b,), p, jnp.int32)
        us = _time(paged, q, kp, vp, table, pos)
        exact = bool(np.array_equal(np.asarray(paged(q, kp, vp, table, pos)),
                                    np.asarray(dense(q, k, v, pos))))
        rows.append((f"kernels.flash_decode_paged_pos{p}", round(us, 1),
                     f"matches_dense={exact}"))
        pm[label] = {"pos": int(p), "us": round(us, 1),
                     "matches_dense": exact}
    metrics["paged"] = pm
    return rows, metrics


# one shared scale per mode so `benchmarks.run --only kernels` and the CLI
# measure the same varlen-prefill workload
PREFILL_QUICK = dict(b=4, hq=8, hkv=4, d=64, chunk=32, max_len=512, bq=16,
                     bkv=128)
PREFILL_FULL = dict(b=8, hq=16, hkv=8, d=128, chunk=128, max_len=4096, bq=32,
                    bkv=128)


def prefill_rows(quick: bool = True):
    """(csv_rows, metrics) for the varlen flash-prefill kernel: dense + int8
    KV over a mixed admission batch (rows at different cache positions with
    different REAL token counts) vs the same launch with every row full —
    what a pow2-bucketed one-shot prefill would compute. Wall-clock +
    measured (q-block, KV-block) visits."""
    cfg = PREFILL_QUICK if quick else PREFILL_FULL
    b, hq, hkv, d = cfg["b"], cfg["hq"], cfg["hkv"], cfg["d"]
    chunk, max_len = cfg["chunk"], cfg["max_len"]
    bq, bkv = cfg["bq"], cfg["bkv"]
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, hq, chunk, d).astype(np.float32) * 0.5)
    k = jnp.asarray(rng.randn(b, hkv, max_len, d).astype(np.float32) * 0.5)
    v = jnp.asarray(rng.randn(b, hkv, max_len, d).astype(np.float32))
    from repro.models.attention import _q8
    kc, ks = _q8(k)
    vc, vs = _q8(v)

    # a mid-admission snapshot: a fresh prompt's first chunk, a long prompt
    # deep in the cache, a 3-token tail chunk, and an idle (mid-decode) row
    pos = jnp.asarray(np.resize([0, max_len // 2, max_len // 4,
                                 max_len - chunk], b), jnp.int32)
    varlen = jnp.asarray(np.resize([chunk, chunk, 3, 0], b), jnp.int32)
    full = jnp.full((b,), chunk, jnp.int32)

    dense = jax.jit(lambda q, k, v, pos, lens: flash_prefill_pallas(
        q, k, v, pos=pos, lengths=lens, bq=bq, bkv=bkv, interpret=True))
    # the cache rides as jit ARGUMENTS (device buffers), not closure
    # constants baked into the jaxpr
    quant = jax.jit(
        lambda q, kc, ks, vc, vs, pos, lens: flash_prefill_quant_pallas(
            q, kc, ks, vc, vs, pos=pos, lengths=lens, bq=bq, bkv=bkv,
            interpret=True))

    # interpret mode emulates every grid step's DMA whether or not the block
    # was pruned, so CPU wall-clock is copy-bound — the visit counts are the
    # work metric that carries to TPU, where the clamped index maps skip the
    # HBM fetches outright
    rows, metrics = [], {"shape": dict(cfg), "variants": {},
                         "cost_metric": "visited_blocks",
                         "note": "interpret-mode wall-clock is DMA-emulation "
                                 "bound; visited_blocks measures the work "
                                 "that scales with REAL prompt tokens"}
    for variant in ("dense", "int8kv"):
        vm = {}
        for label, lens in (("varlen", varlen), ("fullchunk", full)):
            expected, total = prefill_block_visits(
                pos, lens, chunk, max_len, bq=bq, bkv=bkv)
            if variant == "dense":
                us = _time(dense, q, k, v, pos, lens)
                _, vis = flash_prefill_pallas(
                    q, k, v, pos=pos, lengths=lens, bq=bq, bkv=bkv,
                    interpret=True, debug_visits=True)
            else:
                us = _time(quant, q, kc, ks, vc, vs, pos, lens)
                _, vis = flash_prefill_quant_pallas(
                    q, kc, ks, vc, vs, pos=pos, lengths=lens, bq=bq,
                    bkv=bkv, interpret=True, debug_visits=True)
            measured = int(np.asarray(vis).sum())
            rows.append((f"kernels.flash_prefill_{variant}_{label}",
                         round(us, 1),
                         f"qkv_blocks={measured}/{total * hkv}"))
            vm[label] = {"us": round(us, 1), "visited_blocks": measured,
                         "expected_blocks": expected * hkv,
                         "total_blocks": total * hkv}
        vm["varlen_over_full_blocks"] = round(
            vm["varlen"]["visited_blocks"] /
            max(vm["fullchunk"]["visited_blocks"], 1), 3)
        metrics["variants"][variant] = vm

    # paged variant: the same mixed admission batch through the block-pool
    # indirection at bs == bkv, bitwise-checked against the dense launch
    kp, table = _paged_pool(np.asarray(k), bkv, seed=1)
    vp, _ = _paged_pool(np.asarray(v), bkv, seed=1)
    paged = jax.jit(
        lambda q, kp, vp, tbl, pos, lens: flash_prefill_paged_pallas(
            q, kp, vp, table=tbl, pos=pos, lengths=lens, bq=bq,
            interpret=True))
    pm = {"block_size": bkv, "pool_blocks": int(kp.shape[0])}
    for label, lens in (("varlen", varlen), ("fullchunk", full)):
        us = _time(paged, q, kp, vp, table, pos, lens)
        exact = bool(np.array_equal(
            np.asarray(paged(q, kp, vp, table, pos, lens)),
            np.asarray(dense(q, k, v, pos, lens))))
        rows.append((f"kernels.flash_prefill_paged_{label}", round(us, 1),
                     f"matches_dense={exact}"))
        pm[label] = {"us": round(us, 1), "matches_dense": exact}
    metrics["paged"] = pm
    return rows, metrics


# one shared scale per mode so `benchmarks.run --only kernels` and the CLI
# measure the same weight-quant GEMM workload
WQ_QUICK = dict(m=64, k=256, n=256)
WQ_FULL = dict(m=256, k=1024, n=1024)
WQ_FORMATS = ("int4", "int8", "fp8a", "fp8b")


def weight_quant_rows(quick: bool = True):
    """(csv_rows, metrics) for the resident-weight GEMM plane: per format,
    wall-clock of the codes path (ref XLA emulation + pallas interpret) vs
    quantize-on-the-fly and dense f32, HBM bytes/param, and the bitwise
    checks that gate the residency story (dequant == per-channel fake-quant;
    pallas resident result == pallas on-the-fly result)."""
    shp = WQ_QUICK if quick else WQ_FULL
    m, k, n = shp["m"], shp["k"], shp["n"]
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w = jnp.asarray(rng.randn(k, n), jnp.float32)

    dense = jax.jit(lambda a, b: jnp.dot(a, b,
                                         preferred_element_type=jnp.float32))
    dense_us = _time(dense, x, w)
    rows = [(f"kernels.wq_dense_f32_{m}x{k}x{n}", round(dense_us, 1),
             "bytes_per_param=4.0")]
    metrics = {"shape": dict(shp), "dense_f32_us": round(dense_us, 1),
               "formats": {},
               "note": "interpret/XLA-emulation wall-clock on CPU — the "
                       "carrying metrics are bytes_per_param (HBM weight "
                       "traffic) and the bitwise equivalence flags"}
    for fmt in WQ_FORMATS:
        qw = F.quantize_weight(w, fmt)
        # the codes pytree rides as jit ARGUMENTS (device buffers), so the
        # timed path is exactly the serving path: no per-call weight quant
        res_ref = jax.jit(lambda a, q: api.ops.matmul_codes(a, q,
                                                            backend="ref"))
        res_pal = jax.jit(lambda a, q: api.ops.matmul_codes(
            a, q, backend="pallas", interpret=True))
        fly = jax.jit(lambda a, b, f=fmt: api.ops.matmul(
            a, b, format=f, backend="pallas", interpret=True))
        ref_us = _time(res_ref, x, qw)
        pal_us = _time(res_pal, x, qw)
        fly_us = _time(fly, x, w)
        bpp = qw.bytes_per_param
        exact = bool(np.array_equal(np.asarray(res_pal(x, qw)),
                                    np.asarray(fly(x, w))))
        rows.append((f"kernels.wq_resident_{fmt}_{m}x{k}x{n}",
                     round(pal_us, 1),
                     f"bytes_per_param={bpp}|matches_onthefly={exact}"))
        metrics["formats"][fmt] = {
            "bytes_per_param": bpp,
            "hbm_weight_bytes": int(qw.codes.size * qw.codes.dtype.itemsize
                                    + qw.scale.size * 4),
            "resident_ref_us": round(ref_us, 1),
            "resident_pallas_us": round(pal_us, 1),
            "onthefly_pallas_us": round(fly_us, 1),
            "pallas_matches_onthefly": exact,
        }
    return rows, metrics


def run(quick: bool = True):
    rows = []
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(512, 512), jnp.float32)
    w = jnp.asarray(rng.randn(512, 512), jnp.float32)
    for mode in ("bf16", "int8", "fp8a"):
        f = jax.jit(lambda a, b, m=mode: api.ops.matmul(a, b, format=m,
                                                        backend="ref"))
        us = _time(f, x, w)
        rows.append((f"kernels.aio_matmul_{mode}_512", round(us, 1),
                     "xla_emulation_path"))

    q = jnp.asarray(rng.randn(1, 8, 512, 64), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(1, 4, 2048, 64), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(1, 4, 2048, 64), jnp.float32)
    f = jax.jit(lambda q, k, v: chunked_attention(q, k, v, chunk=512))
    rows.append(("kernels.chunked_attention_512x2048", round(_time(f, q, k, v), 1),
                 "gqa_4kv_8q"))

    dec_rows, _ = decode_rows(quick=quick)
    rows.extend(dec_rows)

    pre_rows, _ = prefill_rows(quick=quick)
    rows.extend(pre_rows)

    wq_rows, _ = weight_quant_rows(quick=quick)
    rows.extend(wq_rows)

    # multi-tenant grouped GEMM: utilization = the Fig 8 packing metric
    tenants = [(jnp.asarray(rng.randn(256, 128), jnp.float32),
                jnp.asarray(rng.randn(128, 256), jnp.float32)),
               (jnp.asarray(rng.randn(384, 256), jnp.float32),
                jnp.asarray(rng.randn(256, 128), jnp.float32))]
    t0 = time.perf_counter()
    _, util = api.ops.morphable_multi_gemm(tenants, backend="ref")
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("kernels.morphable_multi_gemm_2tenants", round(us, 1),
                 f"pack_utilization={util:.3f}"))
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke scale (CI): small decode shapes")
    ap.add_argument("--json", default="BENCH_decode.json",
                    help="where the decode-attention metrics land")
    ap.add_argument("--wq-json", default="BENCH_wq.json",
                    help="where the weight-quant GEMM metrics land")
    ap.add_argument("--prefill-json", default="BENCH_prefill.json",
                    help="where the varlen-prefill metrics land")
    args = ap.parse_args()
    rows, metrics = decode_rows(quick=args.quick)
    pre_rows, pre_metrics = prefill_rows(quick=args.quick)
    wq_rows, wq_metrics = weight_quant_rows(quick=args.quick)
    print("name,us_per_call,derived")
    for n, us, derived in rows + pre_rows + wq_rows:
        print(f"{n},{us},{derived}")
    with open(args.json, "w") as f:
        json.dump({"quick": args.quick, **metrics}, f, indent=2)
    with open(args.wq_json, "w") as f:
        json.dump({"quick": args.quick, **wq_metrics}, f, indent=2)
    with open(args.prefill_json, "w") as f:
        json.dump({"quick": args.quick, **pre_metrics}, f, indent=2)
    print(f"[kernels_bench] decode metrics -> {args.json}")
    for variant, vm in metrics["variants"].items():
        print(f"  {variant}: long/short wall-clock "
              f"{vm['long_over_short_us']}x, kv-block visits "
              f"{vm['long_over_short_blocks']}x "
              f"({vm['short']['visited_blocks']} vs "
              f"{vm['long']['visited_blocks']} of "
              f"{vm['long']['total_blocks']})")
    print(f"  paged: long matches_dense="
          f"{metrics['paged']['long']['matches_dense']} "
          f"({metrics['paged']['long']['us']}us vs dense "
          f"{metrics['variants']['dense']['long']['us']}us)")
    print(f"[kernels_bench] varlen-prefill metrics -> {args.prefill_json}")
    for variant, vm in pre_metrics["variants"].items():
        print(f"  {variant}: varlen visits "
              f"{vm['varlen_over_full_blocks']}x of a full chunk "
              f"({vm['varlen']['visited_blocks']} vs "
              f"{vm['fullchunk']['visited_blocks']} of "
              f"{vm['fullchunk']['total_blocks']})")
    print(f"[kernels_bench] weight-quant GEMM metrics -> {args.wq_json}")
    for fmt, fm in wq_metrics["formats"].items():
        print(f"  {fmt}: {fm['bytes_per_param']} B/param "
              f"(dense 4.0), resident {fm['resident_pallas_us']}us vs "
              f"on-the-fly {fm['onthefly_pallas_us']}us, "
              f"kernel-bit-identical={fm['pallas_matches_onthefly']}")
    paged_ok = all(
        m["paged"][lbl]["matches_dense"]
        for m, labels in ((metrics, ("short", "long")),
                          (pre_metrics, ("varlen", "fullchunk")))
        for lbl in labels)
    if not paged_ok or not all(fm["pallas_matches_onthefly"]
                               for fm in wq_metrics["formats"].values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
