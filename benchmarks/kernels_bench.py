"""Pallas kernel wall-clock (interpret mode on CPU — correctness-path timing,
not TPU perf; TPU perf is the §Roofline analysis) + morphable-GEMM
utilization, the kernel-level Fig 8 analogue."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.kernels.flash_attention import chunked_attention


def _time(f, *args, reps=5):
    f(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(512, 512), jnp.float32)
    w = jnp.asarray(rng.randn(512, 512), jnp.float32)
    for mode in ("bf16", "int8", "fp8a"):
        f = jax.jit(lambda a, b, m=mode: api.ops.matmul(a, b, format=m,
                                                        backend="ref"))
        us = _time(f, x, w)
        rows.append((f"kernels.aio_matmul_{mode}_512", round(us, 1),
                     "xla_emulation_path"))

    q = jnp.asarray(rng.randn(1, 8, 512, 64), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(1, 4, 2048, 64), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(1, 4, 2048, 64), jnp.float32)
    f = jax.jit(lambda q, k, v: chunked_attention(q, k, v, chunk=512))
    rows.append(("kernels.chunked_attention_512x2048", round(_time(f, q, k, v), 1),
                 "gqa_4kv_8q"))

    # multi-tenant grouped GEMM: utilization = the Fig 8 packing metric
    tenants = [(jnp.asarray(rng.randn(256, 128), jnp.float32),
                jnp.asarray(rng.randn(128, 256), jnp.float32)),
               (jnp.asarray(rng.randn(384, 256), jnp.float32),
                jnp.asarray(rng.randn(256, 128), jnp.float32))]
    t0 = time.perf_counter()
    _, util = api.ops.morphable_multi_gemm(tenants, backend="ref")
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("kernels.morphable_multi_gemm_2tenants", round(us, 1),
                 f"pack_utilization={util:.3f}"))
    return rows
