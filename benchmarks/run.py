"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  table2      multiplier (Table II)       fig14  utilization (Fig 14)
  fig15       speedup/efficiency (Fig 15) vic    multi-tenant (§VI-C)
  table4      GPU comparison (Table IV)   roofline  §Roofline terms
  kernels     Pallas kernel wall-clock (interpret-mode, CPU)
  serving     continuous vs wave-synchronous batching (tokens/sec, steps)
"""
import argparse
import sys
import traceback


class _Section:
    def __init__(self, fn):
        self.run = fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names to run")
    args = ap.parse_args()

    from . import (gpu_table4, kernels_bench, multiplier, multitenant,
                   roofline, serving_bench, speedup, utilization)
    modules = {
        "multiplier": multiplier,
        "utilization": utilization,
        "speedup": speedup,
        "multitenant": multitenant,
        "gpu_table4": gpu_table4,
        "roofline": roofline,
        "roofline_opt": _Section(roofline.run_opt),
        "kernels": kernels_bench,
        "serving": serving_bench,
    }
    selected = (args.only.split(",") if args.only else list(modules))
    print("name,us_per_call,derived")
    failed = []
    for name in selected:
        try:
            for row in modules[name].run():
                n, us, derived = row
                print(f"{n},{us},{derived}")
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED_SECTIONS,{len(failed)},{'|'.join(failed)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
