"""§VI-C — multi-tenant execution time (image captioning + classification,
INT8): All-rounder vs SARA vs Mirroring vs rigid SA."""
from repro.perfmodel.simulate import multi_tenant_scenario

PAPER = {"allrounder": 30.30, "sara": 33.33, "mirroring": 93.65,
         "tpu_sa": 1050.0}


def run():
    rows = []
    ours = multi_tenant_scenario("int8", mode="eq1")
    for name, ms in ours.items():
        rows.append((f"vic.multitenant.{name}", round(ms * 1e3, 1),
                     f"modeled_ms={ms:.2f}|paper_ms={PAPER[name]}"))
    # ordering among the flexible designs (the paper's core claim); our
    # rigid-SA model is more charitable than the paper's simulator at
    # batch-1 online inference (no DRAM-stall / time-slicing charges), so
    # the TPU-SA absolute is reported but not gated — see EXPERIMENTS.md.
    order_ok = ours["allrounder"] < ours["sara"] <= ours["mirroring"]
    rows.append(("vic.flexible_ordering_matches_paper", 0.0, str(order_ok)))
    rows.append(("vic.allrounder_within_paper_band", 0.0,
                 str(0.5 * PAPER["allrounder"] < ours["allrounder"]
                     < 1.5 * PAPER["allrounder"])))
    return rows
