"""§Roofline — three-term roofline per (arch x shape) from the dry-run JSONs.

    t_compute    = HLO_FLOPs_total   / (chips * 197e12)     [bf16 peak/chip]
    t_memory     = HLO_bytes_total   / (chips * 819e9)      [HBM BW/chip]
    t_collective = wire_bytes/device / 50e9                 [per-link ICI]

The dry-run stores PER-DEVICE flops/bytes (the compiled SPMD module is the
per-device program), so chips cancel in the first two terms; the collective
term uses the documented single-link serialization model (an upper bound —
v5e has 4 ICI links; DESIGN.md §6).
"""
import json
from pathlib import Path

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_LINK = 50e9           # bytes/s / link

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
DRYRUN_OPT_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun_opt"


def roofline_terms(rec):
    t_comp = rec["hlo_flops"] / PEAK_FLOPS
    t_mem = rec["hlo_bytes"] / HBM_BW
    t_coll = rec["collective_bytes"]["total"] / ICI_LINK
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])
    frac = rec["model_flops"] / rec["chips"] / PEAK_FLOPS / max(
        t_comp, t_mem, t_coll, 1e-30)
    return {"t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dom[0],
            "useful_flops_ratio": rec["model_flops"] / rec["chips"] /
            max(rec["hlo_flops"], 1e-30),
            "roofline_fraction": min(frac, 1.0)}


def load_records(mesh="16x16", tag="", dir_=None):
    recs = []
    base = dir_ or DRYRUN_DIR
    if not base.exists():
        return recs
    for f in sorted(base.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("skipped") or r.get("error"):
            continue
        if r.get("mesh") != mesh or "hlo_flops" not in r:
            continue
        if tag is not None and r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def run(dir_=None, prefix="roofline"):
    rows = []
    recs = load_records(dir_=dir_)
    for r in recs:
        t = roofline_terms(r)
        rows.append((
            f"{prefix}.{r['arch']}.{r['shape']}",
            round(max(t["t_compute_s"], t["t_memory_s"],
                      t["t_collective_s"]) * 1e6, 1),
            f"comp={t['t_compute_s']:.4g}s|mem={t['t_memory_s']:.4g}s"
            f"|coll={t['t_collective_s']:.4g}s|dom={t['dominant']}"
            f"|useful={t['useful_flops_ratio']:.3f}"
            f"|roofline_frac={t['roofline_fraction']:.3f}"))
    if not rows:
        rows.append((f"{prefix}.no_dryrun_records", 0.0,
                     "run repro.launch.dryrun first"))
    return rows


def run_opt():
    """Optimized-path sweep (manual TP/SP + explicit EP; EXPERIMENTS §Perf)."""
    return run(dir_=DRYRUN_OPT_DIR, prefix="roofline_opt")
