"""The paper's §VI-C scenario end-to-end on the JAX substrate:

Two applications share one "chip" (here: the local device mesh):
  * image captioning  — a vision-conditioned MoE LM (olmoe smoke stands in
    for the CNN+Transformer captioner; enc-dec engines need encoder-memory
    plumbing listed as future work),
  * text assistant    — a decoder-only LM tenant.

The morphable scheduler fissions the mesh per Fig 8, each tenant runs its
serving engine on its partition, INT8 weights via the AIO format plane, and
we report per-tenant latency + the fused vs fissioned trade-off.

Run:  python examples/multi_tenant_serving.py
"""
import time

import jax
import numpy as np

from repro import api
from repro.configs import get_smoke
from repro.core import formats as F
from repro.models import init_params
from repro.serving import Request, ServingEngine
from repro.tenancy import MorphableScheduler, Tenant


def quantize_params_int8(params):
    """PTQ the linear weights to int8 codes+scale and dequantize back —
    the serving deployment path of the format plane."""
    def q(path_leaf):
        leaf = path_leaf
        if leaf.ndim >= 2 and leaf.shape[-1] >= 8:
            codes, scale = F.quantize_scaled(leaf, F.INT8, axis=-1, pow2=True)
            return F.decode(codes, F.INT8) * scale
        return leaf
    return jax.tree.map(q, params)


def run_tenant(name, arch, n_requests=3, max_new=6, int8=True):
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(hash(name) % 2 ** 31), cfg)
    if int8:
        params = quantize_params_int8(params)
    eng = ServingEngine(cfg, params, slots=2, max_len=96,
                        policy=api.ExecutionPolicy(backend="ref"))
    rng = np.random.RandomState(0)
    t0 = time.time()
    for rid in range(n_requests):
        eng.submit(Request(rid, rng.randint(1, cfg.vocab, 6).astype(np.int32),
                           max_new_tokens=max_new))
    done = eng.run_until_drained()
    dt = (time.time() - t0) * 1e3
    print(f"  [{name}] {len(done)} requests in {dt:.0f} ms "
          f"({sum(len(r.out_tokens) for r in done)} tokens, int8={int8})")
    return dt


def main():
    sched = MorphableScheduler()
    tenants = [Tenant("captioning", 64, 512, fmt="int8"),
               Tenant("assistant", 64, 768, fmt="int8")]
    parts = sched.reconfigure(tenants)
    print(f"fusion plan: {sched.plan.describe()}")
    for p in parts:
        print(f"  partition {p.tenants}: {p.mesh.devices.size} device(s)")

    print("-- fissioned (each tenant on its partition) --")
    t0 = time.time()
    lat = {}
    lat["captioning"] = sched.run("captioning", run_tenant, "captioning",
                                  "olmoe_1b_7b")
    lat["assistant"] = sched.run("assistant", run_tenant, "assistant",
                                 "olmo_1b")
    makespan_par = max(lat.values())

    print("-- serialized (rigid-SA style: one tenant at a time) --")
    t_serial = run_tenant("captioning", "olmoe_1b_7b") + \
        run_tenant("assistant", "olmo_1b")
    print(f"fissioned makespan ~{makespan_par:.0f} ms (concurrent on real "
          f"partitions) vs serialized {t_serial:.0f} ms")
    print("multi_tenant_serving OK")


if __name__ == "__main__":
    main()
