"""End-to-end driver: train a ~small LM with the hybrid-FP8 recipe the paper
evaluates (Fig 14-b/15-b): FP8-A forward activations/weights via fake-quant,
fp32 master weights, bf16-compressed gradient all-reduce — then validate the
paper's premise by comparing the loss trajectory against the bf16 baseline.

Run:  python examples/fp8_training.py [--steps 60]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models.layers import QuantPolicy
from repro.runtime import Trainer, TrainerConfig


def train(cfg, steps, tag):
    mesh = make_local_mesh()
    tr = Trainer(cfg, TrainerConfig(ckpt_dir=f"/tmp/fp8ex_{tag}",
                                    ckpt_every=10 ** 9, total_steps=steps,
                                    base_lr=2e-3, warmup=5), mesh,
                 key=jax.random.key(0))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=8, seq=64, seed=7))
    tr.run(iter(data), steps)
    return [m["loss"] for m in tr.metrics_log]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    base = get_smoke("qwen2_1p5b")
    fp8 = dataclasses.replace(
        base, quant=QuantPolicy(activations="fp8a", weights="fp8a"))

    l_bf16 = train(base, args.steps, "bf16")
    l_fp8 = train(fp8, args.steps, "fp8")
    print(f"{'step':>5s} {'bf16':>9s} {'fp8a':>9s}")
    for i in range(0, args.steps, max(args.steps // 10, 1)):
        print(f"{i:5d} {l_bf16[i]:9.4f} {l_fp8[i]:9.4f}")
    final_gap = l_fp8[-1] - l_bf16[-1]
    print(f"final-loss gap (fp8 - bf16) = {final_gap:+.4f}")
    assert np.isfinite(l_fp8).all(), "fp8 training diverged"
    assert l_fp8[-1] < l_fp8[0], "fp8 training did not learn"
    print("fp8_training OK — FP8 trains (the premise of the paper's "
          "multi-format support)")


if __name__ == "__main__":
    main()
