"""Morphable execution at the kernel level: sweep tenant mixes through the
grouped-GEMM kernel and report the utilization each fusion plan achieves —
the software reproduction of the paper's Fig 8/Fig 14 story, plus the
perfmodel's view of the same scenario on the actual All-rounder hardware.

Run:  python examples/morphable_inference.py
"""
import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core.morphable import enumerate_fusion_plans, plan_for_tenants
from repro.perfmodel.accelerators import ACCELERATORS
from repro.perfmodel.latency import model_latency
from repro.perfmodel.workloads import inference_ops


def kernel_level():
    print("=== kernel level: tenant mixes through one grouped launch ===")
    rng = np.random.RandomState(0)
    mixes = {
        "one big GEMM": [(1024, 1024, 1024)],
        "two wide GEMMs (Fig 3)": [(128, 512, 2048), (128, 512, 1536)],
        "four small tenants": [(100, 64, 96), (60, 128, 64),
                               (200, 96, 128), (50, 256, 80)],
    }
    for name, shapes in mixes.items():
        tenants = [(jnp.asarray(rng.randn(m, k), jnp.float32),
                    jnp.asarray(rng.randn(k, n), jnp.float32))
                   for m, k, n in shapes]
        _, util = api.ops.morphable_multi_gemm(tenants, backend="ref")
        plan, assign = plan_for_tenants([(k, n) for m, k, n in shapes])
        print(f"  {name:26s} pack util {util:5.3f}  "
              f"plan {plan.describe()}  assign {assign}")


def hardware_level():
    print("=== perfmodel: the same morphing on the modeled hardware ===")
    print(f"  {len(enumerate_fusion_plans())} legal fusion plans "
          f"(Fig 8 e-h + symmetries)")
    ops = inference_ops("mobilenetv2", 1)
    for name in ("allrounder", "tpu_sa"):
        acc = ACCELERATORS[name]
        r = model_latency(ops, acc, "int8")
        print(f"  mobilenetv2 int8 inference on {name:10s}: "
              f"{r['cycles']/4e5:8.2f} ms @400MHz, util {r['utilization']:.3f}")


if __name__ == "__main__":
    kernel_level()
    hardware_level()
    print("morphable_inference OK")
