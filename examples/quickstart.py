"""Quickstart: the paper's two ideas in ten minutes.

1. The all-in-one format plane: quantize one tensor to every format the
   multiplier supports, and run a quantized matmul through the Pallas kernel.
2. The morphable plane: run two unrelated "tenant" GEMMs through ONE grouped
   kernel launch (Fig 8 at kernel scale).
3. Train a small LM for a few steps with the full production stack
   (sharded params, AdamW master weights, checkpointing).

Run:  python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core import formats as F
from repro.core.aio_mac import aio_fp_multiply


def demo_formats():
    print("=== 1. all-in-one multiplier formats ===")
    x = jnp.asarray(np.random.RandomState(0).randn(4).astype(np.float32) * 3)
    for name in ("bf16", "fp8a", "fp8b", "int8", "int4"):
        q = F.quantize(x, F.REGISTRY[name])
        print(f"  {name:5s} {np.asarray(q)}")
    # programmable bias = free power-of-two scaling (paper §III)
    fmt = F.FP8A
    codes = F.encode(x, fmt)
    scaled = F.decode(codes, fmt.with_bias(fmt.bias - 3))   # == x * 2^3
    print("  bias-folded x8 :", np.asarray(scaled))

    # the bit-accurate hardware model multiplies codes directly
    a = np.asarray(F.encode(jnp.float32(1.5), fmt))
    b = np.asarray(F.encode(jnp.float32(-2.25), fmt))
    prod_code = aio_fp_multiply(a, b, fmt, fmt, F.BF16)
    print("  1.5 x -2.25 via CSM datapath =",
          float(F.decode(jnp.asarray(prod_code), F.BF16)))


def demo_quant_matmul():
    print("=== 2. quantized matmul through the Pallas kernel ===")
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(256, 256).astype(np.float32))
    w = jnp.asarray(rng.randn(256, 256).astype(np.float32))
    exact = np.asarray(x) @ np.asarray(w)
    # one policy object declares the backend once; the format plane sweeps —
    # interpret mode on CPU, real kernels on TPU
    for mode in ("bf16", "int8", "fp8a"):
        with api.policy(format=mode, backend="pallas"):
            out = api.ops.matmul(x, w)
        rel = np.abs(np.asarray(out) - exact).max() / np.abs(exact).max()
        print(f"  {mode:5s} rel err vs f32 = {rel:.4f}")


def demo_morphable():
    print("=== 3. morphable multi-tenant GEMM (Fig 8) ===")
    rng = np.random.RandomState(2)
    tenants = [(jnp.asarray(rng.randn(100, 64), jnp.float32),
                jnp.asarray(rng.randn(64, 96), jnp.float32)),
               (jnp.asarray(rng.randn(300, 120), jnp.float32),
                jnp.asarray(rng.randn(120, 50), jnp.float32))]
    with api.policy(backend="pallas"):
        results, util = api.ops.morphable_multi_gemm(tenants)
    for i, ((xi, wi), r) in enumerate(zip(tenants, results)):
        err = np.abs(np.asarray(r) - np.asarray(xi) @ np.asarray(wi)).max()
        print(f"  tenant {i}: shape {r.shape}, max err {err:.2e}")
    print(f"  pack utilization = {util:.3f} (the Fig 14 metric)")


def demo_training():
    print("=== 4. few training steps on the production stack ===")
    from repro.configs import get_smoke
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.mesh import make_local_mesh
    from repro.runtime import Trainer, TrainerConfig
    cfg = get_smoke("olmo_1b")
    mesh = make_local_mesh()
    tr = Trainer(cfg, TrainerConfig(ckpt_dir="/tmp/quickstart_ckpt",
                                    ckpt_every=100, total_steps=10,
                                    base_lr=1e-3, warmup=2), mesh)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=4, seq=32))
    tr.run(iter(data), 6, on_step=lambda s, m: print(
        f"  step {s}: loss {m['loss']:.4f}"))


if __name__ == "__main__":
    demo_formats()
    demo_quant_matmul()
    demo_morphable()
    demo_training()
    print("quickstart OK")
